//! Integration tests over the full coordinator (mock runtime): the
//! paper's qualitative claims must hold in the battery-constrained
//! regime, plus lifecycle behaviours (recharge, early stop, config IO).

use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::metrics::Summary;
use eafl::runtime::MockRuntime;

/// Battery-tight scenario shared by the comparison tests.
fn tight_config(kind: SelectorKind, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default(kind);
    cfg.name = format!("itest-{kind}");
    cfg.federation.rounds = rounds;
    cfg.federation.num_clients = 80;
    cfg.data.min_samples = 20;
    cfg.data.max_samples = 80;
    cfg.data.test_samples = 256;
    cfg.devices.min_init_battery = 0.10;
    cfg.devices.max_init_battery = 0.6;
    cfg
}

fn run(kind: SelectorKind, rounds: usize) -> Summary {
    let runtime = MockRuntime::default();
    Coordinator::new(tight_config(kind, rounds), &runtime)
        .unwrap()
        .run()
        .unwrap()
        .summary()
}

/// Paper Fig. 4a: Oort (battery-oblivious) must drop out strictly more
/// clients than EAFL in the battery-constrained regime.
#[test]
fn eafl_drops_fewer_clients_than_oort() {
    let eafl = run(SelectorKind::Eafl, 150);
    let oort = run(SelectorKind::Oort, 150);
    assert!(
        oort.total_dropouts > eafl.total_dropouts,
        "oort={} must exceed eafl={}",
        oort.total_dropouts,
        eafl.total_dropouts
    );
}

/// Paper Fig. 3c: while the population is alive, EAFL's fairness must
/// stay at or above Oort's (Oort "initially enjoys the same levels of
/// fairness but then ... degrades"). Compared as the mean over the
/// series' live region — once everyone is dead the index is frozen and
/// meaningless.
#[test]
fn eafl_fairness_at_least_oort() {
    let runtime = MockRuntime::default();
    let mean_live_fairness = |kind: SelectorKind| -> f64 {
        let mut cfg = ExperimentConfig::paper_default(kind); // moderate regime
        cfg.name = format!("itest-fair-{kind}");
        cfg.federation.rounds = 200;
        cfg.federation.num_clients = 80;
        cfg.data.min_samples = 20;
        cfg.data.max_samples = 80;
        cfg.data.test_samples = 256;
        let log = Coordinator::new(cfg, &runtime).unwrap().run().unwrap();
        let live: Vec<f64> = log
            .records
            .iter()
            .skip(50) // past the exploration warm-up
            .filter(|r| r.alive_fraction > 0.5)
            .map(|r| r.fairness)
            .collect();
        assert!(!live.is_empty(), "population died too early for the comparison");
        live.iter().sum::<f64>() / live.len() as f64
    };
    let eafl = mean_live_fairness(SelectorKind::Eafl);
    let oort = mean_live_fairness(SelectorKind::Oort);
    assert!(
        eafl >= oort - 0.01,
        "live-region fairness: eafl {eafl:.3} must be >= oort {oort:.3}"
    );
}

/// Paper Fig. 4b: Random (no pacer, waits for the tail) has the longest
/// rounds.
#[test]
fn random_rounds_are_longest() {
    let eafl = run(SelectorKind::Eafl, 100);
    let random = run(SelectorKind::Random, 100);
    assert!(
        random.mean_round_duration_s > eafl.mean_round_duration_s,
        "random={:.1}s must exceed eafl={:.1}s",
        random.mean_round_duration_s,
        eafl.mean_round_duration_s
    );
}

/// All rounds run, wall clock advances, model improves (mock decay).
#[test]
fn training_progresses_end_to_end() {
    let runtime = MockRuntime::default();
    let cfg = tight_config(SelectorKind::Eafl, 60);
    let log = Coordinator::new(cfg, &runtime).unwrap().run().unwrap();
    assert_eq!(log.records.len(), 60);
    let first_acc = log.records.iter().find(|r| r.committed).unwrap().test_accuracy;
    let last = log.records.last().unwrap();
    assert!(last.test_accuracy > first_acc, "accuracy must improve");
    assert!(last.wall_clock_h > 0.0);
    assert!(log.summary().committed_rounds > 40, "most rounds should commit");
}

/// The recharge model revives dead clients after the cooldown.
#[test]
fn recharge_model_revives_clients() {
    let runtime = MockRuntime::default();
    let mut harsh = tight_config(SelectorKind::Oort, 200);
    harsh.devices.min_init_battery = 0.05;
    harsh.devices.max_init_battery = 0.25;
    harsh.devices.busy_drain_per_hour = 0.10;

    let without = Coordinator::new(harsh.clone(), &runtime).unwrap().run().unwrap();
    let mut with = harsh;
    with.devices.recharge_after_hours = 1.0;
    with.devices.recharge_to_fraction = 0.9;
    let with = Coordinator::new(with, &runtime).unwrap().run().unwrap();

    let alive_without = without.records.last().unwrap().alive_fraction;
    let alive_with = with.records.last().unwrap().alive_fraction;
    assert!(
        alive_with > alive_without,
        "recharge must keep more clients alive: {alive_with} vs {alive_without}"
    );
}

/// A population that fully dies stops the run early.
#[test]
fn run_stops_when_population_dies() {
    let runtime = MockRuntime::default();
    let mut cfg = tight_config(SelectorKind::Oort, 500);
    cfg.federation.num_clients = 10;
    cfg.federation.participants_per_round = 5;
    cfg.devices.min_init_battery = 0.02;
    cfg.devices.max_init_battery = 0.08;
    cfg.devices.busy_drain_per_hour = 0.5; // brutal background drain
    cfg.devices.busy_probability = 1.0;
    cfg.selector.min_battery_frac = 0.0;
    let log = Coordinator::new(cfg, &runtime).unwrap().run().unwrap();
    assert!(log.records.len() < 500, "run must stop early when everyone is dead");
    assert_eq!(log.records.last().unwrap().alive_fraction, 0.0);
}

/// Config round-trips through TOML and drives the coordinator.
#[test]
fn config_file_roundtrip_drives_run() {
    let dir = std::env::temp_dir().join(format!("eafl-itest-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    let mut cfg = tight_config(SelectorKind::Eafl, 5);
    cfg.name = "from-file".into();
    std::fs::write(&path, cfg.to_toml()).unwrap();

    let loaded = ExperimentConfig::from_toml_file(&path).unwrap();
    assert_eq!(loaded, cfg);
    let runtime = MockRuntime::default();
    let log = Coordinator::new(loaded, &runtime).unwrap().run().unwrap();
    assert_eq!(log.records.len(), 5);
    std::fs::remove_dir_all(&dir).ok();
}

/// FedAvg and YoGi both converge on the mock (different speeds are
/// fine; both must improve).
#[test]
fn both_aggregators_improve_accuracy() {
    for agg in [
        eafl::config::AggregatorKind::FedAvg,
        eafl::config::AggregatorKind::Yogi,
    ] {
        let runtime = MockRuntime::default();
        let mut cfg = tight_config(SelectorKind::Eafl, 50);
        cfg.federation.aggregator = agg;
        let log = Coordinator::new(cfg, &runtime).unwrap().run().unwrap();
        let last = log.records.last().unwrap();
        assert!(
            last.test_accuracy > 0.1,
            "{agg:?} should reach >10% accuracy on the mock, got {}",
            last.test_accuracy
        );
    }
}

/// Cross-selector determinism guard: two full compare-style runs under
/// the same seeds give identical headline numbers.
#[test]
fn compare_runs_are_deterministic() {
    let a = run(SelectorKind::Eafl, 40);
    let b = run(SelectorKind::Eafl, 40);
    assert_eq!(a.total_dropouts, b.total_dropouts);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.wall_clock_h, b.wall_clock_h);
}
