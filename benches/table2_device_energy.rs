//! Bench + reproduction of paper Table 2 (device tiers) and the §4.2
//! compute-energy model E = P·t built on it.
//!
//! Run: cargo bench --bench table2_device_energy

use eafl::benchkit::{bb, Bench};
use eafl::device::{DeviceSpec, ALL_TIERS};
use eafl::energy::{compute_energy_joules, RoundEnergy};
use eafl::network::{LinkProfile, Medium};

fn main() {
    println!("=== Table 2 reproduction ===");
    println!(
        "{:<38} {:>9} {:>10} {:>8} {:>9}",
        "Device", "Power(W)", "Perf/W", "RAM", "Battery"
    );
    for t in ALL_TIERS {
        let s = DeviceSpec::for_tier(t);
        println!(
            "{:<38} {:>9.2} {:>7.2} fps/W {:>4.0}GB {:>6.0}mAh",
            s.model, s.avg_power_w, s.perf_per_watt, s.ram_gb, s.battery_mah
        );
    }
    println!("\n(paper values: 6.33/5.44/2.98 W, 5.94/4.03/3.55 fps/W,");
    println!(" 4000/3450/3000 mAh — pinned by unit tests)");

    println!("\n=== microbenchmarks ===");
    let link = LinkProfile { medium: Medium::Wifi, down_mbps: 20.0, up_mbps: 8.0 };
    let mut bench = Bench::new();
    bench.run("compute_energy_joules", || {
        for t in ALL_TIERS {
            bb(compute_energy_joules(&DeviceSpec::for_tier(t), bb(200.0)));
        }
    });
    bench.run("RoundEnergy::for_participation (full round model)", || {
        for t in ALL_TIERS {
            bb(RoundEnergy::for_participation(
                &DeviceSpec::for_tier(t),
                &link,
                bb(276_492),
                bb(200.0),
            ));
        }
    });
    bench.run("battery_joules + relative_speed derivations", || {
        for t in ALL_TIERS {
            let s = DeviceSpec::for_tier(t);
            bb(s.battery_joules());
            bb(s.relative_speed());
        }
    });
}
