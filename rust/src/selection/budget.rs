//! Energy-budget participant selection — EAFL's Eq. (1) reward ranking
//! constrained by a campaign-wide joule budget.
//!
//! The coordinator owns an
//! [`EnergyLedger`](crate::coordinator::EnergyLedger) (projected vs.
//! actual spend, reconciled every round from the simulation's
//! `energy_spent_j`) and pushes the remaining envelope down through
//! [`Selector::set_budget`] before each plan. Three policies decide
//! how the remaining joules translate into this round's cohort:
//!
//!  - **hard-cap** — never start a round whose projected participant
//!    energy would breach the remaining campaign budget: walk the full
//!    (reward desc, id asc) ranking and take every candidate whose
//!    projected `round_energy_j` still fits, shrinking k greedily when
//!    the envelope runs short.
//!  - **amortized** — spread the envelope evenly over the remaining
//!    schedule: the per-round allowance is `remaining_j /
//!    remaining_rounds`, knapsack-filled from the same ranking
//!    (skip-and-continue, so a single expensive client cannot starve
//!    the round).
//!  - **deadline-aware** — amortized, but when the inner Oort pacer is
//!    holding a relaxed deadline (aggregate utility stalled), the
//!    allowance is multiplied by `budget_spend_ahead` — spend budget
//!    faster while the model is starved for utility — capped by the
//!    total remaining envelope.
//!
//! Rewards are EAFL's Eq. (1) (min-max-normalized Oort utility blended
//! with the power term at `eafl_f`) plus the shared staleness bonus;
//! candidates with no utility evidence yet score by the power term
//! alone — the same signal EAFL's exploration arm draws by. Unlike
//! Oort/EAFL the policy walk is fully deterministic (no weighted band
//! draw): budget decisions must be auditable, and the staleness bonus
//! alone keeps near-ties rotating.
//!
//! **Budget caveat:** the walk spends *projected* energy (the SoA
//! pool's cached `round_energy` at plan time). Under static networks
//! actual spend never exceeds the projection, so Σ actual ≤ budget
//! holds strictly; on degraded/congested networks the simulation can
//! re-resolve energy upward, and the ledger's actual column absorbs
//! the overshoot in the *next* round's remaining envelope.

use crate::util::rng::Rng;

use crate::config::{BudgetPolicy, SelectorConfig};

use super::utility::{
    eafl_reward, min_max_normalize_in_place, oort_utility, power_term, staleness_bonus,
};
use super::{Candidate, OortSelector, RoundFeedback, Selector};

pub struct BudgetSelector {
    cfg: SelectorConfig,
    /// Inner Oort machinery reused for the pacer (deadline + the
    /// deadline-aware policy's spend-ahead signal).
    oort: OortSelector,
    /// Joules left in the campaign envelope, pushed by the coordinator
    /// before every plan. Infinite until the first `set_budget` —
    /// an unwired selector ranks like deterministic EAFL.
    remaining_j: f64,
    /// Rounds left in the schedule (including the one being planned).
    remaining_rounds: u64,
    /// Latched when eligible candidates existed but the remaining
    /// envelope could not fund a single one.
    exhausted: bool,
    /// Reusable per-round scratch.
    utils: Vec<f64>,
    ranked: Vec<(usize, f64, f64)>,
}

impl BudgetSelector {
    pub fn new(cfg: SelectorConfig) -> Self {
        let oort = OortSelector::new(cfg.clone());
        Self {
            cfg,
            oort,
            remaining_j: f64::INFINITY,
            remaining_rounds: 0,
            exhausted: false,
            utils: Vec::new(),
            ranked: Vec::new(),
        }
    }

    /// This round's spending allowance under the configured policy.
    fn allowance_j(&self) -> f64 {
        match self.cfg.budget_policy {
            BudgetPolicy::HardCap => self.remaining_j,
            BudgetPolicy::Amortized => {
                self.remaining_j / self.remaining_rounds.max(1) as f64
            }
            BudgetPolicy::DeadlineAware => {
                let per_round = self.remaining_j / self.remaining_rounds.max(1) as f64;
                if self.oort.pacer_relaxed() {
                    (per_round * self.cfg.budget_spend_ahead.max(1.0))
                        .min(self.remaining_j)
                } else {
                    per_round
                }
            }
        }
    }

    /// Build the full (reward desc, id asc) ranking into `self.ranked`
    /// as `(id, reward, round_energy_j)` triples.
    fn rank(&mut self, round: u64, candidates: &[Candidate], deadline: f64) {
        self.utils.clear();
        for c in candidates {
            if let Some(stat) = c.stat_util {
                let duration = c.measured_duration_s.unwrap_or(c.expected_duration_s);
                self.utils.push(oort_utility(stat, deadline, duration, self.cfg.alpha));
            }
        }
        min_max_normalize_in_place(&mut self.utils);

        self.ranked.clear();
        let mut explored_cursor = 0usize;
        for c in candidates {
            let power = power_term(c.battery_frac, c.projected_drain_frac);
            let base = if c.stat_util.is_some() {
                let u = self.utils[explored_cursor];
                explored_cursor += 1;
                eafl_reward(self.cfg.eafl_f, u, power)
            } else {
                // No utility evidence yet: rank by the power term alone
                // (EAFL's exploration signal).
                power
            };
            let reward = base
                + staleness_bonus(round, c.last_selected_round, self.cfg.ucb_weight) * 0.25;
            self.ranked.push((c.id, reward, c.round_energy_j));
        }
        self.ranked
            .sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    }

    /// The select body with the round deadline already computed.
    fn select_with_deadline(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        deadline: f64,
    ) -> Vec<usize> {
        if candidates.is_empty() || k == 0 {
            return Vec::new();
        }
        self.rank(round, candidates, deadline);
        let allowance = self.allowance_j();

        // Greedy knapsack over the ranking: take the best-rewarded
        // candidates whose projected energy still fits the allowance,
        // skipping (not stopping at) the ones that don't — a single
        // expensive high-reward client must not starve the round.
        let mut selected = Vec::with_capacity(k.min(candidates.len()));
        let mut spent = 0.0f64;
        for &(id, _, cost) in &self.ranked {
            if selected.len() == k {
                break;
            }
            if spent + cost <= allowance {
                selected.push(id);
                spent += cost;
            }
        }

        // Terminal signal: the *campaign* envelope (not this round's
        // amortized slice) can no longer fund the cheapest candidate.
        self.exhausted = self.remaining_j.is_finite()
            && self
                .ranked
                .iter()
                .all(|&(_, _, cost)| cost > self.remaining_j);
        selected
    }
}

impl Selector for BudgetSelector {
    fn select(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        _rng: &mut Rng,
    ) -> Vec<usize> {
        let deadline = self.deadline_s(candidates);
        self.select_with_deadline(round, candidates, k, deadline)
    }

    fn plan(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        _rng: &mut Rng,
    ) -> (Vec<usize>, f64) {
        let deadline = self.deadline_s(candidates);
        let selected = self.select_with_deadline(round, candidates, k, deadline);
        (selected, deadline)
    }

    fn feedback(&mut self, fb: &RoundFeedback<'_>) {
        // Keeps the pacer (deadline + spend-ahead signal) live.
        self.oort.feedback(fb);
    }

    fn deadline_s(&mut self, candidates: &[Candidate]) -> f64 {
        self.oort.deadline_s(candidates)
    }

    fn set_budget(&mut self, remaining_j: f64, remaining_rounds: u64) {
        self.remaining_j = remaining_j.max(0.0);
        self.remaining_rounds = remaining_rounds;
    }

    fn budget_exhausted(&self) -> bool {
        self.exhausted
    }

    fn name(&self) -> &'static str {
        "budget"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::ParticipantOutcome;

    fn cand(id: usize, util: Option<f64>, battery: f64, energy_j: f64) -> Candidate {
        Candidate {
            id,
            stat_util: util,
            measured_duration_s: util.map(|_| 100.0),
            expected_duration_s: 100.0,
            last_selected_round: None,
            battery_frac: battery,
            projected_drain_frac: 0.02,
            round_energy_j: energy_j,
        }
    }

    fn budget_cfg(policy: BudgetPolicy) -> SelectorConfig {
        let mut cfg = SelectorConfig::default();
        cfg.kind = crate::config::SelectorKind::Budget;
        cfg.budget_j = 10_000.0;
        cfg.budget_policy = policy;
        cfg.ucb_weight = 0.0;
        cfg
    }

    #[test]
    fn unwired_selector_fills_k_from_the_reward_ranking() {
        // Before the coordinator pushes a ledger, the envelope is
        // infinite: plain deterministic EAFL-style top-k.
        let mut s = BudgetSelector::new(budget_cfg(BudgetPolicy::HardCap));
        let cands: Vec<Candidate> =
            (0..10).map(|i| cand(i, Some(i as f64), 0.9, 50.0)).collect();
        let picked = s.select(5, &cands, 4, &mut Rng::seed_from_u64(0));
        assert_eq!(picked.len(), 4);
        assert!(!s.budget_exhausted());
        // Highest-utility ids dominate the deterministic ranking.
        assert!(picked.contains(&9) && picked.contains(&8));
    }

    #[test]
    fn hard_cap_shrinks_k_to_fit_the_envelope() {
        let mut s = BudgetSelector::new(budget_cfg(BudgetPolicy::HardCap));
        s.set_budget(120.0, 10);
        // Every candidate costs 50 J: only 2 of k=4 fit in 120 J.
        let cands: Vec<Candidate> =
            (0..8).map(|i| cand(i, Some(i as f64), 0.9, 50.0)).collect();
        let picked = s.select(5, &cands, 4, &mut Rng::seed_from_u64(0));
        assert_eq!(picked.len(), 2, "must shrink k, not breach the cap");
        let spent: f64 = picked.len() as f64 * 50.0;
        assert!(spent <= 120.0);
        assert!(!s.budget_exhausted(), "50 J still affordable");
    }

    #[test]
    fn hard_cap_skips_expensive_candidates_rather_than_stopping() {
        let mut s = BudgetSelector::new(budget_cfg(BudgetPolicy::HardCap));
        s.set_budget(100.0, 10);
        // Best-rewarded candidate is unaffordable; the cheaper, lower
        // reward ones must still fill the round.
        let cands = vec![
            cand(0, Some(100.0), 0.9, 500.0),
            cand(1, Some(10.0), 0.9, 40.0),
            cand(2, Some(5.0), 0.9, 40.0),
        ];
        let picked = s.select(5, &cands, 3, &mut Rng::seed_from_u64(0));
        assert_eq!(picked, vec![1, 2]);
    }

    #[test]
    fn exhausted_when_nothing_is_affordable() {
        let mut s = BudgetSelector::new(budget_cfg(BudgetPolicy::HardCap));
        s.set_budget(10.0, 5);
        let cands: Vec<Candidate> =
            (0..4).map(|i| cand(i, Some(1.0), 0.9, 50.0)).collect();
        let picked = s.select(5, &cands, 4, &mut Rng::seed_from_u64(0));
        assert!(picked.is_empty());
        assert!(s.budget_exhausted());
        // A refilled envelope clears the latch on the next select.
        s.set_budget(200.0, 5);
        let picked = s.select(6, &cands, 4, &mut Rng::seed_from_u64(0));
        assert!(!picked.is_empty());
        assert!(!s.budget_exhausted());
    }

    #[test]
    fn amortized_spreads_the_envelope_over_remaining_rounds() {
        let mut s = BudgetSelector::new(budget_cfg(BudgetPolicy::Amortized));
        // 1000 J over 10 rounds = 100 J/round: two 40 J picks fit, a
        // third would breach the allowance even though the campaign
        // envelope holds plenty.
        s.set_budget(1000.0, 10);
        let cands: Vec<Candidate> =
            (0..6).map(|i| cand(i, Some(i as f64), 0.9, 40.0)).collect();
        let picked = s.select(5, &cands, 5, &mut Rng::seed_from_u64(0));
        assert_eq!(picked.len(), 2);
        assert!(!s.budget_exhausted(), "campaign envelope is far from empty");
    }

    #[test]
    fn amortized_last_round_spends_whatever_remains() {
        let mut s = BudgetSelector::new(budget_cfg(BudgetPolicy::Amortized));
        s.set_budget(200.0, 1);
        let cands: Vec<Candidate> =
            (0..6).map(|i| cand(i, Some(i as f64), 0.9, 40.0)).collect();
        let picked = s.select(9, &cands, 5, &mut Rng::seed_from_u64(0));
        assert_eq!(picked.len(), 5, "last round's allowance is the full remainder");
    }

    #[test]
    fn deadline_aware_spends_ahead_only_when_pacer_relaxed() {
        let mut cfg = budget_cfg(BudgetPolicy::DeadlineAware);
        cfg.budget_spend_ahead = 2.0;
        let mut s = BudgetSelector::new(cfg);
        s.set_budget(1000.0, 10);
        let cands: Vec<Candidate> =
            (0..6).map(|i| cand(i, Some(i as f64), 0.9, 40.0)).collect();
        // Pacer not relaxed: allowance 100 J ⇒ 2 picks.
        let picked = s.select(5, &cands, 5, &mut Rng::seed_from_u64(0));
        assert_eq!(picked.len(), 2);

        // Stall the pacer (5 good rounds then 5 bad, Oort's window):
        let out = |u: f64| ParticipantOutcome {
            id: 0,
            stat_util: Some(u),
            duration_s: 100.0,
            completed: true,
        };
        for r in 0..5 {
            s.feedback(&RoundFeedback { round: r, outcomes: &[out(10.0)] });
        }
        for r in 5..10 {
            s.feedback(&RoundFeedback { round: r, outcomes: &[out(0.1)] });
        }
        // Relaxed: allowance 200 J ⇒ 5 picks fit (5·40 = 200).
        let picked = s.select(6, &cands, 5, &mut Rng::seed_from_u64(0));
        assert_eq!(picked.len(), 5, "spend-ahead must widen the allowance");
    }

    #[test]
    fn deadline_aware_spend_ahead_never_exceeds_the_envelope() {
        let mut cfg = budget_cfg(BudgetPolicy::DeadlineAware);
        cfg.budget_spend_ahead = 100.0;
        let mut s = BudgetSelector::new(cfg);
        s.set_budget(90.0, 2);
        let out = |u: f64| ParticipantOutcome {
            id: 0,
            stat_util: Some(u),
            duration_s: 100.0,
            completed: true,
        };
        for r in 0..5 {
            s.feedback(&RoundFeedback { round: r, outcomes: &[out(10.0)] });
        }
        for r in 5..10 {
            s.feedback(&RoundFeedback { round: r, outcomes: &[out(0.1)] });
        }
        let cands: Vec<Candidate> =
            (0..6).map(|i| cand(i, Some(1.0), 0.9, 40.0)).collect();
        let picked = s.select(6, &cands, 6, &mut Rng::seed_from_u64(0));
        // 45 J/round × 100 would be 4500 J; the cap holds it at the
        // 90 J envelope ⇒ 2 × 40 J picks.
        assert_eq!(picked.len(), 2);
    }

    #[test]
    fn ranking_is_deterministic_and_battery_aware() {
        // f=0: reward is the power term alone ⇒ highest battery wins,
        // and repeated calls return identical picks (no weighted draw).
        let mut cfg = budget_cfg(BudgetPolicy::HardCap);
        cfg.eafl_f = 0.0;
        let mut s = BudgetSelector::new(cfg);
        s.set_budget(1000.0, 10);
        let cands = vec![
            cand(0, Some(100.0), 0.10, 50.0),
            cand(1, Some(1.0), 0.95, 50.0),
            cand(2, Some(50.0), 0.50, 50.0),
        ];
        let a = s.select(5, &cands, 1, &mut Rng::seed_from_u64(0));
        let b = s.select(5, &cands, 1, &mut Rng::seed_from_u64(77));
        assert_eq!(a, vec![1], "f=0 must pick the highest battery");
        assert_eq!(a, b, "policy walk must be rng-independent");
    }

    #[test]
    fn unexplored_candidates_rank_by_power() {
        // Cold start (nobody measured): the ranking degenerates to the
        // power term, so the budget family is battery-greedy on round 1
        // just like EAFL's fixed fallback.
        let mut s = BudgetSelector::new(budget_cfg(BudgetPolicy::HardCap));
        s.set_budget(1000.0, 10);
        let cands = vec![cand(0, None, 0.05, 50.0), cand(1, None, 0.95, 50.0)];
        let picked = s.select(1, &cands, 1, &mut Rng::seed_from_u64(0));
        assert_eq!(picked, vec![1]);
    }

    #[test]
    fn never_exceeds_k_or_duplicates() {
        let mut s = BudgetSelector::new(budget_cfg(BudgetPolicy::Amortized));
        s.set_budget(5000.0, 20);
        let cands: Vec<Candidate> = (0..25)
            .map(|i| {
                cand(i, if i % 3 == 0 { Some(i as f64) } else { None }, 0.7, 30.0)
            })
            .collect();
        for round in 1..20 {
            let picked = s.select(round, &cands, 10, &mut Rng::seed_from_u64(round));
            assert!(picked.len() <= 10);
            let mut d = picked.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), picked.len());
        }
    }
}
