//! The FL server loop (paper Fig. 1 / Fig. 2), assembled from the
//! staged [`RoundEngine`](super::engine) phases.
//!
//! Per round: [`PlanPhase`] builds candidates (gated by the scenario's
//! availability model) and the selector picks K → [`SimPhase`] resolves
//! timing, battery deaths and stragglers on the event queue over the
//! scenario's effective links → [`ExecPhase`] runs REAL local SGD for
//! completing clients (parallel across worker threads, deterministic
//! commit order) → [`CommitPhase`] applies the quorum rule and
//! aggregates (YoGi/FedAvg) → [`BatteryAccounting`] + the scenario's
//! recharge policy drain participants and bystanders → [`FeedbackPhase`]
//! updates utilities and the miss blacklist → [`RecordPhase`] emits the
//! metrics row. Rounds with fewer than `min_report_fraction·K`
//! completions fail and are not aggregated (FedScale semantics); their
//! time still elapses. The environment models come from
//! `cfg.scenario` (preset name or TOML file, see [`crate::scenario`]).

use anyhow::Result;

use crate::aggregation::{make_aggregator, Aggregator};
use crate::config::ExperimentConfig;
use crate::data::SyntheticSpeech;
use crate::metrics::MetricsLog;
use crate::obs::{EventSink, PhaseProfiler, RoundEvent};
use crate::runtime::ModelRuntime;
use crate::scenario::{Scenario, ScenarioEnv, WakeWheel};
use crate::selection::{make_selector, Candidate, Selector};
use crate::sim::FailureKind;
use crate::training::{Trainer, TrainerBufs};
use crate::util::rng::Rng;

use super::accounting::BatteryAccounting;
use super::engine::{
    CommitPhase, EnergyLedger, ExecPhase, FeedbackPhase, PlanPhase, RecordPhase, RoundPlan,
    SimPhase, SimulatedRound,
};
use super::registry::{LifecycleEvent, Registry};

/// Worker threads for the execution phase: `EAFL_WORKERS` if set, else
/// the machine's available parallelism (capped — per-client training is
/// short enough that more threads stop paying off).
fn default_workers() -> usize {
    if let Ok(v) = std::env::var("EAFL_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// Scenario models get their own deterministic stream derived from the
/// experiment seeds, so a campaign's grid seed pins the environment
/// (availability draws, trace churn, degraded-tail membership) exactly
/// like it pins the data and devices.
fn scenario_seed(cfg: &ExperimentConfig) -> u64 {
    cfg.data
        .seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(cfg.devices.seed.rotate_left(17))
        ^ 0x5CE9_A210_C0FF_EE00
}

/// The coordinator owns the full experiment state and drives the
/// engine phases round by round.
pub struct Coordinator<'r> {
    cfg: ExperimentConfig,
    runtime: &'r dyn ModelRuntime,
    registry: Registry,
    selector: Box<dyn Selector>,
    aggregator: Box<dyn Aggregator>,
    /// The experiment's environment: availability + network + recharge
    /// models resolved from `cfg.scenario`.
    env: ScenarioEnv,
    data: SyntheticSpeech,
    global_params: Vec<f32>,
    /// Simulated wall clock, hours.
    clock_h: f64,
    rng: Rng,
    log: MetricsLog,
    /// Reused batch buffers, one per execution worker (§Perf L3: no
    /// per-round allocation; slot 0 doubles as the eval buffers).
    bufs_pool: Vec<TrainerBufs>,
    /// Reusable candidate arena the plan phase filters the pool into —
    /// no fresh N-element Vec per round.
    candidate_arena: Vec<Candidate>,
    /// Reusable sorted-participant scratch for background accounting.
    selected_scratch: Vec<usize>,
    /// Availability cache driven by the scenario model's declared
    /// change times — `None` for always-on scenarios, where the plan
    /// phase needs no gate at all.
    wake: Option<WakeWheel>,
    /// Execution-phase worker threads.
    workers: usize,
    /// Carried between eval points.
    last_accuracy: f64,
    last_test_loss: f64,
    /// Deterministic event stream (`--trace`): `None` means the seams
    /// skip event construction entirely — one `is_some()` branch per
    /// phase is the whole hot-path cost.
    sink: Option<Box<dyn EventSink>>,
    /// Separate wall-time channel; never interleaved with `sink`.
    profiler: Option<PhaseProfiler>,
    /// Reused buffer for draining the registry's lifecycle journal.
    lifecycle_scratch: Vec<LifecycleEvent>,
    /// Campaign energy ledger (projected vs. actual spend, reconciled
    /// each round from the sim's `energy_spent_j`). Inactive — pure
    /// bookkeeping — unless `selector.budget_j > 0`.
    ledger: EnergyLedger,
}

impl<'r> Coordinator<'r> {
    pub fn new(cfg: ExperimentConfig, runtime: &'r dyn ModelRuntime) -> Result<Self> {
        let mut cfg = cfg;
        // Resolve the environment first: a scenario may override device
        // knobs, and the combined config is what gets validated.
        let scenario = Scenario::resolve(&cfg.scenario)?;
        scenario.apply_overrides(&mut cfg);
        cfg.validate()?;
        anyhow::ensure!(
            cfg.data.batch_size == runtime.train_batch(),
            "config batch_size ({}) must match the AOT artifact's train batch ({})",
            cfg.data.batch_size,
            runtime.train_batch()
        );
        let data = SyntheticSpeech::new(
            runtime.input_hw(),
            runtime.num_classes(),
            cfg.data.noise_std,
            cfg.data.seed,
        );
        let registry = Registry::build(&cfg, runtime.num_classes(), runtime.param_count());
        let selector = make_selector(&cfg.selector);
        let aggregator = make_aggregator(
            cfg.federation.aggregator,
            runtime.param_count(),
            cfg.training.server_learning_rate,
        );
        let env = scenario.build_env(
            scenario_seed(&cfg),
            cfg.federation.num_clients,
            &cfg.devices,
        );
        let wake = if env.availability.is_always_available() {
            None
        } else {
            Some(WakeWheel::new(env.availability.as_ref(), cfg.federation.num_clients, 0.0))
        };
        let global_params = runtime.init_params(cfg.training.init_seed)?;
        let bufs_pool = vec![TrainerBufs::new(runtime)];
        let budget_j = cfg.selector.budget_j;
        let rng = Rng::seed_from_u64(cfg.data.seed ^ cfg.devices.seed ^ 0x5EED);
        let log = MetricsLog::new(cfg.name.clone());
        Ok(Self {
            cfg,
            runtime,
            registry,
            selector,
            aggregator,
            env,
            data,
            global_params,
            clock_h: 0.0,
            rng,
            log,
            bufs_pool,
            candidate_arena: Vec::new(),
            selected_scratch: Vec::new(),
            wake,
            workers: default_workers(),
            last_accuracy: 0.0,
            last_test_loss: f64::NAN,
            sink: None,
            profiler: None,
            lifecycle_scratch: Vec::new(),
            ledger: EnergyLedger::new(budget_j),
        })
    }

    /// Override the execution-phase worker count (builder style). The
    /// campaign runner pins this to 1 so experiments — not clients —
    /// are the unit of parallelism; seeded results are identical at
    /// any setting.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.set_workers(workers);
        self
    }

    /// Override the execution-phase worker count in place.
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Attach a deterministic event sink: enables the registry's
    /// lifecycle journal and emits the identifying `RunStarted` event.
    pub fn set_sink(&mut self, mut sink: Box<dyn EventSink>) {
        self.registry.set_journal(true);
        sink.emit(&RoundEvent::RunStarted {
            name: self.cfg.name.clone(),
            selector: self.cfg.selector.kind.to_string(),
            scenario: self.env.name.clone(),
            clients: self.cfg.federation.num_clients,
            rounds: self.cfg.federation.rounds,
            seed: self.cfg.data.seed,
        });
        self.sink = Some(sink);
    }

    /// Detach and return the event sink (tests drive `run_round`
    /// manually and then inspect a `MemorySink`).
    pub fn take_sink(&mut self) -> Option<Box<dyn EventSink>> {
        self.registry.set_journal(false);
        self.sink.take()
    }

    /// Attach the wall-time phase profiler (the non-deterministic
    /// channel; see [`crate::obs`]).
    pub fn set_profiler(&mut self, profiler: PhaseProfiler) {
        self.profiler = Some(profiler);
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Name of the resolved environment scenario.
    pub fn scenario_name(&self) -> &str {
        &self.env.name
    }

    pub fn clock_h(&self) -> f64 {
        self.clock_h
    }

    /// The campaign energy ledger (inactive when no budget is set).
    pub fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }

    pub fn global_params(&self) -> &[f32] {
        &self.global_params
    }

    /// Run the configured number of rounds; returns the metrics log.
    pub fn run(mut self) -> Result<MetricsLog> {
        let rounds = self.cfg.federation.rounds;
        for round in 1..=rounds as u64 {
            self.run_round(round)?;
            // Budget stop: the campaign envelope is spent (ledger) or
            // the budget selector concluded nothing affordable remains.
            // Terminal for ANY selector when a budget is configured.
            if self.ledger.active()
                && (self.ledger.exhausted() || self.selector.budget_exhausted())
            {
                if let Some(sink) = self.sink.as_mut() {
                    sink.emit(&RoundEvent::BudgetExhausted {
                        round,
                        budget_j: self.ledger.budget_j,
                        spent_j: self.ledger.actual_j,
                    });
                }
                eprintln!(
                    "[eafl] round {round}: energy budget exhausted \
                     ({:.0} of {:.0} J spent); stopping",
                    self.ledger.actual_j, self.ledger.budget_j
                );
                break;
            }
            // An all-dead fleet only ends the experiment when nothing
            // can revive it; under a reviving policy (cooldown,
            // overnight window, solar) empty rounds keep elapsing so
            // the clock reaches the next charging opportunity.
            if self.registry.alive_count() == 0 && !self.env.recharge.can_revive() {
                eprintln!("[eafl] round {round}: entire population dead; stopping early");
                break;
            }
        }
        // Flush explicitly so trace-file write errors fail the run
        // instead of vanishing in a Drop.
        if let Some(sink) = self.sink.as_mut() {
            sink.flush()?;
        }
        if let Some(profiler) = &self.profiler {
            profiler.write()?;
        }
        Ok(self.log)
    }

    /// Execute one round end to end through the engine phases.
    pub fn run_round(&mut self, round: u64) -> Result<()> {
        let mut t0 = self.phase_start();
        // Push the remaining envelope down before planning so the
        // budget family can pace this round's cohort against it (other
        // selectors ignore the hook; the ledger still tallies).
        if self.ledger.active() {
            let remaining_rounds =
                (self.cfg.federation.rounds as u64).saturating_sub(round - 1);
            self.selector.set_budget(self.ledger.remaining_j(), remaining_rounds);
        }
        // --- Phase 1: candidate planning (availability-gated) -------------
        // Bring the wake-wheel cache up to this round's clock first: only
        // the clients whose model-declared change time is due get
        // re-evaluated, so the plan gate reads a bitmap instead of making
        // N dynamic model calls.
        // The wheel also surfaces the change list (ids whose bit actually
        // flipped) so the incremental eligible arena patches membership
        // in O(flips) instead of rescanning the bitmap.
        if let Some(w) = self.wake.as_mut() {
            w.advance(self.env.availability.as_ref(), self.clock_h);
        }
        let avail = self.wake.as_ref().map(|w| (w.avail(), w.changed()));
        let plan = PlanPhase::run(
            &mut self.registry,
            self.selector.as_mut(),
            &self.cfg,
            &self.env,
            round,
            self.clock_h,
            avail,
            &mut self.rng,
            &mut self.candidate_arena,
        );
        self.emit_plan_events(&plan);
        t0 = self.phase_done("plan", t0);

        // --- Phase 2: event-driven round simulation on effective links ----
        let sim = SimPhase::run(&plan, &self.registry, &self.env, self.clock_h);
        let end_clock_h = self.clock_h + sim.round_hours;
        self.emit_outcome_events(round, &sim);
        t0 = self.phase_done("sim", t0);

        // --- Phase 3: real local training (parallel) ----------------------
        let exec = ExecPhase { runtime: self.runtime, data: &self.data, workers: self.workers }
            .run(
                &self.registry,
                &self.global_params,
                &plan,
                &sim,
                &self.cfg.training,
                &mut self.bufs_pool,
            )?;
        t0 = self.phase_done("exec", t0);

        // --- Phase 4: commit or fail the round ----------------------------
        let commit = CommitPhase::run(
            &self.cfg.federation,
            self.aggregator.as_mut(),
            &mut self.global_params,
            plan.selected.len(),
            &exec.updates,
        )?;
        t0 = self.phase_done("commit", t0);

        // --- Phase 5: battery accounting + recharge policy ----------------
        BatteryAccounting::drain_participants(
            &mut self.registry,
            &sim.outcome.results,
            self.clock_h,
        );
        self.selected_scratch.clear();
        self.selected_scratch.extend_from_slice(&plan.selected);
        self.selected_scratch.sort_unstable();
        BatteryAccounting::drain_background(
            &mut self.registry,
            &self.selected_scratch,
            &self.cfg.devices,
            sim.round_hours,
            end_clock_h,
        );
        self.env.recharge.apply(&mut self.registry, self.clock_h, end_clock_h);
        // Drain the lifecycle journal only after recharge: deaths and
        // revivals are then complete for the round, so the running
        // depleted−revived count at the commit event below equals the
        // record's `cumulative_dead`.
        self.emit_lifecycle_events();
        t0 = self.phase_done("account", t0);

        // --- Phase 6: stats + selector feedback ---------------------------
        FeedbackPhase::run(&mut self.registry, self.selector.as_mut(), round, &exec.outcomes);
        t0 = self.phase_done("feedback", t0);

        // --- Evaluation ---------------------------------------------------
        let fed = &self.cfg.federation;
        if commit.committed && (round % fed.eval_interval as u64 == 0 || round == 1) {
            let test = self.data.test_set(self.cfg.data.test_samples);
            let mut trainer = Trainer::with_bufs(
                self.runtime,
                &self.data,
                std::mem::replace(&mut self.bufs_pool[0], TrainerBufs::empty()),
            );
            let ev = trainer.evaluate(&self.global_params, &test);
            self.bufs_pool[0] = trainer.into_bufs();
            let ev = ev?;
            self.last_accuracy = ev.accuracy;
            self.last_test_loss = ev.mean_loss;
        }
        t0 = self.phase_done("eval", t0);

        // --- Phase 7: record ----------------------------------------------
        self.clock_h = end_clock_h;
        // Reconcile the energy ledger: projected from the ORIGINAL plan
        // (what the selector budgeted), actual from the simulation
        // (early deaths spend less; degraded networks can spend more).
        self.ledger.record(
            plan.plans.iter().map(|p| p.round_energy_j).sum(),
            sim.outcome.results.iter().map(|r| r.energy_spent_j).sum(),
        );
        self.log.push(RecordPhase::run(
            &self.registry,
            &plan,
            &sim,
            &exec,
            &commit,
            self.clock_h,
            self.last_accuracy,
            self.last_test_loss,
        ));
        // Last event of the round, mirroring the metrics row — so a
        // trace alone reproduces the run summary (`eafl trace
        // summarize`).
        self.emit_round_committed();
        let _ = self.phase_done("record", t0);
        Ok(())
    }

    // --- observability seams ----------------------------------------------

    fn phase_start(&self) -> Option<std::time::Instant> {
        self.profiler.as_ref().map(|_| std::time::Instant::now())
    }

    /// Record the span since `t0` under `phase` and start the next
    /// span. `None` in, `None` out when no profiler is attached.
    fn phase_done(
        &mut self,
        phase: &'static str,
        t0: Option<std::time::Instant>,
    ) -> Option<std::time::Instant> {
        match (self.profiler.as_mut(), t0) {
            (Some(p), Some(t)) => {
                p.record(phase, t.elapsed());
                Some(std::time::Instant::now())
            }
            _ => None,
        }
    }

    /// `RoundPlanned` + one `ClientSelected` per pick, emitted before
    /// any round mutation so `battery_frac` is exactly the
    /// drain-effective value the selector saw.
    fn emit_plan_events(&mut self, plan: &RoundPlan) {
        let Self { sink, registry, clock_h, .. } = self;
        let Some(sink) = sink.as_mut() else { return };
        sink.emit(&RoundEvent::RoundPlanned {
            round: plan.round,
            clock_h: *clock_h,
            eligible: plan.eligible,
            selected: plan.selected.len(),
            deadline_s: plan.deadline_s,
        });
        for &id in &plan.selected {
            sink.emit(&RoundEvent::ClientSelected {
                round: plan.round,
                id,
                score: registry.client(id).stats.stat_util.unwrap_or(0.0),
                battery_frac: registry.effective_battery_frac(id),
            });
        }
    }

    /// Per-participant outcomes in simulation order (worker-count
    /// independent by the exec phase's commit-order guarantee).
    fn emit_outcome_events(&mut self, round: u64, sim: &SimulatedRound) {
        let clock_h = self.clock_h;
        let Some(sink) = self.sink.as_mut() else { return };
        for r in &sim.outcome.results {
            if r.completed {
                sink.emit(&RoundEvent::ClientReported {
                    round,
                    id: r.id,
                    duration_s: r.active_s,
                    energy_j: r.energy_spent_j,
                });
            } else {
                let cause = match r.failure {
                    Some(FailureKind::BatteryDeath) => crate::obs::DropCause::Death,
                    _ => crate::obs::DropCause::Deadline,
                };
                sink.emit(&RoundEvent::ClientDropped {
                    round,
                    id: r.id,
                    cause,
                    at_h: clock_h + r.active_s / 3600.0,
                    energy_j: r.energy_spent_j,
                });
            }
        }
    }

    /// Forward the registry's journaled liveness flips (deaths from FL
    /// drain and the background death wheel, recharge revivals) in
    /// mutation order.
    fn emit_lifecycle_events(&mut self) {
        if self.sink.is_none() {
            return;
        }
        let mut events = std::mem::take(&mut self.lifecycle_scratch);
        self.registry.drain_journal(&mut events);
        if let Some(sink) = self.sink.as_mut() {
            for ev in &events {
                let ev = match *ev {
                    LifecycleEvent::Depleted { id, at_h } => {
                        RoundEvent::BatteryDepleted { id, at_h }
                    }
                    LifecycleEvent::Revived { id, at_h, battery_frac } => {
                        RoundEvent::BatteryRevived { id, at_h, battery_frac }
                    }
                };
                sink.emit(&ev);
            }
        }
        events.clear();
        self.lifecycle_scratch = events;
    }

    fn emit_round_committed(&mut self) {
        let Self { sink, log, ledger, .. } = self;
        let (Some(sink), Some(rec)) = (sink.as_mut(), log.last()) else { return };
        sink.emit(&RoundEvent::RoundCommitted {
            round: rec.round,
            committed: rec.committed,
            completed: rec.completed,
            accuracy: rec.test_accuracy,
            train_loss: rec.train_loss,
            energy_j: rec.total_fl_energy_j,
            wall_clock_h: rec.wall_clock_h,
            // NaN (→ null in the trace) when no budget is configured.
            budget_remaining_j: if ledger.active() {
                ledger.remaining_j()
            } else {
                f64::NAN
            },
        });
    }
}
