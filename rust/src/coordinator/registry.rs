//! Client registry: per-client device + link + battery + data shard +
//! utility statistics. The coordinator's source of truth — selectors
//! see read-only [`Candidate`] projections built here (paper Fig. 2:
//! the coordinator "registers each client's profile ... and forwards
//! the characteristics to the server running EAFL").
//!
//! ## The million-client fast path
//!
//! At deployment scale (the regimes AutoFL and global-energy-budget FL
//! operate in) the per-round cost of this module is what bounds the
//! whole simulator, so the registry is structured as two synchronized
//! views:
//!
//!  - `clients: Vec<ClientState>` — the authoritative array-of-structs
//!    state (device, link, battery, shard, stats). Private: every
//!    mutation goes through [`Registry::battery_mut`] /
//!    [`Registry::stats_mut`] guards (or the convenience wrappers), so
//!    the derived views below can never go stale.
//!  - [`ClientPool`] — a struct-of-arrays cache of everything the plan
//!    path reads per round. The *static* projections (link transfer
//!    times, compute time, projected round energy/drain — invariant
//!    under a static network) are computed once at build time and only
//!    recomputed for a client whose device/link state actually changes
//!    ([`Registry::refresh_projection`]); the *dynamic* mirrors
//!    (battery fraction, liveness, selection stats) are updated by the
//!    mutation guards.
//!  - [`PoolAggregates`] — population sums maintained incrementally at
//!    the mutation sites, so the per-round metrics row is O(1) instead
//!    of five O(N) scans: alive count, Σ battery fraction over alive
//!    clients, Σ FL energy, and the Σc / Σc² moments Jain's fairness
//!    index needs. Float sums use [`FixedSum`] (exact i128 fixed-point)
//!    so the incremental state is *bit-identical* to a brute-force
//!    rebuild after any mutation sequence — see
//!    `rust/tests/pool_aggregates.rs`.
//!
//! [`Registry::fill_candidates`] filters the pool into a caller-owned
//! candidate arena with zero allocation and zero energy-model
//! recomputation; the allocating [`Registry::candidates`] recomputes
//! everything from the AoS state and is kept as the reference (and as
//! the pre-refactor baseline in `benches/plan_path_throughput.rs`).
//!
//! ## Lazy background drain (the zero-cost-idle-client ledger)
//!
//! Background idle/busy drain is a *rate*, identical for every client
//! of the same class — so the registry never sweeps N batteries per
//! round. Instead a [`DrainLedger`] keeps one cumulative drained
//! fraction per class (`s = Σ rate·Δt`) plus a per-client **anchor**
//! `(charge, s-at-anchor)` captured whenever a battery is actually
//! touched. The true charge is then the pure function
//!
//! ```text
//! effective = anchor_charge − capacity · (s − anchor_s)
//! ```
//!
//! **Invariant: aggregates and candidates reflect drain as-of the round
//! clock, applied on touch.** Anchors move *only* at guard drops
//! ([`Registry::battery_mut`] re-anchors on drop and settles pending
//! drain on entry), so identical mutation streams produce identical
//! anchors — and because materialization ([`Registry::settle_all`],
//! the `EAFL_EAGER_DRAIN=1` sweep) evaluates the same pure function
//! *without* moving the anchor, the lazy and eager paths land on
//! bit-identical charge levels, death times and metrics.
//!
//! Deaths are found without scanning: each alive client is registered
//! in a per-class [`BucketWheel`] keyed by `u = fraction + anchor_s`
//! (it dies when `s` reaches ≈ `u`); [`Registry::advance_background`]
//! pops only the due buckets per epoch, re-checks the exact predicate
//! on each fired entry, and kills exactly the clients the eager sweep
//! would have — stamped at the same end-of-epoch instant. The pool
//! also maintains O(1) dead / below-capacity index sets so recharge
//! policies scan revival candidates instead of the population.

use std::ops::{Deref, DerefMut};

use crate::config::ExperimentConfig;
use crate::data::{partition_clients, ClientShard};
use crate::device::{generate_profiles, Battery, DeviceProfile};
use crate::energy::RoundEnergy;
use crate::network::{generate_links, LinkProfile};
use crate::selection::{battery_floor_admits, Candidate};
use crate::util::fixed::FixedSum;
use crate::util::index_set::IndexSet;
use crate::util::wheel::BucketWheel;

/// Mutable per-client selection statistics.
#[derive(Debug, Clone, Default)]
pub struct ClientStats {
    /// Last measured Oort statistical utility (None = unexplored).
    pub stat_util: Option<f64>,
    /// Last measured participation duration, seconds.
    pub measured_duration_s: Option<f64>,
    /// Round of the client's last selection; `None` if never selected
    /// (a separate state from "selected at round 0" — the old `0 =
    /// never` sentinel conflated the two and skewed staleness bonuses).
    pub last_selected_round: Option<u64>,
    pub times_selected: u64,
    pub times_completed: u64,
    /// Consecutive deadline misses (Oort-style blacklist trigger).
    pub consecutive_misses: u32,
    /// Client is ineligible until this round (exclusive).
    pub banned_until_round: u64,
}

/// One registered client.
#[derive(Debug, Clone)]
pub struct ClientState {
    pub id: usize,
    pub device: DeviceProfile,
    pub link: LinkProfile,
    pub battery: Battery,
    pub shard: ClientShard,
    pub stats: ClientStats,
}

impl ClientState {
    /// Seconds of local compute for `local_steps` steps of `batch`.
    pub fn compute_secs(&self, local_steps: usize, batch: usize) -> f64 {
        (local_steps * batch) as f64 / self.device.samples_per_sec
    }

    /// Estimated full-round duration: download + compute + upload.
    pub fn expected_duration_s(
        &self,
        payload_bytes: usize,
        local_steps: usize,
        batch: usize,
    ) -> f64 {
        self.link.download_secs(payload_bytes)
            + self.compute_secs(local_steps, batch)
            + self.link.upload_secs(payload_bytes)
    }

    /// Projected energy of the next round's participation.
    pub fn projected_energy(
        &self,
        payload_bytes: usize,
        local_steps: usize,
        batch: usize,
    ) -> RoundEnergy {
        RoundEnergy::for_participation(
            &self.device.spec,
            &self.link,
            payload_bytes,
            self.compute_secs(local_steps, batch),
        )
    }
}

/// Struct-of-arrays projection cache — everything the plan path reads,
/// one contiguous array per field (all indexed by client id).
///
/// Invariant: entry `i` always equals what a fresh recomputation from
/// `clients[i]` (with the registry's build-time `local_steps` / `batch`
/// / `payload_bytes`) would produce. Static fields change only through
/// [`Registry::refresh_projection`]; dynamic fields are written by the
/// mutation guards.
#[derive(Debug, Clone, Default)]
pub struct ClientPool {
    // --- static projections (build time / refresh_projection) ---
    pub download_s: Vec<f64>,
    pub compute_s: Vec<f64>,
    pub upload_s: Vec<f64>,
    pub expected_duration_s: Vec<f64>,
    /// Total projected participation energy for one round, joules.
    pub round_energy_j: Vec<f64>,
    /// `round_energy_j / capacity` — the candidate's projected drain.
    pub drain_frac: Vec<f64>,
    /// Battery capacity, joules (static; the lazy-drain closed form
    /// multiplies it by the elapsed cumulative drain fraction).
    pub capacity_j: Vec<f64>,
    // --- dynamic mirrors (mutation guards) ---
    pub alive: Vec<bool>,
    pub battery_frac: Vec<f64>,
    pub charge_j: Vec<f64>,
    pub stat_util: Vec<Option<f64>>,
    pub measured_duration_s: Vec<Option<f64>>,
    /// Round of last selection, `u64::MAX` = never selected (the SoA
    /// column keeps the dense `u64` encoding; the candidate projection
    /// converts the sentinel back to `Option<u64>`).
    pub last_selected_round: Vec<u64>,
    pub banned_until_round: Vec<u64>,
    // --- liveness indices (mutation guards; free-list style) ---
    /// Clients whose battery is currently dead — the revival
    /// candidates recharge policies scan instead of all N clients.
    /// Membership order is unspecified (swap-remove).
    pub dead: IndexSet,
    /// Clients whose *materialized* charge is below capacity (i.e.
    /// could absorb charge). In lazy mode a client with pending
    /// un-settled drain may still read as full here — policies that
    /// need the drain-effective view must settle first.
    pub below_capacity: IndexSet,
}

impl ClientPool {
    fn with_capacity(n: usize) -> Self {
        let mut p = Self::default();
        macro_rules! reserve {
            ($($f:ident),*) => { $( p.$f.reserve_exact(n); )* };
        }
        reserve!(
            download_s,
            compute_s,
            upload_s,
            expected_duration_s,
            round_energy_j,
            drain_frac,
            capacity_j,
            alive,
            battery_frac,
            charge_j,
            stat_util,
            measured_duration_s,
            last_selected_round,
            banned_until_round
        );
        p.dead = IndexSet::with_capacity(n);
        p.below_capacity = IndexSet::with_capacity(n);
        p
    }
}

/// Population aggregates maintained incrementally at every mutation
/// site; the O(1) source for the per-round metrics row.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolAggregates {
    /// Clients whose battery is currently alive.
    pub alive: usize,
    /// Σ battery fraction over *alive* clients (exact fixed-point).
    pub battery_frac_sum: FixedSum,
    /// Σ cumulative FL energy over all clients, joules (exact).
    pub fl_energy_j: FixedSum,
    /// Σ times_selected over all clients (Jain numerator moment).
    pub selected_sum: u64,
    /// Σ times_selected² over all clients (Jain denominator moment).
    pub selected_sum_sq: u128,
}

impl PoolAggregates {
    /// Brute-force rebuild from per-client state — the reference the
    /// incremental state must equal *exactly* (FixedSum makes the float
    /// sums order-independent, so `==` is the right comparison).
    pub fn recompute(registry: &Registry) -> Self {
        let mut agg = Self::default();
        for c in registry.clients() {
            if c.battery.is_alive() {
                agg.alive += 1;
                agg.battery_frac_sum.add(c.battery.fraction());
            }
            agg.fl_energy_j.add(c.battery.fl_energy_j);
            agg.selected_sum += c.stats.times_selected;
            agg.selected_sum_sq += (c.stats.times_selected as u128).pow(2);
        }
        agg
    }
}

/// Death-wheel bucket width, in cumulative-drained-fraction units
/// (2⁻¹⁰ ≈ 0.001 of a battery). An entry fires at most one bucket
/// early (the exact predicate is re-checked), so a near-death client
/// refires for at most `width / per-epoch-drain` epochs before dying.
const DEATH_BUCKET_WIDTH: f64 = 1.0 / 1024.0;

/// Slack added to the wheel threshold so float error in the `u =
/// fraction + s` keys can never postpone a due death past its epoch.
/// The key error is a few ulps of `u` (≲ 1e-11 even after 10⁴
/// simulated hours of cumulative drain) — far below this margin,
/// which itself sits far below the bucket width, so the slack only
/// ever pulls in (already-due or one-bucket-early) entries whose
/// exact predicate decides the outcome.
const DEATH_SAFETY: f64 = 1e-7;

/// Threshold slack for the eligible arena's battery-floor wheels — the
/// same float-ulp argument as [`DEATH_SAFETY`]: the margin only pulls
/// in already-due (or one-bucket-early) entries, and the exact
/// [`battery_floor_admits`] predicate decides every fired entry.
const FLOOR_SAFETY: f64 = 1e-7;

/// Ban-wheel bucket width. Keys are whole round numbers
/// (`banned_until_round as f64`), so width 1.0 makes every bucket start
/// coincide with its key: a ban-release entry fires exactly at its
/// release round, never early.
const BAN_BUCKET_WIDTH: f64 = 1.0;

/// The lazy background-drain ledger: one cumulative drained fraction
/// per drain class plus per-client anchors (see the module docs).
///
/// Class 0 = idle devices, class 1 = `background_busy` devices; the
/// class is a static property of the device profile, so two cumsums
/// cover the whole population.
#[derive(Debug, Clone)]
struct DrainLedger {
    /// Cumulative drained capacity-fraction per class since t = 0.
    s_frac: [f64; 2],
    /// Ledger clock: the end of the last advanced epoch — the instant
    /// lazily discovered deaths are stamped with (matching the eager
    /// sweep, which drained bystanders at each round's end clock).
    now_h: f64,
    /// Per-client drain class (0 or 1).
    class_of: Vec<u8>,
    /// Materialized charge at the client's last anchor, joules.
    anchor_charge_j: Vec<f64>,
    /// Class cumsum at the client's last anchor.
    anchor_s_frac: Vec<f64>,
    /// The exact `fraction + s` key this client contributed to
    /// `u_sum` and registered in the death wheel (valid while
    /// `contributing`).
    anchor_u: Vec<f64>,
    /// Wheel-entry generation, bumped on every re-anchor; fired
    /// entries with a stale generation are discarded (lazy deletion).
    anchor_gen: Vec<u32>,
    /// Whether the client is currently counted in `u_sum` /
    /// `alive_in_class` (⇔ its battery is alive).
    contributing: Vec<bool>,
    /// Σ (fraction_i + s_class_i) over all contributing clients (one
    /// shared accumulator, so at s = 0 it carries the exact same grid
    /// state the pre-ledger per-fraction sum did) — with
    /// `alive_in_class`, yields the population's effective mean
    /// battery in O(1): (u_sum − Σ_c n_c·s_c) / n.
    u_sum: FixedSum,
    alive_in_class: [usize; 2],
    /// Death wheels keyed by `anchor_u`, per class.
    wheels: [BucketWheel; 2],
    /// Reusable scratch for fired wheel entries.
    fired: Vec<(u32, u32)>,
}

impl DrainLedger {
    fn new(clients: &[ClientState]) -> Self {
        let n = clients.len();
        let mut led = Self {
            s_frac: [0.0; 2],
            now_h: 0.0,
            class_of: Vec::with_capacity(n),
            anchor_charge_j: Vec::with_capacity(n),
            anchor_s_frac: vec![0.0; n],
            anchor_u: vec![0.0; n],
            anchor_gen: vec![0; n],
            contributing: vec![false; n],
            u_sum: FixedSum::default(),
            alive_in_class: [0; 2],
            wheels: [
                BucketWheel::new(DEATH_BUCKET_WIDTH),
                BucketWheel::new(DEATH_BUCKET_WIDTH),
            ],
            fired: Vec::new(),
        };
        for (id, c) in clients.iter().enumerate() {
            let class = c.device.background_busy as usize;
            led.class_of.push(class as u8);
            led.anchor_charge_j.push(c.battery.charge_joules());
            if c.battery.is_alive() {
                let u = c.battery.fraction(); // + s, which is 0 at build
                led.anchor_u[id] = u;
                led.u_sum.add(u);
                led.alive_in_class[class] += 1;
                led.contributing[id] = true;
                led.wheels[class].insert(u, id as u32, 0);
            }
        }
        led
    }
}

/// One alive↔dead battery transition, recorded at the mirror-sync
/// choke point when journaling is enabled (observability traces).
///
/// Every liveness flip — FL-drain deaths, background death-wheel
/// kills, recharge revivals — flows through
/// [`Registry::sync_battery_mirrors`], so this journal sees each flip
/// exactly once, in mutation order. That order is a pure function of
/// the seeded simulation (sim-result order for FL deaths, wheel order
/// for background deaths, ascending-id order for revivals), which is
/// what makes trace files byte-identical across worker counts, shard
/// splits and drain modes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LifecycleEvent {
    /// Battery hit zero at simulated hour `at_h` (the battery's own
    /// death stamp: mid-round for FL deaths, end-of-epoch for
    /// background deaths — identical in lazy and eager mode).
    Depleted { id: usize, at_h: f64 },
    /// A dead battery came back above zero; `at_h` is the ledger clock
    /// at revival (the recharge window's end).
    Revived { id: usize, at_h: f64, battery_frac: f64 },
}

/// How the plan phase exposes scenario availability to the eligible
/// arena: either the always-on fast case (nothing to gate, nothing to
/// watch) or the coordinator's [`WakeWheel`](crate::scenario::WakeWheel)
/// state — the cached bitmap plus the ids whose bit flipped during the
/// wheel's last advance (the arena's availability change list).
#[derive(Clone, Copy)]
pub enum AvailabilityView<'a> {
    /// Every client is reachable every round.
    AlwaysOn,
    /// Wake-wheel cache: `bits[id]` is the availability at the round
    /// clock; `changed` lists (ascending) the ids whose bit flipped
    /// since the previous advance.
    Cached { bits: &'a [bool], changed: &'a [u32] },
}

impl AvailabilityView<'_> {
    #[inline]
    fn get(&self, id: usize) -> bool {
        match self {
            AvailabilityView::AlwaysOn => true,
            AvailabilityView::Cached { bits, .. } => bits[id],
        }
    }

    /// Discriminant for the arena's view-consistency check: patching
    /// only composes with change lists from one view kind, so switching
    /// kinds forces a full rebuild.
    fn kind(&self) -> u8 {
        match self {
            AvailabilityView::AlwaysOn => 0,
            AvailabilityView::Cached { .. } => 1,
        }
    }
}

/// The incrementally maintained eligible-candidate arena — the plan
/// phase's replacement for the per-round O(N)
/// [`Registry::fill_candidates`] walk.
///
/// `members` is always exactly what `fill_candidates(round, floor,
/// avail, ..)` would produce (same ids, same ascending order, same
/// `Candidate` bits — property-tested in
/// `rust/tests/candidate_arena.rs`), but it is *patched* per round from
/// four O(changed) event sources instead of rebuilt:
///
///  - **floor wheels** (per drain class, keyed by the lazy ledger's
///    `anchor_u` like the death wheel, popped at `s + floor` instead of
///    `s`) fire members whose drain-effective fraction may have reached
///    the battery floor;
///  - the **ban wheel** (1-round buckets keyed by `banned_until_round`)
///    fires blacklist releases exactly at their release round;
///  - the wake wheel's **availability change list** re-evaluates
///    clients whose presence bit flipped;
///  - the **dirty list**, marked by every mutation guard at the
///    existing mirror-sync choke points (`sync_battery_mirrors`,
///    `sync_stats`, `refresh_projection`), re-evaluates clients whose
///    battery / stats / link state changed — FL drains, charges,
///    recharge revivals, bans, link migrations.
///
/// Membership is therefore a *guarded mirror* in the same sense as the
/// SoA pool columns: no mutation path can change a client's
/// eligibility without either flowing through a guard (dirty mark) or
/// being a pure function of round time (wheels, change list).
///
/// Invariant: `in_floor_wheel[id]` ⇔ the floor wheel holds exactly one
/// entry for `id` at the ledger's *current* `anchor_gen[id]` (stale
/// generations are lazily discarded on fire, like the death wheel).
/// Members are armed; non-members may carry a harmless armed entry
/// until it fires.
struct EligibleArena {
    /// False until the first `refresh_eligible` does its one full O(N)
    /// build. While false, dirty marks are dropped (nothing to patch) —
    /// which is also what keeps the `EAFL_REBUILD_CANDIDATES=1` escape
    /// hatch from accumulating an unbounded dirty list.
    built: bool,
    /// The battery floor the arena was built for (bit-compared; a
    /// different floor forces a rebuild).
    min_battery_frac: f64,
    /// View-kind discriminant the arena was built under.
    avail_kind: u8,
    /// id → index into `members`; `u32::MAX` = not a member.
    pos: Vec<u32>,
    /// The eligible candidates, ascending id.
    members: Vec<Candidate>,
    /// Per-class battery-floor-crossing wheels (class 0 idle, 1 busy).
    floor_wheels: [BucketWheel; 2],
    in_floor_wheel: Vec<bool>,
    /// Blacklist-release wheel keyed by `banned_until_round`.
    ban_wheel: BucketWheel,
    /// Guard-marked ids awaiting re-evaluation (deduped via
    /// `dirty_flag`).
    dirty: Vec<u32>,
    dirty_flag: Vec<bool>,
    /// Ledger cumsums at the last refresh: when an epoch advanced, every
    /// member's projected `battery_frac` is stale and gets recomputed.
    last_s: [f64; 2],
    // Reusable scratch — no per-round allocation in steady state.
    fired: Vec<(u32, u32)>,
    eval: Vec<u32>,
    adds: Vec<u32>,
    removals: Vec<u32>,
    merge_scratch: Vec<Candidate>,
}

impl Default for EligibleArena {
    fn default() -> Self {
        Self {
            built: false,
            // NaN bit-compares unequal to every real floor, so the
            // first refresh always takes the full-build path.
            min_battery_frac: f64::NAN,
            avail_kind: u8::MAX,
            pos: Vec::new(),
            members: Vec::new(),
            floor_wheels: [
                BucketWheel::new(DEATH_BUCKET_WIDTH),
                BucketWheel::new(DEATH_BUCKET_WIDTH),
            ],
            in_floor_wheel: Vec::new(),
            ban_wheel: BucketWheel::new(BAN_BUCKET_WIDTH),
            dirty: Vec::new(),
            dirty_flag: Vec::new(),
            last_s: [0.0; 2],
            fired: Vec::new(),
            eval: Vec::new(),
            adds: Vec::new(),
            removals: Vec::new(),
            merge_scratch: Vec::new(),
        }
    }
}

impl EligibleArena {
    /// Queue `id` for re-evaluation at the next refresh. No-op until
    /// the arena is built (a rebuild sees everything anyway).
    #[inline]
    fn mark_dirty(&mut self, id: usize) {
        if self.built && !self.dirty_flag[id] {
            self.dirty_flag[id] = true;
            self.dirty.push(id as u32);
        }
    }
}

/// The full client population.
pub struct Registry {
    clients: Vec<ClientState>,
    pool: ClientPool,
    aggregates: PoolAggregates,
    /// Lazy background-drain state (see the module docs).
    ledger: DrainLedger,
    /// Incrementally maintained eligible-candidate arena (see
    /// [`EligibleArena`]); unbuilt until the first
    /// [`Registry::refresh_eligible`].
    arena: EligibleArena,
    /// Liveness-flip journal (see [`LifecycleEvent`]); empty and
    /// cost-free unless a trace sink enabled it.
    journal: Vec<LifecycleEvent>,
    journal_enabled: bool,
    /// Model payload exchanged each round (flat params as f32 bytes).
    /// Private like `clients`: it feeds every cached projection, so
    /// mutating it without a pool rebuild would silently stale the
    /// transfer-time and energy entries.
    payload_bytes: usize,
    /// Local steps the cached projections were built for.
    local_steps: usize,
    /// Batch size the cached projections were built for.
    batch: usize,
}

impl Registry {
    /// Build the population from the experiment config: device traces,
    /// link traces and the non-IID partition are all seeded and merged
    /// 1:1 by client index. Per-client projections are cached in the
    /// SoA pool for the config's `training.local_steps` ×
    /// `data.batch_size` workload.
    pub fn build(cfg: &ExperimentConfig, num_classes: usize, param_count: usize) -> Self {
        let n = cfg.federation.num_clients;
        let devices = generate_profiles(&cfg.devices, n);
        let links = generate_links(&cfg.network, n);
        let partition = partition_clients(&cfg.data, num_classes, n);
        let clients: Vec<ClientState> = devices
            .into_iter()
            .zip(links)
            .zip(partition.shards)
            .enumerate()
            .map(|(id, ((device, link), shard))| {
                let battery = Battery::new(&device.spec, device.init_battery_frac);
                ClientState { id, device, link, battery, shard, stats: ClientStats::default() }
            })
            .collect();
        let mut registry = Self {
            clients,
            // Placeholders only: rebuild_pool constructs the real ones.
            pool: ClientPool::default(),
            aggregates: PoolAggregates::default(),
            ledger: DrainLedger::new(&[]),
            arena: EligibleArena::default(),
            journal: Vec::new(),
            journal_enabled: false,
            payload_bytes: param_count * 4,
            local_steps: cfg.training.local_steps,
            batch: cfg.data.batch_size,
        };
        registry.rebuild_pool();
        registry
    }

    /// Populate the SoA pool, the aggregates and the drain ledger from
    /// scratch.
    fn rebuild_pool(&mut self) {
        let (payload, steps, batch) = (self.payload_bytes, self.local_steps, self.batch);
        let mut pool = ClientPool::with_capacity(self.clients.len());
        for (id, c) in self.clients.iter().enumerate() {
            let energy = c.projected_energy(payload, steps, batch).total();
            pool.download_s.push(c.link.download_secs(payload));
            pool.compute_s.push(c.compute_secs(steps, batch));
            pool.upload_s.push(c.link.upload_secs(payload));
            pool.expected_duration_s.push(c.expected_duration_s(payload, steps, batch));
            pool.round_energy_j.push(energy);
            pool.drain_frac.push(energy / c.battery.capacity_joules());
            pool.capacity_j.push(c.battery.capacity_joules());
            pool.alive.push(c.battery.is_alive());
            pool.battery_frac.push(c.battery.fraction());
            pool.charge_j.push(c.battery.charge_joules());
            pool.stat_util.push(c.stats.stat_util);
            pool.measured_duration_s.push(c.stats.measured_duration_s);
            pool.last_selected_round.push(c.stats.last_selected_round.unwrap_or(u64::MAX));
            pool.banned_until_round.push(c.stats.banned_until_round);
            if !c.battery.is_alive() {
                pool.dead.insert(id);
            }
            if c.battery.charge_joules() < c.battery.capacity_joules() {
                pool.below_capacity.insert(id);
            }
        }
        self.pool = pool;
        self.aggregates = PoolAggregates::recompute(self);
        self.ledger = DrainLedger::new(&self.clients);
        self.arena = EligibleArena::default();
    }

    /// Recompute one client's *static* projections after its device or
    /// link profile changed (a scenario hot-swapping hardware, a future
    /// link-migration event). The static network assumption makes this
    /// the only place static pool entries are ever rewritten — O(1) per
    /// changed client instead of an O(N) rebuild.
    pub fn refresh_projection(&mut self, id: usize) {
        let (payload, steps, batch) = (self.payload_bytes, self.local_steps, self.batch);
        let c = &self.clients[id];
        let energy = c.projected_energy(payload, steps, batch).total();
        let download_s = c.link.download_secs(payload);
        let compute_s = c.compute_secs(steps, batch);
        let upload_s = c.link.upload_secs(payload);
        let expected = c.expected_duration_s(payload, steps, batch);
        let drain_frac = energy / c.battery.capacity_joules();
        let p = &mut self.pool;
        p.download_s[id] = download_s;
        p.compute_s[id] = compute_s;
        p.upload_s[id] = upload_s;
        p.expected_duration_s[id] = expected;
        p.round_energy_j[id] = energy;
        p.drain_frac[id] = drain_frac;
        self.arena.mark_dirty(id);
    }

    /// Mutable access to a client's link profile; the projection cache
    /// entry is refreshed when the guard drops.
    pub fn link_mut(&mut self, id: usize) -> LinkMut<'_> {
        LinkMut { registry: self, id }
    }

    pub fn len(&self) -> usize {
        self.clients.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    /// Read-only view of one client.
    pub fn client(&self, id: usize) -> &ClientState {
        &self.clients[id]
    }

    /// Read-only view of the whole population.
    pub fn clients(&self) -> &[ClientState] {
        &self.clients
    }

    /// Model payload exchanged each round (flat params as f32 bytes).
    pub fn payload_bytes(&self) -> usize {
        self.payload_bytes
    }

    /// The SoA projection cache (read-only; kept in sync by the
    /// mutation guards).
    pub fn pool(&self) -> &ClientPool {
        &self.pool
    }

    /// The incrementally maintained population aggregates.
    pub fn aggregates(&self) -> &PoolAggregates {
        &self.aggregates
    }

    // --- mutation guards ---------------------------------------------------

    /// Mutable access to a client's battery. Any lazily accrued
    /// background drain is settled (materialized) *before* the guard
    /// captures its old-state snapshot, so the mutation operates on the
    /// true charge level; aggregates, pool mirrors and the drain anchor
    /// are re-synced when the guard drops, so arbitrary battery
    /// mutations (drain, charge, revive) stay consistent.
    pub fn battery_mut(&mut self, id: usize) -> BatteryMut<'_> {
        self.settle(id);
        let b = &self.clients[id].battery;
        BatteryMut {
            was_alive: b.is_alive(),
            old_frac: b.fraction(),
            old_fl_energy: b.fl_energy_j,
            registry: self,
            id,
        }
    }

    /// Mutable access to a client's selection statistics. The Jain
    /// moments (Σc, Σc²) and pool mirrors are re-synced on drop.
    pub fn stats_mut(&mut self, id: usize) -> StatsMut<'_> {
        let old_times_selected = self.clients[id].stats.times_selected;
        StatsMut { old_times_selected, registry: self, id }
    }

    /// Drain `energy_j` of FL work from client `id` at simulation time
    /// `now_h`; returns the supplied fraction (see
    /// [`Battery::drain_fl`]).
    pub fn drain_fl(&mut self, id: usize, energy_j: f64, now_h: f64) -> f64 {
        self.battery_mut(id).drain_fl(energy_j, now_h)
    }

    /// Drain background (idle/busy) energy from client `id`.
    pub fn drain_background(&mut self, id: usize, energy_j: f64, now_h: f64) -> f64 {
        self.battery_mut(id).drain_background(energy_j, now_h)
    }

    /// Add charge to client `id` (revives a dead battery with charge).
    pub fn charge_add(&mut self, id: usize, energy_j: f64) {
        self.battery_mut(id).charge_add(energy_j);
    }

    /// Recharge client `id` to `fraction` of capacity and revive it.
    pub fn recharge_to(&mut self, id: usize, fraction: f64) {
        self.battery_mut(id).recharge_to(fraction);
    }

    /// Full post-mutation re-sync: mirrors *and* a fresh drain anchor.
    /// This is the guard-drop path — the only place anchors move.
    fn sync_battery(&mut self, id: usize, was_alive: bool, old_frac: f64, old_fl: f64) {
        self.sync_battery_mirrors(id, was_alive, old_frac, old_fl);
        self.re_anchor(id);
    }

    /// Re-sync the aggregates, pool mirrors and liveness indices from
    /// the battery's materialized state — *without* touching the drain
    /// anchor. Settling (materialization of already-accrued drain)
    /// uses this path directly, so a settle never moves an anchor and
    /// the materialized level stays a pure function of (anchor, s) in
    /// both lazy and eager mode.
    fn sync_battery_mirrors(&mut self, id: usize, was_alive: bool, old_frac: f64, old_fl: f64) {
        let b = &self.clients[id].battery;
        let (alive, frac, fl, charge) =
            (b.is_alive(), b.fraction(), b.fl_energy_j, b.charge_joules());
        let agg = &mut self.aggregates;
        if was_alive {
            agg.alive -= 1;
            agg.battery_frac_sum.sub(old_frac);
        }
        if alive {
            agg.alive += 1;
            agg.battery_frac_sum.add(frac);
        }
        agg.fl_energy_j.sub(old_fl);
        agg.fl_energy_j.add(fl);
        self.pool.alive[id] = alive;
        self.pool.battery_frac[id] = frac;
        self.pool.charge_j[id] = charge;
        if alive {
            self.pool.dead.remove(id);
        } else {
            self.pool.dead.insert(id);
        }
        if charge < self.pool.capacity_j[id] {
            self.pool.below_capacity.insert(id);
        } else {
            self.pool.below_capacity.remove(id);
        }
        // Every battery mutation — FL drains, charges, revivals, wheel
        // kills, settles — flows through here, so this one mark keeps
        // arena membership a guarded mirror of the battery state.
        self.arena.mark_dirty(id);
        if self.journal_enabled && was_alive != alive {
            let ev = if alive {
                LifecycleEvent::Revived { id, at_h: self.ledger.now_h, battery_frac: frac }
            } else {
                // Prefer the battery's own death stamp (mid-round for
                // FL deaths); the ledger clock is only a fallback for
                // batteries that died without recording one.
                let at_h = self.clients[id].battery.died_at_h.unwrap_or(self.ledger.now_h);
                LifecycleEvent::Depleted { id, at_h }
            };
            self.journal.push(ev);
        }
    }

    /// Enable/disable the lifecycle journal (attached trace sinks turn
    /// it on). Off by default: journaling costs one branch per battery
    /// mirror sync and nothing else.
    pub fn set_journal(&mut self, enabled: bool) {
        self.journal_enabled = enabled;
        if !enabled {
            self.journal.clear();
        }
    }

    /// Move all journaled lifecycle events (in mutation order) into
    /// `out`, leaving the journal empty.
    pub fn drain_journal(&mut self, out: &mut Vec<LifecycleEvent>) {
        out.append(&mut self.journal);
    }

    /// Move a client's drain anchor to "now": materialized charge,
    /// current class cumsum. Re-registers the client's `u_sum`
    /// contribution and death-wheel entry (alive clients only) and
    /// bumps the wheel generation so stale entries die lazily.
    fn re_anchor(&mut self, id: usize) {
        let class = self.ledger.class_of[id] as usize;
        let led = &mut self.ledger;
        if led.contributing[id] {
            led.u_sum.sub(led.anchor_u[id]);
            led.alive_in_class[class] -= 1;
            led.contributing[id] = false;
        }
        let b = &self.clients[id].battery;
        led.anchor_charge_j[id] = b.charge_joules();
        led.anchor_s_frac[id] = led.s_frac[class];
        led.anchor_gen[id] = led.anchor_gen[id].wrapping_add(1);
        if b.is_alive() {
            let u = b.fraction() + led.s_frac[class];
            led.anchor_u[id] = u;
            led.u_sum.add(u);
            led.alive_in_class[class] += 1;
            led.contributing[id] = true;
            led.wheels[class].insert(u, id as u32, led.anchor_gen[id]);
        }
        // The generation bump just invalidated any floor-wheel entry;
        // re-arm current members at the fresh (key, gen) so their next
        // floor crossing still fires. Non-members need no entry — they
        // re-enter through the dirty/change paths, which arm them then.
        if self.arena.built {
            if self.arena.pos[id] != u32::MAX && self.ledger.contributing[id] {
                let class = self.ledger.class_of[id] as usize;
                self.arena.floor_wheels[class].insert(
                    self.ledger.anchor_u[id],
                    id as u32,
                    self.ledger.anchor_gen[id],
                );
                self.arena.in_floor_wheel[id] = true;
            } else {
                self.arena.in_floor_wheel[id] = false;
            }
        }
    }

    /// Drop a client from the ledger's contributing set after its
    /// battery died (wheel kill, or a defensive settle-kill).
    fn ledger_mark_dead(&mut self, id: usize) {
        let class = self.ledger.class_of[id] as usize;
        let led = &mut self.ledger;
        if led.contributing[id] {
            led.u_sum.sub(led.anchor_u[id]);
            led.alive_in_class[class] -= 1;
            led.contributing[id] = false;
        }
        led.anchor_charge_j[id] = 0.0;
        led.anchor_s_frac[id] = led.s_frac[class];
        led.anchor_gen[id] = led.anchor_gen[id].wrapping_add(1);
        if self.arena.built {
            // Dead clients carry no valid floor-wheel entry (the gen
            // bump lazily deleted it); membership is removed at the next
            // refresh via the dirty mark the mirror sync just made.
            self.arena.in_floor_wheel[id] = false;
        }
    }

    /// Materialize any lazily accrued background drain for one client:
    /// write the closed-form effective charge into the battery and
    /// re-sync the mirrors, *without* moving the anchor. Idempotent —
    /// settling twice at the same cumsum books nothing the second time.
    fn settle(&mut self, id: usize) {
        if !self.clients[id].battery.is_alive() {
            return;
        }
        let class = self.ledger.class_of[id] as usize;
        let ds = self.ledger.s_frac[class] - self.ledger.anchor_s_frac[id];
        if ds <= 0.0 {
            return;
        }
        let eff = self.ledger.anchor_charge_j[id] - self.pool.capacity_j[id] * ds;
        let b = &self.clients[id].battery;
        let (old_frac, old_fl) = (b.fraction(), b.fl_energy_j);
        self.clients[id].battery.settle_background(eff, self.ledger.now_h);
        self.sync_battery_mirrors(id, true, old_frac, old_fl);
        if !self.clients[id].battery.is_alive() {
            // The wheel fires due deaths during the epoch advance, so a
            // settle outside the advance only ever sees survivors —
            // but keep the ledger coherent if one slips through.
            self.ledger_mark_dead(id);
        }
    }

    /// Materialize pending background drain for the whole population —
    /// the legacy-cost O(N) sweep. The `EAFL_EAGER_DRAIN=1` escape
    /// hatch runs this every round; the lazy path only needs it before
    /// direct reads of raw battery state (tests, offline analysis).
    /// Anchors never move here, so a settled population is bit-
    /// identical between modes.
    pub fn settle_all(&mut self) {
        for id in 0..self.clients.len() {
            self.settle(id);
        }
    }

    /// Advance the background-drain clock by one epoch: credit
    /// `rate × round_hours` to each class cumsum, exempt this round's
    /// participants (their background time was consumed by FL work —
    /// re-anchored at the new cumsum with charge unchanged, *before*
    /// the wheels run so no participant is killed by drain it never
    /// incurred), then fire the due death-wheel buckets.
    ///
    /// Cost: O(participants + fired wheel entries) — independent of
    /// the population size. Deaths land exactly where the eager sweep
    /// put them: same set of clients, same `end_clock_h` timestamp,
    /// same charge bits (the exact predicate is evaluated per fired
    /// entry; buckets only pre-filter).
    pub fn advance_background(
        &mut self,
        sorted_selected: &[usize],
        idle_rate_per_h: f64,
        busy_rate_per_h: f64,
        round_hours: f64,
        end_clock_h: f64,
    ) {
        let dh = round_hours.max(0.0);
        self.ledger.s_frac[0] += idle_rate_per_h.max(0.0) * dh;
        self.ledger.s_frac[1] += busy_rate_per_h.max(0.0) * dh;
        self.ledger.now_h = end_clock_h;
        for &id in sorted_selected {
            self.re_anchor(id);
        }
        for class in 0..2 {
            let threshold = self.ledger.s_frac[class] + DEATH_SAFETY;
            let mut fired = std::mem::take(&mut self.ledger.fired);
            fired.clear();
            self.ledger.wheels[class].pop_due(threshold, &mut fired);
            for &(id32, gen) in &fired {
                let id = id32 as usize;
                if gen != self.ledger.anchor_gen[id] || !self.ledger.contributing[id] {
                    continue; // stale registration (anchor moved or died)
                }
                let ds = self.ledger.s_frac[class] - self.ledger.anchor_s_frac[id];
                let eff = self.ledger.anchor_charge_j[id] - self.pool.capacity_j[id] * ds;
                if eff <= f64::EPSILON {
                    let b = &self.clients[id].battery;
                    let (old_frac, old_fl) = (b.fraction(), b.fl_energy_j);
                    self.clients[id].battery.settle_background(eff, end_clock_h);
                    debug_assert!(!self.clients[id].battery.is_alive());
                    self.sync_battery_mirrors(id, true, old_frac, old_fl);
                    self.ledger_mark_dead(id);
                } else {
                    // Fired a bucket early: re-register at the same key
                    // (same generation — the anchor hasn't moved).
                    self.ledger.wheels[class].insert(self.ledger.anchor_u[id], id32, gen);
                }
            }
            self.ledger.fired = fired;
        }
    }

    /// The client's drain-effective charge (joules): its materialized
    /// charge minus background drain accrued since its last anchor,
    /// evaluated closed-form without touching the battery. This is
    /// what candidates, plans and the death predicate see — "drain
    /// as-of the round clock, applied on touch".
    pub fn effective_charge_j(&self, id: usize) -> f64 {
        let b = &self.clients[id].battery;
        if !b.is_alive() {
            return 0.0;
        }
        let class = self.ledger.class_of[id] as usize;
        let ds = self.ledger.s_frac[class] - self.ledger.anchor_s_frac[id];
        if ds <= 0.0 {
            return b.charge_joules();
        }
        (self.ledger.anchor_charge_j[id] - self.pool.capacity_j[id] * ds).max(0.0)
    }

    /// Drain-effective battery fraction in [0, 1] — the lazy
    /// counterpart of `battery.fraction()`.
    pub fn effective_battery_frac(&self, id: usize) -> f64 {
        (self.effective_charge_j(id) / self.pool.capacity_j[id]).clamp(0.0, 1.0)
    }

    /// Per-class cumulative background-drained fraction since t = 0
    /// (class 0 = idle, class 1 = busy). Exposed for tests and the
    /// throughput bench.
    pub fn background_cumsum(&self) -> [f64; 2] {
        self.ledger.s_frac
    }

    fn sync_stats(&mut self, id: usize, old_times_selected: u64) {
        // Mirror still holds the pre-mutation ban round — the arena's
        // release wheel needs the transition, not just the new value.
        let old_ban = self.pool.banned_until_round[id];
        let s = &self.clients[id].stats;
        let agg = &mut self.aggregates;
        agg.selected_sum = agg.selected_sum - old_times_selected + s.times_selected;
        agg.selected_sum_sq = agg.selected_sum_sq - (old_times_selected as u128).pow(2)
            + (s.times_selected as u128).pow(2);
        self.pool.stat_util[id] = s.stat_util;
        self.pool.measured_duration_s[id] = s.measured_duration_s;
        self.pool.last_selected_round[id] = s.last_selected_round.unwrap_or(u64::MAX);
        self.pool.banned_until_round[id] = s.banned_until_round;
        if self.arena.built {
            let new_ban = self.pool.banned_until_round[id];
            if new_ban != old_ban {
                // Arm the release: the wheel fires the entry exactly at
                // round `new_ban`, when the ban (exclusive) expires. A
                // shortened or already-expired ban leaves a stale entry
                // behind — it fires later, re-evaluates, and is a no-op.
                self.arena.ban_wheel.insert(new_ban as f64, id as u32, 0);
            }
            self.arena.mark_dirty(id);
        }
    }

    // --- O(1) population metrics (incremental aggregates) ------------------

    /// Clients currently alive (battery not dead). O(1).
    pub fn alive_count(&self) -> usize {
        self.aggregates.alive
    }

    /// Clients whose battery has died so far (Fig. 4a's cumulative
    /// drop-out count). O(1).
    pub fn dead_count(&self) -> usize {
        self.len() - self.alive_count()
    }

    /// Mean *drain-effective* battery fraction over alive clients;
    /// **0.0 when none are alive** (an exhausted fleet reports zero
    /// usable charge). O(1).
    ///
    /// Closed form from the drain ledger: each alive client's
    /// effective fraction is `(u_i − s_class)` where `u_i` is its
    /// anchored `fraction + s` key, so the population sum is
    /// `u_sum − Σ_class n_class·s_class` — no scan, and both lazy and
    /// eager mode evaluate the identical expression (the anchors and
    /// cumsums are mode-independent), so the metrics rows agree
    /// bit-for-bit. With no epochs advanced (s = 0) the correction
    /// term is exactly 0.0 and this reduces to the plain quantized
    /// mean of `fraction()` the pre-ledger registry reported.
    pub fn mean_battery_alive(&self) -> f64 {
        if self.aggregates.alive == 0 {
            return 0.0;
        }
        let led = &self.ledger;
        let correction = led.alive_in_class[0] as f64 * led.s_frac[0]
            + led.alive_in_class[1] as f64 * led.s_frac[1];
        (led.u_sum.value() - correction) / self.aggregates.alive as f64
    }

    /// Total FL energy drawn across the population, joules. O(1).
    pub fn total_fl_energy_j(&self) -> f64 {
        self.aggregates.fl_energy_j.value()
    }

    /// Per-client selection counts (allocating; kept for tests and
    /// offline analysis — the metrics row reads the Jain moments from
    /// [`Registry::aggregates`] instead).
    pub fn selection_counts(&self) -> Vec<u64> {
        self.clients.iter().map(|c| c.stats.times_selected).collect()
    }

    // --- candidate construction --------------------------------------------

    /// Fast path: filter eligible clients into `out` (cleared first)
    /// straight from the SoA pool — no allocation in steady state, no
    /// energy-model recomputation. `available` gates on the scenario's
    /// availability model; eligibility is alive ∧ above the battery
    /// floor ∧ not blacklisted. The battery floor and the candidate's
    /// `battery_frac` use the *drain-effective* fraction (closed-form
    /// from the lazy ledger), so selection always sees drain as-of the
    /// round clock without any battery sweep. Produces exactly what
    /// [`Registry::candidates`] (with the registry's build-time
    /// steps/batch) followed by an availability `retain` would.
    pub fn fill_candidates<F: FnMut(usize) -> bool>(
        &self,
        round: u64,
        min_battery_frac: f64,
        mut available: F,
        out: &mut Vec<Candidate>,
    ) {
        out.clear();
        let p = &self.pool;
        for id in 0..self.clients.len() {
            if !p.alive[id] {
                continue;
            }
            let frac = self.effective_battery_frac(id);
            if !battery_floor_admits(frac, min_battery_frac)
                || p.banned_until_round[id] > round
                || !available(id)
            {
                continue;
            }
            out.push(self.make_candidate(id, frac));
        }
    }

    /// The single construction site for a [`Candidate`]'s pool
    /// projection — `fill_candidates` and the eligible arena both build
    /// through here, so their fields are bit-identical by construction.
    #[inline]
    fn make_candidate(&self, id: usize, battery_frac: f64) -> Candidate {
        let p = &self.pool;
        Candidate {
            id,
            stat_util: p.stat_util[id],
            measured_duration_s: p.measured_duration_s[id],
            expected_duration_s: p.expected_duration_s[id],
            last_selected_round: match p.last_selected_round[id] {
                u64::MAX => None,
                r => Some(r),
            },
            battery_frac,
            projected_drain_frac: p.drain_frac[id],
            round_energy_j: p.round_energy_j[id],
        }
    }

    /// Eligibility predicate, stated once: alive ∧ strictly above the
    /// battery floor ([`battery_floor_admits`]) ∧ not blacklisted ∧
    /// available.
    #[inline]
    fn is_eligible(
        &self,
        id: usize,
        round: u64,
        min_battery_frac: f64,
        frac: f64,
        view: &AvailabilityView<'_>,
    ) -> bool {
        self.pool.alive[id]
            && battery_floor_admits(frac, min_battery_frac)
            && self.pool.banned_until_round[id] <= round
            && view.get(id)
    }

    /// Bring the eligible arena up to date for `round` — the plan
    /// phase's O(changed) replacement for a full
    /// [`Registry::fill_candidates`] walk. Read the result with
    /// [`Registry::eligible`].
    ///
    /// The first call (or a floor / view-kind change) does one full
    /// O(N) build; every later call patches: blacklist releases pop off
    /// the ban wheel, battery-floor crossings pop off the per-class
    /// floor wheels (driven by the same lazy-drain cumsums and anchor
    /// generations as the death wheel), availability flips arrive on
    /// the view's change list, and guard-level mutations arrive on the
    /// dirty list — so per-round cost is O(selected + floor-crossings +
    /// availability flips), plus an O(members) `battery_frac` refresh
    /// when a drain epoch advanced (the selector reads every member
    /// anyway, so that adds no asymptotic round cost).
    ///
    /// `round` must be non-decreasing across calls (the ban wheel is a
    /// monotone queue) — true for every engine loop. Byte-identity with
    /// the rebuild path at any worker count, shard split and drain mode
    /// is enforced by `rust/tests/candidate_arena.rs` and ci.sh's
    /// `EAFL_REBUILD_CANDIDATES=1` tier.
    pub fn refresh_eligible(
        &mut self,
        round: u64,
        min_battery_frac: f64,
        view: AvailabilityView<'_>,
    ) {
        if !self.arena.built
            || self.arena.min_battery_frac.to_bits() != min_battery_frac.to_bits()
            || self.arena.avail_kind != view.kind()
        {
            self.rebuild_eligible(round, min_battery_frac, view);
        } else {
            self.patch_eligible(round, view);
        }
    }

    /// The eligible candidates as of the last
    /// [`Registry::refresh_eligible`], ascending id — bit-identical to
    /// what `fill_candidates` would produce for the same (round, floor,
    /// availability).
    pub fn eligible(&self) -> &[Candidate] {
        &self.arena.members
    }

    /// The one full O(N) arena build: scan the pool with the shared
    /// predicate, arm every member in its class's floor wheel, and arm
    /// ban releases for every currently blacklisted client.
    fn rebuild_eligible(
        &mut self,
        round: u64,
        min_battery_frac: f64,
        view: AvailabilityView<'_>,
    ) {
        let n = self.clients.len();
        let arena = &mut self.arena;
        arena.min_battery_frac = min_battery_frac;
        arena.avail_kind = view.kind();
        arena.members.clear();
        arena.pos.clear();
        arena.pos.resize(n, u32::MAX);
        arena.in_floor_wheel.clear();
        arena.in_floor_wheel.resize(n, false);
        arena.dirty_flag.clear();
        arena.dirty_flag.resize(n, false);
        arena.dirty.clear();
        arena.floor_wheels = [
            BucketWheel::new(DEATH_BUCKET_WIDTH),
            BucketWheel::new(DEATH_BUCKET_WIDTH),
        ];
        arena.ban_wheel = BucketWheel::new(BAN_BUCKET_WIDTH);
        arena.last_s = self.ledger.s_frac;
        arena.built = true;
        for id in 0..n {
            if self.pool.banned_until_round[id] > round {
                self.arena.ban_wheel.insert(
                    self.pool.banned_until_round[id] as f64,
                    id as u32,
                    0,
                );
            }
            let frac = self.effective_battery_frac(id);
            if !self.is_eligible(id, round, min_battery_frac, frac, &view) {
                continue;
            }
            self.arena.pos[id] = self.arena.members.len() as u32;
            let cand = self.make_candidate(id, frac);
            self.arena.members.push(cand);
            let class = self.ledger.class_of[id] as usize;
            self.arena.floor_wheels[class].insert(
                self.ledger.anchor_u[id],
                id as u32,
                self.ledger.anchor_gen[id],
            );
            self.arena.in_floor_wheel[id] = true;
        }
    }

    /// Patch the arena from the four change sources (see
    /// [`Registry::refresh_eligible`]).
    fn patch_eligible(&mut self, round: u64, view: AvailabilityView<'_>) {
        let floor = self.arena.min_battery_frac;
        let mut eval = std::mem::take(&mut self.arena.eval);
        let mut fired = std::mem::take(&mut self.arena.fired);
        eval.clear();

        // Blacklist releases due this round. Whole-round buckets fire
        // exactly at the release round; stale entries (a ban extended
        // or shortened since registration) just re-evaluate to a no-op.
        fired.clear();
        self.arena.ban_wheel.pop_due(round as f64, &mut fired);
        for &(id32, _) in &fired {
            eval.push(id32);
        }

        // Battery-floor crossings: a member with anchor key `u` crosses
        // the floor when `u − s_class ≤ floor`, so pop at
        // `s_class + floor` (+ ulp slack). The exact predicate decides
        // each fired entry; early fires re-arm below.
        for class in 0..2 {
            let threshold = self.ledger.s_frac[class] + floor + FLOOR_SAFETY;
            fired.clear();
            self.arena.floor_wheels[class].pop_due(threshold, &mut fired);
            for &(id32, gen) in &fired {
                let id = id32 as usize;
                if gen != self.ledger.anchor_gen[id] {
                    continue; // stale registration (anchor moved or died)
                }
                self.arena.in_floor_wheel[id] = false;
                eval.push(id32);
            }
        }

        // Availability flips since the wake wheel's last advance.
        if let AvailabilityView::Cached { changed, .. } = view {
            eval.extend_from_slice(changed);
        }

        // Guard-marked mutations (battery / stats / link).
        for &id32 in &self.arena.dirty {
            self.arena.dirty_flag[id32 as usize] = false;
            eval.push(id32);
        }
        self.arena.dirty.clear();

        // One pass per touched client, in ascending-id order (the
        // result is a pure function of state, but sorting also hands
        // the merge below pre-sorted add/removal lists).
        eval.sort_unstable();
        eval.dedup();

        let mut adds = std::mem::take(&mut self.arena.adds);
        let mut removals = std::mem::take(&mut self.arena.removals);
        adds.clear();
        removals.clear();
        for &id32 in &eval {
            let id = id32 as usize;
            let frac = self.effective_battery_frac(id);
            let want = self.is_eligible(id, round, floor, frac, &view);
            let have = self.arena.pos[id] != u32::MAX;
            if want && have {
                // Still eligible, state changed: refresh in place.
                let cand = self.make_candidate(id, frac);
                let idx = self.arena.pos[id] as usize;
                self.arena.members[idx] = cand;
            } else if want {
                adds.push(id32);
            } else if have {
                removals.push(id32);
            }
        }

        // Membership changes: one sorted merge preserves ascending-id
        // order — the same order the rebuild's 0..n walk emits.
        if !adds.is_empty() || !removals.is_empty() {
            let mut merged = std::mem::take(&mut self.arena.merge_scratch);
            merged.clear();
            let members = std::mem::take(&mut self.arena.members);
            let (mut ai, mut ri) = (0usize, 0usize);
            for m in &members {
                while ai < adds.len() && (adds[ai] as usize) < m.id {
                    let id = adds[ai] as usize;
                    let frac = self.effective_battery_frac(id);
                    let cand = self.make_candidate(id, frac);
                    merged.push(cand);
                    ai += 1;
                }
                if ri < removals.len() && removals[ri] as usize == m.id {
                    ri += 1;
                    self.arena.pos[m.id] = u32::MAX;
                    continue;
                }
                merged.push(*m);
            }
            while ai < adds.len() {
                let id = adds[ai] as usize;
                let frac = self.effective_battery_frac(id);
                let cand = self.make_candidate(id, frac);
                merged.push(cand);
                ai += 1;
            }
            debug_assert_eq!(ri, removals.len(), "every removal was a member");
            for (i, m) in merged.iter().enumerate() {
                self.arena.pos[m.id] = i as u32;
            }
            self.arena.merge_scratch = members;
            self.arena.members = merged;
        }

        // Arm every touched member that lost (or never had) its floor
        // entry: fresh admissions, and early fires that stayed
        // eligible, re-arm at the current (key, generation).
        for &id32 in &eval {
            let id = id32 as usize;
            if self.arena.pos[id] != u32::MAX && !self.arena.in_floor_wheel[id] {
                let class = self.ledger.class_of[id] as usize;
                self.arena.floor_wheels[class].insert(
                    self.ledger.anchor_u[id],
                    id32,
                    self.ledger.anchor_gen[id],
                );
                self.arena.in_floor_wheel[id] = true;
            }
        }

        // A drain-epoch advance stales every member's projected
        // battery_frac (the candidates must read drain as-of the round
        // clock); recompute them in one pass. O(members) — but the
        // selector reads every member anyway, so the round's asymptotic
        // cost is unchanged, and rounds with no epoch advance skip it.
        if self.arena.last_s != self.ledger.s_frac {
            let mut members = std::mem::take(&mut self.arena.members);
            for m in &mut members {
                m.battery_frac = self.effective_battery_frac(m.id);
            }
            self.arena.members = members;
            self.arena.last_s = self.ledger.s_frac;
        }

        self.arena.eval = eval;
        self.arena.fired = fired;
        self.arena.adds = adds;
        self.arena.removals = removals;
    }

    /// Reference path: build selector candidates by recomputing every
    /// projection from the AoS state. Semantically identical to
    /// [`Registry::fill_candidates`] when called with the registry's
    /// build-time `local_steps`/`batch`; kept allocating and
    /// recomputing on purpose as the property-test reference and the
    /// pre-refactor baseline in `benches/plan_path_throughput.rs`.
    pub fn candidates(
        &self,
        round: u64,
        min_battery_frac: f64,
        local_steps: usize,
        batch: usize,
    ) -> Vec<Candidate> {
        self.clients
            .iter()
            .filter(|c| {
                c.battery.is_alive()
                    && battery_floor_admits(
                        self.effective_battery_frac(c.id),
                        min_battery_frac,
                    )
                    && c.stats.banned_until_round <= round
            })
            .map(|c| {
                let energy =
                    c.projected_energy(self.payload_bytes, local_steps, batch).total();
                Candidate {
                    id: c.id,
                    stat_util: c.stats.stat_util,
                    measured_duration_s: c.stats.measured_duration_s,
                    expected_duration_s: c.expected_duration_s(
                        self.payload_bytes,
                        local_steps,
                        batch,
                    ),
                    last_selected_round: c.stats.last_selected_round,
                    battery_frac: self.effective_battery_frac(c.id),
                    projected_drain_frac: energy / c.battery.capacity_joules(),
                    round_energy_j: energy,
                }
            })
            .collect()
    }
}

/// Guard for battery mutation: dereferences to [`Battery`]; re-syncs
/// the pool mirrors and aggregates when dropped.
pub struct BatteryMut<'a> {
    registry: &'a mut Registry,
    id: usize,
    was_alive: bool,
    old_frac: f64,
    old_fl_energy: f64,
}

impl Deref for BatteryMut<'_> {
    type Target = Battery;
    fn deref(&self) -> &Battery {
        &self.registry.clients[self.id].battery
    }
}

impl DerefMut for BatteryMut<'_> {
    fn deref_mut(&mut self) -> &mut Battery {
        &mut self.registry.clients[self.id].battery
    }
}

impl Drop for BatteryMut<'_> {
    fn drop(&mut self) {
        self.registry.sync_battery(self.id, self.was_alive, self.old_frac, self.old_fl_energy);
    }
}

/// Guard for stats mutation: dereferences to [`ClientStats`]; re-syncs
/// the Jain moments and pool mirrors when dropped.
pub struct StatsMut<'a> {
    registry: &'a mut Registry,
    id: usize,
    old_times_selected: u64,
}

impl Deref for StatsMut<'_> {
    type Target = ClientStats;
    fn deref(&self) -> &ClientStats {
        &self.registry.clients[self.id].stats
    }
}

impl DerefMut for StatsMut<'_> {
    fn deref_mut(&mut self) -> &mut ClientStats {
        &mut self.registry.clients[self.id].stats
    }
}

impl Drop for StatsMut<'_> {
    fn drop(&mut self) {
        self.registry.sync_stats(self.id, self.old_times_selected);
    }
}

/// Guard for link-profile mutation: dereferences to [`LinkProfile`];
/// recomputes the client's static projections when dropped.
pub struct LinkMut<'a> {
    registry: &'a mut Registry,
    id: usize,
}

impl Deref for LinkMut<'_> {
    type Target = LinkProfile;
    fn deref(&self) -> &LinkProfile {
        &self.registry.clients[self.id].link
    }
}

impl DerefMut for LinkMut<'_> {
    fn deref_mut(&mut self) -> &mut LinkProfile {
        &mut self.registry.clients[self.id].link
    }
}

impl Drop for LinkMut<'_> {
    fn drop(&mut self) {
        self.registry.refresh_projection(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SelectorKind;

    fn registry() -> Registry {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        Registry::build(&cfg, 35, 1000)
    }

    #[test]
    fn build_merges_profiles_one_to_one() {
        let r = registry();
        assert_eq!(r.len(), 40);
        assert_eq!(r.payload_bytes(), 4000);
        for (i, c) in r.clients().iter().enumerate() {
            assert_eq!(c.id, i);
            assert!(!c.shard.samples.is_empty());
            assert!(c.battery.is_alive());
        }
        assert_eq!(r.alive_count(), 40);
    }

    #[test]
    fn expected_duration_decomposes() {
        let r = registry();
        let c = r.client(0);
        let d = c.expected_duration_s(r.payload_bytes(), 5, 20);
        let manual = c.link.download_secs(r.payload_bytes())
            + c.compute_secs(5, 20)
            + c.link.upload_secs(r.payload_bytes());
        assert!((d - manual).abs() < 1e-12);
        assert!(d > 0.0);
    }

    #[test]
    fn candidates_respect_battery_floor() {
        let mut r = registry();
        // Kill half the clients.
        let cap = r.client(0).battery.capacity_joules();
        for id in 0..20 {
            r.drain_fl(id, cap * 2.0, 0.0);
        }
        let cands = r.candidates(1, 0.02, 5, 20);
        assert!(cands.len() <= 20);
        assert!(cands.iter().all(|c| c.battery_frac > 0.02));
        assert_eq!(r.dead_count(), 20);
    }

    #[test]
    fn projections_are_positive_fractions() {
        let r = registry();
        for cand in r.candidates(1, 0.0, 5, 20) {
            assert!(cand.projected_drain_frac > 0.0);
            assert!(cand.projected_drain_frac < 1.0, "one round must not eat a full battery");
            assert!((0.0..=1.0).contains(&cand.battery_frac));
        }
    }

    #[test]
    fn selection_counts_track_stats() {
        let mut r = registry();
        r.stats_mut(3).times_selected = 7;
        let counts = r.selection_counts();
        assert_eq!(counts[3], 7);
        assert_eq!(counts.iter().sum::<u64>(), 7);
        assert_eq!(r.aggregates().selected_sum, 7);
        assert_eq!(r.aggregates().selected_sum_sq, 49);
    }

    #[test]
    fn fill_candidates_matches_reference() {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        let mut r = Registry::build(&cfg, 35, 1000);
        // Perturb state: kill some, ban some, give some stats.
        let cap = r.client(0).battery.capacity_joules();
        r.drain_fl(2, cap * 2.0, 1.0);
        r.drain_fl(5, cap * 0.6, 1.0);
        r.stats_mut(7).banned_until_round = 9;
        {
            let mut s = r.stats_mut(11);
            s.stat_util = Some(42.0);
            s.measured_duration_s = Some(120.0);
            s.last_selected_round = Some(3);
            s.times_selected = 2;
        }
        let reference =
            r.candidates(4, 0.01, cfg.training.local_steps, cfg.data.batch_size);
        let mut fast = Vec::new();
        r.fill_candidates(4, 0.01, |_| true, &mut fast);
        assert_eq!(fast.len(), reference.len());
        for (a, b) in fast.iter().zip(&reference) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.stat_util, b.stat_util);
            assert_eq!(a.measured_duration_s, b.measured_duration_s);
            assert_eq!(a.expected_duration_s, b.expected_duration_s);
            assert_eq!(a.last_selected_round, b.last_selected_round);
            assert_eq!(a.battery_frac, b.battery_frac);
            assert_eq!(a.projected_drain_frac, b.projected_drain_frac);
            assert_eq!(a.round_energy_j, b.round_energy_j);
        }
        // Availability gate filters within the fast path.
        let mut gated = Vec::new();
        r.fill_candidates(4, 0.01, |id| id % 2 == 0, &mut gated);
        assert!(gated.iter().all(|c| c.id % 2 == 0));
        assert!(gated.len() < fast.len());
    }

    /// Bit-exact candidate-slice equality: ids, order, every field.
    fn assert_bit_identical(got: &[Candidate], want: &[Candidate]) {
        assert_eq!(got.len(), want.len(), "candidate counts differ");
        for (a, b) in got.iter().zip(want) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.stat_util.map(f64::to_bits), b.stat_util.map(f64::to_bits));
            assert_eq!(
                a.measured_duration_s.map(f64::to_bits),
                b.measured_duration_s.map(f64::to_bits)
            );
            assert_eq!(
                a.expected_duration_s.to_bits(),
                b.expected_duration_s.to_bits(),
                "expected_duration_s for id {}",
                a.id
            );
            assert_eq!(a.last_selected_round, b.last_selected_round);
            assert_eq!(
                a.battery_frac.to_bits(),
                b.battery_frac.to_bits(),
                "battery_frac for id {}",
                a.id
            );
            assert_eq!(a.projected_drain_frac.to_bits(), b.projected_drain_frac.to_bits());
            assert_eq!(a.round_energy_j.to_bits(), b.round_energy_j.to_bits());
        }
    }

    #[test]
    fn eligible_arena_tracks_rebuild_through_mutations() {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        let mut r = Registry::build(&cfg, 35, 1000);
        let floor = 0.01;
        let mut reference = Vec::new();

        // Round 1: the first refresh is the full build.
        r.refresh_eligible(1, floor, AvailabilityView::AlwaysOn);
        r.fill_candidates(1, floor, |_| true, &mut reference);
        assert_bit_identical(r.eligible(), &reference);

        // Round 2: deaths, partial drains, a ban, stats, a link change.
        let cap = r.client(0).battery.capacity_joules();
        r.drain_fl(2, cap * 2.0, 1.0);
        r.drain_fl(5, cap * 0.6, 1.0);
        r.stats_mut(7).banned_until_round = 4;
        {
            let mut s = r.stats_mut(11);
            s.stat_util = Some(42.0);
            s.measured_duration_s = Some(120.0);
            s.last_selected_round = Some(1);
            s.times_selected = 1;
        }
        r.link_mut(3).up_mbps *= 0.5;
        r.refresh_eligible(2, floor, AvailabilityView::AlwaysOn);
        r.fill_candidates(2, floor, |_| true, &mut reference);
        assert_bit_identical(r.eligible(), &reference);
        assert!(r.eligible().iter().all(|c| c.id != 2), "dead client evicted");
        assert!(r.eligible().iter().all(|c| c.id != 7), "banned client evicted");

        // Round 3: a lazy background epoch (participant 0 exempt) —
        // every member's drain-effective battery_frac must refresh.
        r.advance_background(&[0], 0.004, 0.01, 3.0, 3.0);
        r.refresh_eligible(3, floor, AvailabilityView::AlwaysOn);
        r.fill_candidates(3, floor, |_| true, &mut reference);
        assert_bit_identical(r.eligible(), &reference);

        // Round 4: nothing is marked dirty — the ban wheel alone must
        // re-admit client 7 exactly at its release round.
        r.refresh_eligible(4, floor, AvailabilityView::AlwaysOn);
        r.fill_candidates(4, floor, |_| true, &mut reference);
        assert_bit_identical(r.eligible(), &reference);
        assert!(r.eligible().iter().any(|c| c.id == 7), "ban released on time");

        // Round 5: revival re-admits through the battery-guard dirty path.
        r.recharge_to(2, 0.8);
        r.refresh_eligible(5, floor, AvailabilityView::AlwaysOn);
        r.fill_candidates(5, floor, |_| true, &mut reference);
        assert_bit_identical(r.eligible(), &reference);
        assert!(r.eligible().iter().any(|c| c.id == 2), "revived client re-admitted");
    }

    #[test]
    fn eligible_arena_follows_availability_change_lists() {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        let mut r = Registry::build(&cfg, 35, 1000);
        let n = r.len();
        let floor = 0.01;
        let mut bits = vec![true; n];
        let mut reference = Vec::new();

        r.refresh_eligible(1, floor, AvailabilityView::Cached { bits: &bits, changed: &[] });
        r.fill_candidates(1, floor, |id| bits[id], &mut reference);
        assert_bit_identical(r.eligible(), &reference);

        // Flip a few bits; only the change list carries the news.
        bits[4] = false;
        bits[9] = false;
        r.refresh_eligible(2, floor, AvailabilityView::Cached { bits: &bits, changed: &[4, 9] });
        r.fill_candidates(2, floor, |id| bits[id], &mut reference);
        assert_bit_identical(r.eligible(), &reference);
        assert!(r.eligible().iter().all(|c| c.id != 4 && c.id != 9));

        // Flip one back.
        bits[4] = true;
        r.refresh_eligible(3, floor, AvailabilityView::Cached { bits: &bits, changed: &[4] });
        r.fill_candidates(3, floor, |id| bits[id], &mut reference);
        assert_bit_identical(r.eligible(), &reference);
        assert!(r.eligible().iter().any(|c| c.id == 4));

        // Switching view kinds forces a rebuild (membership from the
        // cached bitmap would otherwise leak into the always-on view).
        r.refresh_eligible(4, floor, AvailabilityView::AlwaysOn);
        r.fill_candidates(4, floor, |_| true, &mut reference);
        assert_bit_identical(r.eligible(), &reference);
        assert!(r.eligible().iter().any(|c| c.id == 9));
    }

    #[test]
    fn battery_floor_boundary_is_exclusive_at_every_site() {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        let mut r = Registry::build(&cfg, 35, 1000);
        // 0.25 is a power of two: `recharge_to` computes charge =
        // capacity × 0.25 and `fraction()` divides it back out, both
        // exact in binary floating point — so the client sits on the
        // boundary *bit-for-bit*, with no epoch advance to blur it.
        let floor = 0.25;
        r.recharge_to(0, floor);
        assert_eq!(r.effective_battery_frac(0).to_bits(), floor.to_bits());

        // The convention, stated once: admission is strictly above.
        assert!(!battery_floor_admits(floor, floor));
        assert!(battery_floor_admits(f64::from_bits(floor.to_bits() + 1), floor));

        // All three sites agree at the exact boundary.
        let mut fast = Vec::new();
        r.fill_candidates(1, floor, |_| true, &mut fast);
        assert!(fast.iter().all(|c| c.id != 0), "fill_candidates excludes the boundary");
        let reference = r.candidates(1, floor, cfg.training.local_steps, cfg.data.batch_size);
        assert!(reference.iter().all(|c| c.id != 0), "candidates excludes the boundary");
        r.refresh_eligible(1, floor, AvailabilityView::AlwaysOn);
        assert!(r.eligible().iter().all(|c| c.id != 0), "arena excludes the boundary");
        assert_bit_identical(r.eligible(), &fast);

        // One ulp of charge above the floor admits at every site.
        let cap = r.client(0).battery.capacity_joules();
        r.charge_add(0, cap * 1e-9);
        r.fill_candidates(2, floor, |_| true, &mut fast);
        assert!(fast.iter().any(|c| c.id == 0));
        r.refresh_eligible(2, floor, AvailabilityView::AlwaysOn);
        assert_bit_identical(r.eligible(), &fast);
    }

    #[test]
    fn lifecycle_journal_records_flips_in_mutation_order() {
        let mut r = registry();
        let cap = r.client(0).battery.capacity_joules();
        let mut out = Vec::new();

        // Disabled by default: flips are not recorded.
        r.drain_fl(0, cap * 2.0, 1.0);
        r.drain_journal(&mut out);
        assert!(out.is_empty());

        r.set_journal(true);
        r.drain_fl(1, cap * 2.0, 2.5); // death, mid-round stamp
        r.drain_fl(2, cap * 0.25, 2.6); // drain without a flip: no entry
        r.recharge_to(0, 0.5); // revival of the pre-journal death
        r.drain_journal(&mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], LifecycleEvent::Depleted { id: 1, at_h: 2.5 });
        match out[1] {
            LifecycleEvent::Revived { id, battery_frac, .. } => {
                assert_eq!(id, 0);
                assert!((battery_frac - 0.5).abs() < 1e-12);
            }
            other => panic!("expected a revival, got {other:?}"),
        }

        // Draining leaves the journal empty; disabling clears it.
        out.clear();
        r.drain_journal(&mut out);
        assert!(out.is_empty());
        r.drain_fl(3, cap * 2.0, 3.0);
        r.set_journal(false);
        r.drain_journal(&mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn mean_battery_alive_is_zero_when_none_alive() {
        let mut r = registry();
        for id in 0..r.len() {
            let cap = r.client(id).battery.capacity_joules();
            r.drain_fl(id, cap * 2.0, 0.0);
        }
        assert_eq!(r.alive_count(), 0);
        // Documented contract: an exhausted fleet reports 0.0 usable
        // charge, not the vacuous 1.0.
        assert_eq!(r.mean_battery_alive(), 0.0);
    }

    #[test]
    fn aggregates_follow_mutations_exactly() {
        let mut r = registry();
        let cap = r.client(0).battery.capacity_joules();
        r.drain_fl(0, cap * 0.5, 1.0);
        r.drain_background(1, cap * 0.25, 1.0);
        r.charge_add(1, cap * 0.1);
        r.drain_fl(3, cap * 5.0, 2.0); // kills client 3
        r.recharge_to(3, 0.8);
        r.stats_mut(4).times_selected = 3;
        r.stats_mut(9).times_selected = 1;
        assert_eq!(*r.aggregates(), PoolAggregates::recompute(&r));
        assert_eq!(r.aggregates().selected_sum, 4);
        assert_eq!(r.aggregates().selected_sum_sq, 10);
    }

    /// Brute-force liveness predicate: effective charge above the dead
    /// threshold.
    fn effectively_alive(r: &Registry, id: usize) -> bool {
        r.client(id).battery.is_alive() && r.effective_charge_j(id) > f64::EPSILON
    }

    #[test]
    fn lazy_drain_defers_materialization_until_touch() {
        let mut r = registry();
        let raw_before: Vec<f64> =
            r.clients().iter().map(|c| c.battery.charge_joules()).collect();
        r.advance_background(&[], 0.02, 0.05, 1.5, 1.5);
        // Raw battery state is untouched; the effective view has drained.
        let mut drained = 0;
        for id in 0..r.len() {
            assert_eq!(r.client(id).battery.charge_joules(), raw_before[id]);
            if r.client(id).battery.is_alive()
                && r.effective_charge_j(id) < raw_before[id]
            {
                drained += 1;
            }
        }
        assert!(drained > 0, "someone must have accrued drain");
        // Settling materializes exactly the effective bits, and the
        // aggregates stay equal to a brute-force rebuild.
        let effective: Vec<f64> = (0..r.len()).map(|id| r.effective_charge_j(id)).collect();
        r.settle_all();
        for id in 0..r.len() {
            assert_eq!(r.client(id).battery.charge_joules(), effective[id], "id {id}");
        }
        assert_eq!(*r.aggregates(), PoolAggregates::recompute(&r));
        // Settling is idempotent.
        let booked: Vec<f64> =
            r.clients().iter().map(|c| c.battery.background_energy_j).collect();
        r.settle_all();
        for id in 0..r.len() {
            assert_eq!(r.client(id).battery.charge_joules(), effective[id]);
            assert_eq!(r.client(id).battery.background_energy_j, booked[id]);
        }
    }

    #[test]
    fn wheel_kills_exactly_the_effectively_dead_at_epoch_end() {
        let mut r = registry();
        // Pull everyone to assorted low levels so deaths stagger.
        for id in 0..r.len() {
            let target = 0.002 + 0.004 * (id as f64 / r.len() as f64);
            r.recharge_to(id, target);
        }
        let mut clock = 0.0;
        for epoch in 1..=40u64 {
            clock += 0.25;
            r.advance_background(&[], 0.01, 0.02, 0.25, clock);
            for id in 0..r.len() {
                let alive = r.client(id).battery.is_alive();
                // After an advance, every alive client is effectively
                // alive and every effectively-dead client has been
                // killed and stamped at this epoch's end clock.
                assert_eq!(
                    alive,
                    effectively_alive(&r, id),
                    "epoch {epoch} id {id}: wheel missed a death or over-killed"
                );
                if !alive {
                    let died = r.client(id).battery.died_at_h.expect("stamped");
                    assert!(died > 0.0 && died <= clock + 1e-12);
                    let epochs = died / 0.25;
                    assert!((epochs.round() - epochs).abs() < 1e-9, "end-of-epoch stamp");
                    assert_eq!(r.client(id).battery.charge_joules(), 0.0);
                }
            }
            assert_eq!(*r.aggregates(), PoolAggregates::recompute(&r));
        }
        assert_eq!(r.alive_count(), 0, "everyone drains out eventually");
    }

    #[test]
    fn participants_are_exempt_from_epoch_drain() {
        let mut r = registry();
        let participant = 3usize;
        let bystander = 4usize;
        let eff_p = r.effective_charge_j(participant);
        let eff_b = r.effective_charge_j(bystander);
        r.advance_background(&[participant], 0.03, 0.03, 1.0, 1.0);
        assert_eq!(
            r.effective_charge_j(participant),
            eff_p,
            "participant must not absorb the epoch's background drain"
        );
        assert!(r.effective_charge_j(bystander) < eff_b, "bystander drains");
        // Next epoch the participant drains again like everyone else.
        r.advance_background(&[], 0.03, 0.03, 1.0, 2.0);
        assert!(r.effective_charge_j(participant) < eff_p);
    }

    #[test]
    fn liveness_indices_track_membership() {
        let mut r = registry();
        assert!(r.pool().dead.is_empty());
        let cap = r.client(6).battery.capacity_joules();
        r.drain_fl(6, cap * 2.0, 1.0);
        assert!(r.pool().dead.contains(6));
        assert!(r.pool().below_capacity.contains(6), "dead ⇒ below capacity");
        r.recharge_to(6, 1.0);
        assert!(!r.pool().dead.contains(6));
        assert!(!r.pool().below_capacity.contains(6), "recharged to exactly full");
        r.drain_background(6, cap * 0.1, 2.0);
        assert!(r.pool().below_capacity.contains(6));
        assert!(!r.pool().dead.contains(6));
        // A wheel kill lands in the dead set too.
        r.recharge_to(7, 0.001);
        let mut clock = 0.0;
        while r.client(7).battery.is_alive() {
            clock += 1.0;
            r.advance_background(&[], 0.01, 0.01, 1.0, clock);
            assert!(clock < 100.0, "client 7 must die from background drain");
        }
        assert!(r.pool().dead.contains(7));
    }

    #[test]
    fn closed_form_mean_matches_effective_scan() {
        let mut r = registry();
        let mut clock = 0.0;
        for step in 1..=10u64 {
            clock += 0.5;
            r.advance_background(&[(step as usize) % r.len()], 0.015, 0.04, 0.5, clock);
            if step % 3 == 0 {
                let id = (step as usize * 7) % r.len();
                let cap = r.client(id).battery.capacity_joules();
                r.charge_add(id, cap * 0.05);
            }
            let alive = (0..r.len()).filter(|&id| r.client(id).battery.is_alive()).count();
            if alive == 0 {
                break;
            }
            let scan: f64 = (0..r.len())
                .filter(|&id| r.client(id).battery.is_alive())
                .map(|id| r.effective_battery_frac(id))
                .sum::<f64>()
                / alive as f64;
            assert!(
                (r.mean_battery_alive() - scan).abs() < 1e-6,
                "step {step}: closed-form mean {} vs scan {scan}",
                r.mean_battery_alive()
            );
        }
    }

    #[test]
    fn link_mut_refreshes_projection() {
        let mut r = registry();
        let before = r.pool().expected_duration_s[5];
        {
            let mut link = r.link_mut(5);
            link.down_mbps *= 0.5;
            link.up_mbps *= 0.5;
        }
        let after = r.pool().expected_duration_s[5];
        assert!(after > before, "halved bandwidth must lengthen the projection");
        // And the pool matches a fresh reference projection.
        let cands = r.candidates(1, 0.0, r.local_steps, r.batch);
        let c5 = cands.iter().find(|c| c.id == 5).unwrap();
        assert_eq!(c5.expected_duration_s, after);
    }
}
