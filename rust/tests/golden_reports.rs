//! Golden-report tier: `eafl run` summary.json bytes for all four
//! scenario presets at a fixed seed, pinned under `rust/tests/golden/`.
//!
//! The point is drift detection: a refactor that changes any simulated
//! number — battery accounting, selection order, RNG stream, JSON
//! formatting — shows up here as a byte diff against the committed
//! golden, instead of silently shifting the paper's reproduced figures.
//!
//! Bless protocol: when a golden file does not exist yet (or
//! `EAFL_BLESS=1` is set after an *intentional* behavior change), the
//! test writes the file and passes; commit the new goldens with the
//! change that explains them. Every test run — blessing or not — still
//! proves worker-count invariance by producing each report twice, at
//! `EAFL_WORKERS=1` and `EAFL_WORKERS=7`, and requiring identical bytes.

use std::path::{Path, PathBuf};
use std::process::Command;

const BIN: &str = env!("CARGO_BIN_EXE_eafl");
const PRESETS: [&str; 4] = ["steady", "diurnal", "commuter", "solar-edge"];

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust").join("tests").join("golden")
}

/// One fixed-seed `eafl run` for a preset; returns the summary bytes.
fn run_summary(preset: &str, workers: &str, out: &Path) -> String {
    let _ = std::fs::remove_dir_all(out);
    std::fs::create_dir_all(out).unwrap();
    let output = Command::new(BIN)
        .args([
            "run",
            "--mock",
            "--selector",
            "eafl",
            "--scenario",
            preset,
            "--rounds",
            "12",
            "--clients",
            "16",
        ])
        .arg("--out")
        .arg(out)
        .env("EAFL_WORKERS", workers)
        .output()
        .expect("spawning eafl run");
    assert!(
        output.status.success(),
        "eafl run --scenario {preset} failed:\n{}",
        String::from_utf8_lossy(&output.stderr)
    );
    std::fs::read_to_string(out.join("run-eafl.summary.json"))
        .expect("run must write run-eafl.summary.json")
}

#[test]
fn run_summary_bytes_are_pinned_for_every_preset() {
    let scratch = std::env::temp_dir().join(format!("eafl-golden-{}", std::process::id()));
    let bless = std::env::var("EAFL_BLESS").map_or(false, |v| v == "1");
    std::fs::create_dir_all(golden_dir()).unwrap();
    let mut blessed = Vec::new();
    for preset in PRESETS {
        let produced = run_summary(preset, "1", &scratch.join(preset));
        // Worker-count invariance is part of the pin: the same bytes
        // must come out of a differently-threaded process.
        let reproduced = run_summary(preset, "7", &scratch.join(format!("{preset}-w7")));
        assert_eq!(
            produced, reproduced,
            "{preset}: summary bytes differ between EAFL_WORKERS=1 and =7"
        );

        let golden_path = golden_dir().join(format!("run-{preset}.summary.json"));
        if bless || !golden_path.exists() {
            std::fs::write(&golden_path, &produced).unwrap();
            blessed.push(golden_path);
            continue;
        }
        let golden = std::fs::read_to_string(&golden_path).unwrap();
        assert_eq!(
            produced,
            golden,
            "{preset}: `eafl run` summary drifted from {}.\nIf this change is \
             intentional, re-bless with EAFL_BLESS=1 and commit the new golden \
             alongside the change that explains it.",
            golden_path.display()
        );
    }
    for path in &blessed {
        eprintln!(
            "[golden] blessed {} — commit it so future runs enforce these bytes",
            path.display()
        );
    }
    let _ = std::fs::remove_dir_all(&scratch);
}

/// The presets must actually pin *different* trajectories — if two
/// scenario presets produced byte-identical summaries the golden tier
/// would be pinning less than it claims.
#[test]
fn presets_produce_distinct_summaries() {
    let scratch =
        std::env::temp_dir().join(format!("eafl-golden-distinct-{}", std::process::id()));
    let steady = run_summary("steady", "1", &scratch.join("steady"));
    let diurnal = run_summary("diurnal", "1", &scratch.join("diurnal"));
    assert_ne!(
        steady, diurnal,
        "steady and diurnal presets must not produce identical summaries"
    );
    let _ = std::fs::remove_dir_all(&scratch);
}
