//! Scenario subsystem acceptance: environment models must be
//! seed-deterministic (byte-identical campaign reports at any worker /
//! job count), must actually differentiate environments (diurnal ≠
//! steady under the same seed), must never panic when availability
//! empties a round, and must make partial campaigns resumable.

use eafl::campaign::{run_campaign, CampaignGrid, CampaignSpec};
use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::metrics::MetricsLog;
use eafl::runtime::MockRuntime;

fn tiny_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.federation.rounds = 6;
    cfg.federation.num_clients = 16;
    cfg.federation.participants_per_round = 4;
    cfg.federation.eval_interval = 3;
    cfg.data.min_samples = 5;
    cfg.data.max_samples = 15;
    cfg.data.test_samples = 256;
    cfg
}

fn all_scenario_spec(workers_per_run: usize, jobs: usize) -> CampaignSpec {
    let mut spec = CampaignSpec::new("scn", tiny_base());
    spec.grid = CampaignGrid {
        selectors: vec![SelectorKind::Random, SelectorKind::Eafl],
        scenarios: vec![
            "steady".into(),
            "diurnal".into(),
            "commuter".into(),
            "solar-edge".into(),
        ],
        seeds: vec![1, 2],
        f_values: Vec::new(),
        client_counts: Vec::new(),
        budgets: Vec::new(),
    };
    spec.jobs = jobs;
    spec.workers_per_run = workers_per_run;
    spec
}

/// Same seed + scenario name ⇒ byte-identical campaign report whether
/// each experiment trains on 1 worker thread or 8, and whatever the
/// campaign job count — scenarios must not break the engine's
/// worker-count invariance.
#[test]
fn campaign_reports_byte_identical_across_worker_and_job_counts() {
    let runtime = MockRuntime::default();
    let a = run_campaign(&all_scenario_spec(1, 1), &runtime, None).unwrap();
    let b = run_campaign(&all_scenario_spec(8, 4), &runtime, None).unwrap();
    assert_eq!(a.runs.len(), 2 * 4 * 2, "selectors x scenarios x seeds");
    assert_eq!(a.to_csv(), b.to_csv(), "scenario campaigns must be worker-invariant");
    assert_eq!(a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
}

fn battery_tight(scenario: &str, seed: u64) -> MetricsLog {
    let runtime = MockRuntime::default();
    let mut cfg = tiny_base();
    cfg.name = format!("dd-{scenario}-{seed}");
    cfg.scenario = scenario.to_string();
    cfg.federation.rounds = 40;
    cfg.federation.num_clients = 24;
    cfg.federation.participants_per_round = 8;
    cfg.devices.min_init_battery = 0.08;
    cfg.devices.max_init_battery = 0.35;
    cfg.devices.busy_drain_per_hour = 0.08;
    cfg.data.seed = seed;
    cfg.devices.seed = seed.wrapping_mul(31).wrapping_add(7);
    Coordinator::new(cfg, &runtime).unwrap().run().unwrap()
}

/// The environment axis must have teeth: under the same seeds, the
/// diurnal scenario produces a different trajectory — and a different
/// drop-out count — than steady.
#[test]
fn diurnal_differs_from_steady_under_the_same_seed() {
    let mut any_dropout_diff = false;
    for seed in [1u64, 2, 3] {
        let steady = battery_tight("steady", seed);
        let diurnal = battery_tight("diurnal", seed);
        assert_ne!(
            steady.to_csv(),
            diurnal.to_csv(),
            "seed {seed}: availability gating must change the round series"
        );
        // And reruns of the same scenario reproduce exactly.
        assert_eq!(steady.to_csv(), battery_tight("steady", seed).to_csv());
        assert_eq!(diurnal.to_csv(), battery_tight("diurnal", seed).to_csv());
        any_dropout_diff |=
            steady.summary().total_dropouts != diurnal.summary().total_dropouts;
    }
    assert!(
        any_dropout_diff,
        "diurnal must change the drop-out count for at least one seed"
    );
}

/// Edge case from the issue: a scenario whose availability admits
/// nobody at round start. The engine must skip such rounds (selected =
/// 0, not committed, clock still advances) — never panic.
#[test]
fn zero_eligible_round_is_skipped_not_a_panic() {
    let dir = std::env::temp_dir().join(format!("eafl-blackout-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("blackout.toml");
    std::fs::write(
        &path,
        "name = \"blackout\"\n\
         [availability]\n\
         kind = \"diurnal\"\n\
         min_available = 0\n\
         max_available = 0\n",
    )
    .unwrap();

    let runtime = MockRuntime::default();
    let mut cfg = tiny_base();
    cfg.federation.rounds = 3;
    cfg.scenario = path.to_string_lossy().to_string();
    let log = Coordinator::new(cfg, &runtime).unwrap().run().unwrap();
    assert_eq!(log.records.len(), 3, "rounds still elapse");
    let mut last_wall = 0.0;
    for r in &log.records {
        assert_eq!(r.selected, 0, "nobody is available, nobody is selected");
        assert_eq!(r.completed, 0);
        assert!(!r.committed);
        assert!(r.wall_clock_h > last_wall, "the clock must keep advancing");
        last_wall = r.wall_clock_h;
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A reviving recharge policy must keep an all-dead fleet simulating:
/// empty rounds elapse until the charging window arrives and brings
/// devices back, instead of the server stopping the experiment early.
#[test]
fn reviving_policy_keeps_an_all_dead_fleet_running() {
    let dir = std::env::temp_dir().join(format!("eafl-revive-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plugged-in.toml");
    std::fs::write(
        &path,
        "name = \"plugged-in\"\n\
         [recharge]\n\
         kind = \"overnight\"\n\
         start_hour = 1\n\
         end_hour = 23\n\
         rate_frac_per_h = 0.3\n",
    )
    .unwrap();

    let runtime = MockRuntime::default();
    let mut cfg = tiny_base();
    cfg.scenario = path.to_string_lossy().to_string();
    cfg.selector.kind = SelectorKind::Random;
    cfg.selector.min_battery_frac = 0.0;
    // Empty rounds advance by the 5-minute re-poll wait, so 60 rounds
    // comfortably cover death (well before 1:00 sim time) plus the
    // wait until the charging window opens.
    cfg.federation.rounds = 60;
    // Brutal background drain: the whole fleet dies within the first
    // simulated hour, before the 1:00 charging window opens.
    cfg.devices.min_init_battery = 0.02;
    cfg.devices.max_init_battery = 0.04;
    cfg.devices.busy_drain_per_hour = 5.0;
    cfg.devices.busy_probability = 1.0;
    let log = Coordinator::new(cfg, &runtime).unwrap().run().unwrap();

    assert_eq!(log.records.len(), 60, "a reviving policy must not stop the run early");
    assert!(
        log.records.iter().any(|r| r.alive_fraction == 0.0),
        "the fleet should have fully died before the window opened"
    );
    assert!(
        log.records.last().unwrap().alive_fraction > 0.0,
        "the charging window must have revived the fleet by the end"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Campaign resume: a partial campaign in the output directory is
/// continued, not recomputed — completed grid cells are reloaded from
/// their summaries and the final merged report is byte-identical to a
/// from-scratch run of the full grid.
#[test]
fn resume_skips_completed_cells_and_reproduces_the_report() {
    let dir = std::env::temp_dir().join(format!("eafl-resume-{}", std::process::id()));
    let fresh_dir =
        std::env::temp_dir().join(format!("eafl-resume-fresh-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&fresh_dir).ok();
    let runtime = MockRuntime::default();

    // First, a partial campaign: one seed only.
    let mut partial = all_scenario_spec(1, 2);
    partial.grid.scenarios = vec!["steady".into(), "diurnal".into()];
    partial.grid.seeds = vec![1];
    run_campaign(&partial, &runtime, Some(&dir)).unwrap();

    // Now the full grid into the same directory: the seed-1 cells must
    // be reloaded (their summary files already exist), the seed-2 cells
    // computed fresh.
    let mut full = partial.clone();
    full.grid.seeds = vec![1, 2];
    let resumed = run_campaign(&full, &runtime, Some(&dir)).unwrap();

    // Reference: the same full grid in a clean directory.
    let scratch = run_campaign(&full, &runtime, Some(&fresh_dir)).unwrap();
    assert_eq!(resumed.to_csv(), scratch.to_csv(), "resume must not change results");
    assert_eq!(
        resumed.to_json().to_string_pretty(),
        scratch.to_json().to_string_pretty()
    );

    // And a second rerun over the now-complete directory recomputes
    // nothing at all (every cell cached) yet still writes the same
    // merged report.
    let rerun = run_campaign(&full, &runtime, Some(&dir)).unwrap();
    assert_eq!(rerun.to_csv(), scratch.to_csv());

    // --fresh semantics: resume off recomputes and still matches.
    let mut fresh = full.clone();
    fresh.resume = false;
    let recomputed = run_campaign(&fresh, &runtime, Some(&dir)).unwrap();
    assert_eq!(recomputed.to_csv(), scratch.to_csv());

    // A different --rounds into the same directory must NOT reuse the
    // old summaries: cell names match but the round count disagrees.
    let mut shorter = full.clone();
    shorter.base.federation.rounds = 4;
    let short = run_campaign(&shorter, &runtime, Some(&dir)).unwrap();
    assert!(
        short.runs.iter().all(|r| r.summary.rounds == 4),
        "stale summaries with a different round count were reused"
    );

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&fresh_dir).ok();
}
