//! Layer-3 coordinator — the FL server loop that is the paper's system
//! surface, structured as a staged round engine:
//!
//!  - [`engine`] — the six explicit phases of a round (plan → simulate
//!    → execute → commit → feedback → record) with typed IO; the
//!    execution phase trains clients in parallel.
//!  - [`accounting`](self) — battery drain + pluggable recharge policy.
//!  - [`Registry`] — per-client device/link/battery/shard state, with
//!    the SoA [`ClientPool`] projection cache and the incrementally
//!    maintained [`PoolAggregates`] that make the non-training round
//!    path allocation-free and O(selected) (see the crate docs' "fast
//!    path" section).
//!  - [`Coordinator`] — owns the experiment state and drives the
//!    phases round by round.

mod accounting;
mod engine;
mod registry;
mod server;

pub use accounting::{
    eager_drain_forced, rebuild_candidates_forced, recharge_policy_from, BatteryAccounting,
    CooldownRecharge, NoRecharge, RechargePolicy,
};
pub use engine::{
    quorum_required, CommitDecision, CommitPhase, EnergyLedger, ExecPhase, ExecutionOutcome,
    FeedbackPhase, PlanPhase, RecordPhase, RoundPlan, SimPhase, SimulatedRound,
};
pub use registry::{
    AvailabilityView, BatteryMut, ClientPool, ClientState, ClientStats, LifecycleEvent, LinkMut,
    PoolAggregates, Registry, StatsMut,
};
pub use server::Coordinator;
