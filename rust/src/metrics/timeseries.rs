//! Per-round experiment time series — one [`RoundRecord`] per committed
//! (or failed) round, CSV/JSON emission, and end-of-run [`Summary`].
//! These series ARE the paper's figures: accuracy (3a), train loss
//! (3b), fairness (3c), cumulative drop-outs (4a), round duration (4b).

use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One row of the experiment time series.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: u64,
    /// Simulated wall-clock at round end, hours.
    pub wall_clock_h: f64,
    /// Round duration, seconds.
    pub round_duration_s: f64,
    /// Clients selected / completed / dropped (battery death mid-round)
    /// / deadline-missed this round.
    pub selected: usize,
    pub completed: usize,
    pub dropped: usize,
    pub deadline_missed: usize,
    /// Whether enough clients reported for the round to commit.
    pub committed: bool,
    /// Mean training loss over completing clients (NaN if none).
    pub train_loss: f64,
    /// Latest test accuracy in [0,1] (carried between eval points).
    pub test_accuracy: f64,
    /// Latest test loss (carried between eval points).
    pub test_loss: f64,
    /// Jain's fairness index over all clients' selection counts.
    pub fairness: f64,
    /// Cumulative clients whose battery has died (drop-outs, Fig. 4a).
    pub cumulative_dead: usize,
    /// Fraction of the population still alive.
    pub alive_fraction: f64,
    /// Mean battery fraction over alive clients.
    pub mean_battery: f64,
    /// Total FL energy spent so far across the population, joules.
    pub total_fl_energy_j: f64,
}

/// End-of-run summary (what the paper quotes in headline numbers).
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub rounds: u64,
    pub wall_clock_h: f64,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub final_train_loss: f64,
    pub final_fairness: f64,
    pub total_dropouts: usize,
    pub total_fl_energy_j: f64,
    pub mean_round_duration_s: f64,
    pub committed_rounds: u64,
    pub failed_rounds: u64,
}

impl Summary {
    /// JSON via the in-tree codec (offline build — no serde).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("wall_clock_h".into(), Json::Num(self.wall_clock_h));
        m.insert("final_accuracy".into(), Json::Num(self.final_accuracy));
        m.insert("best_accuracy".into(), Json::Num(self.best_accuracy));
        m.insert(
            "final_train_loss".into(),
            if self.final_train_loss.is_finite() {
                Json::Num(self.final_train_loss)
            } else {
                Json::Null
            },
        );
        m.insert("final_fairness".into(), Json::Num(self.final_fairness));
        m.insert("total_dropouts".into(), Json::Num(self.total_dropouts as f64));
        m.insert("total_fl_energy_j".into(), Json::Num(self.total_fl_energy_j));
        m.insert("mean_round_duration_s".into(), Json::Num(self.mean_round_duration_s));
        m.insert("committed_rounds".into(), Json::Num(self.committed_rounds as f64));
        m.insert("failed_rounds".into(), Json::Num(self.failed_rounds as f64));
        Json::Obj(m)
    }

    /// Parse a summary back from its JSON — the inverse of
    /// [`Summary::to_json`], used by campaign resume to treat a partial
    /// campaign.json / per-run summary.json as already-done grid cells.
    /// `final_train_loss: null` maps back to NaN.
    pub fn from_json(j: &Json) -> Result<Self> {
        fn num(j: &Json, key: &str) -> Result<f64> {
            j.field(key)?
                .as_f64()
                .ok_or_else(|| anyhow!("summary field {key:?} is not a number"))
        }
        Ok(Self {
            name: j
                .field("name")?
                .as_str()
                .ok_or_else(|| anyhow!("summary name is not a string"))?
                .to_string(),
            rounds: num(j, "rounds")? as u64,
            wall_clock_h: num(j, "wall_clock_h")?,
            final_accuracy: num(j, "final_accuracy")?,
            best_accuracy: num(j, "best_accuracy")?,
            final_train_loss: match j.field("final_train_loss")? {
                Json::Null => f64::NAN,
                v => v
                    .as_f64()
                    .ok_or_else(|| anyhow!("final_train_loss is not a number"))?,
            },
            final_fairness: num(j, "final_fairness")?,
            total_dropouts: num(j, "total_dropouts")? as usize,
            total_fl_energy_j: num(j, "total_fl_energy_j")?,
            mean_round_duration_s: num(j, "mean_round_duration_s")?,
            committed_rounds: num(j, "committed_rounds")? as u64,
            failed_rounds: num(j, "failed_rounds")? as u64,
        })
    }
}

/// Accumulating experiment log.
#[derive(Debug, Clone, Default)]
pub struct MetricsLog {
    pub name: String,
    pub records: Vec<RoundRecord>,
}

impl MetricsLog {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), records: Vec::new() }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.records.push(rec);
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.records.last()
    }

    /// CSV with a fixed header (one column per RoundRecord field).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,wall_clock_h,round_duration_s,selected,completed,dropped,\
             deadline_missed,committed,train_loss,test_accuracy,test_loss,\
             fairness,cumulative_dead,alive_fraction,mean_battery,total_fl_energy_j\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6},{:.3},{},{},{},{},{},{:.6},{:.6},{:.6},{:.6},{},{:.6},{:.6},{:.3}\n",
                r.round,
                r.wall_clock_h,
                r.round_duration_s,
                r.selected,
                r.completed,
                r.dropped,
                r.deadline_missed,
                r.committed,
                r.train_loss,
                r.test_accuracy,
                r.test_loss,
                r.fairness,
                r.cumulative_dead,
                r.alive_fraction,
                r.mean_battery,
                r.total_fl_energy_j,
            ));
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
        f.write_all(self.to_csv().as_bytes()).context("writing csv")?;
        Ok(())
    }

    /// Compute the end-of-run summary.
    pub fn summary(&self) -> Summary {
        let last = self.records.last();
        let committed = self.records.iter().filter(|r| r.committed).count() as u64;
        let durations: Vec<f64> = self.records.iter().map(|r| r.round_duration_s).collect();
        Summary {
            name: self.name.clone(),
            rounds: self.records.len() as u64,
            wall_clock_h: last.map_or(0.0, |r| r.wall_clock_h),
            final_accuracy: last.map_or(0.0, |r| r.test_accuracy),
            best_accuracy: self
                .records
                .iter()
                .map(|r| r.test_accuracy)
                .fold(0.0, f64::max),
            final_train_loss: last.map_or(f64::NAN, |r| r.train_loss),
            final_fairness: last.map_or(1.0, |r| r.fairness),
            total_dropouts: last.map_or(0, |r| r.cumulative_dead),
            total_fl_energy_j: last.map_or(0.0, |r| r.total_fl_energy_j),
            mean_round_duration_s: if durations.is_empty() {
                0.0
            } else {
                durations.iter().sum::<f64>() / durations.len() as f64
            },
            committed_rounds: committed,
            failed_rounds: self.records.len() as u64 - committed,
        }
    }

    pub fn write_summary_json(&self, path: &Path) -> Result<()> {
        let text = self.summary().to_json().to_string_pretty();
        std::fs::write(path, text).with_context(|| format!("writing {path:?}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: u64, acc: f64, committed: bool) -> RoundRecord {
        RoundRecord {
            round,
            wall_clock_h: round as f64 * 0.1,
            round_duration_s: 100.0 + round as f64,
            selected: 10,
            completed: 8,
            dropped: 1,
            deadline_missed: 1,
            committed,
            train_loss: 2.0 / (round + 1) as f64,
            test_accuracy: acc,
            test_loss: 1.0,
            fairness: 0.9,
            cumulative_dead: round as usize,
            alive_fraction: 0.95,
            mean_battery: 0.6,
            total_fl_energy_j: 1000.0 * round as f64,
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = MetricsLog::new("t");
        log.push(rec(1, 0.1, true));
        log.push(rec(2, 0.2, false));
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("round,"));
        assert!(csv.lines().nth(2).unwrap().contains("false"));
    }

    #[test]
    fn summary_aggregates() {
        let mut log = MetricsLog::new("exp");
        log.push(rec(1, 0.3, true));
        log.push(rec(2, 0.5, true));
        log.push(rec(3, 0.4, false));
        let s = log.summary();
        assert_eq!(s.rounds, 3);
        assert_eq!(s.best_accuracy, 0.5);
        assert_eq!(s.final_accuracy, 0.4);
        assert_eq!(s.committed_rounds, 2);
        assert_eq!(s.failed_rounds, 1);
        assert_eq!(s.total_dropouts, 3);
    }

    #[test]
    fn empty_log_summary_is_sane() {
        let s = MetricsLog::new("empty").summary();
        assert_eq!(s.rounds, 0);
        assert_eq!(s.final_accuracy, 0.0);
        assert_eq!(s.mean_round_duration_s, 0.0);
    }

    #[test]
    fn summary_json_roundtrips_exactly() {
        let mut log = MetricsLog::new("rt");
        log.push(rec(1, 0.123456789, true));
        log.push(rec(2, 0.5, false));
        let s = log.summary();
        let back = Summary::from_json(&s.to_json()).unwrap();
        assert_eq!(back.name, s.name);
        assert_eq!(back.rounds, s.rounds);
        assert_eq!(back.wall_clock_h, s.wall_clock_h, "f64s survive bit-exactly");
        assert_eq!(back.final_accuracy, s.final_accuracy);
        assert_eq!(back.best_accuracy, s.best_accuracy);
        assert_eq!(back.final_train_loss, s.final_train_loss);
        assert_eq!(back.final_fairness, s.final_fairness);
        assert_eq!(back.total_dropouts, s.total_dropouts);
        assert_eq!(back.total_fl_energy_j, s.total_fl_energy_j);
        assert_eq!(back.mean_round_duration_s, s.mean_round_duration_s);
        assert_eq!(back.committed_rounds, s.committed_rounds);
        assert_eq!(back.failed_rounds, s.failed_rounds);

        // NaN train loss goes through the null encoding.
        let empty = MetricsLog::new("nan").summary();
        assert!(empty.final_train_loss.is_nan());
        let back = Summary::from_json(&empty.to_json()).unwrap();
        assert!(back.final_train_loss.is_nan());

        // And the re-emitted JSON text is byte-identical (resume writes
        // merged reports from parsed summaries).
        assert_eq!(
            Summary::from_json(&s.to_json()).unwrap().to_json().to_string_pretty(),
            s.to_json().to_string_pretty()
        );
    }

    #[test]
    fn csv_roundtrip_file() {
        let dir = std::env::temp_dir().join(format!("eafl-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut log = MetricsLog::new("t");
        log.push(rec(1, 0.1, true));
        let p = dir.join("out.csv");
        log.write_csv(&p).unwrap();
        assert!(std::fs::read_to_string(&p).unwrap().contains("0.100000"));
        log.write_summary_json(&dir.join("s.json")).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(dir.join("s.json")).unwrap()).unwrap();
        assert_eq!(parsed.field("rounds").unwrap().as_usize(), Some(1));
        std::fs::remove_dir_all(&dir).ok();
    }
}
