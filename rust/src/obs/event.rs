//! The typed round-event taxonomy and its JSONL wire form.
//!
//! Events carry only sim-time / seed-pure data: round indices, client
//! ids, simulated clocks (hours), joules, accuracies. Nothing here may
//! depend on wall time, worker count, shard split, or drain mode —
//! that is what makes trace files byte-comparable across every
//! determinism tier (wall-time measurements live in the separate
//! [`profile`](super::profile) channel instead).
//!
//! Wire form: one compact JSON object per line, keys in lexicographic
//! (BTreeMap) order, with a `"ev"` discriminant. Floats that can
//! legitimately be NaN (a failed round's train loss) are encoded as
//! `null`; every other float field is finite by construction.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Why a selected client failed to deliver an update this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropCause {
    /// Missed the round deadline (straggler).
    Deadline,
    /// Battery hit zero mid-round.
    Death,
    /// Went offline mid-round. Batch simulation never produces this
    /// (availability is sampled at plan time), but `eafl serve` clients
    /// can disappear between check-ins, so the taxonomy reserves it.
    Unavailable,
}

impl DropCause {
    pub fn as_str(&self) -> &'static str {
        match self {
            DropCause::Deadline => "deadline",
            DropCause::Death => "death",
            DropCause::Unavailable => "unavailable",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "deadline" => Ok(DropCause::Deadline),
            "death" => Ok(DropCause::Death),
            "unavailable" => Ok(DropCause::Unavailable),
            other => bail!("unknown drop cause {other:?}"),
        }
    }
}

/// One deterministic trace event. See the module docs for the purity
/// contract and `ROADMAP.md` ("Observability") for the taxonomy table.
#[derive(Debug, Clone, PartialEq)]
pub enum RoundEvent {
    /// Emitted once when a sink is attached to a coordinator:
    /// identifies the experiment the following events belong to.
    RunStarted {
        name: String,
        selector: String,
        scenario: String,
        clients: usize,
        rounds: usize,
        seed: u64,
    },
    /// Campaign-cell coordinates; written (before `RunStarted`) at the
    /// head of each per-cell trace of a `sweep --trace DIR`.
    CampaignCell {
        cell: String,
        selector: String,
        scenario: String,
        seed: u64,
        f: f64,
        clients: usize,
    },
    /// Plan phase: how many clients were eligible, how many were
    /// picked, and the reporting deadline the round will enforce.
    RoundPlanned { round: u64, clock_h: f64, eligible: usize, selected: usize, deadline_s: f64 },
    /// One per selected client, in selection order. `score` is the
    /// selector-visible statistical utility (0 before first feedback);
    /// `battery_frac` is the drain-effective fraction the plan saw.
    ClientSelected { round: u64, id: usize, score: f64, battery_frac: f64 },
    /// A selected client delivered its update: simulated active
    /// seconds and joules spent.
    ClientReported { round: u64, id: usize, duration_s: f64, energy_j: f64 },
    /// A selected client failed to deliver. `at_h` is the simulated
    /// clock at which it stopped; `energy_j` is what it burned anyway.
    ClientDropped { round: u64, id: usize, cause: DropCause, at_h: f64, energy_j: f64 },
    /// Battery reached zero — from FL drain or the background death
    /// wheel; `at_h` is the exact simulated expiry stamp (identical in
    /// lazy and eager drain modes).
    BatteryDepleted { id: usize, at_h: f64 },
    /// A dead client came back above zero through a recharge policy.
    BatteryRevived { id: usize, at_h: f64, battery_frac: f64 },
    /// Round epilogue, mirroring the metrics row: quorum outcome,
    /// carried eval accuracy, mean train loss (`null` when no client
    /// completed), cumulative FL energy, and the advanced clock.
    RoundCommitted {
        round: u64,
        committed: bool,
        completed: usize,
        accuracy: f64,
        train_loss: f64,
        energy_j: f64,
        wall_clock_h: f64,
        /// Joules left in the campaign energy budget after this round's
        /// reconciliation; NaN (`null` on the wire) when no budget is
        /// configured.
        budget_remaining_j: f64,
    },
    /// Terminal: the campaign energy budget can fund no further round.
    /// The run stops after this event (`spent_j` is the reconciled
    /// actual spend, which stays <= `budget_j` under static networks).
    BudgetExhausted { round: u64, budget_j: f64, spent_j: f64 },
}

fn num_field(m: &mut BTreeMap<String, Json>, k: &str, v: f64) {
    // The in-tree writer prints non-finite floats as bare words, which
    // is not JSON — encode them as null (only train_loss can hit this).
    m.insert(k.to_string(), if v.is_finite() { Json::Num(v) } else { Json::Null });
}

fn str_field(m: &mut BTreeMap<String, Json>, k: &str, v: &str) {
    m.insert(k.to_string(), Json::Str(v.to_string()));
}

impl RoundEvent {
    /// The `"ev"` discriminant used on the wire.
    pub fn kind(&self) -> &'static str {
        match self {
            RoundEvent::RunStarted { .. } => "run_started",
            RoundEvent::CampaignCell { .. } => "campaign_cell",
            RoundEvent::RoundPlanned { .. } => "round_planned",
            RoundEvent::ClientSelected { .. } => "client_selected",
            RoundEvent::ClientReported { .. } => "client_reported",
            RoundEvent::ClientDropped { .. } => "client_dropped",
            RoundEvent::BatteryDepleted { .. } => "battery_depleted",
            RoundEvent::BatteryRevived { .. } => "battery_revived",
            RoundEvent::RoundCommitted { .. } => "round_committed",
            RoundEvent::BudgetExhausted { .. } => "budget_exhausted",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        str_field(&mut m, "ev", self.kind());
        match self {
            RoundEvent::RunStarted { name, selector, scenario, clients, rounds, seed } => {
                str_field(&mut m, "name", name);
                str_field(&mut m, "selector", selector);
                str_field(&mut m, "scenario", scenario);
                num_field(&mut m, "clients", *clients as f64);
                num_field(&mut m, "rounds", *rounds as f64);
                num_field(&mut m, "seed", *seed as f64);
            }
            RoundEvent::CampaignCell { cell, selector, scenario, seed, f, clients } => {
                str_field(&mut m, "cell", cell);
                str_field(&mut m, "selector", selector);
                str_field(&mut m, "scenario", scenario);
                num_field(&mut m, "seed", *seed as f64);
                num_field(&mut m, "f", *f);
                num_field(&mut m, "clients", *clients as f64);
            }
            RoundEvent::RoundPlanned { round, clock_h, eligible, selected, deadline_s } => {
                num_field(&mut m, "round", *round as f64);
                num_field(&mut m, "clock_h", *clock_h);
                num_field(&mut m, "eligible", *eligible as f64);
                num_field(&mut m, "selected", *selected as f64);
                num_field(&mut m, "deadline_s", *deadline_s);
            }
            RoundEvent::ClientSelected { round, id, score, battery_frac } => {
                num_field(&mut m, "round", *round as f64);
                num_field(&mut m, "id", *id as f64);
                num_field(&mut m, "score", *score);
                num_field(&mut m, "battery_frac", *battery_frac);
            }
            RoundEvent::ClientReported { round, id, duration_s, energy_j } => {
                num_field(&mut m, "round", *round as f64);
                num_field(&mut m, "id", *id as f64);
                num_field(&mut m, "duration_s", *duration_s);
                num_field(&mut m, "energy_j", *energy_j);
            }
            RoundEvent::ClientDropped { round, id, cause, at_h, energy_j } => {
                num_field(&mut m, "round", *round as f64);
                num_field(&mut m, "id", *id as f64);
                str_field(&mut m, "cause", cause.as_str());
                num_field(&mut m, "at_h", *at_h);
                num_field(&mut m, "energy_j", *energy_j);
            }
            RoundEvent::BatteryDepleted { id, at_h } => {
                num_field(&mut m, "id", *id as f64);
                num_field(&mut m, "at_h", *at_h);
            }
            RoundEvent::BatteryRevived { id, at_h, battery_frac } => {
                num_field(&mut m, "id", *id as f64);
                num_field(&mut m, "at_h", *at_h);
                num_field(&mut m, "battery_frac", *battery_frac);
            }
            RoundEvent::RoundCommitted {
                round,
                committed,
                completed,
                accuracy,
                train_loss,
                energy_j,
                wall_clock_h,
                budget_remaining_j,
            } => {
                num_field(&mut m, "round", *round as f64);
                m.insert("committed".to_string(), Json::Bool(*committed));
                num_field(&mut m, "completed", *completed as f64);
                num_field(&mut m, "accuracy", *accuracy);
                num_field(&mut m, "train_loss", *train_loss);
                num_field(&mut m, "energy_j", *energy_j);
                num_field(&mut m, "wall_clock_h", *wall_clock_h);
                num_field(&mut m, "budget_remaining_j", *budget_remaining_j);
            }
            RoundEvent::BudgetExhausted { round, budget_j, spent_j } => {
                num_field(&mut m, "round", *round as f64);
                num_field(&mut m, "budget_j", *budget_j);
                num_field(&mut m, "spent_j", *spent_j);
            }
        }
        Json::Obj(m)
    }

    /// One JSONL line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let kind = j
            .get("ev")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace event missing \"ev\" discriminant"))?;
        let num = |k: &str| -> Result<f64> {
            match j.field(k)? {
                Json::Null => Ok(f64::NAN),
                v => v.as_f64().ok_or_else(|| anyhow!("field {k:?} is not a number")),
            }
        };
        let uint = |k: &str| -> Result<usize> {
            j.field(k)?.as_usize().ok_or_else(|| anyhow!("field {k:?} is not a non-negative integer"))
        };
        let text = |k: &str| -> Result<String> {
            Ok(j.field(k)?
                .as_str()
                .ok_or_else(|| anyhow!("field {k:?} is not a string"))?
                .to_string())
        };
        Ok(match kind {
            "run_started" => RoundEvent::RunStarted {
                name: text("name")?,
                selector: text("selector")?,
                scenario: text("scenario")?,
                clients: uint("clients")?,
                rounds: uint("rounds")?,
                seed: uint("seed")? as u64,
            },
            "campaign_cell" => RoundEvent::CampaignCell {
                cell: text("cell")?,
                selector: text("selector")?,
                scenario: text("scenario")?,
                seed: uint("seed")? as u64,
                f: num("f")?,
                clients: uint("clients")?,
            },
            "round_planned" => RoundEvent::RoundPlanned {
                round: uint("round")? as u64,
                clock_h: num("clock_h")?,
                eligible: uint("eligible")?,
                selected: uint("selected")?,
                deadline_s: num("deadline_s")?,
            },
            "client_selected" => RoundEvent::ClientSelected {
                round: uint("round")? as u64,
                id: uint("id")?,
                score: num("score")?,
                battery_frac: num("battery_frac")?,
            },
            "client_reported" => RoundEvent::ClientReported {
                round: uint("round")? as u64,
                id: uint("id")?,
                duration_s: num("duration_s")?,
                energy_j: num("energy_j")?,
            },
            "client_dropped" => RoundEvent::ClientDropped {
                round: uint("round")? as u64,
                id: uint("id")?,
                cause: DropCause::parse(&text("cause")?)?,
                at_h: num("at_h")?,
                energy_j: num("energy_j")?,
            },
            "battery_depleted" => {
                RoundEvent::BatteryDepleted { id: uint("id")?, at_h: num("at_h")? }
            }
            "battery_revived" => RoundEvent::BatteryRevived {
                id: uint("id")?,
                at_h: num("at_h")?,
                battery_frac: num("battery_frac")?,
            },
            "round_committed" => RoundEvent::RoundCommitted {
                round: uint("round")? as u64,
                committed: j
                    .field("committed")?
                    .as_bool()
                    .ok_or_else(|| anyhow!("field \"committed\" is not a bool"))?,
                completed: uint("completed")?,
                accuracy: num("accuracy")?,
                train_loss: num("train_loss")?,
                energy_j: num("energy_j")?,
                wall_clock_h: num("wall_clock_h")?,
                // Lenient: traces predating the energy ledger have no
                // budget column — read as "no budget" (NaN).
                budget_remaining_j: if j.get("budget_remaining_j").is_some() {
                    num("budget_remaining_j")?
                } else {
                    f64::NAN
                },
            },
            "budget_exhausted" => RoundEvent::BudgetExhausted {
                round: uint("round")? as u64,
                budget_j: num("budget_j")?,
                spent_j: num("spent_j")?,
            },
            other => bail!("unknown trace event kind {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// NaN-able floats (train_loss, budget_remaining_j) go through null
    /// and come back NaN, which PartialEq can't compare — replace them
    /// with a sentinel after asserting NaN-ness survives.
    fn normalized(ev: &RoundEvent) -> RoundEvent {
        let mut ev = ev.clone();
        if let RoundEvent::RoundCommitted { train_loss, budget_remaining_j, .. } = &mut ev
        {
            if train_loss.is_nan() {
                *train_loss = -1.0;
            }
            if budget_remaining_j.is_nan() {
                *budget_remaining_j = -1.0;
            }
        }
        ev
    }

    fn roundtrip(ev: RoundEvent) {
        let line = ev.to_line();
        assert!(!line.contains('\n'));
        let back = RoundEvent::from_json(&Json::parse(&line).unwrap()).unwrap();
        if let (
            RoundEvent::RoundCommitted { train_loss: a, budget_remaining_j: ba, .. },
            RoundEvent::RoundCommitted { train_loss: b, budget_remaining_j: bb, .. },
        ) = (&ev, &back)
        {
            assert_eq!(a.is_nan(), b.is_nan(), "train_loss NaN-ness must survive");
            assert_eq!(ba.is_nan(), bb.is_nan(), "budget NaN-ness must survive");
        }
        assert_eq!(normalized(&ev), normalized(&back));
    }

    #[test]
    fn every_variant_roundtrips_through_jsonl() {
        roundtrip(RoundEvent::RunStarted {
            name: "run-eafl".into(),
            selector: "eafl".into(),
            scenario: "diurnal".into(),
            clients: 16,
            rounds: 10,
            seed: 7,
        });
        roundtrip(RoundEvent::CampaignCell {
            cell: "c-eafl-steady-n12-f0.25-s1".into(),
            selector: "eafl".into(),
            scenario: "steady".into(),
            seed: 1,
            f: 0.25,
            clients: 12,
        });
        roundtrip(RoundEvent::RoundPlanned {
            round: 3,
            clock_h: 1.25,
            eligible: 14,
            selected: 4,
            deadline_s: 900.0,
        });
        roundtrip(RoundEvent::ClientSelected {
            round: 3,
            id: 5,
            score: 0.75,
            battery_frac: 0.6,
        });
        roundtrip(RoundEvent::ClientReported {
            round: 3,
            id: 5,
            duration_s: 120.5,
            energy_j: 33.0,
        });
        roundtrip(RoundEvent::ClientDropped {
            round: 3,
            id: 6,
            cause: DropCause::Death,
            at_h: 1.5,
            energy_j: 12.0,
        });
        roundtrip(RoundEvent::BatteryDepleted { id: 6, at_h: 1.5 });
        roundtrip(RoundEvent::BatteryRevived { id: 6, at_h: 9.0, battery_frac: 0.2 });
        roundtrip(RoundEvent::RoundCommitted {
            round: 3,
            committed: true,
            completed: 4,
            accuracy: 0.5,
            train_loss: 1.25,
            energy_j: 400.0,
            wall_clock_h: 1.75,
            budget_remaining_j: 1200.0,
        });
        roundtrip(RoundEvent::RoundCommitted {
            round: 4,
            committed: true,
            completed: 4,
            accuracy: 0.5,
            train_loss: 1.0,
            energy_j: 450.0,
            wall_clock_h: 2.0,
            budget_remaining_j: f64::NAN,
        });
        roundtrip(RoundEvent::BudgetExhausted {
            round: 9,
            budget_j: 5000.0,
            spent_j: 4987.5,
        });
    }

    #[test]
    fn nan_train_loss_encodes_as_null() {
        let ev = RoundEvent::RoundCommitted {
            round: 1,
            committed: false,
            completed: 0,
            accuracy: 0.0,
            train_loss: f64::NAN,
            energy_j: 0.0,
            wall_clock_h: 0.1,
            budget_remaining_j: f64::NAN,
        };
        let line = ev.to_line();
        assert!(line.contains("\"train_loss\": null"), "{line}");
        assert!(line.contains("\"budget_remaining_j\": null"), "{line}");
        roundtrip(ev);
    }

    #[test]
    fn pre_ledger_round_committed_lines_still_parse() {
        // Traces written before the energy ledger carry no
        // budget_remaining_j — the decoder must default it to NaN.
        let line = r#"{"accuracy": 0.5, "committed": true, "completed": 4, "energy_j": 400, "ev": "round_committed", "round": 3, "train_loss": 1.25, "wall_clock_h": 1.75}"#;
        let ev = RoundEvent::from_json(&Json::parse(line).unwrap()).unwrap();
        match ev {
            RoundEvent::RoundCommitted { budget_remaining_j, round, .. } => {
                assert_eq!(round, 3);
                assert!(budget_remaining_j.is_nan());
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn drop_cause_covers_taxonomy() {
        for c in [DropCause::Deadline, DropCause::Death, DropCause::Unavailable] {
            assert_eq!(DropCause::parse(c.as_str()).unwrap(), c);
        }
        assert!(DropCause::parse("gremlins").is_err());
    }

    #[test]
    fn unknown_kind_is_an_error() {
        let j = Json::parse(r#"{"ev": "frobnicate"}"#).unwrap();
        assert!(RoundEvent::from_json(&j).is_err());
        let j = Json::parse(r#"{"no_ev": 1}"#).unwrap();
        assert!(RoundEvent::from_json(&j).is_err());
    }
}
