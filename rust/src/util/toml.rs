//! TOML-subset parser/writer for experiment configs.
//!
//! Supported grammar (everything `ExperimentConfig` emits):
//!   - `[table]` / `[table.subtable]` headers
//!   - `key = value` with value ∈ {string, integer, float, bool,
//!     array of numbers}
//!   - `#` comments, blank lines
//!
//! The document model is a flat map from dotted path (`table.key`) to
//! [`TomlValue`]; config structs read typed values through the
//! accessors with defaults.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    NumArray(Vec<f64>),
}

/// A parsed TOML-subset document: dotted-path → value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(header) = line.strip_prefix('[') {
                let header = header
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated table header", lineno + 1))?
                    .trim();
                if header.is_empty() {
                    bail!("line {}: empty table header", lineno + 1);
                }
                prefix = header.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let path = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            entries.insert(
                path,
                parse_value(value.trim())
                    .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?,
            );
        }
        Ok(Self { entries })
    }

    // --- typed accessors (with defaults) -------------------------------------

    pub fn get_str(&self, path: &str) -> Option<&str> {
        match self.entries.get(path) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_f64(&self, path: &str) -> Option<f64> {
        match self.entries.get(path) {
            Some(TomlValue::Num(n)) => Some(*n),
            _ => None,
        }
    }

    pub fn get_usize(&self, path: &str) -> Option<usize> {
        self.get_f64(path)
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| n as usize)
    }

    pub fn get_u32(&self, path: &str) -> Option<u32> {
        self.get_usize(path).map(|n| n as u32)
    }

    pub fn get_u64(&self, path: &str) -> Option<u64> {
        self.get_usize(path).map(|n| n as u64)
    }

    pub fn get_f32(&self, path: &str) -> Option<f32> {
        self.get_f64(path).map(|n| n as f32)
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        match self.entries.get(path) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn get_num_array(&self, path: &str) -> Option<&[f64]> {
        match self.entries.get(path) {
            Some(TomlValue::NumArray(a)) => Some(a),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string {text:?}"))?;
        // Minimal escapes (configs only need these).
        return Ok(TomlValue::Str(
            inner.replace("\\\"", "\"").replace("\\\\", "\\"),
        ));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array {text:?}"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::NumArray(Vec::new()));
        }
        let nums = inner
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .map_err(|e| anyhow!("bad array element {s:?}: {e}"))
            })
            .collect::<Result<Vec<f64>>>()?;
        return Ok(TomlValue::NumArray(nums));
    }
    text.replace('_', "")
        .parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|e| anyhow!("unrecognized value {text:?}: {e}"))
}

/// Incremental writer producing the same subset the parser accepts.
#[derive(Debug, Default)]
pub struct TomlWriter {
    out: String,
    current_table: Option<String>,
}

impl TomlWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn table(&mut self, name: &str) -> &mut Self {
        if !self.out.is_empty() {
            self.out.push('\n');
        }
        let _ = writeln!(self.out, "[{name}]");
        self.current_table = Some(name.to_string());
        self
    }

    pub fn str(&mut self, key: &str, value: &str) -> &mut Self {
        let escaped = value.replace('\\', "\\\\").replace('"', "\\\"");
        let _ = writeln!(self.out, "{key} = \"{escaped}\"");
        self
    }

    pub fn num(&mut self, key: &str, value: f64) -> &mut Self {
        if value.fract() == 0.0 && value.abs() < 1e15 {
            let _ = writeln!(self.out, "{key} = {}", value as i64);
        } else {
            let _ = writeln!(self.out, "{key} = {value}");
        }
        self
    }

    pub fn boolean(&mut self, key: &str, value: bool) -> &mut Self {
        let _ = writeln!(self.out, "{key} = {value}");
        self
    }

    pub fn num_array(&mut self, key: &str, values: &[f64]) -> &mut Self {
        let body: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        let _ = writeln!(self.out, "{key} = [{}]", body.join(", "));
        self
    }

    pub fn finish(&self) -> String {
        self.out.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_values() {
        let doc = TomlDoc::parse(
            r#"
            name = "exp-1"   # the experiment
            [federation]
            rounds = 500
            fraction = 0.25
            enabled = true
            [devices]
            tier_fractions = [0.25, 0.4, 0.35]
            seed = 1_000
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("exp-1"));
        assert_eq!(doc.get_usize("federation.rounds"), Some(500));
        assert_eq!(doc.get_f64("federation.fraction"), Some(0.25));
        assert_eq!(doc.get_bool("federation.enabled"), Some(true));
        assert_eq!(
            doc.get_num_array("devices.tier_fractions"),
            Some(&[0.25, 0.4, 0.35][..])
        );
        assert_eq!(doc.get_u64("devices.seed"), Some(1000));
    }

    #[test]
    fn writer_output_reparses() {
        let mut w = TomlWriter::new();
        w.str("name", "paper \"quoted\"");
        w.table("federation");
        w.num("rounds", 500.0).num("lr", 0.05).boolean("on", false);
        w.table("devices");
        w.num_array("tiers", &[0.1, 0.9]);
        let text = w.finish();
        let doc = TomlDoc::parse(&text).unwrap();
        assert_eq!(doc.get_str("name"), Some("paper \"quoted\""));
        assert_eq!(doc.get_usize("federation.rounds"), Some(500));
        assert_eq!(doc.get_f64("federation.lr"), Some(0.05));
        assert_eq!(doc.get_bool("federation.on"), Some(false));
        assert_eq!(doc.get_num_array("devices.tiers"), Some(&[0.1, 0.9][..]));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("keyonly").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        assert!(TomlDoc::parse("k = nonsense").is_err());
    }

    #[test]
    fn missing_keys_are_none() {
        let doc = TomlDoc::parse("[a]\nx = 1").unwrap();
        assert_eq!(doc.get_f64("a.y"), None);
        assert_eq!(doc.get_str("a.x"), None, "type mismatch is None, not panic");
        assert_eq!(doc.get_usize("a.x"), Some(1));
    }
}
