//! Fig. 3 regeneration bench (shortened): test accuracy (3a), train
//! loss (3b) and Jain's fairness (3c) series for EAFL vs Oort vs Random
//! under identical seeds.
//!
//! Uses the analytic mock runtime so the bench isolates COORDINATOR
//! time; the real-SGD version of this experiment is
//! `examples/e2e_speech_training.rs` (recorded in EXPERIMENTS.md).
//!
//! Run: cargo bench --bench fig3_accuracy

use eafl::benchkit::Bench;
use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::metrics::MetricsLog;
use eafl::runtime::MockRuntime;

fn run(kind: SelectorKind, rounds: usize) -> MetricsLog {
    let runtime = MockRuntime::default();
    let mut cfg = ExperimentConfig::paper_default(kind);
    cfg.name = format!("fig3-{kind}");
    cfg.federation.rounds = rounds;
    cfg.federation.num_clients = 100;
    cfg.devices.min_init_battery = 0.15;
    cfg.devices.max_init_battery = 0.8;
    Coordinator::new(cfg, &runtime).unwrap().run().unwrap()
}

fn main() {
    const ROUNDS: usize = 150;
    let mut bench = Bench::heavy();
    let mut logs = Vec::new();
    for kind in [SelectorKind::Eafl, SelectorKind::Oort, SelectorKind::Random] {
        let log = bench.run_once(&format!("fig3 series {kind} ({ROUNDS} rounds, mock)"), || {
            run(kind, ROUNDS)
        });
        logs.push((kind, log));
    }

    println!("\n=== Fig 3a/3b/3c series (sampled every 30 rounds) ===");
    println!(
        "{:<8} {:>6} {:>10} {:>12} {:>10}",
        "selector", "round", "accuracy", "train_loss", "fairness"
    );
    for (kind, log) in &logs {
        for r in log.records.iter().step_by(30) {
            println!(
                "{:<8} {:>6} {:>10.4} {:>12.4} {:>10.3}",
                kind.to_string(),
                r.round,
                r.test_accuracy,
                r.train_loss,
                r.fairness
            );
        }
    }

    println!("\n=== expected shape checks (paper Fig. 3) ===");
    let get = |k: SelectorKind| logs.iter().find(|(kk, _)| *kk == k).unwrap().1.summary();
    let eafl = get(SelectorKind::Eafl);
    let oort = get(SelectorKind::Oort);
    let random = get(SelectorKind::Random);
    println!(
        "final fairness: eafl={:.3} oort={:.3} random={:.3}  (paper: eafl&random high, oort degraded: {})",
        eafl.final_fairness,
        oort.final_fairness,
        random.final_fairness,
        if eafl.final_fairness >= oort.final_fairness { "HOLDS" } else { "VIOLATED" }
    );
    println!(
        "final accuracy: eafl={:.4} oort={:.4} random={:.4}  (paper: eafl best: {})",
        eafl.final_accuracy,
        oort.final_accuracy,
        random.final_accuracy,
        if eafl.final_accuracy >= oort.final_accuracy { "HOLDS" } else { "VIOLATED" }
    );
}
