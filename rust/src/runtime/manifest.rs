//! `artifacts/manifest.json` — the shape/packing contract written by
//! `python -m compile.aot` and consumed here. The flat-parameter packing
//! order must match `python/compile/model.py::PARAM_SPEC` exactly.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use crate::util::json::Json;

/// One named parameter tensor inside the flat vector.
#[derive(Debug, Clone)]
pub struct ParamSpecEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpecEntry {
    /// Number of scalars this entry occupies in the flat vector.
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub param_count: usize,
    pub num_classes: usize,
    pub input_hw: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub param_spec: Vec<ParamSpecEntry>,
    /// entry-point name -> HLO text filename (relative to artifact dir).
    pub artifacts: HashMap<String, String>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let m = Self::from_json_text(&text).context("parsing manifest.json")?;
        m.validate()?;
        Ok(m)
    }

    /// Parse from JSON text (no validation).
    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let usize_field = |key: &str| -> Result<usize> {
            j.field(key)?
                .as_usize()
                .ok_or_else(|| anyhow!("field {key:?} is not a non-negative integer"))
        };
        let param_spec = j
            .field("param_spec")?
            .as_arr()
            .ok_or_else(|| anyhow!("param_spec is not an array"))?
            .iter()
            .map(|e| {
                let name = e
                    .field("name")?
                    .as_str()
                    .ok_or_else(|| anyhow!("param name not a string"))?
                    .to_string();
                let shape = e
                    .field("shape")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("param shape not an array"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
                    .collect::<Result<Vec<usize>>>()?;
                Ok(ParamSpecEntry { name, shape })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = j
            .field("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts is not an object"))?
            .iter()
            .map(|(k, v)| {
                Ok((
                    k.clone(),
                    v.as_str()
                        .ok_or_else(|| anyhow!("artifact {k:?} not a string"))?
                        .to_string(),
                ))
            })
            .collect::<Result<HashMap<_, _>>>()?;
        Ok(Manifest {
            param_count: usize_field("param_count")?,
            num_classes: usize_field("num_classes")?,
            input_hw: usize_field("input_hw")?,
            train_batch: usize_field("train_batch")?,
            eval_batch: usize_field("eval_batch")?,
            param_spec,
            artifacts,
        })
    }

    /// Internal consistency checks (spec sizes sum to param_count, all
    /// referenced artifact files declared).
    pub fn validate(&self) -> Result<()> {
        let total: usize = self.param_spec.iter().map(|e| e.size()).sum();
        ensure!(
            total == self.param_count,
            "param_spec sums to {total}, manifest says {}",
            self.param_count
        );
        for key in ["train_step", "eval_step", "init_params"] {
            ensure!(self.artifacts.contains_key(key), "manifest missing artifact {key:?}");
        }
        ensure!(self.train_batch > 0 && self.eval_batch > 0, "batch sizes must be positive");
        Ok(())
    }

    /// Absolute path of a named artifact.
    pub fn artifact_path(&self, dir: &Path, key: &str) -> Result<PathBuf> {
        let fname = self
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!("unknown artifact {key:?}"))?;
        Ok(dir.join(fname))
    }

    /// Elements in one training input batch (`B * HW * HW`, C = 1).
    pub fn train_x_len(&self) -> usize {
        self.train_batch * self.input_hw * self.input_hw
    }

    /// Elements in one eval input batch.
    pub fn eval_x_len(&self) -> usize {
        self.eval_batch * self.input_hw * self.input_hw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            param_count: 12,
            num_classes: 35,
            input_hw: 32,
            train_batch: 20,
            eval_batch: 128,
            param_spec: vec![
                ParamSpecEntry { name: "w".into(), shape: vec![2, 5] },
                ParamSpecEntry { name: "b".into(), shape: vec![2] },
            ],
            artifacts: [
                ("train_step", "t.hlo.txt"),
                ("eval_step", "e.hlo.txt"),
                ("init_params", "i.hlo.txt"),
            ]
            .into_iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect(),
        }
    }

    #[test]
    fn validates_consistent_manifest() {
        sample().validate().unwrap();
    }

    #[test]
    fn rejects_bad_param_total() {
        let mut m = sample();
        m.param_count = 99;
        assert!(m.validate().is_err());
    }

    #[test]
    fn rejects_missing_artifact() {
        let mut m = sample();
        m.artifacts.remove("eval_step");
        assert!(m.validate().is_err());
    }

    #[test]
    fn batch_lengths() {
        let m = sample();
        assert_eq!(m.train_x_len(), 20 * 32 * 32);
        assert_eq!(m.eval_x_len(), 128 * 32 * 32);
    }

    #[test]
    fn parses_real_manifest_json() {
        let text = r#"{
          "param_count": 12,
          "num_classes": 35,
          "input_hw": 32,
          "train_batch": 20,
          "eval_batch": 128,
          "param_spec": [
            {"name": "w", "shape": [2, 5]},
            {"name": "b", "shape": [2]}
          ],
          "artifacts": {
            "train_step": "train_step.hlo.txt",
            "eval_step": "eval_step.hlo.txt",
            "init_params": "init_params.hlo.txt"
          }
        }"#;
        let m = Manifest::from_json_text(text).unwrap();
        m.validate().unwrap();
        assert_eq!(m.param_spec[0].size(), 10);
        assert_eq!(m.artifacts["train_step"], "train_step.hlo.txt");
    }

    #[test]
    fn parse_errors_are_descriptive() {
        let err = Manifest::from_json_text("{}").unwrap_err();
        assert!(format!("{err}").contains("param_"), "got {err}");
    }
}
