//! FedAvg (McMahan et al., AISTATS'17): the global model becomes the
//! sample-weighted mean of the completing clients' parameters.

use anyhow::{ensure, Result};

use super::{weighted_mean, Aggregator, ClientUpdate};

/// Stateless sample-weighted averaging.
pub struct FedAvg;

impl Aggregator for FedAvg {
    fn aggregate(&mut self, global: &mut [f32], updates: &[ClientUpdate]) -> Result<()> {
        ensure!(!updates.is_empty(), "FedAvg needs at least one update");
        for u in updates {
            ensure!(u.params.len() == global.len(), "update length mismatch");
        }
        let mut mean = vec![0.0f32; global.len()];
        weighted_mean(updates, &mut mean);
        global.copy_from_slice(&mean);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "fedavg"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_client_replaces_global() {
        let mut global = vec![0.0, 0.0];
        let updates = vec![ClientUpdate { params: vec![1.0, 2.0], weight: 5.0 }];
        FedAvg.aggregate(&mut global, &updates).unwrap();
        assert_eq!(global, vec![1.0, 2.0]);
    }

    #[test]
    fn equal_weights_average() {
        let mut global = vec![9.0];
        let updates = vec![
            ClientUpdate { params: vec![2.0], weight: 1.0 },
            ClientUpdate { params: vec![4.0], weight: 1.0 },
        ];
        FedAvg.aggregate(&mut global, &updates).unwrap();
        assert!((global[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_empty_and_mismatched() {
        let mut global = vec![0.0];
        assert!(FedAvg.aggregate(&mut global, &[]).is_err());
        let bad = vec![ClientUpdate { params: vec![1.0, 2.0], weight: 1.0 }];
        assert!(FedAvg.aggregate(&mut global, &bad).is_err());
    }
}
