//! Multi-experiment campaign runner — the paper's figures are grids,
//! not single runs (Figs. 3–4 are selector × seed sweeps, the ablation
//! is an f sweep), so the unit of work here is a whole *campaign*:
//!
//!  1. [`CampaignGrid`] expands selectors × seeds × f-values × client
//!     counts against a base [`ExperimentConfig`] into named run
//!     configs (empty axes inherit the base value);
//!  2. [`run_campaign`] executes the runs across `jobs` worker threads
//!     — experiments are embarrassingly parallel, each gets its own
//!     [`Coordinator`] pinned to 1 execution worker so threads × runs
//!     don't oversubscribe — sharing one `&dyn ModelRuntime`;
//!  3. per-run CSV/summary files plus a merged `campaign.json` and
//!     `campaign.csv` land in the output directory.
//!
//! Deterministic: a run's seeds derive only from its grid coordinates,
//! so any subset of a campaign reproduces bit-identically, at any job
//! count, in any execution order.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use anyhow::{Context, Result};

use crate::config::{ExperimentConfig, SelectorKind};
use crate::coordinator::Coordinator;
use crate::metrics::Summary;
use crate::runtime::ModelRuntime;
use crate::util::json::Json;

/// The sweep axes. Empty `f_values` / `client_counts` inherit the base
/// config's value (a single grid point on that axis).
#[derive(Debug, Clone)]
pub struct CampaignGrid {
    pub selectors: Vec<SelectorKind>,
    pub seeds: Vec<u64>,
    pub f_values: Vec<f64>,
    pub client_counts: Vec<usize>,
}

impl Default for CampaignGrid {
    /// The headline comparison grid: all three selectors × three seeds
    /// at the base config's f and population.
    fn default() -> Self {
        Self {
            selectors: vec![SelectorKind::Eafl, SelectorKind::Oort, SelectorKind::Random],
            seeds: vec![1, 2, 3],
            f_values: Vec::new(),
            client_counts: Vec::new(),
        }
    }
}

/// A whole campaign: base config + grid + parallelism.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Campaign name (used in the merged output file names).
    pub name: String,
    pub base: ExperimentConfig,
    pub grid: CampaignGrid,
    /// Experiments to run concurrently.
    pub jobs: usize,
    /// Execution-phase worker threads inside each experiment (the
    /// campaign default of 1 makes experiments the parallel unit).
    pub workers_per_run: usize,
}

impl CampaignSpec {
    pub fn new(name: impl Into<String>, base: ExperimentConfig) -> Self {
        Self {
            name: name.into(),
            base,
            grid: CampaignGrid::default(),
            jobs: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            workers_per_run: 1,
        }
    }
}

/// One grid point: the coordinates plus the fully resolved config.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub selector: SelectorKind,
    pub seed: u64,
    pub f: f64,
    pub clients: usize,
    pub cfg: ExperimentConfig,
}

/// One finished run: its coordinates plus the end-of-run summary.
#[derive(Debug, Clone)]
pub struct CampaignRun {
    pub selector: SelectorKind,
    pub seed: u64,
    pub f: f64,
    pub clients: usize,
    pub summary: Summary,
}

/// The merged campaign result, in grid order.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub name: String,
    pub runs: Vec<CampaignRun>,
}

/// Derive every per-run RNG stream from the grid seed so seeds — not
/// incidental config state — pin the run.
fn apply_seed(cfg: &mut ExperimentConfig, seed: u64) {
    cfg.data.seed = seed;
    cfg.devices.seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    cfg.network.seed = seed.wrapping_mul(0xBF58_476D_1CE4_E5B9).wrapping_add(2);
    cfg.training.init_seed = (seed as u32).wrapping_mul(2_654_435_761).wrapping_add(3);
}

/// Expand the grid into fully resolved, uniquely named run configs.
/// Order: selector (outermost) → clients → f → seed; the f axis only
/// applies to EAFL (other selectors ignore f and get a single point).
pub fn expand(spec: &CampaignSpec) -> Vec<RunSpec> {
    let f_values: Vec<f64> = if spec.grid.f_values.is_empty() {
        vec![spec.base.selector.eafl_f]
    } else {
        spec.grid.f_values.clone()
    };
    let client_counts: Vec<usize> = if spec.grid.client_counts.is_empty() {
        vec![spec.base.federation.num_clients]
    } else {
        spec.grid.client_counts.clone()
    };
    let mut runs = Vec::new();
    for &selector in &spec.grid.selectors {
        // f only parameterizes EAFL's Eq. (1) reward; Oort and Random
        // never read it, so for them the axis collapses to one point —
        // otherwise every extra f value would repeat identical runs.
        let selector_f: &[f64] = if selector == SelectorKind::Eafl {
            &f_values
        } else {
            &f_values[..1]
        };
        for &clients in &client_counts {
            for &f in selector_f {
                for &seed in &spec.grid.seeds {
                    let mut cfg = spec.base.clone();
                    cfg.selector.kind = selector;
                    cfg.selector.eafl_f = f;
                    cfg.federation.num_clients = clients;
                    cfg.federation.participants_per_round =
                        cfg.federation.participants_per_round.min(clients);
                    apply_seed(&mut cfg, seed);
                    cfg.name = format!("{}-{selector}-n{clients}-f{f}-s{seed}", spec.name);
                    runs.push(RunSpec { selector, seed, f, clients, cfg });
                }
            }
        }
    }
    runs
}

fn run_one(
    run: &RunSpec,
    runtime: &dyn ModelRuntime,
    out_dir: Option<&Path>,
    workers_per_run: usize,
) -> Result<CampaignRun> {
    let cfg = run.cfg.clone();
    let name = cfg.name.clone();
    let log = Coordinator::new(cfg, runtime)
        .with_context(|| format!("building coordinator for {name}"))?
        .with_workers(workers_per_run)
        .run()
        .with_context(|| format!("running {name}"))?;
    if let Some(dir) = out_dir {
        log.write_csv(&dir.join(format!("{name}.csv")))?;
        log.write_summary_json(&dir.join(format!("{name}.summary.json")))?;
    }
    Ok(CampaignRun {
        selector: run.selector,
        seed: run.seed,
        f: run.f,
        clients: run.clients,
        summary: log.summary(),
    })
}

/// Run the whole campaign; `out_dir` (if given) receives per-run CSVs
/// and the merged `<name>.campaign.json` / `<name>.campaign.csv`.
pub fn run_campaign(
    spec: &CampaignSpec,
    runtime: &dyn ModelRuntime,
    out_dir: Option<&Path>,
) -> Result<CampaignReport> {
    let runs = expand(spec);
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    }
    let jobs = spec.jobs.max(1).min(runs.len().max(1));

    // First failure aborts the rest of the grid: experiments can take
    // hours each, so nobody wants 26 more runs after run 1 errored.
    let failed = AtomicBool::new(false);
    let mut collected: Vec<(usize, Result<CampaignRun>)> = if jobs <= 1 {
        let mut out = Vec::new();
        for (i, r) in runs.iter().enumerate() {
            let res = run_one(r, runtime, out_dir, spec.workers_per_run);
            let is_err = res.is_err();
            out.push((i, res));
            if is_err {
                break;
            }
        }
        out
    } else {
        // Work-stealing over an atomic cursor; each worker accumulates
        // (index, result) locally, merged and re-ordered after join —
        // scheduling order never touches results.
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..jobs)
                .map(|_| {
                    scope.spawn(|| {
                        let mut local = Vec::new();
                        loop {
                            if failed.load(Ordering::Relaxed) {
                                break;
                            }
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            let Some(run) = runs.get(i) else { break };
                            let res = run_one(run, runtime, out_dir, spec.workers_per_run);
                            if res.is_err() {
                                failed.store(true, Ordering::Relaxed);
                            }
                            local.push((i, res));
                        }
                        local
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("campaign worker panicked"))
                .collect()
        })
    };
    collected.sort_by_key(|(i, _)| *i);

    let mut finished = Vec::with_capacity(collected.len());
    for (_, r) in collected {
        finished.push(r?);
    }
    let report = CampaignReport { name: spec.name.clone(), runs: finished };
    if let Some(dir) = out_dir {
        let json_path = dir.join(format!("{}.campaign.json", report.name));
        std::fs::write(&json_path, report.to_json().to_string_pretty())
            .with_context(|| format!("writing {json_path:?}"))?;
        let csv_path = dir.join(format!("{}.campaign.csv", report.name));
        std::fs::write(&csv_path, report.to_csv())
            .with_context(|| format!("writing {csv_path:?}"))?;
    }
    Ok(report)
}

impl CampaignReport {
    /// Merged summary as JSON (in-tree codec; offline build, no serde).
    pub fn to_json(&self) -> Json {
        let runs: Vec<Json> = self
            .runs
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("selector".to_string(), Json::Str(r.selector.to_string()));
                m.insert("seed".to_string(), Json::Num(r.seed as f64));
                m.insert("f".to_string(), Json::Num(r.f));
                m.insert("clients".to_string(), Json::Num(r.clients as f64));
                m.insert("summary".to_string(), r.summary.to_json());
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("campaign".to_string(), Json::Str(self.name.clone()));
        top.insert("total_runs".to_string(), Json::Num(self.runs.len() as f64));
        top.insert("runs".to_string(), Json::Arr(runs));
        Json::Obj(top)
    }

    /// One CSV row per run (the merged table the plots consume).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "selector,seed,f,clients,rounds,committed_rounds,final_accuracy,\
             best_accuracy,final_fairness,total_dropouts,mean_round_duration_s,\
             wall_clock_h,total_fl_energy_j\n",
        );
        for r in &self.runs {
            let s = &r.summary;
            out.push_str(&format!(
                "{},{},{},{},{},{},{:.6},{:.6},{:.6},{},{:.3},{:.6},{:.3}\n",
                r.selector,
                r.seed,
                r.f,
                r.clients,
                s.rounds,
                s.committed_rounds,
                s.final_accuracy,
                s.best_accuracy,
                s.final_fairness,
                s.total_dropouts,
                s.mean_round_duration_s,
                s.wall_clock_h,
                s.total_fl_energy_j,
            ));
        }
        out
    }

    /// Mean final accuracy per selector (quick cross-seed aggregate).
    pub fn mean_accuracy_by_selector(&self) -> Vec<(SelectorKind, f64)> {
        let mut acc: Vec<(SelectorKind, f64, usize)> = Vec::new();
        for r in &self.runs {
            match acc.iter_mut().find(|(k, _, _)| *k == r.selector) {
                Some(slot) => {
                    slot.1 += r.summary.final_accuracy;
                    slot.2 += 1;
                }
                None => acc.push((r.selector, r.summary.final_accuracy, 1)),
            }
        }
        acc.into_iter().map(|(k, sum, n)| (k, sum / n as f64)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        cfg.federation.rounds = 3;
        cfg.federation.num_clients = 12;
        cfg.federation.participants_per_round = 4;
        cfg.data.min_samples = 5;
        cfg.data.max_samples = 15;
        cfg
    }

    #[test]
    fn expand_is_the_product_with_f_only_for_eafl() {
        let mut spec = CampaignSpec::new("t", base());
        spec.grid = CampaignGrid {
            selectors: vec![SelectorKind::Eafl, SelectorKind::Random],
            seeds: vec![7, 8],
            f_values: vec![0.25, 0.5],
            client_counts: vec![10, 20],
        };
        let runs = expand(&spec);
        // EAFL gets the full 2 clients x 2 f x 2 seeds; Random ignores
        // f so its axis collapses: 2 clients x 1 f x 2 seeds.
        assert_eq!(runs.len(), 8 + 4);
        // Outermost axis is the selector.
        assert!(runs[..8].iter().all(|r| r.selector == SelectorKind::Eafl));
        assert!(runs[8..].iter().all(|r| r.selector == SelectorKind::Random));
        assert!(runs[8..].iter().all(|r| r.f == 0.25), "non-EAFL pins f to the first value");
        // Names are unique.
        let mut names: Vec<&str> = runs.iter().map(|r| r.cfg.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), runs.len());
        // Seeds land in the config.
        assert!(runs.iter().all(|r| r.cfg.data.seed == r.seed));
        // K is clamped to the population.
        assert!(runs
            .iter()
            .all(|r| r.cfg.federation.participants_per_round <= r.cfg.federation.num_clients));
        for r in &runs {
            r.cfg.validate().unwrap();
        }
    }

    #[test]
    fn empty_axes_inherit_base() {
        let spec = CampaignSpec::new("t", base());
        let runs = expand(&spec);
        assert_eq!(runs.len(), 3 * 3); // default grid: 3 selectors × 3 seeds
        assert!(runs.iter().all(|r| r.f == spec.base.selector.eafl_f));
        assert!(runs.iter().all(|r| r.clients == spec.base.federation.num_clients));
    }

    #[test]
    fn report_csv_has_one_row_per_run_plus_header() {
        let report = CampaignReport {
            name: "t".into(),
            runs: vec![CampaignRun {
                selector: SelectorKind::Eafl,
                seed: 1,
                f: 0.25,
                clients: 10,
                summary: crate::metrics::MetricsLog::new("x").summary(),
            }],
        };
        let csv = report.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("selector,seed,f,clients,"));
        let parsed = Json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert_eq!(parsed.field("total_runs").unwrap().as_usize(), Some(1));
    }
}
