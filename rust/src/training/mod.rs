//! Local training & evaluation executor.
//!
//! Drives the AOT-compiled train/eval executables (via [`ModelRuntime`])
//! for each selected client: materializes batches from the client's
//! shard through the procedural dataset, runs the configured number of
//! local SGD steps, and returns the updated parameters plus the
//! per-example losses that feed Oort/EAFL's statistical utility.
//!
//! Buffers are preallocated once and reused across every client and
//! round — the per-step hot path performs no heap allocation beyond
//! what the runtime itself requires.

use anyhow::Result;

use crate::data::{ClientShard, SampleRef, SyntheticSpeech};
use crate::runtime::ModelRuntime;
use crate::selection::utility::statistical_utility;

/// Result of one client's local training.
#[derive(Debug, Clone)]
pub struct LocalTrainResult {
    /// Locally updated flat parameters.
    pub params: Vec<f32>,
    /// Mean loss over the client's final local step.
    pub final_loss: f32,
    /// Oort statistical utility computed from ALL per-example losses
    /// observed across the local steps (Eq. 2's |B_i|·sqrt(mean L²)).
    pub stat_util: f64,
    /// Aggregation weight: the client's sample count.
    pub weight: f64,
}

/// Evaluation result over the held-out test set.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub accuracy: f64,
    pub mean_loss: f64,
    pub samples: usize,
}

/// Preallocated batch buffers, owned by the coordinator and reused
/// across every client, step and round (§Perf L3 iteration 1: the
/// trainer used to allocate ~600 KB of batch buffers per round).
#[derive(Debug, Clone)]
pub struct TrainerBufs {
    train_x: Vec<f32>,
    train_y: Vec<i32>,
    eval_x: Vec<f32>,
    eval_y: Vec<i32>,
    loss_acc: Vec<f32>,
}

impl TrainerBufs {
    pub fn new(runtime: &dyn ModelRuntime) -> Self {
        let fl = runtime.input_hw() * runtime.input_hw();
        Self {
            train_x: vec![0.0; runtime.train_batch() * fl],
            train_y: vec![0; runtime.train_batch()],
            eval_x: vec![0.0; runtime.eval_batch() * fl],
            eval_y: vec![0; runtime.eval_batch()],
            loss_acc: Vec::new(),
        }
    }

    /// Cheap placeholder used while the real buffers are checked out.
    pub fn empty() -> Self {
        Self {
            train_x: Vec::new(),
            train_y: Vec::new(),
            eval_x: Vec::new(),
            eval_y: Vec::new(),
            loss_acc: Vec::new(),
        }
    }
}

/// Reusable trainer over a runtime + dataset + borrowed buffers.
pub struct Trainer<'a> {
    runtime: &'a dyn ModelRuntime,
    data: &'a SyntheticSpeech,
    bufs: TrainerBufs,
}

impl<'a> Trainer<'a> {
    pub fn new(runtime: &'a dyn ModelRuntime, data: &'a SyntheticSpeech) -> Self {
        Self::with_bufs(runtime, data, TrainerBufs::new(runtime))
    }

    /// Construct around caller-owned buffers (zero allocation); call
    /// [`Trainer::into_bufs`] afterwards to reclaim them.
    pub fn with_bufs(
        runtime: &'a dyn ModelRuntime,
        data: &'a SyntheticSpeech,
        bufs: TrainerBufs,
    ) -> Self {
        debug_assert_eq!(data.feature_len(), runtime.input_hw() * runtime.input_hw());
        debug_assert_eq!(bufs.train_y.len(), runtime.train_batch());
        Self { runtime, data, bufs }
    }

    /// Hand the buffers back for reuse next round.
    pub fn into_bufs(self) -> TrainerBufs {
        self.bufs
    }

    /// Run `local_steps` SGD steps for one client starting from the
    /// global model. Batches slide over the shard with wraparound, with
    /// a per-round rotation so successive rounds see different windows.
    pub fn train_client(
        &mut self,
        global: &[f32],
        shard: &ClientShard,
        lr: f32,
        local_steps: usize,
        round: u64,
    ) -> Result<LocalTrainResult> {
        let b = self.runtime.train_batch();
        let n = shard.samples.len().max(1);
        let mut params = global.to_vec();
        let mut final_loss = 0.0;
        self.bufs.loss_acc.clear();
        for step in 0..local_steps {
            // Rotating window start: decorrelates batches across rounds.
            let start = ((round as usize).wrapping_mul(31) + step * b) % n;
            self.fill_window(&shard.samples, start, shard.channel_gain);
            let out =
                self.runtime.train_step(&params, &self.bufs.train_x, &self.bufs.train_y, lr)?;
            params = out.params;
            final_loss = out.mean_loss;
            self.bufs.loss_acc.extend_from_slice(&out.per_example_loss);
        }
        // Eq. (2) statistical utility over everything this client saw,
        // scaled so |B_i| reflects the client's dataset size (Oort uses
        // the client's sample count as the prefactor).
        let mean_sq = if self.bufs.loss_acc.is_empty() {
            0.0
        } else {
            self.bufs.loss_acc.iter().map(|&l| (l as f64) * (l as f64)).sum::<f64>()
                / self.bufs.loss_acc.len() as f64
        };
        let stat_util = shard.samples.len() as f64 * mean_sq.sqrt();
        Ok(LocalTrainResult {
            params,
            final_loss,
            stat_util,
            weight: shard.samples.len() as f64,
        })
    }

    fn fill_window(&mut self, samples: &[SampleRef], start: usize, gain: f32) {
        let fl = self.data.feature_len();
        let b = self.bufs.train_y.len();
        for i in 0..b {
            let s = samples[(start + i) % samples.len()];
            self.data
                .fill_features(s, gain, &mut self.bufs.train_x[i * fl..(i + 1) * fl]);
            self.bufs.train_y[i] = s.0 as i32;
        }
    }

    /// Evaluate `params` over the test set (truncated to a multiple of
    /// the eval batch so padded duplicates never skew accuracy).
    pub fn evaluate(&mut self, params: &[f32], test: &[SampleRef]) -> Result<EvalResult> {
        let b = self.runtime.eval_batch();
        let batches = test.len() / b;
        anyhow::ensure!(batches > 0, "test set smaller than eval batch ({} < {b})", test.len());
        let fl = self.data.feature_len();
        let mut correct = 0i64;
        let mut loss_sum = 0.0f64;
        for bi in 0..batches {
            for i in 0..b {
                let s = test[bi * b + i];
                self.data
                    .fill_features(s, 1.0, &mut self.bufs.eval_x[i * fl..(i + 1) * fl]);
                self.bufs.eval_y[i] = s.0 as i32;
            }
            let out = self.runtime.eval_step(params, &self.bufs.eval_x, &self.bufs.eval_y)?;
            correct += out.correct as i64;
            loss_sum += out.mean_loss as f64;
        }
        let samples = batches * b;
        Ok(EvalResult {
            accuracy: correct as f64 / samples as f64,
            mean_loss: loss_sum / batches as f64,
            samples,
        })
    }

    /// Convenience: the statistical utility of a raw loss vector
    /// (exposed for tests and the benches).
    pub fn stat_util_of(losses: &[f32]) -> f64 {
        statistical_utility(losses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockRuntime;

    fn fixture() -> (MockRuntime, SyntheticSpeech, ClientShard) {
        let rt = MockRuntime::tiny();
        let data = SyntheticSpeech::new(rt.input_hw, rt.num_classes, 0.3, 1);
        let shard = ClientShard {
            labels: vec![0, 1],
            samples: (0..10).map(|i| ((i % 2) as u16, i as u32)).collect(),
            channel_gain: 1.0,
        };
        (rt, data, shard)
    }

    #[test]
    fn local_training_reduces_loss() {
        let (rt, data, shard) = fixture();
        let mut t = Trainer::new(&rt, &data);
        let global = rt.init_params(0).unwrap();
        let r1 = t.train_client(&global, &shard, 0.05, 1, 1).unwrap();
        let r20 = t.train_client(&global, &shard, 0.05, 20, 1).unwrap();
        assert!(r20.final_loss < r1.final_loss);
        assert_eq!(r20.params.len(), rt.param_count);
    }

    #[test]
    fn stat_util_positive_and_weighted_by_shard_size() {
        let (rt, data, shard) = fixture();
        let mut big = shard.clone();
        big.samples = (0..40).map(|i| ((i % 2) as u16, 100 + i as u32)).collect();
        let mut t = Trainer::new(&rt, &data);
        let global = rt.init_params(0).unwrap();
        let small = t.train_client(&global, &shard, 0.05, 2, 1).unwrap();
        let large = t.train_client(&global, &big, 0.05, 2, 1).unwrap();
        assert!(small.stat_util > 0.0);
        assert!(large.stat_util > small.stat_util);
        assert_eq!(large.weight, 40.0);
    }

    #[test]
    fn evaluate_truncates_to_full_batches() {
        let (rt, data, _) = fixture();
        let mut t = Trainer::new(&rt, &data);
        let global = rt.init_params(0).unwrap();
        let test = data.test_set(rt.eval_batch * 2 + 3); // 3 stragglers dropped
        let r = t.evaluate(&global, &test).unwrap();
        assert_eq!(r.samples, rt.eval_batch * 2);
        assert!((0.0..=1.0).contains(&r.accuracy));
    }

    #[test]
    fn evaluate_rejects_tiny_test_set() {
        let (rt, data, _) = fixture();
        let mut t = Trainer::new(&rt, &data);
        let global = rt.init_params(0).unwrap();
        assert!(t.evaluate(&global, &data.test_set(3)).is_err());
    }

    #[test]
    fn deterministic_across_identical_calls() {
        let (rt, data, shard) = fixture();
        let mut t = Trainer::new(&rt, &data);
        let global = rt.init_params(0).unwrap();
        let a = t.train_client(&global, &shard, 0.05, 3, 7).unwrap();
        let b = t.train_client(&global, &shard, 0.05, 3, 7).unwrap();
        assert_eq!(a.params, b.params);
        assert_eq!(a.stat_util, b.stat_util);
    }
}
