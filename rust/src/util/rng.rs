//! Deterministic RNG: xoshiro256++ (Blackman & Vigna) seeded through
//! splitmix64, plus the distribution helpers the simulator needs.
//!
//! Chosen over a crates.io dependency because the build is offline and
//! reproducibility across runs/platforms is a hard requirement for the
//! simulation: the generator is fully specified here, so seeds in
//! configs pin entire experiments bit-for-bit.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 so similar seeds give uncorrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn gen_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + self.gen_f64() * (hi - lo)
    }

    /// Uniform f32 in [lo, hi).
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.gen_f32() * (hi - lo)
    }

    /// Uniform usize in [lo, hi] (inclusive). Uses Lemire-style
    /// rejection to avoid modulo bias.
    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // Full u64 range — cannot happen for usize ranges we use.
            return lo.wrapping_add(self.next_u64() as usize);
        }
        // Rejection sampling on the top bits.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as usize;
            }
        }
    }

    /// Uniform i32 in [lo, hi] inclusive.
    pub fn gen_range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.gen_range_usize(0, (hi - lo) as usize) as i32
    }

    /// Bernoulli(p).
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u = 0 exactly.
        let u = loop {
            let u = self.gen_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.gen_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Log-normal with `median` and shape `sigma`: exp(N(ln median, σ)).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (median.ln() + sigma * self.normal()).exp()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_range_usize(0, i);
            slice.swap(i, j);
        }
    }

    /// Uniformly chosen element (None if empty).
    pub fn choose<'a, T>(&mut self, slice: &'a [T]) -> Option<&'a T> {
        if slice.is_empty() {
            None
        } else {
            Some(&slice[self.gen_range_usize(0, slice.len() - 1)])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(Rng::seed_from_u64(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::seed_from_u64(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn range_usize_inclusive_and_unbiased_ends() {
        let mut r = Rng::seed_from_u64(2);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..10_000 {
            let v = r.gen_range_usize(3, 7);
            assert!((3..=7).contains(&v));
            hit_lo |= v == 3;
            hit_hi |= v == 7;
        }
        assert!(hit_lo && hit_hi);
        assert_eq!(r.gen_range_usize(5, 5), 5);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<f64> = (0..20_001).map(|_| r.lognormal(10.0, 0.6)).collect();
        v.sort_by(f64::total_cmp);
        let median = v[v.len() / 2];
        assert!((median - 10.0).abs() < 0.5, "median {median}");
        assert!(v.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn bernoulli_frequency() {
        let mut r = Rng::seed_from_u64(5);
        let hits = (0..10_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
        assert_ne!(v, sorted, "49!/50! chance of identity — treat as failure");
    }

    #[test]
    fn choose_empty_and_single() {
        let mut r = Rng::seed_from_u64(7);
        let empty: [u8; 0] = [];
        assert!(r.choose(&empty).is_none());
        assert_eq!(r.choose(&[9]), Some(&9));
    }
}
