//! Ablation bench over EAFL's Eq. (1) blend weight f — the design
//! choice DESIGN.md calls out (§3.1 Q2 trade-off). f = 1 degenerates to
//! Oort-like utility chasing, f = 0 to pure battery chasing; the paper
//! operates at f = 0.25.
//!
//! Run: cargo bench --bench ablation_f_sweep

use eafl::benchkit::Bench;
use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::metrics::Summary;
use eafl::runtime::MockRuntime;

fn run(f: f64, rounds: usize) -> Summary {
    let runtime = MockRuntime::default();
    let mut cfg = ExperimentConfig::paper_default(SelectorKind::Eafl);
    cfg.name = format!("f={f}");
    cfg.federation.rounds = rounds;
    cfg.federation.num_clients = 100;
    cfg.selector.eafl_f = f;
    cfg.devices.min_init_battery = 0.10;
    cfg.devices.max_init_battery = 0.6;
    Coordinator::new(cfg, &runtime).unwrap().run().unwrap().summary()
}

fn main() {
    const ROUNDS: usize = 150;
    let mut bench = Bench::heavy();
    let mut rows = Vec::new();
    for f in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let s = bench.run_once(&format!("f-sweep f={f} ({ROUNDS} rounds, mock)"), || {
            run(f, ROUNDS)
        });
        rows.push((f, s));
    }

    println!("\n=== Eq. (1) f ablation ===");
    println!(
        "{:<6} {:>9} {:>10} {:>10} {:>13} {:>12}",
        "f", "acc", "dropouts", "fairness", "mean_rnd(s)", "energy(kJ)"
    );
    for (f, s) in &rows {
        println!(
            "{:<6} {:>9.4} {:>10} {:>10.3} {:>13.1} {:>12.1}",
            f,
            s.final_accuracy,
            s.total_dropouts,
            s.final_fairness,
            s.mean_round_duration_s,
            s.total_fl_energy_j / 1000.0
        );
    }

    // Shape check: battery-heavier blends (smaller f) must not drop
    // MORE clients than the pure-utility extreme.
    let d0 = rows[0].1.total_dropouts; // f = 0
    let d1 = rows.last().unwrap().1.total_dropouts; // f = 1
    println!(
        "\nshape: dropouts(f=0)={d0} <= dropouts(f=1)={d1}: {}",
        if d0 <= d1 { "HOLDS" } else { "VIOLATED" }
    );
}
