//! Network-evolution models — how a client's [`LinkProfile`] looks at a
//! given point in simulated time.
//!
//! [`SimPhase`](crate::coordinator::SimPhase) consults the scenario's
//! network model when resolving a round: the *plan* (and therefore the
//! selector's deadline) is built from the server's registered profiles,
//! but the *simulated reality* uses the effective link — so degraded
//! networks surface as extra stragglers and extra communication energy,
//! exactly the failure mode a static simulator cannot show.
//!
//! Like the availability models, every implementation is a pure
//! function of (seed, client, time).

use crate::network::LinkProfile;

use super::hash01;

/// Evolves per-client link profiles over simulated time. Must be
/// deterministic and side-effect free.
pub trait NetworkModel: Send + Sync {
    /// Effective link for client `id` at wall-clock `clock_h`, derived
    /// from its registered `base` profile.
    fn link_at(&self, id: usize, base: &LinkProfile, clock_h: f64) -> LinkProfile;

    /// True when `link_at` is the identity — lets the sim phase reuse
    /// the plan's timings without re-deriving them.
    fn is_static(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

/// Scale both directions of a link, flooring the factor so a
/// misconfigured scenario cannot produce a zero-bandwidth link (the
/// transfer-time math divides by it).
fn scale_link(base: &LinkProfile, factor: f64) -> LinkProfile {
    let f = factor.max(0.01);
    LinkProfile {
        medium: base.medium,
        down_mbps: base.down_mbps * f,
        up_mbps: base.up_mbps * f,
    }
}

/// Hour-of-day containment for a daily window; `start > end` wraps
/// midnight (e.g. 22→6).
pub fn in_daily_window(hour_of_day: f64, start: f64, end: f64) -> bool {
    if start <= end {
        hour_of_day >= start && hour_of_day < end
    } else {
        hour_of_day >= start || hour_of_day < end
    }
}

/// The seed environment: links never change.
pub struct StaticNetwork;

impl NetworkModel for StaticNetwork {
    fn link_at(&self, _id: usize, base: &LinkProfile, _clock_h: f64) -> LinkProfile {
        *base
    }
    fn is_static(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "static"
    }
}

/// A fixed, seed-deterministic fraction of clients runs on links far
/// slower than their registered profile — the server's estimates are
/// systematically optimistic for the degraded tail.
pub struct DegradedTail {
    pub seed: u64,
    /// Fraction of the population in the degraded tail, [0, 1].
    pub fraction: f64,
    /// Bandwidth multiplier applied to degraded clients (e.g. 0.25).
    pub factor: f64,
}

impl DegradedTail {
    /// Whether `id` is in the degraded tail (stable over the whole run).
    pub fn is_degraded(&self, id: usize) -> bool {
        hash01(self.seed, id as u64, 0xDE_617AD) < self.fraction
    }
}

impl NetworkModel for DegradedTail {
    fn link_at(&self, id: usize, base: &LinkProfile, _clock_h: f64) -> LinkProfile {
        if self.is_degraded(id) {
            scale_link(base, self.factor)
        } else {
            *base
        }
    }
    fn name(&self) -> &'static str {
        "degraded-tail"
    }
}

/// Everyone's bandwidth collapses during a daily congestion window
/// (rush hour, evening streaming peak): a population-wide, wall-clock
/// keyed effect rather than a per-client one.
pub struct CongestionWindow {
    /// Daily window [start_hour, end_hour) in hours of day; wraps
    /// midnight when start > end.
    pub start_hour: f64,
    pub end_hour: f64,
    /// Bandwidth multiplier inside the window.
    pub factor: f64,
}

impl NetworkModel for CongestionWindow {
    fn link_at(&self, _id: usize, base: &LinkProfile, clock_h: f64) -> LinkProfile {
        if in_daily_window(clock_h.rem_euclid(24.0), self.start_hour, self.end_hour) {
            scale_link(base, self.factor)
        } else {
            *base
        }
    }
    fn name(&self) -> &'static str {
        "congestion"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::Medium;

    fn base() -> LinkProfile {
        LinkProfile { medium: Medium::Wifi, down_mbps: 20.0, up_mbps: 8.0 }
    }

    #[test]
    fn static_network_is_identity() {
        let l = StaticNetwork.link_at(3, &base(), 17.5);
        assert_eq!(l.down_mbps, 20.0);
        assert_eq!(l.up_mbps, 8.0);
        assert!(StaticNetwork.is_static());
    }

    #[test]
    fn degraded_tail_hits_roughly_the_configured_fraction() {
        let m = DegradedTail { seed: 3, fraction: 0.5, factor: 0.25 };
        let degraded = (0..1000).filter(|&id| m.is_degraded(id)).count();
        assert!((350..=650).contains(&degraded), "got {degraded}/1000");
        // Stable per client, applied to both directions.
        for id in 0..50 {
            let l = m.link_at(id, &base(), 0.0);
            let l2 = m.link_at(id, &base(), 999.0);
            assert_eq!(l.down_mbps, l2.down_mbps, "tail membership is time-invariant");
            if m.is_degraded(id) {
                assert!((l.down_mbps - 5.0).abs() < 1e-12);
                assert!((l.up_mbps - 2.0).abs() < 1e-12);
            } else {
                assert_eq!(l.down_mbps, 20.0);
            }
        }
    }

    #[test]
    fn degraded_fraction_extremes() {
        let none = DegradedTail { seed: 1, fraction: 0.0, factor: 0.1 };
        let all = DegradedTail { seed: 1, fraction: 1.0, factor: 0.1 };
        assert!((0..200).all(|id| !none.is_degraded(id)));
        assert!((0..200).all(|id| all.is_degraded(id)));
    }

    #[test]
    fn congestion_window_keys_on_hour_of_day() {
        let m = CongestionWindow { start_hour: 17.0, end_hour: 21.0, factor: 0.5 };
        assert_eq!(m.link_at(0, &base(), 18.0).down_mbps, 10.0);
        assert_eq!(m.link_at(0, &base(), 18.0 + 48.0).down_mbps, 10.0, "daily repeat");
        assert_eq!(m.link_at(0, &base(), 10.0).down_mbps, 20.0);
        assert_eq!(m.link_at(0, &base(), 21.0).down_mbps, 20.0, "end exclusive");
    }

    #[test]
    fn midnight_wrapping_window() {
        assert!(in_daily_window(23.0, 22.0, 6.0));
        assert!(in_daily_window(2.0, 22.0, 6.0));
        assert!(!in_daily_window(12.0, 22.0, 6.0));
        assert!(in_daily_window(22.0, 22.0, 6.0));
        assert!(!in_daily_window(6.0, 22.0, 6.0));
    }

    #[test]
    fn scale_floors_pathological_factors() {
        let l = scale_link(&base(), 0.0);
        assert!(l.down_mbps > 0.0 && l.up_mbps > 0.0);
    }
}
