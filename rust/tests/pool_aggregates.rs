//! Fast-path consistency properties: the registry's incrementally
//! maintained SoA pool + population aggregates must be *exactly* (not
//! approximately) the state a brute-force rebuild produces after any
//! mutation sequence, and the Fenwick weighted sampler must pick the
//! same clients as its linear-scan reference on the same RNG stream.

use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::{Coordinator, PoolAggregates, Registry};
use eafl::runtime::MockRuntime;
use eafl::selection::{weighted_sample_linear, Candidate, FenwickSampler};
use eafl::util::prop::forall;
use eafl::util::rng::Rng;

fn small_registry(rng: &mut Rng) -> (ExperimentConfig, Registry) {
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.federation.num_clients = rng.gen_range_usize(5, 40);
    cfg.devices.seed = rng.next_u64();
    cfg.network.seed = rng.next_u64();
    cfg.data.seed = rng.next_u64();
    cfg.data.min_samples = 3;
    cfg.data.max_samples = 8;
    let registry = Registry::build(&cfg, 35, 1000);
    (cfg, registry)
}

/// Apply one random mutation through the registry's guard API.
fn random_mutation(registry: &mut Registry, rng: &mut Rng, step: u64) {
    let id = rng.gen_range_usize(0, registry.len() - 1);
    let cap = registry.client(id).battery.capacity_joules();
    match rng.gen_range_usize(0, 6) {
        0 => {
            // FL drain — sometimes lethal.
            let e = cap * rng.gen_range_f64(0.0, 1.5);
            registry.drain_fl(id, e, step as f64 * 0.1);
        }
        1 => {
            let e = cap * rng.gen_range_f64(0.0, 0.2);
            registry.drain_background(id, e, step as f64 * 0.1);
        }
        2 => {
            registry.charge_add(id, cap * rng.gen_range_f64(0.0, 0.6));
        }
        3 => {
            registry.recharge_to(id, rng.gen_f64());
        }
        4 => {
            // Feedback-style stats update (selection + utility).
            let util = rng.gen_range_f64(0.0, 300.0);
            let dur = rng.gen_range_f64(10.0, 2000.0);
            let mut s = registry.stats_mut(id);
            s.times_selected += 1;
            s.last_selected_round = Some(step);
            s.stat_util = Some(util);
            s.measured_duration_s = Some(dur);
        }
        _ => {
            // Blacklist-style ban.
            registry.stats_mut(id).banned_until_round = step + 10;
        }
    }
}

/// Incremental aggregates == brute-force recomputation, bit for bit,
/// after arbitrary drain/charge/ban/feedback sequences.
#[test]
fn prop_aggregates_exactly_match_bruteforce() {
    forall(64, |rng| {
        let (_cfg, mut registry) = small_registry(rng);
        assert_eq!(*registry.aggregates(), PoolAggregates::recompute(&registry));
        let steps = rng.gen_range_usize(1, 120) as u64;
        for step in 0..steps {
            random_mutation(&mut registry, rng, step);
        }
        let brute = PoolAggregates::recompute(&registry);
        assert_eq!(
            *registry.aggregates(),
            brute,
            "incremental aggregates drifted from brute force"
        );
        // The O(1) metric accessors agree with O(N) scans.
        let alive = registry.clients().iter().filter(|c| c.battery.is_alive()).count();
        assert_eq!(registry.alive_count(), alive);
        assert_eq!(registry.dead_count(), registry.len() - alive);
        let fl: f64 = registry.clients().iter().map(|c| c.battery.fl_energy_j).sum();
        assert!((registry.total_fl_energy_j() - fl).abs() < 1e-6);
        let counts = registry.selection_counts();
        assert_eq!(
            registry.aggregates().selected_sum,
            counts.iter().sum::<u64>()
        );
        assert_eq!(
            registry.aggregates().selected_sum_sq,
            counts.iter().map(|&c| (c as u128) * (c as u128)).sum::<u128>()
        );
    });
}

/// The SoA fast path produces the same candidates as the allocating
/// reference that recomputes every projection, after any mutations.
#[test]
fn prop_fill_candidates_matches_reference() {
    forall(48, |rng| {
        let (cfg, mut registry) = small_registry(rng);
        let steps = rng.gen_range_usize(0, 60) as u64;
        for step in 0..steps {
            random_mutation(&mut registry, rng, step);
        }
        let round = rng.gen_range_usize(1, 30) as u64;
        let floor = rng.gen_range_f64(0.0, 0.3);
        // Deterministic pseudo-availability gate, applied to both paths.
        let avail_seed = rng.next_u64();
        let gate = |id: usize| (id as u64).wrapping_mul(avail_seed) % 4 != 0;

        let mut reference = registry.candidates(
            round,
            floor,
            cfg.training.local_steps,
            cfg.data.batch_size,
        );
        reference.retain(|c| gate(c.id));
        let mut fast: Vec<Candidate> = Vec::new();
        registry.fill_candidates(round, floor, gate, &mut fast);

        assert_eq!(fast.len(), reference.len());
        for (a, b) in fast.iter().zip(&reference) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.stat_util, b.stat_util);
            assert_eq!(a.measured_duration_s, b.measured_duration_s);
            assert_eq!(a.expected_duration_s, b.expected_duration_s);
            assert_eq!(a.last_selected_round, b.last_selected_round);
            assert_eq!(a.battery_frac, b.battery_frac);
            assert_eq!(a.projected_drain_frac, b.projected_drain_frac);
            assert_eq!(a.round_energy_j, b.round_energy_j);
        }
    });
}

/// Fenwick inverse-CDF sampling picks exactly what the linear-scan
/// reference picks, for the same weights and RNG stream.
#[test]
fn prop_fenwick_sampler_matches_linear_reference() {
    forall(96, |rng| {
        let n = rng.gen_range_usize(1, 300);
        let weights: Vec<f64> = (0..n)
            .map(|_| match rng.gen_range_usize(0, 3) {
                0 => rng.gen_range_f64(1e-12, 1e-6), // tiny
                1 => rng.gen_range_f64(0.1, 10.0),   // typical
                _ => rng.gen_range_f64(100.0, 1e6),  // dominant
            })
            .collect();
        let k = rng.gen_range_usize(1, n + 3);
        let draw_seed = rng.next_u64();
        let mut sampler = FenwickSampler::new(&weights);
        let fenwick = sampler.sample_distinct(k, &mut Rng::seed_from_u64(draw_seed));
        let linear =
            weighted_sample_linear(&weights, k, &mut Rng::seed_from_u64(draw_seed));
        assert_eq!(fenwick, linear, "n={n} k={k}");
        assert_eq!(fenwick.len(), k.min(n), "must draw k distinct or exhaust");
        let mut dedup = fenwick.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), fenwick.len(), "duplicate draw");
    });
}

/// Degenerate pool: every weight zero. The sampler must fall back to
/// uniform minimal weights (nobody gets literal zero probability, the
/// old linear scans' clamp semantics) and still match the reference.
#[test]
fn fenwick_all_zero_weights_stay_drawable_and_match_linear() {
    for n in [1usize, 2, 7, 64] {
        let weights = vec![0.0; n];
        for draw_seed in 0..8u64 {
            let mut sampler = FenwickSampler::new(&weights);
            let fenwick = sampler.sample_distinct(n, &mut Rng::seed_from_u64(draw_seed));
            let linear =
                weighted_sample_linear(&weights, n, &mut Rng::seed_from_u64(draw_seed));
            assert_eq!(fenwick, linear, "n={n} seed={draw_seed}");
            // Exhaustive and distinct: every index drawn exactly once.
            let mut sorted = fenwick;
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "n={n} seed={draw_seed}");
        }
    }
}

/// A single eligible client is always the pick — whatever its weight
/// (positive, zero, subnormal, infinite, NaN) — and the pool exhausts
/// after one draw, on both implementations.
#[test]
fn fenwick_single_eligible_client_is_always_picked() {
    for w in [1.0, 0.0, -3.0, 1e-320, 1e300, f64::INFINITY, f64::NAN] {
        for draw_seed in 0..8u64 {
            let mut sampler = FenwickSampler::new(&[w]);
            let mut rng = Rng::seed_from_u64(draw_seed);
            assert_eq!(sampler.draw(&mut rng), Some(0), "w={w}");
            assert_eq!(sampler.draw(&mut rng), None, "pool must exhaust, w={w}");
            assert_eq!(
                weighted_sample_linear(&[w], 2, &mut Rng::seed_from_u64(draw_seed)),
                vec![0],
                "w={w}"
            );
        }
    }
}

/// Weights spanning subnormals to 1e300 in one pool: quantization
/// floors the pool max at 1e-290 so a subnormal-dominated pool cannot
/// overflow the u64 grid, and the huge dynamic range must not break
/// Fenwick == linear pick equality.
#[test]
fn fenwick_extreme_weight_spans_match_linear() {
    let pools: Vec<Vec<f64>> = vec![
        // Full span: subnormal .. 1e300 (everything below ~1e290 times
        // the max quantizes to the minimal 1 grid unit).
        vec![1e-320, 5e-310, 1e-290, 1e-30, 1.0, 1e30, 1e300],
        // All-subnormal pool: max < the 1e-290 quantization floor —
        // the scale must stay finite (this is the overflow regression).
        vec![1e-320, 5e-310, 3e-308],
        // Exactly at the floor plus neighbors straddling it.
        vec![1e-290, 9.9e-291, 1.1e-290],
        // Huge weights only.
        vec![1e300, 5e299, 1e280],
        // Mixture with non-finite and non-positive entries (clamped to
        // the minimal weight on both implementations).
        vec![f64::INFINITY, 1e300, -1.0, 0.0, f64::NAN, 1e-320],
    ];
    for (i, weights) in pools.iter().enumerate() {
        let n = weights.len();
        for k in [1usize, 2, n, n + 3] {
            for draw_seed in 0..16u64 {
                let mut sampler = FenwickSampler::new(weights);
                assert!(
                    sampler.total() < u64::MAX / 2,
                    "pool {i}: quantized total overflowed the grid ({})",
                    sampler.total()
                );
                let fenwick =
                    sampler.sample_distinct(k, &mut Rng::seed_from_u64(draw_seed));
                let linear =
                    weighted_sample_linear(weights, k, &mut Rng::seed_from_u64(draw_seed));
                assert_eq!(fenwick, linear, "pool {i} k={k} seed={draw_seed}");
                assert_eq!(fenwick.len(), k.min(n), "pool {i} k={k}");
            }
        }
    }
}

/// End to end: a full coordinator run (every engine mutation site —
/// sim drains, background drains, recharge, feedback, blacklist)
/// leaves the incremental aggregates exactly equal to brute force.
#[test]
fn coordinator_run_keeps_aggregates_exact() {
    for kind in [SelectorKind::Random, SelectorKind::Oort, SelectorKind::Eafl] {
        let mut cfg = ExperimentConfig::smoke(kind);
        cfg.federation.rounds = 8;
        cfg.data.min_samples = 5;
        cfg.data.max_samples = 20;
        cfg.data.test_samples = 128;
        // Exercise the recharge mutation path too.
        cfg.devices.recharge_after_hours = 0.5;
        cfg.devices.recharge_to_fraction = 0.6;
        let runtime = MockRuntime { train_batch: cfg.data.batch_size, ..MockRuntime::default() };
        let mut coordinator = Coordinator::new(cfg, &runtime).unwrap();
        for round in 1..=8u64 {
            coordinator.run_round(round).unwrap();
            let registry = coordinator.registry();
            assert_eq!(
                *registry.aggregates(),
                PoolAggregates::recompute(registry),
                "{kind:?} round {round}: aggregates drifted"
            );
        }
    }
}
