//! Deterministic event queue keyed on simulated seconds.
//!
//! `BinaryHeap` over (time, seq) with seq as the tie-breaker so that
//! events scheduled first fire first at equal timestamps — no
//! nondeterminism leaks into metrics from heap ordering.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A timed event carrying a payload.
#[derive(Debug, Clone)]
pub struct Event<T> {
    pub time_s: f64,
    seq: u64,
    pub payload: T,
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time_s == other.time_s && self.seq == other.seq
    }
}
impl<T> Eq for Event<T> {}

impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap on (time, seq).
        other
            .time_s
            .total_cmp(&self.time_s)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue with FIFO tie-breaking.
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    next_seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Schedule `payload` at simulated time `time_s`.
    ///
    /// Contract: event times must be finite and non-negative. A NaN
    /// would silently corrupt the heap order (`total_cmp` puts NaN at
    /// an extreme, not where the caller expects), so this is enforced
    /// in release builds too — corrupt timestamps are a determinism
    /// bug, not a recoverable condition.
    pub fn push(&mut self, time_s: f64, payload: T) {
        assert!(time_s.is_finite() && time_s >= 0.0, "bad event time {time_s}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time_s, seq, payload });
    }

    pub fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.push(5.0, 1);
        q.push(5.0, 2);
        q.push(5.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "bad event time")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "bad event time")]
    fn rejects_negative_time() {
        let mut q = EventQueue::new();
        q.push(-1.0, ());
    }

    #[test]
    #[should_panic(expected = "bad event time")]
    fn rejects_infinite_time() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, ());
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.push(0.0, ());
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }
}
