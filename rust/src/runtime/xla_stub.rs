//! Offline stand-in for the PJRT-backed runtime.
//!
//! Compiled when the `xla` cargo feature is OFF (the default): the
//! build then has no dependency on the `xla` bridge crate, and any
//! attempt to load the real runtime fails at *load* time with an
//! actionable message instead of at build time. Keeps `eafl run` /
//! `compare` / the examples compiling unchanged — they all fall back
//! to (or are pointed at) [`super::MockRuntime`] via `--mock`.

use std::path::Path;

use anyhow::{bail, Result};

use super::{EvalOutput, ModelRuntime, TrainOutput};

/// Unconstructible placeholder for the PJRT runtime. [`XlaRuntime::load`]
/// always fails in this build; the `ModelRuntime` impl exists only so
/// call sites type-check identically with and without the feature.
#[derive(Debug)]
pub struct XlaRuntime {
    _unconstructible: std::convert::Infallible,
}

impl XlaRuntime {
    /// Always fails: this binary was built without the `xla` feature.
    pub fn load(dir: &Path) -> Result<Self> {
        bail!(
            "eafl was built without the `xla` feature — the PJRT runtime for \
             artifacts in {dir:?} is unavailable. Rebuild with `cargo build \
             --features xla` (needs the xla bridge crate and `make artifacts`) \
             or pass --mock to use the analytic runtime"
        )
    }

    /// Default artifact location relative to the repo root, overridable
    /// via `EAFL_ARTIFACTS` (kept in sync with the real runtime).
    pub fn default_dir() -> std::path::PathBuf {
        std::env::var_os("EAFL_ARTIFACTS")
            .map(Into::into)
            .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
    }
}

impl ModelRuntime for XlaRuntime {
    fn param_count(&self) -> usize {
        match self._unconstructible {}
    }
    fn train_batch(&self) -> usize {
        match self._unconstructible {}
    }
    fn eval_batch(&self) -> usize {
        match self._unconstructible {}
    }
    fn num_classes(&self) -> usize {
        match self._unconstructible {}
    }
    fn input_hw(&self) -> usize {
        match self._unconstructible {}
    }
    fn init_params(&self, _seed: u32) -> Result<Vec<f32>> {
        match self._unconstructible {}
    }
    fn train_step(&self, _params: &[f32], _x: &[f32], _y: &[i32], _lr: f32) -> Result<TrainOutput> {
        match self._unconstructible {}
    }
    fn eval_step(&self, _params: &[f32], _x: &[f32], _y: &[i32]) -> Result<EvalOutput> {
        match self._unconstructible {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_with_actionable_message() {
        let err = XlaRuntime::load(Path::new("artifacts")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("--mock"), "must route users to the mock: {msg}");
        assert!(msg.contains("xla"), "must name the missing feature: {msg}");
    }

    #[test]
    fn default_dir_honors_env_override() {
        // Don't mutate the env (tests run in parallel); just check the
        // non-overridden default.
        if std::env::var_os("EAFL_ARTIFACTS").is_none() {
            assert_eq!(XlaRuntime::default_dir(), std::path::PathBuf::from("artifacts"));
        }
    }
}
