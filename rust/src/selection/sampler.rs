//! Weighted sampling without replacement — the one draw primitive both
//! selectors use (Oort's exploitation band and EAFL's energy-weighted
//! exploration previously carried separate inline O(k·N) linear scans).
//!
//! Weights are quantized to u64 grid units relative to the pool
//! maximum, which makes every prefix sum *exact and associative*: the
//! O(log n) Fenwick inverse-CDF descent is then provably identical to a
//! linear scan over the same quantized weights — not merely close in
//! distribution. [`weighted_sample_linear`] is that linear-scan
//! reference, kept for the equivalence property test
//! (`rust/tests/pool_aggregates.rs`) and as the baseline in
//! `benches/plan_path_throughput.rs`. Both consume exactly one
//! `rng.gen_f64()` per draw, so swapping implementations never perturbs
//! the RNG stream.

use crate::util::rng::Rng;

/// Quantization grid: the largest weight maps to 2³² units, so relative
/// resolution is ~2.3e-10 and a million-entry pool tops out near 2⁵²
/// total units — comfortably inside u64.
const WEIGHT_GRID: f64 = (1u64 << 32) as f64;

/// Map raw weights onto the exact integer grid, into a reused buffer.
/// Non-positive and non-finite weights get the minimal representable
/// weight (1 unit), so every entry stays drawable — matching the old
/// linear scans' clamp semantics where no candidate had literally zero
/// probability. The max is floored at 1e-290 so a subnormal pool can
/// never overflow `scale` (and with it the u64 grid) to infinity.
/// Takes a cloneable iterator (two passes: max, then map) so callers
/// can feed weights straight out of their pools without staging them
/// in a `Vec<f64>` first.
fn quantize_weights_into<I>(weights: I, out: &mut Vec<u64>)
where
    I: Iterator<Item = f64> + Clone,
{
    out.clear();
    let max = weights.clone().filter(|w| w.is_finite()).fold(0.0f64, f64::max);
    if max <= 0.0 {
        out.extend(weights.map(|_| 1));
        return;
    }
    let scale = WEIGHT_GRID / max.max(1e-290);
    out.extend(weights.map(|w| {
        if w.is_finite() && w > 0.0 {
            ((w * scale).ceil() as u64).max(1)
        } else {
            1
        }
    }));
}

/// One draw's target grid position from a single uniform variate.
fn target_units(r: f64, total: u64) -> u64 {
    ((r * total as f64) as u64).min(total - 1)
}

/// Fenwick-tree (binary indexed) inverse-CDF sampler over quantized
/// weights. Build is O(n); each draw-without-replacement is O(log n).
pub struct FenwickSampler {
    /// 1-indexed Fenwick tree over quantized weights.
    tree: Vec<u64>,
    /// Current weight of each (0-indexed) item; 0 = removed.
    weights: Vec<u64>,
    /// Sum of all remaining weights.
    total: u64,
    /// Largest power of two ≤ n (descent mask).
    top_bit: usize,
}

impl FenwickSampler {
    /// An empty sampler — the reusable-scratch starting point; call
    /// [`FenwickSampler::rebuild`] to load a pool.
    pub fn empty() -> Self {
        Self { tree: Vec::new(), weights: Vec::new(), total: 0, top_bit: 0 }
    }

    /// Build a sampler over `weights` (see [`quantize_weights_into`]
    /// for the clamp semantics).
    pub fn new(weights: &[f64]) -> Self {
        let mut sampler = Self::empty();
        sampler.rebuild(weights);
        sampler
    }

    /// Reload the sampler with a fresh pool, reusing the tree and
    /// weight buffers — steady-state O(n) with zero allocation, which
    /// is what keeps the selectors' per-round draws allocation-free.
    pub fn rebuild(&mut self, weights: &[f64]) {
        self.rebuild_from(weights.iter().copied());
    }

    /// [`FenwickSampler::rebuild`] from a cloneable weight iterator —
    /// lets the selectors quantize straight out of their `(id, weight)`
    /// pools with no staging buffer.
    pub fn rebuild_from<I>(&mut self, weights: I)
    where
        I: Iterator<Item = f64> + Clone,
    {
        quantize_weights_into(weights, &mut self.weights);
        let n = self.weights.len();
        self.tree.clear();
        self.tree.resize(n + 1, 0);
        // O(n) Fenwick construction.
        for i in 0..n {
            let pos = i + 1;
            self.tree[pos] += self.weights[i];
            let parent = pos + (pos & pos.wrapping_neg());
            if parent <= n {
                let subtotal = self.tree[pos];
                self.tree[parent] += subtotal;
            }
        }
        self.total = self.weights.iter().sum();
        let top_exp =
            if n == 0 { 0 } else { usize::BITS as usize - 1 - n.leading_zeros() as usize };
        self.top_bit = 1usize << top_exp;
    }

    /// Remaining (non-removed) total weight in grid units.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Draw one index without replacement; `None` once the pool is
    /// exhausted. Consumes exactly one `gen_f64` per successful draw.
    pub fn draw(&mut self, rng: &mut Rng) -> Option<usize> {
        if self.total == 0 {
            return None;
        }
        let target = target_units(rng.gen_f64(), self.total);
        // Descent: find the largest pos with prefix_sum(pos) <= target;
        // the picked item is then `pos` (0-indexed), the owner of the
        // grid interval containing `target`.
        let n = self.weights.len();
        let mut pos = 0usize;
        let mut rem = target;
        let mut mask = self.top_bit;
        while mask != 0 {
            let next = pos + mask;
            if next <= n && self.tree[next] <= rem {
                pos = next;
                rem -= self.tree[next];
            }
            mask >>= 1;
        }
        let idx = pos; // prefix_sum(idx) <= target < prefix_sum(idx + 1)
        self.remove(idx);
        Some(idx)
    }

    /// Zero out `idx`'s weight so it cannot be drawn again.
    fn remove(&mut self, idx: usize) {
        let w = self.weights[idx];
        debug_assert!(w > 0, "drew an already-removed index");
        self.weights[idx] = 0;
        self.total -= w;
        let n = self.weights.len();
        let mut pos = idx + 1;
        while pos <= n {
            self.tree[pos] -= w;
            pos += pos & pos.wrapping_neg();
        }
    }

    /// Draw up to `k` distinct indices (fewer if the pool runs out).
    pub fn sample_distinct(&mut self, k: usize, rng: &mut Rng) -> Vec<usize> {
        let mut picked = Vec::with_capacity(k.min(self.weights.len()));
        while picked.len() < k {
            match self.draw(rng) {
                Some(idx) => picked.push(idx),
                None => break,
            }
        }
        picked
    }
}

/// Linear-scan reference: identical quantization, identical RNG
/// consumption, O(k·n) — the executable specification the Fenwick
/// sampler is tested against.
pub fn weighted_sample_linear(weights: &[f64], k: usize, rng: &mut Rng) -> Vec<usize> {
    let mut q = Vec::new();
    quantize_weights_into(weights.iter().copied(), &mut q);
    let mut total: u64 = q.iter().sum();
    let mut picked = Vec::with_capacity(k.min(q.len()));
    while picked.len() < k && total > 0 {
        let target = target_units(rng.gen_f64(), total);
        let mut cum = 0u64;
        let mut idx = 0usize;
        for (i, &w) in q.iter().enumerate() {
            cum += w;
            if target < cum {
                idx = i;
                break;
            }
        }
        picked.push(idx);
        total -= q[idx];
        q[idx] = 0;
    }
    picked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fenwick_matches_linear_reference() {
        let mut rng = Rng::seed_from_u64(1);
        for n in [1usize, 2, 3, 17, 100, 1000] {
            let weights: Vec<f64> =
                (0..n).map(|_| rng.gen_range_f64(1e-9, 50.0)).collect();
            for k in [1usize, 2, n / 2 + 1, n, n + 5] {
                for seed in 0..5 {
                    let mut s = FenwickSampler::new(&weights);
                    let a = s.sample_distinct(k, &mut Rng::seed_from_u64(seed));
                    let b =
                        weighted_sample_linear(&weights, k, &mut Rng::seed_from_u64(seed));
                    assert_eq!(a, b, "n={n} k={k} seed={seed}");
                }
            }
        }
    }

    #[test]
    fn draws_are_distinct_and_exhaustive() {
        let weights = vec![5.0, 1.0, 3.0, 0.0, 2.0];
        let mut s = FenwickSampler::new(&weights);
        let mut rng = Rng::seed_from_u64(7);
        let picked = s.sample_distinct(10, &mut rng);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4], "exhausts the pool, no repeats");
        assert!(s.draw(&mut rng).is_none());
    }

    #[test]
    fn heavy_weight_dominates() {
        let weights = vec![1.0, 1000.0, 1.0];
        let mut first_pick_heavy = 0;
        for seed in 0..200 {
            let mut s = FenwickSampler::new(&weights);
            let p = s.sample_distinct(1, &mut Rng::seed_from_u64(seed));
            if p == vec![1] {
                first_pick_heavy += 1;
            }
        }
        assert!(first_pick_heavy > 180, "got {first_pick_heavy}/200");
    }

    #[test]
    fn zero_and_negative_weights_stay_drawable() {
        // Degenerate pools (all-zero, negatives, NaN) fall back to
        // uniform minimal weights rather than dividing by zero.
        for weights in [vec![0.0, 0.0, 0.0], vec![-1.0, 0.0, f64::NAN]] {
            let mut s = FenwickSampler::new(&weights);
            let picked = s.sample_distinct(3, &mut Rng::seed_from_u64(3));
            let mut sorted = picked;
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2]);
        }
    }

    #[test]
    fn empty_pool_yields_nothing() {
        let mut s = FenwickSampler::new(&[]);
        assert!(s.draw(&mut Rng::seed_from_u64(0)).is_none());
        assert!(weighted_sample_linear(&[], 3, &mut Rng::seed_from_u64(0)).is_empty());
    }

    #[test]
    fn subnormal_pools_do_not_overflow_the_grid() {
        // A pool whose max weight is subnormal must not blow the scale
        // (and with it every quantized weight) up to infinity/u64::MAX.
        let weights = vec![1e-305, 5e-306, 1e-320];
        let mut s = FenwickSampler::new(&weights);
        assert!(s.total() < u64::MAX / 2, "grid overflowed: {}", s.total());
        let picked = s.sample_distinct(3, &mut Rng::seed_from_u64(1));
        let mut sorted = picked;
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2]);
    }

    #[test]
    fn rebuild_reuses_cleanly() {
        let mut s = FenwickSampler::new(&[1.0, 2.0, 3.0]);
        s.sample_distinct(2, &mut Rng::seed_from_u64(2));
        // Reloading with a different pool behaves exactly like a fresh
        // sampler over that pool.
        let weights = vec![4.0, 1.0, 0.5, 9.0];
        s.rebuild(&weights);
        for seed in 0..10 {
            let mut fresh = FenwickSampler::new(&weights);
            let a = fresh.sample_distinct(4, &mut Rng::seed_from_u64(seed));
            s.rebuild(&weights);
            let b = s.sample_distinct(4, &mut Rng::seed_from_u64(seed));
            assert_eq!(a, b);
        }
    }
}
