#!/usr/bin/env bash
# Render BENCH_history.jsonl (appended per commit by
# append_bench_history.sh) as a per-SHA benchmark trend table.
#
# Thin wrapper over `eafl trend` so the table logic lives in one place
# (rust/src/benchkit.rs) and stays unit-tested; this script only finds a
# built binary and forwards the flags.
#
# Usage: bench_trend.sh [--history FILE] [--csv] [--out FILE]

set -euo pipefail

cd "$(dirname "$0")/.."

bin=""
for candidate in target/release/eafl target/debug/eafl; do
  if [ -x "$candidate" ]; then
    bin="$candidate"
    break
  fi
done
if [ -z "$bin" ]; then
  echo "bench_trend: no built eafl binary — run \`cargo build --release\` first" >&2
  exit 1
fi

exec "$bin" trend "$@"
