//! Selector microbenchmarks: per-round selection cost for Random, Oort
//! and EAFL across population sizes 100..100k — L3's own hot path
//! (everything except model execution).
//!
//! Run: cargo bench --bench selection_micro

use eafl::benchkit::{bb, Bench};
use eafl::config::{SelectorConfig, SelectorKind};
use eafl::selection::{make_selector, Candidate};
use eafl::util::rng::Rng;

fn candidates(n: usize) -> Vec<Candidate> {
    let mut rng = Rng::seed_from_u64(7);
    (0..n)
        .map(|id| Candidate {
            id,
            // 70% explored with varied utility, 30% fresh.
            stat_util: if rng.gen_bool(0.7) {
                Some(rng.gen_range_f64(1.0, 400.0))
            } else {
                None
            },
            measured_duration_s: Some(rng.gen_range_f64(60.0, 900.0)),
            expected_duration_s: rng.gen_range_f64(60.0, 900.0),
            last_selected_round: rng.gen_range_usize(0, 50) as u64,
            battery_frac: rng.gen_f64(),
            projected_drain_frac: rng.gen_range_f64(0.001, 0.05),
        })
        .collect()
}

fn main() {
    let mut bench = Bench::new();
    for n in [100usize, 1_000, 10_000, 100_000] {
        let cands = candidates(n);
        for kind in [SelectorKind::Random, SelectorKind::Oort, SelectorKind::Eafl] {
            let mut cfg = SelectorConfig::default();
            cfg.kind = kind;
            let mut selector = make_selector(&cfg);
            let mut rng = Rng::seed_from_u64(11);
            let mut round = 0u64;
            bench.run(&format!("{kind} select K=10 of N={n}"), || {
                round += 1;
                bb(selector.select(round, bb(&cands), 10, &mut rng));
            });
        }
    }
}
