//! Dense index set with O(1) insert / remove / contains — the
//! free-list-style liveness indices behind the registry's sub-O(N)
//! maintenance paths.
//!
//! A classic sparse/dense pair: `dense` holds the member ids in
//! arbitrary order, `pos[id]` holds each member's slot in `dense`
//! (`u32::MAX` = absent). Removal swap-removes from `dense`, so both
//! operations are O(1) and iteration is a contiguous slice scan over
//! exactly the members — no hashing, no tombstones, no per-round
//! compaction.
//!
//! The iteration order is an implementation detail (it depends on the
//! insert/remove history), so callers that need deterministic output
//! must sort the ids they collect — see `CooldownRecharge`, which
//! sorts its revival candidates before mutating batteries.

/// O(1) set over indices `0..capacity`.
#[derive(Debug, Clone)]
pub struct IndexSet {
    dense: Vec<u32>,
    pos: Vec<u32>,
}

const ABSENT: u32 = u32::MAX;

impl Default for IndexSet {
    /// Empty set over an empty universe — a placeholder until
    /// [`IndexSet::with_capacity`] builds the real one.
    fn default() -> Self {
        Self::with_capacity(0)
    }
}

impl IndexSet {
    /// Empty set over the id universe `0..capacity`.
    pub fn with_capacity(capacity: usize) -> Self {
        assert!(capacity < ABSENT as usize, "IndexSet capacity overflow");
        Self { dense: Vec::new(), pos: vec![ABSENT; capacity] }
    }

    /// Insert `id`; no-op if already present. Returns whether it was
    /// newly inserted.
    pub fn insert(&mut self, id: usize) -> bool {
        if self.pos[id] != ABSENT {
            return false;
        }
        self.pos[id] = self.dense.len() as u32;
        self.dense.push(id as u32);
        true
    }

    /// Remove `id`; no-op if absent. Returns whether it was present.
    /// Swap-remove: the last member takes the vacated dense slot.
    pub fn remove(&mut self, id: usize) -> bool {
        let slot = self.pos[id];
        if slot == ABSENT {
            return false;
        }
        let last = *self.dense.last().expect("non-empty: id is present");
        self.dense.swap_remove(slot as usize);
        if last as usize != id {
            self.pos[last as usize] = slot;
        }
        self.pos[id] = ABSENT;
        true
    }

    /// Membership test.
    pub fn contains(&self, id: usize) -> bool {
        self.pos[id] != ABSENT
    }

    /// The members, in unspecified order.
    pub fn ids(&self) -> &[u32] {
        &self.dense
    }

    /// Member at dense slot `i` — for index-based iteration that stays
    /// valid under swap-remove of the *current* element (don't advance
    /// `i` after removing `self.ids()[i]`).
    pub fn at(&self, i: usize) -> usize {
        self.dense[i] as usize
    }

    pub fn len(&self) -> usize {
        self.dense.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dense.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeSet;

    #[test]
    fn insert_remove_contains_roundtrip() {
        let mut s = IndexSet::with_capacity(10);
        assert!(s.insert(3));
        assert!(!s.insert(3), "double insert is a no-op");
        assert!(s.insert(7));
        assert!(s.contains(3) && s.contains(7) && !s.contains(0));
        assert_eq!(s.len(), 2);
        assert!(s.remove(3));
        assert!(!s.remove(3), "double remove is a no-op");
        assert!(!s.contains(3) && s.contains(7));
        assert_eq!(s.ids(), &[7]);
    }

    #[test]
    fn swap_remove_keeps_positions_consistent() {
        let mut s = IndexSet::with_capacity(5);
        for id in 0..5 {
            s.insert(id);
        }
        // Removing from the middle moves the tail member into its slot.
        s.remove(1);
        assert!(!s.contains(1));
        for id in [0usize, 2, 3, 4] {
            assert!(s.contains(id), "id {id} lost by swap-remove");
            assert!(s.remove(id));
        }
        assert!(s.is_empty());
    }

    #[test]
    fn prop_matches_btreeset_reference() {
        let mut rng = Rng::seed_from_u64(42);
        let cap = 64usize;
        let mut s = IndexSet::with_capacity(cap);
        let mut reference = BTreeSet::new();
        for _ in 0..2000 {
            let id = rng.gen_range_usize(0, cap - 1);
            if rng.gen_bool(0.5) {
                assert_eq!(s.insert(id), reference.insert(id));
            } else {
                assert_eq!(s.remove(id), reference.remove(&id));
            }
            assert_eq!(s.len(), reference.len());
        }
        let mut got: Vec<u32> = s.ids().to_vec();
        got.sort_unstable();
        let want: Vec<u32> = reference.iter().map(|&id| id as u32).collect();
        assert_eq!(got, want);
        for id in 0..cap {
            assert_eq!(s.contains(id), reference.contains(&id));
        }
    }
}
