//! Event sinks: where the deterministic event stream goes.
//!
//! Three implementations, one per consumer class:
//!
//! - [`NullSink`] — the default. The coordinator holds
//!   `Option<Box<dyn EventSink>>` and skips *building* events entirely
//!   when no sink is attached, so the hot path pays a single
//!   `is_some()` branch per seam and zero allocation; `NullSink`
//!   exists for callers that want a sink object anyway.
//! - [`MemorySink`] — collects events in a `Vec` for unit tests and
//!   for in-process consumers (the future `eafl serve` observers).
//! - [`JsonlSink`] — buffered file writer, one compact JSON object per
//!   line, headed by the `eafl-trace-v1` schema tag. Write errors are
//!   latched and surfaced on [`EventSink::flush`] so `emit` stays
//!   infallible on the hot path.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::event::RoundEvent;
use super::TRACE_SCHEMA;

/// A consumer of the deterministic round-event stream. `Send` because
/// campaign workers move whole coordinators across threads.
pub trait EventSink: Send {
    fn emit(&mut self, event: &RoundEvent);

    /// Push buffered output to its destination and report any write
    /// error encountered so far. Called once at end of run.
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Discards everything.
#[derive(Debug, Default)]
pub struct NullSink;

impl EventSink for NullSink {
    fn emit(&mut self, _event: &RoundEvent) {}
}

/// Collects events in memory (tests, in-process observers).
#[derive(Debug, Default)]
pub struct MemorySink {
    pub events: Vec<RoundEvent>,
}

impl MemorySink {
    pub fn new() -> Self {
        Self::default()
    }
}

impl EventSink for MemorySink {
    fn emit(&mut self, event: &RoundEvent) {
        self.events.push(event.clone());
    }
}

/// JSONL trace file (`--trace FILE`): schema header line, then one
/// event per line in emission order.
pub struct JsonlSink {
    out: BufWriter<File>,
    path: PathBuf,
    /// First write error, surfaced on `flush`.
    error: Option<std::io::Error>,
}

impl JsonlSink {
    /// Create (truncate) the trace file and write the schema header.
    /// Fails immediately on unwritable paths so `--trace` errors
    /// surface before any simulation work.
    pub fn create(path: &Path) -> Result<Self> {
        let file = File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        let mut sink =
            Self { out: BufWriter::new(file), path: path.to_path_buf(), error: None };
        sink.write_line(&format!("{{\"schema\": \"{TRACE_SCHEMA}\"}}"));
        Ok(sink)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn write_line(&mut self, line: &str) {
        if self.error.is_some() {
            return;
        }
        if let Err(e) =
            self.out.write_all(line.as_bytes()).and_then(|()| self.out.write_all(b"\n"))
        {
            self.error = Some(e);
        }
    }
}

impl EventSink for JsonlSink {
    fn emit(&mut self, event: &RoundEvent) {
        let line = event.to_line();
        self.write_line(&line);
    }

    fn flush(&mut self) -> Result<()> {
        if let Some(e) = self.error.take() {
            return Err(e).with_context(|| format!("writing trace file {}", self.path.display()));
        }
        self.out
            .flush()
            .with_context(|| format!("flushing trace file {}", self.path.display()))
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        // Best-effort: the coordinator flushes explicitly at end of run
        // to propagate errors; this covers early-exit paths.
        let _ = self.out.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_sink_collects_in_order() {
        let mut sink = MemorySink::new();
        sink.emit(&RoundEvent::BatteryDepleted { id: 1, at_h: 0.5 });
        sink.emit(&RoundEvent::BatteryRevived { id: 1, at_h: 9.0, battery_frac: 0.3 });
        assert_eq!(sink.events.len(), 2);
        assert!(matches!(sink.events[0], RoundEvent::BatteryDepleted { id: 1, .. }));
        sink.flush().unwrap();
    }

    #[test]
    fn null_sink_is_a_no_op() {
        let mut sink = NullSink;
        sink.emit(&RoundEvent::BatteryDepleted { id: 0, at_h: 0.0 });
        sink.flush().unwrap();
    }

    #[test]
    fn jsonl_sink_writes_header_and_lines() {
        let dir = std::env::temp_dir().join(format!("eafl-sink-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace.jsonl");
        let mut sink = JsonlSink::create(&path).unwrap();
        sink.emit(&RoundEvent::BatteryDepleted { id: 3, at_h: 1.0 });
        sink.flush().unwrap();
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], format!("{{\"schema\": \"{TRACE_SCHEMA}\"}}"));
        assert_eq!(lines[1], r#"{"at_h": 1, "ev": "battery_depleted", "id": 3}"#);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn jsonl_sink_rejects_unwritable_path() {
        let err = JsonlSink::create(Path::new("/nonexistent-dir/deep/t.jsonl"))
            .err()
            .expect("must fail");
        assert!(format!("{err:#}").contains("trace"), "{err:#}");
    }
}
