//! Integration tests over the REAL runtime: AOT artifacts -> PJRT CPU
//! -> execute. Requires `make artifacts` (the Makefile's `test` target
//! guarantees ordering).
//!
//! These tests validate the L3<->L2 contract end to end: shapes, real
//! gradient descent through the Pallas-kernel HLO, and the full
//! coordinator loop doing real SGD.
//!
//! Gated behind the `xla` cargo feature: the default offline build has
//! no PJRT bridge (runtime::XlaRuntime is a stub that fails at load),
//! so this whole suite compiles to nothing unless built with
//! `cargo test --features xla` after `make artifacts`.

#![cfg(feature = "xla")]

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, OnceLock};

use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::data::SyntheticSpeech;
use eafl::runtime::{ModelRuntime, XlaRuntime};
use eafl::training::Trainer;

fn artifact_dir() -> PathBuf {
    std::env::var_os("EAFL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

// PJRT CPU client is process-global state; share ONE runtime across
// tests behind a mutex (XlaRuntime is Send but not Sync — the xla
// crate's wrappers hold Rc internals — so cargo's parallel test
// threads must serialize access).
fn runtime() -> MutexGuard<'static, XlaRuntime> {
    static RT: OnceLock<Mutex<XlaRuntime>> = OnceLock::new();
    RT.get_or_init(|| {
        Mutex::new(
            XlaRuntime::load(&artifact_dir())
                .expect("artifacts missing — run `make artifacts` first"),
        )
    })
    .lock()
    // A failed sibling test must not cascade: the runtime itself is
    // stateless between calls, so poisoning is safe to clear.
    .unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[test]
fn manifest_contract_matches_model() {
    let rt = runtime();
    assert_eq!(rt.param_count(), 69_123);
    assert_eq!(rt.num_classes(), 35);
    assert_eq!(rt.input_hw(), 32);
    assert_eq!(rt.train_batch(), 20); // paper batch size
}

#[test]
fn init_params_deterministic_and_seed_sensitive() {
    let rt = runtime();
    let a = rt.init_params(7).unwrap();
    let b = rt.init_params(7).unwrap();
    let c = rt.init_params(8).unwrap();
    assert_eq!(a.len(), rt.param_count());
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert!(a.iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_descends_on_fixed_batch() {
    let rt = runtime();
    let data = SyntheticSpeech::new(rt.input_hw(), rt.num_classes(), 0.6, 3);
    let mut x = vec![0.0f32; rt.train_batch() * data.feature_len()];
    let mut y = vec![0i32; rt.train_batch()];
    let samples: Vec<(u16, u32)> = (0..20).map(|i| ((i % 5) as u16, i as u32)).collect();
    data.fill_batch(&samples, 1.0, &mut x, &mut y);

    let mut params = rt.init_params(1).unwrap();
    let first = rt.train_step(&params, &x, &y, 0.05).unwrap();
    assert_eq!(first.per_example_loss.len(), rt.train_batch());
    let mut loss = first.mean_loss;
    params = first.params;
    for _ in 0..20 {
        let out = rt.train_step(&params, &x, &y, 0.05).unwrap();
        params = out.params;
        loss = out.mean_loss;
    }
    assert!(
        loss < first.mean_loss * 0.7,
        "20 steps must cut loss: {} -> {loss}",
        first.mean_loss
    );
    assert!(params.iter().all(|v| v.is_finite()));
}

#[test]
fn per_example_losses_mean_matches_scalar() {
    let rt = runtime();
    let data = SyntheticSpeech::new(rt.input_hw(), rt.num_classes(), 0.6, 4);
    let mut x = vec![0.0f32; rt.train_batch() * data.feature_len()];
    let mut y = vec![0i32; rt.train_batch()];
    let samples: Vec<(u16, u32)> = (0..20).map(|i| ((i % 7) as u16, i as u32)).collect();
    data.fill_batch(&samples, 1.0, &mut x, &mut y);
    let params = rt.init_params(2).unwrap();
    let out = rt.train_step(&params, &x, &y, 0.05).unwrap();
    let mean: f32 =
        out.per_example_loss.iter().sum::<f32>() / out.per_example_loss.len() as f32;
    assert!(
        (mean - out.mean_loss).abs() < 1e-4,
        "mean(per_example)={mean} vs scalar={}",
        out.mean_loss
    );
}

#[test]
fn eval_step_counts_are_consistent() {
    let rt = runtime();
    let data = SyntheticSpeech::new(rt.input_hw(), rt.num_classes(), 0.6, 5);
    let mut x = vec![0.0f32; rt.eval_batch() * data.feature_len()];
    let mut y = vec![0i32; rt.eval_batch()];
    let test = data.test_set(rt.eval_batch());
    data.fill_batch(&test, 1.0, &mut x, &mut y);
    let params = rt.init_params(3).unwrap();
    let out = rt.eval_step(&params, &x, &y).unwrap();
    assert!((0..=rt.eval_batch() as i32).contains(&out.correct));
    assert!(out.mean_loss > 0.0 && out.mean_loss.is_finite());
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let rt = runtime();
    let params = rt.init_params(0).unwrap();
    assert!(rt.train_step(&params, &[0.0; 3], &[0; 20], 0.05).is_err());
    assert!(rt.train_step(&params[..10], &[0.0; 20 * 1024], &[0; 20], 0.05).is_err());
    assert!(rt.eval_step(&params, &[0.0; 128 * 1024], &[0; 5]).is_err());
}

/// Real trainer: a client with separable data learns it.
#[test]
fn trainer_overfits_one_client_shard() {
    let rt = runtime();
    let data = SyntheticSpeech::new(rt.input_hw(), rt.num_classes(), 0.4, 6);
    let shard = eafl::data::ClientShard {
        labels: vec![0, 1, 2, 3],
        samples: (0..40).map(|i| ((i % 4) as u16, i as u32)).collect(),
        channel_gain: 1.0,
    };
    let mut trainer = Trainer::new(&*rt, &data);
    let global = rt.init_params(9).unwrap();
    let short = trainer.train_client(&global, &shard, 0.05, 2, 1).unwrap();
    let long = trainer.train_client(&global, &shard, 0.05, 40, 1).unwrap();
    assert!(
        long.final_loss < short.final_loss * 0.8,
        "more local steps must fit better: {} vs {}",
        long.final_loss,
        short.final_loss
    );
    assert!(long.stat_util > 0.0);
}

/// The full coordinator over the REAL runtime: accuracy beats the
/// 1/35 ≈ 2.9% random-guess floor within a short run.
#[test]
fn coordinator_learns_with_real_runtime() {
    let rt = runtime();
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.federation.rounds = 60; // past the non-IID + YoGi cold start
    cfg.federation.eval_interval = 5;
    cfg.federation.num_clients = 30;
    // paper-default shard sizes: enough local data to learn from
    cfg.data.min_samples = 60;
    cfg.data.max_samples = 240;
    let log = Coordinator::new(cfg, &*rt).unwrap().run().unwrap();
    let last = log.records.last().unwrap();
    assert!(
        last.test_accuracy > 0.2,
        "real training must climb well past the 2.9% guess floor, got {}",
        last.test_accuracy
    );
    assert!(log.summary().committed_rounds >= 45);
}
