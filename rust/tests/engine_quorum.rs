//! Round-commit quorum edge cases and worker-count determinism over
//! the staged RoundEngine (mock runtime).

use eafl::config::{ExperimentConfig, FederationConfig, SelectorKind};
use eafl::coordinator::{quorum_required, CommitPhase, Coordinator};
use eafl::runtime::MockRuntime;

fn fed(k: usize, frac: f64) -> FederationConfig {
    FederationConfig {
        participants_per_round: k,
        min_report_fraction: frac,
        ..FederationConfig::default()
    }
}

// --- quorum_required / CommitPhase::decide boundaries ----------------------

#[test]
fn exactly_at_quorum_commits_and_one_below_fails() {
    let f = fed(10, 0.5);
    assert_eq!(quorum_required(10, 0.5, 10), 5);
    assert!(CommitPhase::decide(&f, 10, 5).committed, "exactly at quorum must commit");
    assert!(!CommitPhase::decide(&f, 10, 4).committed, "one below quorum must fail");
    assert!(CommitPhase::decide(&f, 10, 10).committed);
}

#[test]
fn all_drop_never_commits_even_at_zero_fraction() {
    // min_report_fraction = 0 still demands >= 1 report: a round where
    // everyone dropped has nothing to aggregate and must not commit.
    let f = fed(10, 0.0);
    assert_eq!(quorum_required(10, 0.0, 10), 1);
    assert!(!CommitPhase::decide(&f, 10, 0).committed);
    assert!(CommitPhase::decide(&f, 10, 1).committed);
}

#[test]
fn empty_selection_cannot_commit() {
    for frac in [0.0, 0.5, 1.0] {
        let f = fed(10, frac);
        let d = CommitPhase::decide(&f, 0, 0);
        assert_eq!(d.required, 1);
        assert!(!d.committed, "an empty round must fail (frac={frac})");
    }
}

#[test]
fn required_exceeding_selected_is_capped() {
    // K=10 at 90% wants 9 reports, but the candidate pool only yielded
    // 4 participants: all 4 reporting must still commit (otherwise a
    // thin population makes every round unwinnable).
    let f = fed(10, 0.9);
    assert_eq!(quorum_required(10, 0.9, 4), 4);
    assert!(CommitPhase::decide(&f, 4, 4).committed);
    assert!(!CommitPhase::decide(&f, 4, 3).committed);
}

#[test]
fn fractional_quorum_rounds_up() {
    // ceil(7 * 0.5) = 4, not 3.
    let f = fed(7, 0.5);
    assert_eq!(quorum_required(7, 0.5, 7), 4);
    assert!(!CommitPhase::decide(&f, 7, 3).committed);
    assert!(CommitPhase::decide(&f, 7, 4).committed);
}

// --- worker-count determinism ----------------------------------------------

/// The acceptance bar for the parallel execution phase: the SAME seeded
/// experiment must produce byte-identical per-round metrics whether the
/// round trains clients on 1 worker thread or 8.
#[test]
fn metrics_identical_at_1_and_8_workers() {
    let run_with = |workers: usize| {
        let runtime = MockRuntime::default();
        let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        cfg.federation.rounds = 25;
        cfg.federation.participants_per_round = 8;
        let coord = Coordinator::new(cfg, &runtime).unwrap().with_workers(workers);
        assert_eq!(coord.workers(), workers);
        coord.run().unwrap()
    };
    let a = run_with(1);
    let b = run_with(8);
    assert_eq!(a.to_csv(), b.to_csv(), "worker count must not change seeded metrics");
    // And not only the formatted CSV — the summaries' raw floats too.
    let (sa, sb) = (a.summary(), b.summary());
    assert_eq!(sa.final_accuracy, sb.final_accuracy);
    assert_eq!(sa.final_train_loss.is_nan(), sb.final_train_loss.is_nan());
    assert_eq!(sa.wall_clock_h, sb.wall_clock_h);
    assert_eq!(sa.total_fl_energy_j, sb.total_fl_energy_j);
}

/// Same property for every selector, with an intermediate worker count
/// that does not divide K evenly (uneven chunking).
#[test]
fn uneven_worker_chunks_stay_deterministic() {
    for kind in [SelectorKind::Random, SelectorKind::Oort, SelectorKind::Eafl] {
        let run_with = |workers: usize| {
            let runtime = MockRuntime::default();
            let mut cfg = ExperimentConfig::smoke(kind);
            cfg.federation.rounds = 12;
            cfg.federation.participants_per_round = 7;
            Coordinator::new(cfg, &runtime).unwrap().with_workers(workers).run().unwrap()
        };
        let csv1 = run_with(1).to_csv();
        for workers in [2, 3, 5] {
            assert_eq!(csv1, run_with(workers).to_csv(), "{kind:?} at {workers} workers");
        }
    }
}
