//! Layer-3 ↔ Layer-2 bridge: load the AOT-compiled HLO artifacts and run
//! them on the PJRT CPU client from the coordinator's hot path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax≥0.5
//! serialized protos carry 64-bit instruction ids that xla_extension
//! 0.5.1 rejects, while the text parser reassigns ids cleanly.
//!
//! Two implementations of [`ModelRuntime`]:
//!  - [`XlaRuntime`] — the real thing (PJRT CPU, compiled executables).
//!  - [`MockRuntime`] — a deterministic analytic stand-in used by unit
//!    tests, property tests and the coordinator-only benches so they do
//!    not pay XLA compilation; the e2e example and integration tests use
//!    the real runtime.

mod manifest;
mod mock;
#[cfg(feature = "xla")]
mod xla_runtime;
#[cfg(not(feature = "xla"))]
mod xla_stub;

pub use manifest::{Manifest, ParamSpecEntry};
pub use mock::MockRuntime;
#[cfg(feature = "xla")]
pub use xla_runtime::XlaRuntime;
#[cfg(not(feature = "xla"))]
pub use xla_stub::XlaRuntime;

use anyhow::Result;

/// Output of one local SGD step.
#[derive(Debug, Clone)]
pub struct TrainOutput {
    /// Updated flat parameter vector (length = manifest.param_count).
    pub params: Vec<f32>,
    /// Mean loss over the batch.
    pub mean_loss: f32,
    /// Per-example losses — feed Oort/EAFL statistical utility (Eq. 2).
    pub per_example_loss: Vec<f32>,
}

/// Output of one evaluation batch.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutput {
    /// Number of correctly classified examples in the batch.
    pub correct: i32,
    /// Mean loss over the batch.
    pub mean_loss: f32,
}

/// The model-execution interface the coordinator depends on.
///
/// Implementations must be deterministic for a given input so that
/// simulation runs are reproducible under a fixed seed.
///
/// `Send + Sync` is part of the contract: the round engine's execution
/// phase ([`crate::coordinator::ExecPhase`]) trains clients on
/// worker threads that share one `&dyn ModelRuntime`, and the campaign
/// runner shares one runtime across concurrent experiments. Step calls
/// take `&self` and must be safe to invoke from multiple threads
/// (internally serializing if the backend is single-threaded, as the
/// PJRT-backed runtime does).
pub trait ModelRuntime: Send + Sync {
    /// Flat parameter vector length `P`.
    fn param_count(&self) -> usize;
    /// Train-step batch size baked into the executable.
    fn train_batch(&self) -> usize;
    /// Eval-step batch size baked into the executable.
    fn eval_batch(&self) -> usize;
    /// Number of classes.
    fn num_classes(&self) -> usize;
    /// Input feature-map side length.
    fn input_hw(&self) -> usize;

    /// He-initialized flat parameters from a seed.
    fn init_params(&self, seed: u32) -> Result<Vec<f32>>;

    /// One SGD step. `x` is `f32[B*HW*HW]` (NHWC, C=1) and `y` is
    /// `i32[B]` with `B == self.train_batch()`.
    fn train_step(&self, params: &[f32], x: &[f32], y: &[i32], lr: f32) -> Result<TrainOutput>;

    /// One evaluation batch with `B == self.eval_batch()`.
    fn eval_step(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOutput>;
}
