//! Budget-family invariants over real coordinator runs (mock runtime):
//! the hard-cap selector never spends past the campaign envelope, the
//! amortized policy honors its per-round allowance, and the campaign
//! budget axis traces out a monotone energy/accuracy frontier.
//!
//! The hard-cap argument these tests pin: each round the selector
//! plans at most `remaining = budget - actual_so_far` joules of
//! *projected* energy, and on static-link scenarios (steady, diurnal)
//! the simulation never spends more than the plan projected (early
//! battery deaths spend less) — so by induction the actual total never
//! crosses the budget.

use eafl::config::{BudgetPolicy, ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::runtime::MockRuntime;

fn budget_base(scenario: &str, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Budget);
    cfg.name = format!("binv-{scenario}-s{seed}");
    cfg.federation.rounds = 12;
    cfg.federation.num_clients = 16;
    cfg.federation.participants_per_round = 4;
    cfg.data.min_samples = 5;
    cfg.data.max_samples = 15;
    cfg.data.test_samples = 128;
    cfg.scenario = scenario.to_string();
    // Same per-axis stream derivation the campaign runner uses, so
    // seeds — not incidental state — pin each trajectory.
    cfg.data.seed = seed;
    cfg.devices.seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    cfg.network.seed = seed.wrapping_mul(0xBF58_476D_1CE4_E5B9).wrapping_add(2);
    cfg.training.init_seed = (seed as u32).wrapping_mul(2_654_435_761).wrapping_add(3);
    cfg
}

/// Total FL energy of the trajectory when the budget never binds —
/// the yardstick the binding budgets below are derived from.
fn unconstrained_energy(scenario: &str, seed: u64, runtime: &MockRuntime) -> f64 {
    let mut cfg = budget_base(scenario, seed);
    cfg.selector.budget_j = 1e15;
    let log = Coordinator::new(cfg, runtime).unwrap().run().unwrap();
    let e = log.summary().total_fl_energy_j;
    assert!(e > 0.0, "probe run spent no energy — the scenario is degenerate");
    e
}

/// Drive rounds manually (the ledger is only inspectable while we still
/// own the coordinator) and check the per-round envelope plus the
/// terminal Σ-spent bound.
fn check_hard_cap(scenario: &str, seed: u64, runtime: &MockRuntime) {
    let budget = unconstrained_energy(scenario, seed, runtime) * 0.35;
    let mut cfg = budget_base(scenario, seed);
    let rounds = cfg.federation.rounds as u64;
    cfg.selector.budget_j = budget;
    cfg.selector.budget_policy = BudgetPolicy::HardCap;
    let mut c = Coordinator::new(cfg, runtime).unwrap();
    for round in 1..=rounds {
        let before = *c.ledger();
        c.run_round(round).unwrap();
        let after = *c.ledger();
        // The round's planned energy fits the envelope that was left.
        let planned = after.projected_j - before.projected_j;
        assert!(
            planned <= before.remaining_j() + 1e-6,
            "{scenario}/s{seed} round {round}: planned {planned} J > remaining {} J",
            before.remaining_j()
        );
        if after.exhausted() {
            break;
        }
    }
    let l = *c.ledger();
    assert!(
        l.actual_j <= l.budget_j + 1e-6,
        "{scenario}/s{seed}: hard-cap spent {} J of a {} J budget",
        l.actual_j,
        l.budget_j
    );
    assert!(l.actual_j > 0.0, "{scenario}/s{seed}: budget so tight nothing ever ran");
}

/// The acceptance property: Σ actual spend ≤ budget, across seeds and
/// both static-link scenarios.
#[test]
fn hard_cap_never_spends_past_the_budget() {
    let runtime = MockRuntime::default();
    for scenario in ["steady", "diurnal"] {
        for seed in [1u64, 2, 3, 7, 11] {
            check_hard_cap(scenario, seed, &runtime);
        }
    }
}

/// Amortized pacing telescopes: every round plans at most
/// remaining / remaining_rounds, which sums to at most the budget over
/// the campaign.
#[test]
fn amortized_allowance_telescopes_over_the_campaign() {
    let runtime = MockRuntime::default();
    for seed in [1u64, 2, 3] {
        let budget = unconstrained_energy("steady", seed, &runtime) * 0.5;
        let mut cfg = budget_base("steady", seed);
        let rounds = cfg.federation.rounds as u64;
        cfg.selector.budget_j = budget;
        cfg.selector.budget_policy = BudgetPolicy::Amortized;
        let mut c = Coordinator::new(cfg, &runtime).unwrap();
        for round in 1..=rounds {
            let before = *c.ledger();
            c.run_round(round).unwrap();
            let after = *c.ledger();
            let planned = after.projected_j - before.projected_j;
            let allowance = before.remaining_j() / (rounds - (round - 1)) as f64;
            assert!(
                planned <= allowance + 1e-6,
                "s{seed} round {round}: planned {planned} J > allowance {allowance} J"
            );
            if after.exhausted() {
                break;
            }
        }
        let l = *c.ledger();
        assert!(l.actual_j <= l.budget_j + 1e-6, "s{seed}: amortized overspent");
    }
}

/// A budgeted run ends with a budget_exhausted trace event, and every
/// round_committed line carries the running envelope.
#[test]
fn exhausted_budget_is_a_terminal_trace_event() {
    let runtime = MockRuntime::default();
    let budget = unconstrained_energy("steady", 1, &runtime) * 0.2;
    let mut cfg = budget_base("steady", 1);
    cfg.selector.budget_j = budget;
    let dir = std::env::temp_dir().join(format!("eafl-binv-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("budget.trace.jsonl");
    let mut c = Coordinator::new(cfg, &runtime).unwrap();
    c.set_sink(Box::new(eafl::obs::JsonlSink::create(&path).unwrap()));
    let log = c.run().unwrap();
    assert!(
        (log.records.len() as u64) < 12,
        "a 20% budget must stop the run early, ran {} rounds",
        log.records.len()
    );
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.matches(r#""ev": "budget_exhausted""#).count(), 1);
    assert!(text.contains(r#""budget_remaining_j""#));
    // Budgeted runs never encode the envelope as null (that spelling is
    // reserved for unlimited runs).
    for line in text.lines().filter(|l| l.contains(r#""ev": "round_committed""#)) {
        assert!(!line.contains(r#""budget_remaining_j": null"#), "{line}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Unlimited runs keep the envelope out of band: budget_remaining_j is
/// null on every committed round and no budget_exhausted event fires.
#[test]
fn unlimited_runs_encode_no_envelope() {
    let runtime = MockRuntime::default();
    let mut cfg = budget_base("steady", 1);
    cfg.selector.kind = SelectorKind::Eafl; // any non-budget selector
    cfg.selector.budget_j = 0.0;
    let dir = std::env::temp_dir().join(format!("eafl-binv-null-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("unlimited.trace.jsonl");
    let mut c = Coordinator::new(cfg, &runtime).unwrap();
    c.set_sink(Box::new(eafl::obs::JsonlSink::create(&path).unwrap()));
    c.run().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.matches(r#""ev": "budget_exhausted""#).count(), 0);
    let committed = text
        .lines()
        .filter(|l| l.contains(r#""ev": "round_committed""#))
        .collect::<Vec<_>>();
    assert!(!committed.is_empty());
    for line in committed {
        assert!(line.contains(r#""budget_remaining_j": null"#), "{line}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The campaign budget axis traces a monotone frontier: with a
/// budget-oblivious selector the ledger only decides *when to stop*,
/// so trajectories under increasing budgets are prefixes of one another
/// — committed rounds, energy spent and best accuracy are all
/// non-decreasing in the budget.
#[test]
fn frontier_is_monotone_in_budget_on_the_smoke_grid() {
    use eafl::campaign::{run_campaign, CampaignGrid, CampaignSpec};
    let runtime = MockRuntime::default();
    let mut base = budget_base("steady", 1);
    base.selector.kind = SelectorKind::Random;
    base.selector.budget_j = 0.0;
    // Yardstick from the *same* selector the frontier sweeps: an
    // unlimited random run fixes the trajectory every budgeted run
    // below is a prefix of.
    let e = {
        let log = Coordinator::new(base.clone(), &runtime).unwrap().run().unwrap();
        log.summary().total_fl_energy_j
    };
    assert!(e > 0.0);
    let mut spec = CampaignSpec::new("frontier", base);
    spec.grid = CampaignGrid {
        selectors: vec![SelectorKind::Random],
        scenarios: Vec::new(),
        seeds: vec![1],
        f_values: Vec::new(),
        client_counts: Vec::new(),
        budgets: vec![e * 0.25, e * 0.5, e * 2.0],
    };
    spec.jobs = 1;
    let report = run_campaign(&spec, &runtime, None).unwrap();
    assert_eq!(report.runs.len(), 3, "one run per budget");
    for pair in report.runs.windows(2) {
        let (lo, hi) = (&pair[0], &pair[1]);
        assert!(lo.budget_j < hi.budget_j, "grid order follows the budget axis");
        assert!(
            hi.summary.committed_rounds >= lo.summary.committed_rounds,
            "more budget, no fewer rounds: {} vs {}",
            hi.summary.committed_rounds,
            lo.summary.committed_rounds
        );
        assert!(
            hi.summary.total_fl_energy_j >= lo.summary.total_fl_energy_j,
            "more budget, no less energy"
        );
        assert!(
            hi.summary.best_accuracy >= lo.summary.best_accuracy,
            "more budget, no worse best accuracy: {} vs {}",
            hi.summary.best_accuracy,
            lo.summary.best_accuracy
        );
    }
    // The tightest budget actually bound (otherwise this test proves
    // nothing) and the slackest did not.
    assert!(report.runs[0].summary.total_fl_energy_j < e);
    assert_eq!(report.runs[2].summary.rounds, 12, "the slackest budget never binds");
}
