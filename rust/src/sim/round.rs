//! One training round resolved on the event queue.
//!
//! Each participant's timeline is download → compute → upload with
//! durations from its device/link profiles. Two things can prevent a
//! client from reporting:
//!   * **battery death** — its remaining charge cannot supply the
//!     round's energy; it dies at the proportional point of its
//!     timeline (the paper's mid-round drop-out), and
//!   * **deadline miss** — its timeline exceeds the selector's deadline
//!     T (the straggler case); it pays energy up to T, then the server
//!     stops waiting.
//!
//! The round's duration is the latest completion among reporting
//! clients, or the deadline if anyone was still running at T.


use super::EventQueue;

/// Input: one selected client's planned round.
#[derive(Debug, Clone, Copy)]
pub struct ParticipantPlan {
    pub id: usize,
    pub download_s: f64,
    pub compute_s: f64,
    pub upload_s: f64,
    /// Total energy the full round would draw, joules.
    pub round_energy_j: f64,
    /// Battery charge available, joules.
    pub charge_j: f64,
}

impl ParticipantPlan {
    pub fn total_duration_s(&self) -> f64 {
        self.download_s + self.compute_s + self.upload_s
    }
}

/// Why a participant failed to report.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FailureKind {
    /// Battery hit zero mid-round (the paper's drop-out).
    BatteryDeath,
    /// Exceeded the round deadline (classic straggler).
    DeadlineMiss,
}

/// Outcome for one participant.
#[derive(Debug, Clone, Copy)]
pub struct ParticipantResult {
    pub id: usize,
    /// Reported an update within the deadline.
    pub completed: bool,
    pub failure: Option<FailureKind>,
    /// Wall time the client was active this round, seconds.
    pub active_s: f64,
    /// Energy actually drawn from the battery, joules.
    pub energy_spent_j: f64,
}

/// Aggregate outcome of the simulated round.
#[derive(Debug, Clone)]
pub struct RoundSimOutcome {
    pub results: Vec<ParticipantResult>,
    /// Wall-clock duration of the round, seconds.
    pub duration_s: f64,
}

#[derive(Debug, Clone, Copy)]
enum RoundEvent {
    /// Client would finish its full timeline.
    Finish(usize),
    /// Client's battery dies at this instant.
    Death(usize),
    /// Server deadline fires.
    Deadline,
}

/// Resolve a round over `plans` with straggler deadline `deadline_s`.
///
/// Pure function of its inputs — battery mutation happens in the
/// coordinator using the returned energies, keeping this simulator
/// trivially testable.
pub fn simulate_round(plans: &[ParticipantPlan], deadline_s: f64) -> RoundSimOutcome {
    let mut q: EventQueue<RoundEvent> = EventQueue::new();
    for p in plans {
        let duration = p.total_duration_s();
        if p.round_energy_j > p.charge_j && p.round_energy_j > 0.0 {
            // Battery dies at the proportional point of the timeline.
            let frac = (p.charge_j / p.round_energy_j).clamp(0.0, 1.0);
            q.push(duration * frac, RoundEvent::Death(p.id));
        } else {
            q.push(duration, RoundEvent::Finish(p.id));
        }
    }
    q.push(deadline_s, RoundEvent::Deadline);

    let mut results: Vec<ParticipantResult> = plans
        .iter()
        .map(|p| ParticipantResult {
            id: p.id,
            completed: false,
            failure: None,
            active_s: 0.0,
            energy_spent_j: 0.0,
        })
        .collect();
    let index: std::collections::HashMap<usize, usize> =
        plans.iter().enumerate().map(|(i, p)| (p.id, i)).collect();

    let mut latest_completion = 0.0f64;
    let mut any_straggler = false;
    while let Some(ev) = q.pop() {
        match ev.payload {
            RoundEvent::Finish(id) if ev.time_s <= deadline_s => {
                let i = index[&id];
                let p = &plans[i];
                results[i].completed = true;
                results[i].active_s = ev.time_s;
                results[i].energy_spent_j = p.round_energy_j;
                latest_completion = latest_completion.max(ev.time_s);
            }
            RoundEvent::Finish(_) => { /* resolved at Deadline below */ }
            RoundEvent::Death(id) if ev.time_s <= deadline_s => {
                let i = index[&id];
                let p = &plans[i];
                results[i].failure = Some(FailureKind::BatteryDeath);
                results[i].active_s = ev.time_s;
                results[i].energy_spent_j = p.charge_j; // drained flat
            }
            RoundEvent::Death(_) => { /* dies after the server moved on */ }
            RoundEvent::Deadline => {
                // Anyone not yet finished or dead is a straggler: pays
                // energy for the fraction of its timeline it ran.
                for (i, p) in plans.iter().enumerate() {
                    if !results[i].completed && results[i].failure.is_none() {
                        any_straggler = true;
                        results[i].failure = Some(FailureKind::DeadlineMiss);
                        results[i].active_s = deadline_s;
                        let frac =
                            (deadline_s / p.total_duration_s().max(1e-9)).clamp(0.0, 1.0);
                        results[i].energy_spent_j =
                            (p.round_energy_j * frac).min(p.charge_j);
                    }
                }
            }
        }
    }

    // Post-deadline battery deaths: a straggler whose partial energy
    // equals its whole charge also dies (flagged as battery death —
    // it is both late AND flat; battery death dominates for Fig. 4a).
    for (i, p) in plans.iter().enumerate() {
        if results[i].failure == Some(FailureKind::DeadlineMiss)
            && results[i].energy_spent_j >= p.charge_j
            && p.charge_j > 0.0
        {
            results[i].failure = Some(FailureKind::BatteryDeath);
        }
    }

    let duration_s = if any_straggler { deadline_s } else { latest_completion };
    RoundSimOutcome { results, duration_s: duration_s.max(0.0) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(id: usize, total_s: f64, energy: f64, charge: f64) -> ParticipantPlan {
        ParticipantPlan {
            id,
            download_s: total_s * 0.1,
            compute_s: total_s * 0.8,
            upload_s: total_s * 0.1,
            round_energy_j: energy,
            charge_j: charge,
        }
    }

    #[test]
    fn all_complete_round_ends_at_slowest() {
        let plans = vec![plan(0, 100.0, 10.0, 100.0), plan(1, 250.0, 10.0, 100.0)];
        let out = simulate_round(&plans, 1000.0);
        assert!(out.results.iter().all(|r| r.completed));
        assert_eq!(out.duration_s, 250.0);
        assert_eq!(out.results[1].active_s, 250.0);
    }

    #[test]
    fn straggler_forces_deadline_duration() {
        let plans = vec![plan(0, 100.0, 10.0, 100.0), plan(1, 900.0, 10.0, 100.0)];
        let out = simulate_round(&plans, 300.0);
        assert!(out.results[0].completed);
        assert!(!out.results[1].completed);
        assert_eq!(out.results[1].failure, Some(FailureKind::DeadlineMiss));
        assert_eq!(out.duration_s, 300.0);
        // Straggler paid 300/900 of its round energy.
        assert!((out.results[1].energy_spent_j - 10.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn battery_death_mid_round() {
        // Needs 100 J, has 25 J: dies at 25% of its 200 s timeline.
        let plans = vec![plan(0, 200.0, 100.0, 25.0)];
        let out = simulate_round(&plans, 1000.0);
        let r = &out.results[0];
        assert!(!r.completed);
        assert_eq!(r.failure, Some(FailureKind::BatteryDeath));
        assert!((r.active_s - 50.0).abs() < 1e-9);
        assert_eq!(r.energy_spent_j, 25.0);
    }

    #[test]
    fn exact_energy_budget_survives() {
        let plans = vec![plan(0, 100.0, 50.0, 50.0)];
        let out = simulate_round(&plans, 1000.0);
        assert!(out.results[0].completed);
        assert_eq!(out.results[0].energy_spent_j, 50.0);
    }

    #[test]
    fn straggler_that_drains_flat_counts_as_battery_death() {
        // Misses the deadline AND its partial energy >= charge.
        let plans = vec![plan(0, 1000.0, 100.0, 100.0)]; // can afford full round
        let out = simulate_round(&plans, 900.0);
        // 900/1000 of 100 J = 90 J < 100 J charge => plain deadline miss.
        assert_eq!(out.results[0].failure, Some(FailureKind::DeadlineMiss));

        let plans = vec![plan(0, 1000.0, 200.0, 150.0)];
        // Death scheduled at 750 s (150/200 of 1000) — before deadline.
        let out = simulate_round(&plans, 900.0);
        assert_eq!(out.results[0].failure, Some(FailureKind::BatteryDeath));
    }

    #[test]
    fn empty_round_is_zero_duration() {
        let out = simulate_round(&[], 500.0);
        assert!(out.results.is_empty());
        assert_eq!(out.duration_s, 0.0);
    }

    #[test]
    fn energy_never_exceeds_charge() {
        for (energy, charge) in [(10.0, 5.0), (10.0, 10.0), (10.0, 50.0), (0.0, 1.0)] {
            let out = simulate_round(&[plan(0, 120.0, energy, charge)], 60.0);
            assert!(out.results[0].energy_spent_j <= charge + 1e-12);
            assert!(out.results[0].energy_spent_j <= energy + 1e-12);
        }
    }
}
