//! Scenario recharge policies — wall-clock-keyed alternatives to the
//! config's cooldown model (ROADMAP "Scenario phases": overnight
//! charging windows, solar traces).
//!
//! Both implement [`RechargePolicy`] from the accounting module and are
//! applied once per round with the round's wall-clock window, charging
//! *every* device (alive ones top up, dead ones revive once they have
//! charge again) — recharge is a property of the environment, not of
//! the death state.
//!
//! These two deliberately stay O(N) full loops even though the registry
//! keeps O(dead) / O(below-capacity) liveness indices (`index_set`):
//! they add charge *unconditionally*, so every client is a revival or
//! top-up candidate whenever the window overlaps — there is no idle
//! subset to skip. (Iterating the below-capacity set instead would also
//! tie visit order to drain history; `charge_add` commutes, but a full
//! 0..N sweep makes order-independence trivially true.) The cooldown
//! policy, which only ever touches dead clients, is the one that scans
//! its index — see `CooldownRecharge` in `coordinator::accounting`.

use crate::coordinator::{RechargePolicy, Registry};

/// Overlap (hours) of the span `[a, b)` with the daily wall-clock
/// window `[start, end)`, summed over every day the span touches;
/// `start > end` wraps midnight (22→6).
pub fn daily_window_overlap_h(a: f64, b: f64, start: f64, end: f64) -> f64 {
    if b <= a {
        return 0.0;
    }
    // Normalize the daily window into segments within [0, 24).
    let segments: [(f64, f64); 2] = if start <= end {
        [(start, end), (0.0, 0.0)]
    } else {
        [(start, 24.0), (0.0, end)]
    };
    let mut total = 0.0;
    let mut day = (a / 24.0).floor();
    while day * 24.0 < b {
        for &(s, e) in &segments {
            let lo = (day * 24.0 + s).max(a);
            let hi = (day * 24.0 + e).min(b);
            if hi > lo {
                total += hi - lo;
            }
        }
        day += 1.0;
    }
    total
}

/// Devices plugged in during a nightly charging window: every device
/// gains `rate_frac_per_h` of its own capacity per hour of overlap
/// between the round's span and the window.
pub struct OvernightRecharge {
    /// Daily charging window in hours of day; wraps midnight.
    pub start_hour: f64,
    pub end_hour: f64,
    /// Charge rate as battery-fraction per hour (0.25 ⇒ empty→full in 4 h).
    pub rate_frac_per_h: f64,
}

impl RechargePolicy for OvernightRecharge {
    fn apply(&self, registry: &mut Registry, start_clock_h: f64, end_clock_h: f64) {
        let overlap =
            daily_window_overlap_h(start_clock_h, end_clock_h, self.start_hour, self.end_hour);
        if overlap <= 0.0 || self.rate_frac_per_h <= 0.0 {
            return;
        }
        for id in 0..registry.len() {
            let joules = registry.client(id).battery.capacity_joules()
                * self.rate_frac_per_h
                * overlap;
            registry.charge_add(id, joules);
        }
    }
    fn can_revive(&self) -> bool {
        self.rate_frac_per_h > 0.0
    }
    fn name(&self) -> &'static str {
        "overnight"
    }
}

/// Solar harvesting: a piecewise-linear daily trace of charge rate
/// (battery-fraction per hour) sampled at the round's midpoint — the
/// edge-deployment story where devices live or die by daylight.
pub struct SolarRecharge {
    /// `(hour_of_day, frac_per_h)` points sorted by hour; the curve is
    /// linear between points and wraps from the last point back to the
    /// first (24 h later).
    pub trace: Vec<(f64, f64)>,
}

impl SolarRecharge {
    /// Interpolated charge rate (fraction/hour) at an hour of day.
    pub fn rate_at(&self, hour_of_day: f64) -> f64 {
        let t = &self.trace;
        if t.is_empty() {
            return 0.0;
        }
        if t.len() == 1 {
            return t[0].1.max(0.0);
        }
        let h = hour_of_day.rem_euclid(24.0);
        for w in t.windows(2) {
            let (h0, r0) = w[0];
            let (h1, r1) = w[1];
            if h >= h0 && h <= h1 && h1 > h0 {
                return (r0 + (r1 - r0) * (h - h0) / (h1 - h0)).max(0.0);
            }
        }
        // Wrap-around segment: last point → first point + 24 h.
        let (hl, rl) = *t.last().unwrap();
        let (hf, rf) = t[0];
        let span = hf + 24.0 - hl;
        if span <= 0.0 {
            return rl.max(0.0);
        }
        let x = if h >= hl { h - hl } else { h + 24.0 - hl };
        (rl + (rf - rl) * x / span).max(0.0)
    }
}

impl RechargePolicy for SolarRecharge {
    fn apply(&self, registry: &mut Registry, start_clock_h: f64, end_clock_h: f64) {
        let hours = (end_clock_h - start_clock_h).max(0.0);
        if hours <= 0.0 {
            return;
        }
        // Rounds are short relative to the solar curve, so the midpoint
        // rate is an adequate quadrature.
        let rate = self.rate_at((start_clock_h + end_clock_h) * 0.5);
        if rate <= 0.0 {
            return;
        }
        for id in 0..registry.len() {
            let joules = registry.client(id).battery.capacity_joules() * rate * hours;
            registry.charge_add(id, joules);
        }
    }
    fn can_revive(&self) -> bool {
        self.trace.iter().any(|(_, r)| *r > 0.0)
    }
    fn name(&self) -> &'static str {
        "solar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, SelectorKind};

    fn registry() -> Registry {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        Registry::build(&cfg, 35, 1000)
    }

    #[test]
    fn window_overlap_math() {
        // Full day against a wrapped 22→6 window: 8 hours.
        assert!((daily_window_overlap_h(0.0, 24.0, 22.0, 6.0) - 8.0).abs() < 1e-9);
        // 23:00→01:00 next day: one hour each side of midnight.
        assert!((daily_window_overlap_h(23.0, 25.0, 22.0, 6.0) - 2.0).abs() < 1e-9);
        // Entirely inside the early-morning half.
        assert!((daily_window_overlap_h(2.0, 4.0, 22.0, 6.0) - 2.0).abs() < 1e-9);
        // Entirely outside.
        assert_eq!(daily_window_overlap_h(7.0, 8.0, 22.0, 6.0), 0.0);
        // Non-wrapping window.
        assert!((daily_window_overlap_h(8.0, 20.0, 9.0, 17.0) - 8.0).abs() < 1e-9);
        // Degenerate span.
        assert_eq!(daily_window_overlap_h(5.0, 5.0, 22.0, 6.0), 0.0);
        // Multi-day span accumulates every night.
        assert!((daily_window_overlap_h(0.0, 72.0, 22.0, 6.0) - 24.0).abs() < 1e-9);
    }

    #[test]
    fn overnight_charges_inside_window_only() {
        let policy =
            OvernightRecharge { start_hour: 22.0, end_hour: 6.0, rate_frac_per_h: 0.25 };
        let mut r = registry();
        // Kill client 0 outright.
        let cap = r.client(0).battery.capacity_joules();
        r.drain_fl(0, cap * 2.0, 9.0);
        assert!(!r.client(0).battery.is_alive());

        // Daytime round: nothing happens.
        policy.apply(&mut r, 10.0, 11.0);
        assert!(!r.client(0).battery.is_alive());

        // One full hour inside the window: +0.25 of capacity, revived.
        policy.apply(&mut r, 22.0, 23.0);
        assert!(r.client(0).battery.is_alive());
        assert!((r.client(0).battery.fraction() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn overnight_tops_up_alive_clients_and_caps_at_capacity() {
        let policy =
            OvernightRecharge { start_hour: 22.0, end_hour: 6.0, rate_frac_per_h: 1.0 };
        let mut r = registry();
        policy.apply(&mut r, 22.0, 30.0); // 8 h at 1.0/h ≫ capacity
        for c in r.clients() {
            assert!((c.battery.fraction() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn solar_rate_interpolates_and_wraps() {
        let s = SolarRecharge {
            trace: vec![
                (0.0, 0.0),
                (6.0, 0.0),
                (9.0, 0.12),
                (13.0, 0.3),
                (17.0, 0.12),
                (19.0, 0.0),
            ],
        };
        assert!((s.rate_at(13.0) - 0.3).abs() < 1e-12);
        assert!((s.rate_at(11.0) - 0.21).abs() < 1e-12, "midpoint of 9→13 segment");
        assert_eq!(s.rate_at(21.0), 0.0, "wrap segment 19→24 stays at 0");
        assert_eq!(s.rate_at(3.0), 0.0);
        assert!((s.rate_at(13.0 + 24.0) - 0.3).abs() < 1e-12, "24 h periodic");
    }

    #[test]
    fn revival_capability_tracks_rates() {
        let on = OvernightRecharge { start_hour: 22.0, end_hour: 6.0, rate_frac_per_h: 0.25 };
        let off = OvernightRecharge { start_hour: 22.0, end_hour: 6.0, rate_frac_per_h: 0.0 };
        assert!(on.can_revive());
        assert!(!off.can_revive());
        let sun = SolarRecharge { trace: vec![(6.0, 0.0), (12.0, 0.4)] };
        let dark = SolarRecharge { trace: vec![(6.0, 0.0), (12.0, 0.0)] };
        assert!(sun.can_revive());
        assert!(!dark.can_revive());
    }

    #[test]
    fn solar_charges_at_noon_not_midnight() {
        let s = SolarRecharge { trace: vec![(6.0, 0.0), (12.0, 0.4), (18.0, 0.0)] };
        let mut r = registry();
        let before: Vec<f64> =
            r.clients().iter().map(|c| c.battery.charge_joules()).collect();
        s.apply(&mut r, 23.9, 24.1); // midnight: rate 0
        for (c, b) in r.clients().iter().zip(&before) {
            assert_eq!(c.battery.charge_joules(), *b);
        }
        // Drain someone below full so the noon charge is observable.
        let cap = r.client(1).battery.capacity_joules();
        r.drain_fl(1, cap * 0.5, 0.0);
        let drained = r.client(1).battery.charge_joules();
        s.apply(&mut r, 11.5, 12.5); // solar noon
        assert!(r.client(1).battery.charge_joules() > drained);
    }
}
