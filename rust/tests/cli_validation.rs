//! CLI error-path coverage: bad scenarios, bad shard specs and bad
//! flags must surface as clean one-line errors (non-zero exit, message
//! on stderr, no panic/backtrace) *before* any training starts. Drives
//! the real binary — validation that only works in-library is no help
//! to someone on a terminal.

use std::path::PathBuf;
use std::process::{Command, Output};

const BIN: &str = env!("CARGO_BIN_EXE_eafl");

fn eafl(args: &[&str]) -> Output {
    Command::new(BIN).args(args).output().expect("spawning eafl")
}

/// Assert a command fails cleanly: non-zero exit, the expected message
/// fragment on stderr, and no panic machinery in sight.
fn assert_clean_error(args: &[&str], expect: &str) {
    let output = eafl(args);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        !output.status.success(),
        "{args:?} should fail, but exited {}:\n{stderr}",
        output.status
    );
    assert!(
        stderr.contains(expect),
        "{args:?} stderr should mention {expect:?}:\n{stderr}"
    );
    assert!(
        !stderr.contains("panicked") && !stderr.contains("RUST_BACKTRACE"),
        "{args:?} must fail cleanly, not panic:\n{stderr}"
    );
}

fn scenario_file(tag: &str, body: &str) -> (PathBuf, PathBuf) {
    let dir = std::env::temp_dir().join(format!("eafl-cliv-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("scenario.toml");
    std::fs::write(&path, body).unwrap();
    (dir, path)
}

#[test]
fn unknown_scenario_preset_is_a_clean_error() {
    assert_clean_error(&["run", "--mock", "--scenario", "no-such-preset"], "unknown scenario");
    // The error lists the presets, so the fix is one glance away.
    let stderr = String::from_utf8_lossy(
        &eafl(&["run", "--mock", "--scenario", "no-such-preset"]).stderr,
    )
    .into_owned();
    assert!(stderr.contains("steady"), "error should list presets:\n{stderr}");
    // The sweep path fails fast too — before hours of grid cells.
    assert_clean_error(
        &["sweep", "--mock", "--scenario", "steady,bogus", "--rounds", "1"],
        "unknown scenario",
    );
}

#[test]
fn out_of_day_hours_are_rejected_from_the_cli() {
    // Daily windows wrap midnight via start > end; an hour >= 24 would
    // otherwise be silently clipped.
    let (dir, path) = scenario_file(
        "overnight",
        "[recharge]\nkind = \"overnight\"\nstart_hour = 22\nend_hour = 30\n",
    );
    assert_clean_error(&["run", "--mock", "--scenario", path.to_str().unwrap()], "[0, 24)");
    let _ = std::fs::remove_dir_all(&dir);

    let (dir, path) = scenario_file(
        "congestion",
        "[network]\nkind = \"congestion\"\nstart_hour = 17\nend_hour = 25\n",
    );
    assert_clean_error(&["run", "--mock", "--scenario", path.to_str().unwrap()], "[0, 24)");
    let _ = std::fs::remove_dir_all(&dir);

    let (dir, path) = scenario_file(
        "solar-hours",
        "[recharge]\nkind = \"solar\"\ntrace_hours = [20, 28]\ntrace_rates = [0.1, 0.2]\n",
    );
    assert_clean_error(&["run", "--mock", "--scenario", path.to_str().unwrap()], "[0, 24)");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_solar_traces_are_rejected_from_the_cli() {
    // Unsorted hours.
    let (dir, path) = scenario_file(
        "unsorted",
        "[recharge]\nkind = \"solar\"\ntrace_hours = [12, 6]\ntrace_rates = [0.1, 0.2]\n",
    );
    assert_clean_error(
        &["run", "--mock", "--scenario", path.to_str().unwrap()],
        "sorted ascending",
    );
    let _ = std::fs::remove_dir_all(&dir);

    // Unpaired arrays (hours without rates must not silently fall back
    // to the default curve).
    let (dir, path) = scenario_file(
        "unpaired",
        "[recharge]\nkind = \"solar\"\ntrace_hours = [6, 12, 18]\n",
    );
    assert_clean_error(
        &["run", "--mock", "--scenario", path.to_str().unwrap()],
        "provided together",
    );
    let _ = std::fs::remove_dir_all(&dir);

    // Mismatched lengths.
    let (dir, path) = scenario_file(
        "mismatched",
        "[recharge]\nkind = \"solar\"\ntrace_hours = [6, 12]\ntrace_rates = [0.1]\n",
    );
    assert_clean_error(
        &["run", "--mock", "--scenario", path.to_str().unwrap()],
        "equal-length",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_shard_specs_are_clean_errors() {
    assert_clean_error(
        &["sweep", "--mock", "--rounds", "1", "--shard", "4/4"],
        "0-based",
    );
    assert_clean_error(&["sweep", "--mock", "--rounds", "1", "--shard", "nope"], "I/N");
    assert_clean_error(&["sweep", "--mock", "--rounds", "1", "--shard", "1/0"], "shard");
}

#[test]
fn merge_without_directories_is_a_clean_error() {
    assert_clean_error(&["merge"], "at least one");
    // A directory that was never swept has no manifest.
    let dir = std::env::temp_dir().join(format!("eafl-cliv-empty-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    assert_clean_error(&["merge", dir.to_str().unwrap()], "manifest");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_command_and_flags_are_clean_errors() {
    assert_clean_error(&["frobnicate"], "unknown command");
    assert_clean_error(&["run", "--selector", "bogus"], "unknown selector");
    assert_clean_error(&["run", "--rounds"], "requires a value");
}

#[test]
fn unwritable_trace_paths_are_clean_errors() {
    // The sink is created before any training: a bad path fails in
    // milliseconds, not after a 500-round run.
    assert_clean_error(
        &[
            "run",
            "--mock",
            "--rounds",
            "1",
            "--trace",
            "/proc/no-such-dir/cannot/write/t.jsonl",
        ],
        "trace",
    );
    assert_clean_error(&["run", "--mock", "--rounds", "1", "--trace"], "requires a value");
}

#[test]
fn malformed_traces_fed_to_summarize_are_clean_errors() {
    assert_clean_error(&["trace"], "summarize");
    assert_clean_error(&["trace", "frobnicate"], "summarize");
    assert_clean_error(&["trace", "summarize"], "at least one");
    assert_clean_error(&["trace", "summarize", "/no/such/trace.jsonl"], "trace");

    let dir = std::env::temp_dir().join(format!("eafl-cliv-trace-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // Garbage bytes: a parse error naming the file and line, not a panic.
    let garbage = dir.join("garbage.jsonl");
    std::fs::write(&garbage, "this is not JSON\n").unwrap();
    assert_clean_error(&["trace", "summarize", garbage.to_str().unwrap()], "trace");

    // Right shape, wrong schema tag: the error names the expected tag.
    let wrong = dir.join("wrong-schema.jsonl");
    std::fs::write(&wrong, "{\"schema\": \"other-v9\"}\n").unwrap();
    assert_clean_error(&["trace", "summarize", wrong.to_str().unwrap()], "eafl-trace-v1");

    // Valid header but no events: not summarizable.
    let empty = dir.join("headless.jsonl");
    std::fs::write(&empty, "{\"schema\": \"eafl-trace-v1\"}\n").unwrap();
    assert_clean_error(&["trace", "summarize", empty.to_str().unwrap()], "run_started");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trend_without_history_is_a_clean_error() {
    assert_clean_error(&["trend", "--history", "/no/such/history.jsonl"], "history");
}

/// Like [`assert_clean_error`], but additionally pins the exit code to
/// 2: supervisor/fault flag typos are *usage* errors, distinct from
/// cell failures (3) and exhausted retries (4), so scripts can branch
/// on the code without scraping stderr.
fn assert_usage_exit(args: &[&str], expect: &str) {
    let output = eafl(args);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert_eq!(
        output.status.code(),
        Some(2),
        "{args:?} should exit 2 (usage), got {}:\n{stderr}",
        output.status
    );
    assert!(
        stderr.contains(expect),
        "{args:?} stderr should mention {expect:?}:\n{stderr}"
    );
    assert!(
        !stderr.contains("panicked") && !stderr.contains("RUST_BACKTRACE"),
        "{args:?} must fail cleanly, not panic:\n{stderr}"
    );
}

#[test]
fn malformed_fault_specs_are_usage_errors() {
    const S: [&str; 4] = ["sweep", "--mock", "--rounds", "1"];
    // Unknown kind.
    assert_usage_exit(&[&S[..], &["--fault", "explode"]].concat(), "invalid --fault");
    // A kind missing its required parameter.
    assert_usage_exit(&[&S[..], &["--fault", "crash"]].concat(), "invalid --fault");
    assert_usage_exit(&[&S[..], &["--fault", "stall:cell=x"]].concat(), "invalid --fault");
    // Out-of-range / malformed parameter values.
    assert_usage_exit(
        &[&S[..], &["--fault", "crash:after-cells=0"]].concat(),
        "invalid --fault",
    );
    assert_usage_exit(
        &[&S[..], &["--fault", "crash:after-cells=soon"]].concat(),
        "invalid --fault",
    );
    // Unknown artifact kind and unknown key.
    assert_usage_exit(
        &[&S[..], &["--fault", "torn-write:kind=floppy"]].concat(),
        "invalid --fault",
    );
    assert_usage_exit(
        &[&S[..], &["--fault", "crash:after-cells=1:bogus=2"]].concat(),
        "invalid --fault",
    );
    // The flag needs a value at all.
    assert_usage_exit(&[&S[..], &["--fault"]].concat(), "requires a value");
}

#[test]
fn malformed_supervisor_flags_are_usage_errors() {
    const S: [&str; 4] = ["sweep", "--mock", "--rounds", "1"];
    assert_usage_exit(
        &[&S[..], &["--max-retries", "many"]].concat(),
        "invalid --max-retries",
    );
    assert_usage_exit(
        &[&S[..], &["--stall-timeout-s", "soon"]].concat(),
        "invalid --stall-timeout-s",
    );
    assert_usage_exit(
        &[&S[..], &["--stall-timeout-s", "0"]].concat(),
        "positive",
    );
    // Usage errors must win before any grid cell runs: no artifacts.
    let dir = std::env::temp_dir().join(format!("eafl-cliv-fault-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let out = eafl(&[
        "sweep",
        "--mock",
        "--rounds",
        "1",
        "--fault",
        "explode",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(2));
    assert!(!dir.exists(), "a rejected sweep must not create its --out directory");
}

#[test]
fn client_count_bounds_are_clean_errors() {
    // Zero clients: caught by config validation, not an empty-pool panic.
    assert_clean_error(
        &["run", "--mock", "--rounds", "1", "--clients", "0"],
        "num_clients must be > 0",
    );
    // Oversized: the SoA pool + liveness indices allocate O(N) up
    // front, so an absurd count must be refused before the allocator
    // aborts the process.
    assert_clean_error(
        &["run", "--mock", "--rounds", "1", "--clients", "999999999999"],
        "num_clients must be <=",
    );
    // Malformed: a parse error names the flag, not a panic site.
    assert_clean_error(
        &["run", "--mock", "--rounds", "1", "--clients", "abc"],
        "invalid --clients",
    );
    // The sweep grid axis gets the same treatment.
    assert_clean_error(
        &["sweep", "--mock", "--rounds", "1", "--clients", "10,abc"],
        "invalid --clients",
    );
}
