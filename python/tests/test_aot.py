"""AOT path: lowered HLO text is well-formed and the manifest is the
contract the Rust runtime expects."""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts():
    return aot.build_artifacts()


def test_all_entry_points_lowered(artifacts):
    assert set(artifacts) == {
        "train_step.hlo.txt",
        "eval_step.hlo.txt",
        "init_params.hlo.txt",
    }


def test_hlo_text_well_formed(artifacts):
    for name, text in artifacts.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # text interchange, never a serialized proto blob
        assert "\x00" not in text, name


def test_train_step_signature_in_hlo(artifacts):
    """Parameter/rout shapes are baked into the module text."""
    text = artifacts["train_step.hlo.txt"]
    p = model.PARAM_COUNT
    assert f"f32[{p}]" in text  # flat params in and out
    assert f"f32[{aot.TRAIN_BATCH},32,32,1]" in text
    assert f"s32[{aot.TRAIN_BATCH}]" in text
    assert f"f32[{aot.TRAIN_BATCH}]" in text  # per-example losses


def test_eval_step_signature_in_hlo(artifacts):
    text = artifacts["eval_step.hlo.txt"]
    assert f"f32[{aot.EVAL_BATCH},32,32,1]" in text
    assert "s32[]" in text  # correct-count output


def test_manifest_contract():
    m = aot.manifest()
    assert m["param_count"] == model.PARAM_COUNT
    assert m["num_classes"] == 35
    assert m["train_batch"] == 20  # paper §5 batch size
    spec_total = sum(
        int(__import__("math").prod(e["shape"])) for e in m["param_spec"]
    )
    assert spec_total == m["param_count"]
    assert set(m["artifacts"]) == {"train_step", "eval_step", "init_params"}
    json.dumps(m)  # must be JSON-serializable as written
