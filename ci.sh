#!/usr/bin/env bash
# Offline verification pipeline (what `make verify` runs).
#
# Order matters: the cheap compile gate first, then the test suite,
# then lints. clippy/rustfmt are optional components of a toolchain, so
# their absence downgrades to a loud skip instead of a hard failure —
# everything else is strict.

set -euo pipefail
cd "$(dirname "$0")"

# Never touch the network: every dependency is vendored in-tree.
export CARGO_NET_OFFLINE=true

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# Scenario sweep smoke: 2 rounds over two scenarios x two selectors on
# the mock runtime must produce a merged CSV with a scenario column and
# exactly header + 4 rows (2 selectors x 2 scenarios x 1 seed).
echo "==> scenario sweep smoke"
SMOKE_OUT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT"' EXIT
./target/release/eafl sweep --mock --scenario steady,diurnal \
  --selectors random,eafl --seeds 1 --rounds 2 --clients 16 --jobs 2 \
  --out "$SMOKE_OUT" >/dev/null
SMOKE_CSV="$SMOKE_OUT/sweep.campaign.csv"
head -1 "$SMOKE_CSV" | grep -q "^selector,scenario," \
  || { echo "FAIL: merged CSV is missing the scenario column"; exit 1; }
rows="$(wc -l < "$SMOKE_CSV")"
[ "$rows" -eq 5 ] \
  || { echo "FAIL: expected 5 CSV lines (header + 4 runs), got $rows"; exit 1; }
echo "    sweep smoke OK ($rows lines in $(basename "$SMOKE_CSV"))"

if cargo clippy --version >/dev/null 2>&1; then
  echo "==> cargo clippy --all-targets -- -D warnings"
  cargo clippy --all-targets -- -D warnings
else
  echo "==> SKIP clippy (component not installed)"
fi

if cargo fmt --version >/dev/null 2>&1; then
  echo "==> cargo fmt --check"
  cargo fmt --check
else
  echo "==> SKIP rustfmt (component not installed)"
fi

echo "==> verify OK"
