//! Server-side aggregation over flat f32 parameter vectors.
//!
//! The paper's §5 setup uses YoGi (Ramaswamy et al. / Reddi et al.,
//! "Adaptive Federated Optimization") as the aggregation algorithm; we
//! also provide classic sample-weighted FedAvg as the baseline rule.

mod fedavg;
mod yogi;

pub use fedavg::FedAvg;
pub use yogi::Yogi;

use anyhow::Result;

use crate::config::AggregatorKind;

/// One completing client's contribution to a round.
#[derive(Debug, Clone)]
pub struct ClientUpdate {
    /// The client's locally-updated flat parameter vector.
    pub params: Vec<f32>,
    /// Aggregation weight (sample count |B_i|).
    pub weight: f64,
}

/// Server aggregation rule: folds completing clients' updates into the
/// global flat parameter vector in place.
pub trait Aggregator: Send {
    /// Apply one round of updates. `updates` is non-empty and every
    /// vector has `global.len()` elements.
    fn aggregate(&mut self, global: &mut [f32], updates: &[ClientUpdate]) -> Result<()>;

    /// Human-readable name for logs.
    fn name(&self) -> &'static str;
}

/// Instantiate the configured aggregator for `param_count` parameters.
pub fn make_aggregator(kind: AggregatorKind, param_count: usize, server_lr: f32) -> Box<dyn Aggregator> {
    match kind {
        AggregatorKind::FedAvg => Box::new(FedAvg),
        AggregatorKind::Yogi => Box::new(Yogi::new(param_count, server_lr)),
    }
}

/// Sample-weighted mean of client parameter vectors (shared helper).
pub(crate) fn weighted_mean(updates: &[ClientUpdate], out: &mut [f32]) {
    debug_assert!(!updates.is_empty());
    let total: f64 = updates.iter().map(|u| u.weight).sum();
    let total = if total > 0.0 { total } else { updates.len() as f64 };
    out.iter_mut().for_each(|v| *v = 0.0);
    for u in updates {
        let w = (if u.weight > 0.0 { u.weight } else { 1.0 } / total) as f32;
        debug_assert_eq!(u.params.len(), out.len());
        for (o, &p) in out.iter_mut().zip(&u.params) {
            *o += w * p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_mean_respects_weights() {
        let updates = vec![
            ClientUpdate { params: vec![0.0, 0.0], weight: 1.0 },
            ClientUpdate { params: vec![3.0, 6.0], weight: 2.0 },
        ];
        let mut out = vec![0.0; 2];
        weighted_mean(&updates, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6);
        assert!((out[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn zero_weights_fall_back_to_uniform() {
        let updates = vec![
            ClientUpdate { params: vec![1.0], weight: 0.0 },
            ClientUpdate { params: vec![3.0], weight: 0.0 },
        ];
        let mut out = vec![0.0; 1];
        weighted_mean(&updates, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn factory_constructs_both() {
        assert_eq!(make_aggregator(AggregatorKind::FedAvg, 4, 0.1).name(), "fedavg");
        assert_eq!(make_aggregator(AggregatorKind::Yogi, 4, 0.1).name(), "yogi");
    }
}
