#!/usr/bin/env bash
# Offline verification pipeline (what `make verify` runs).
#
# Order matters: the cheap compile gate first, then the test suite,
# then lints. clippy/rustfmt are optional components of a toolchain, so
# their absence downgrades to a loud skip instead of a hard failure —
# everything else is strict.

set -euo pipefail
cd "$(dirname "$0")"

# Never touch the network: every dependency is vendored in-tree.
export CARGO_NET_OFFLINE=true

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
  echo "==> cargo clippy --all-targets -- -D warnings"
  cargo clippy --all-targets -- -D warnings
else
  echo "==> SKIP clippy (component not installed)"
fi

if cargo fmt --version >/dev/null 2>&1; then
  echo "==> cargo fmt --check"
  cargo fmt --check
else
  echo "==> SKIP rustfmt (component not installed)"
fi

echo "==> verify OK"
