//! Real [`ModelRuntime`]: PJRT CPU client executing the AOT artifacts.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Each artifact is compiled exactly once
//! at load time; the per-step path is literal-marshal + execute only.

use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, ensure, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::{EvalOutput, Manifest, ModelRuntime, TrainOutput};

/// PJRT-backed model runtime. One compiled executable per entry point.
pub struct XlaRuntime {
    manifest: Manifest,
    train: PjRtLoadedExecutable,
    eval: PjRtLoadedExecutable,
    init: PjRtLoadedExecutable,
    /// Serializes every step end to end: the xla crate's wrappers are
    /// not thread-safe, so concurrent `ModelRuntime` callers (the
    /// parallel execution phase, campaign threads) hold this lock for
    /// the WHOLE step — literal marshal, execute, and result unmarshal
    /// all go through the same C++ bridge. The mock runtime
    /// parallelizes for real; here workers simply queue — correctness
    /// over concurrency for the bridge.
    exec_lock: Mutex<()>,
    // Client must outlive executables; keep it last in drop order.
    _client: PjRtClient,
}

// SAFETY: the xla crate's raw pointers are neither Send nor Sync by
// declaration. Every `ModelRuntime` entry point (`init_params`,
// `train_step`, `eval_step`) acquires `exec_lock` before its first
// bridge call (Literal construction included) and releases it after
// the last (output `to_vec`/`get_first_element`), so at most one
// thread touches xla-crate state at a time; the remaining field
// (manifest) is plain data.
unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}

impl XlaRuntime {
    /// Load `manifest.json` + all HLO artifacts from `dir` and compile
    /// them on a fresh PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let compile = |key: &str| -> Result<PjRtLoadedExecutable> {
            let path = manifest.artifact_path(dir, key)?;
            let proto = HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {key}: {e}"))
        };
        let train = compile("train_step")?;
        let eval = compile("eval_step")?;
        let init = compile("init_params")?;
        Ok(Self { manifest, train, eval, init, exec_lock: Mutex::new(()), _client: client })
    }

    /// Default artifact location relative to the repo root, overridable
    /// via `EAFL_ARTIFACTS`.
    pub fn default_dir() -> std::path::PathBuf {
        std::env::var_os("EAFL_ARTIFACTS")
            .map(Into::into)
            .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
    }

    fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape f32{dims:?}: {e}"))
    }

    fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape i32{dims:?}: {e}"))
    }

    /// Take the step lock (poison-tolerant: the runtime itself is
    /// stateless between calls, so a panicked sibling can't corrupt it).
    fn lock(&self) -> std::sync::MutexGuard<'_, ()> {
        self.exec_lock.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Execute and unpack the (tupled) result into its element literals.
    /// Caller must hold `exec_lock` (see the `Sync` safety comment).
    fn run(&self, exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Vec<Literal>> {
        let bufs = exe.execute::<Literal>(args).map_err(|e| anyhow!("execute: {e}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync: {e}"))?;
        // aot.py lowers with return_tuple=True: always a tuple literal.
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e}"))
    }
}

impl ModelRuntime for XlaRuntime {
    fn param_count(&self) -> usize {
        self.manifest.param_count
    }
    fn train_batch(&self) -> usize {
        self.manifest.train_batch
    }
    fn eval_batch(&self) -> usize {
        self.manifest.eval_batch
    }
    fn num_classes(&self) -> usize {
        self.manifest.num_classes
    }
    fn input_hw(&self) -> usize {
        self.manifest.input_hw
    }

    fn init_params(&self, seed: u32) -> Result<Vec<f32>> {
        let _guard = self.lock();
        let out = self.run(&self.init, &[Literal::scalar(seed)])?;
        ensure!(out.len() == 1, "init_params returned {} outputs", out.len());
        let params = out[0].to_vec::<f32>().map_err(|e| anyhow!("init to_vec: {e}"))?;
        ensure!(params.len() == self.param_count(), "init param length mismatch");
        Ok(params)
    }

    fn train_step(&self, params: &[f32], x: &[f32], y: &[i32], lr: f32) -> Result<TrainOutput> {
        let _guard = self.lock();
        let b = self.train_batch() as i64;
        let hw = self.input_hw() as i64;
        ensure!(params.len() == self.param_count(), "params length mismatch");
        ensure!(x.len() == self.manifest.train_x_len(), "x length mismatch");
        ensure!(y.len() == self.train_batch(), "y length mismatch");
        let args = [
            Self::literal_f32(params, &[self.param_count() as i64])?,
            Self::literal_f32(x, &[b, hw, hw, 1])?,
            Self::literal_i32(y, &[b])?,
            Literal::scalar(lr),
        ];
        let out = self.run(&self.train, &args)?;
        ensure!(out.len() == 3, "train_step returned {} outputs", out.len());
        Ok(TrainOutput {
            params: out[0].to_vec::<f32>().map_err(|e| anyhow!("params out: {e}"))?,
            mean_loss: out[1]
                .get_first_element::<f32>()
                .map_err(|e| anyhow!("mean_loss out: {e}"))?,
            per_example_loss: out[2]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("per_example out: {e}"))?,
        })
    }

    fn eval_step(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOutput> {
        let _guard = self.lock();
        let b = self.eval_batch() as i64;
        let hw = self.input_hw() as i64;
        ensure!(params.len() == self.param_count(), "params length mismatch");
        ensure!(x.len() == self.manifest.eval_x_len(), "x length mismatch");
        ensure!(y.len() == self.eval_batch(), "y length mismatch");
        let args = [
            Self::literal_f32(params, &[self.param_count() as i64])?,
            Self::literal_f32(x, &[b, hw, hw, 1])?,
            Self::literal_i32(y, &[b])?,
        ];
        let out = self.run(&self.eval, &args)?;
        ensure!(out.len() == 2, "eval_step returned {} outputs", out.len());
        Ok(EvalOutput {
            correct: out[0]
                .get_first_element::<i32>()
                .map_err(|e| anyhow!("correct out: {e}"))?,
            mean_loss: out[1]
                .get_first_element::<f32>()
                .map_err(|e| anyhow!("loss out: {e}"))?,
        })
    }
}
