//! Minimal in-tree `anyhow` (DESIGN.md §2: the build is fully offline,
//! so crates.io dependencies are vendored from scratch).
//!
//! Implements exactly the surface eafl uses:
//!  - [`Error`]: an opaque error carrying a context chain of messages,
//!    convertible from any `std::error::Error` (source chain preserved
//!    as flattened messages).
//!  - [`Result`]: `std::result::Result` with `Error` as the default
//!    error type.
//!  - [`anyhow!`], [`bail!`], [`ensure!`]: formatted construction.
//!  - [`Context`]: `.context(..)` / `.with_context(..)` on `Result`
//!    (both std-error and `Error` payloads) and `Option`.
//!
//! Not implemented (unused here): downcasting, backtraces, `#[source]`
//! derive interop.

use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a non-empty chain of messages, outermost context
/// first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, colon-separated (anyhow's style).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket `From` (and the
// `?` operator on any std error) coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod private {
    pub trait Sealed {}
    impl<T, E> Sealed for std::result::Result<T, E> {}
    impl<T> Sealed for Option<T> {}
}

mod ext {
    /// Unifies "a std error" and "already an `Error`" for `Context`.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }
    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }
    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T>: private::Sealed {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// [`bail!`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");

        let o: Option<u8> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
    }

    #[test]
    fn debug_renders_cause_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }
}
