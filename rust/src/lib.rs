//! # EAFL — Energy-Aware Federated Learning on Battery-Powered Clients
//!
//! Rust + JAX + Pallas reproduction of *"EAFL: Towards Energy-Aware
//! Federated Learning on Battery-Powered Edge Devices"* (Arouj &
//! Abdelmoniem, FedEdge @ MobiCom'22).
//!
//! Three-layer architecture (see `DESIGN.md`):
//!  - **Layer 3 (this crate)** — the FL coordinator: client selection
//!    (Random / Oort / EAFL), event-driven device simulation, energy and
//!    battery accounting, aggregation (FedAvg / YoGi), metrics.
//!  - **Layer 2** — JAX speech-CNN fwd/bwd, AOT-lowered to HLO text at
//!    build time (`make artifacts`), executed here via PJRT.
//!  - **Layer 1** — Pallas kernels (fused dense, fused softmax-xent)
//!    inlined into the Layer-2 HLO.
//!
//! Python never runs on the request path: the `eafl` binary is
//! self-contained once `artifacts/` exists.

pub mod aggregation;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod energy;
pub mod metrics;
pub mod network;
pub mod runtime;
pub mod selection;
pub mod sim;
pub mod training;
pub mod util;

pub use config::ExperimentConfig;
pub use coordinator::Coordinator;
