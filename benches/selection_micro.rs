//! Selector microbenchmarks: per-round selection cost for Random, Oort
//! and EAFL across population sizes 100..100k — L3's own hot path
//! (everything except model execution).
//!
//! Run: cargo bench --bench selection_micro

use eafl::benchkit::{bb, Bench};
use eafl::config::{SelectorConfig, SelectorKind};
use eafl::selection::{
    make_selector, percentile_in_place, weighted_sample_linear, Candidate, FenwickSampler,
};
use eafl::util::rng::Rng;

fn candidates(n: usize) -> Vec<Candidate> {
    let mut rng = Rng::seed_from_u64(7);
    (0..n)
        .map(|id| Candidate {
            id,
            // 70% explored with varied utility, 30% fresh.
            stat_util: if rng.gen_bool(0.7) {
                Some(rng.gen_range_f64(1.0, 400.0))
            } else {
                None
            },
            measured_duration_s: Some(rng.gen_range_f64(60.0, 900.0)),
            expected_duration_s: rng.gen_range_f64(60.0, 900.0),
            last_selected_round: rng.gen_range_usize(0, 50) as u64,
            battery_frac: rng.gen_f64(),
            projected_drain_frac: rng.gen_range_f64(0.001, 0.05),
        })
        .collect()
}

/// The pre-refactor percentile: clone + full sort on every call.
fn percentile_sort_baseline(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

fn main() {
    let mut bench = Bench::new();

    // The selection hot path's primitive: percentile of the candidate
    // duration distribution, computed on every deadline_s call.
    for n in [1_000usize, 100_000] {
        let mut rng = Rng::seed_from_u64(3);
        let durations: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(60.0, 900.0)).collect();
        let mut scratch = durations.clone();
        bench.run(&format!("percentile sort-baseline N={n}"), || {
            bb(percentile_sort_baseline(bb(&durations), 0.8));
        });
        bench.run(&format!("percentile select_nth (in place) N={n}"), || {
            scratch.copy_from_slice(&durations);
            bb(percentile_in_place(bb(&mut scratch), 0.8));
        });
    }

    // The selectors' shared weighted-draw primitive: Fenwick build +
    // O(log n) draws vs the O(k·n) linear reference scan.
    for n in [1_000usize, 100_000] {
        let mut wrng = Rng::seed_from_u64(5);
        let weights: Vec<f64> = (0..n).map(|_| wrng.gen_range_f64(0.01, 10.0)).collect();
        bench.run(&format!("weighted draw k=10 linear N={n}"), || {
            bb(weighted_sample_linear(bb(&weights), 10, &mut Rng::seed_from_u64(1)));
        });
        bench.run(&format!("weighted draw k=10 fenwick N={n}"), || {
            let mut sampler = FenwickSampler::new(bb(&weights));
            bb(sampler.sample_distinct(10, &mut Rng::seed_from_u64(1)));
        });
    }

    // Satellite: `deadline_s` used to clone a durations Vec per call;
    // the Selector trait now takes `&mut self` and reuses an internal
    // scratch buffer, so steady-state calls are allocation-free. The
    // 100k-client population is where the win shows.
    {
        let cands = candidates(100_000);
        for kind in [SelectorKind::Random, SelectorKind::Oort, SelectorKind::Eafl] {
            let mut cfg = SelectorConfig::default();
            cfg.kind = kind;
            let mut selector = make_selector(&cfg);
            bench.run(&format!("{kind} deadline_s N=100000 (scratch reuse)"), || {
                bb(selector.deadline_s(bb(&cands)));
            });
        }
    }

    for n in [100usize, 1_000, 10_000, 100_000] {
        let cands = candidates(n);
        for kind in [SelectorKind::Random, SelectorKind::Oort, SelectorKind::Eafl] {
            let mut cfg = SelectorConfig::default();
            cfg.kind = kind;
            let mut selector = make_selector(&cfg);
            let mut rng = Rng::seed_from_u64(11);
            let mut round = 0u64;
            bench.run(&format!("{kind} select K=10 of N={n}"), || {
                round += 1;
                bb(selector.select(round, bb(&cands), 10, &mut rng));
            });
        }
    }
}
