//! Cross-process determinism tier: the shard/merge protocol's contract
//! is that sharding a campaign across real OS processes changes *how*
//! the grid is computed, never *what* lands on disk. Every test here
//! drives the actual `eafl` binary (CARGO_BIN_EXE_eafl) and compares
//! the merged `campaign.json` / `campaign.csv` **bytes** against a
//! single-process `eafl sweep` reference:
//!
//!  - any shard count (N ∈ {1, 2, 4}), run in any completion order;
//!  - shards sharing one --out directory or scattered across several;
//!  - `--jobs P` self-orchestration (P child processes + auto-merge);
//!  - a shard killed mid-campaign and resumed afterwards;
//!  - and `eafl merge` refusing to pass off a partial grid as done.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use eafl::campaign::shard_of;
use eafl::util::json::Json;

const BIN: &str = env!("CARGO_BIN_EXE_eafl");

/// The test grid: 2 selectors x 2 scenarios x 2 seeds = 8 cells.
/// Chosen so the FNV name partition is non-degenerate: mod 2 splits
/// 4/4, mod 4 splits 1/1/3/3 (asserted in `partition_is_usable`).
const GRID: &[&str] = &[
    "--mock",
    "--rounds",
    "4",
    "--clients",
    "12",
    "--selectors",
    "random,eafl",
    "--scenario",
    "steady,diurnal",
    "--seeds",
    "1,2",
];

/// The 8 cell names the grid above expands to (cell names are the
/// sharding protocol's stable identity, so spelling them out here also
/// pins the naming scheme).
fn cell_names(clients: usize) -> Vec<String> {
    let mut names = Vec::new();
    for selector in ["random", "eafl"] {
        for scenario in ["steady", "diurnal"] {
            for seed in [1, 2] {
                names.push(format!("sweep-{selector}-{scenario}-n{clients}-f0.25-s{seed}"));
            }
        }
    }
    names
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eafl-shard-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn eafl(args: &[&str]) -> Output {
    Command::new(BIN).args(args).output().expect("spawning eafl")
}

fn sweep(grid: &[&str], extra: &[&str], out: &Path) -> Output {
    let mut cmd = Command::new(BIN);
    cmd.arg("sweep").args(grid).args(extra).arg("--out").arg(out);
    cmd.output().expect("spawning eafl sweep")
}

fn assert_ok(output: &Output, what: &str) {
    assert!(
        output.status.success(),
        "{what} failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        output.status,
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr)
    );
}

/// The two merged artifacts whose bytes the whole tier compares.
fn merged_bytes(dir: &Path) -> (String, String) {
    let json = std::fs::read_to_string(dir.join("sweep.campaign.json"))
        .unwrap_or_else(|e| panic!("no merged campaign.json in {dir:?}: {e}"));
    let csv = std::fs::read_to_string(dir.join("sweep.campaign.csv"))
        .unwrap_or_else(|e| panic!("no merged campaign.csv in {dir:?}: {e}"));
    (json, csv)
}

/// Single-process reference sweep into a fresh directory.
fn reference(tag: &str, grid: &[&str]) -> (PathBuf, String, String) {
    let dir = tmp_dir(tag);
    assert_ok(&sweep(grid, &["--jobs", "1"], &dir), "reference sweep");
    let (json, csv) = merged_bytes(&dir);
    (dir, json, csv)
}

#[test]
fn partition_is_usable_for_this_grid() {
    // The other tests lean on every shard owning at least one cell (so
    // "shard completion order" and "missing shard" mean something).
    // This is a property of the fixed cell names — deterministic, but
    // worth failing loudly if the grid is ever edited.
    for count in [2usize, 4] {
        let mut owned = vec![0usize; count];
        for name in cell_names(12) {
            owned[shard_of(&name, count)] += 1;
        }
        assert!(
            owned.iter().all(|&n| n > 0),
            "grid leaves an empty shard at N={count} ({owned:?}); pick a different grid"
        );
    }
}

#[test]
fn single_process_sweep_is_reproducible_and_writes_the_manifest() {
    let (dir_a, json_a, csv_a) = reference("ref-a", GRID);
    let (dir_b, json_b, csv_b) = reference("ref-b", GRID);
    assert_eq!(json_a, json_b, "same grid, same bytes");
    assert_eq!(csv_a, csv_b);

    let parsed = Json::parse(&json_a).unwrap();
    assert_eq!(parsed.field("total_runs").unwrap().as_usize(), Some(8));
    assert_eq!(csv_a.lines().count(), 9, "header + 8 grid cells");

    // Every sweep with an --out writes the grid manifest — and both
    // processes write identical manifest bytes.
    let manifest_a = std::fs::read_to_string(dir_a.join("sweep.manifest.json")).unwrap();
    let manifest_b = std::fs::read_to_string(dir_b.join("sweep.manifest.json")).unwrap();
    assert_eq!(manifest_a, manifest_b);
    assert_eq!(
        Json::parse(&manifest_a).unwrap().field("total_cells").unwrap().as_usize(),
        Some(8)
    );

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

/// The acceptance criterion: `--shard I/N` for N ∈ {1, 2, 4}, shards
/// run in *reverse* order (worst case for any accidental order
/// dependence), sharing one --out; `eafl merge` must reproduce the
/// single-process bytes exactly.
#[test]
fn any_shard_count_merges_byte_identical_in_any_completion_order() {
    let (ref_dir, ref_json, ref_csv) = reference("count-ref", GRID);
    for count in [1usize, 2, 4] {
        let dir = tmp_dir(&format!("count-{count}"));
        // Reverse completion order: shard N-1 finishes first, shard 0
        // last. (Sequential spawning makes the order deterministic.)
        for index in (0..count).rev() {
            let shard = format!("{index}/{count}");
            assert_ok(
                &sweep(GRID, &["--jobs", "1", "--shard", &shard], &dir),
                &format!("shard {shard}"),
            );
        }
        let dir_str = dir.to_str().unwrap();
        assert_ok(&eafl(&["merge", dir_str]), &format!("merge N={count}"));
        let (json, csv) = merged_bytes(&dir);
        assert_eq!(json, ref_json, "N={count}: merged JSON must match single-process");
        assert_eq!(csv, ref_csv, "N={count}: merged CSV must match single-process");
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&ref_dir);
}

/// Shards do not need to share a directory: each can write to its own
/// --out (different hosts, different scratch disks) and `eafl merge
/// DIR...` — in any argument order — reassembles the campaign.
#[test]
fn shards_in_separate_dirs_merge_across_directories() {
    let (ref_dir, ref_json, ref_csv) = reference("dirs-ref", GRID);
    let d0 = tmp_dir("dirs-0");
    let d1 = tmp_dir("dirs-1");
    assert_ok(&sweep(GRID, &["--jobs", "1", "--shard", "0/2"], &d0), "shard 0/2");
    assert_ok(&sweep(GRID, &["--jobs", "1", "--shard", "1/2"], &d1), "shard 1/2");

    // Merge with the directories in *reverse* order, into a third dir.
    let out = tmp_dir("dirs-merged");
    assert_ok(
        &eafl(&[
            "merge",
            d1.to_str().unwrap(),
            d0.to_str().unwrap(),
            "--out",
            out.to_str().unwrap(),
        ]),
        "cross-directory merge",
    );
    let (json, csv) = merged_bytes(&out);
    assert_eq!(json, ref_json);
    assert_eq!(csv, ref_csv);
    for d in [&ref_dir, &d0, &d1, &out] {
        let _ = std::fs::remove_dir_all(d);
    }
}

/// `eafl sweep --jobs P` is the one-command version: P shard child
/// processes over one --out, merged on completion — still byte-stable.
#[test]
fn jobs_flag_self_orchestrates_shard_processes() {
    let (ref_dir, ref_json, ref_csv) = reference("jobs-ref", GRID);
    let dir = tmp_dir("jobs-3");
    let output = sweep(GRID, &["--jobs", "3"], &dir);
    assert_ok(&output, "self-orchestrated sweep --jobs 3");
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(
        stdout.contains("sharding across 3 processes"),
        "expected the orchestration banner, got:\n{stdout}"
    );
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "--jobs 3 must be byte-identical to --jobs 1");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill a shard mid-campaign, then resume it: whatever partial state
/// the kill left behind (torn JSON, missing fingerprints, half the
/// cells done), rerunning the same `--shard I/N` into the same --out
/// must converge to the same merged bytes.
#[test]
fn killed_shard_resumes_to_identical_bytes() {
    // A heavier grid so the shard is plausibly mid-flight when killed
    // (the test is valid — just weaker — if the child wins the race).
    let grid: &[&str] = &[
        "--mock",
        "--rounds",
        "30",
        "--clients",
        "48",
        "--selectors",
        "random,eafl",
        "--scenario",
        "steady,diurnal",
        "--seeds",
        "1,2",
    ];
    let (ref_dir, ref_json, ref_csv) = reference("kill-ref", grid);

    let dir = tmp_dir("kill");
    let mut child = Command::new(BIN)
        .arg("sweep")
        .args(grid)
        .args(["--jobs", "1", "--shard", "0/2"])
        .arg("--out")
        .arg(&dir)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning shard to kill");
    std::thread::sleep(std::time::Duration::from_millis(40));
    let _ = child.kill();
    let _ = child.wait();

    // Resume the killed shard, run its sibling, merge.
    assert_ok(&sweep(grid, &["--jobs", "1", "--shard", "0/2"], &dir), "resumed shard 0/2");
    assert_ok(&sweep(grid, &["--jobs", "1", "--shard", "1/2"], &dir), "shard 1/2");
    assert_ok(&eafl(&["merge", dir.to_str().unwrap()]), "merge after kill+resume");
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "kill+resume must not change a single byte");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A merge over an incomplete campaign must fail loudly and name the
/// missing cells — never emit a partial report that looks complete.
#[test]
fn merge_refuses_a_partial_campaign() {
    let dir = tmp_dir("partial");
    assert_ok(&sweep(GRID, &["--jobs", "1", "--shard", "0/2"], &dir), "shard 0/2");
    let output = eafl(&["merge", dir.to_str().unwrap()]);
    assert!(
        !output.status.success(),
        "merge of half a campaign must fail, got:\n{}",
        String::from_utf8_lossy(&output.stdout)
    );
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(stderr.contains("merge incomplete"), "unhelpful merge error:\n{stderr}");
    // At least one shard-1 cell is named (shard 1/2 owns >= 1 cell —
    // see partition_is_usable_for_this_grid).
    assert!(
        cell_names(12)
            .into_iter()
            .filter(|name| shard_of(name.as_str(), 2) == 1)
            .any(|name| stderr.contains(&name)),
        "error should name a missing cell:\n{stderr}"
    );
    assert!(!stderr.contains("panicked"), "clean error, not a panic:\n{stderr}");
    // And no merged artifacts appeared.
    assert!(!dir.join("sweep.campaign.json").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

// --- Fault-injection matrix -------------------------------------------
//
// Every `FaultPlan` site gets a test: crash (on_cell_finished), stall
// (on_cell_start), torn-write and corrupt for each artifact kind
// (summary, config, manifest, trace, merged campaign). The contract
// under test is always the same: the supervisor retries / the
// quarantine machinery sets the bad bytes aside, and the final merged
// artifacts are byte-identical to a fault-free single-process run.

/// A cell targeted by name in several fault specs; first in grid order.
const CELL: &str = "sweep-random-steady-n12-f0.25-s1";

fn stderr_of(output: &Output) -> String {
    String::from_utf8_lossy(&output.stderr).into_owned()
}

/// The acceptance criterion: every shard child crashes via an injected
/// fault after its first finished cell; the supervisor restarts them
/// (the restart env-scopes the fault off) and the merged bytes match
/// the fault-free reference exactly.
#[test]
fn injected_crash_retries_to_identical_bytes() {
    let (ref_dir, ref_json, ref_csv) = reference("fault-crash-ref", GRID);
    let dir = tmp_dir("fault-crash");
    let output = sweep(GRID, &["--jobs", "2", "--fault", "crash:after-cells=1"], &dir);
    assert_ok(&output, "sweep with injected crash");
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("exit 70"),
        "supervisor should report the injected crash:\n{stderr}"
    );
    assert!(
        stderr.contains("retrying shard"),
        "supervisor should announce the restart:\n{stderr}"
    );
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "crash+retry must converge to fault-free bytes");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A shard that stops heartbeating is killed after --stall-timeout-s
/// and restarted; the retry runs unarmed and the campaign converges.
#[test]
fn stalled_shard_is_killed_and_restarted() {
    let (ref_dir, ref_json, ref_csv) = reference("fault-stall-ref", GRID);
    let dir = tmp_dir("fault-stall");
    let fault = format!("stall:cell={CELL}:ms=8000");
    let output = sweep(
        GRID,
        &["--jobs", "2", "--stall-timeout-s", "1", "--fault", &fault],
        &dir,
    );
    assert_ok(&output, "sweep with injected stall");
    let stderr = stderr_of(&output);
    assert!(stderr.contains("stalled"), "supervisor should report the stall:\n{stderr}");
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "stall-kill+retry must converge to fault-free bytes");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Torn summary: half the summary bytes hit disk, then the child dies.
/// The restarted shard must quarantine the torn file (named on stderr)
/// and recompute the cell.
#[test]
fn torn_summary_write_is_quarantined_on_resume() {
    let (ref_dir, ref_json, ref_csv) = reference("fault-torn-sum-ref", GRID);
    let dir = tmp_dir("fault-torn-sum");
    let fault = format!("torn-write:kind=summary:cell={CELL}");
    let output = sweep(GRID, &["--jobs", "2", "--fault", &fault], &dir);
    assert_ok(&output, "sweep with torn summary write");
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("[quarantine]") && stderr.contains(&format!("{CELL}.summary.json")),
        "resume should quarantine the torn summary by name:\n{stderr}"
    );
    assert!(
        dir.join(format!("{CELL}.summary.json.quarantine")).exists(),
        "torn bytes must be preserved out of band"
    );
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "torn summary must not change the merged bytes");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Torn config fingerprint: the summary landed whole but the
/// fingerprint is half-written. Resume must treat the cell as
/// unverifiable, quarantine the mismatching fingerprint, recompute.
#[test]
fn torn_config_write_is_quarantined_on_resume() {
    let (ref_dir, ref_json, ref_csv) = reference("fault-torn-cfg-ref", GRID);
    let dir = tmp_dir("fault-torn-cfg");
    let fault = format!("torn-write:kind=config:cell={CELL}");
    let output = sweep(GRID, &["--jobs", "2", "--fault", &fault], &dir);
    assert_ok(&output, "sweep with torn config write");
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("[quarantine]") && stderr.contains(&format!("{CELL}.config.toml")),
        "resume should quarantine the torn fingerprint by name:\n{stderr}"
    );
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "torn fingerprint must not change the merged bytes");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Torn trace: the trace file is truncated mid-write and the child
/// dies before the summary lands, so the retry recomputes the cell —
/// including a byte-identical trace.
#[test]
fn torn_trace_write_recomputes_the_cell() {
    let ref_dir = tmp_dir("fault-torn-trace-ref");
    let ref_traces = ref_dir.join("traces");
    assert_ok(
        &sweep(GRID, &["--jobs", "1", "--trace", ref_traces.to_str().unwrap()], &ref_dir),
        "traced reference sweep",
    );
    let (ref_json, ref_csv) = merged_bytes(&ref_dir);
    let ref_trace =
        std::fs::read_to_string(ref_traces.join(format!("{CELL}.trace.jsonl"))).unwrap();

    let dir = tmp_dir("fault-torn-trace");
    let traces = dir.join("traces");
    let fault = format!("torn-write:kind=trace:cell={CELL}");
    let output = sweep(
        GRID,
        &["--jobs", "2", "--trace", traces.to_str().unwrap(), "--fault", &fault],
        &dir,
    );
    assert_ok(&output, "sweep with torn trace write");
    let stderr = stderr_of(&output);
    assert!(stderr.contains("retrying shard"), "torn trace must trigger a retry:\n{stderr}");
    let trace = std::fs::read_to_string(traces.join(format!("{CELL}.trace.jsonl"))).unwrap();
    assert_eq!(trace, ref_trace, "recomputed trace must be byte-identical");
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json);
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Silent config corruption: the child exits 0, but the fingerprint on
/// disk no longer hashes the manifest's config. The supervisor's merge
/// pass must catch it, quarantine both files, and rerun the owner.
#[test]
fn corrupt_config_is_caught_by_merge_fingerprint_check() {
    let (ref_dir, ref_json, ref_csv) = reference("fault-corrupt-cfg-ref", GRID);
    let dir = tmp_dir("fault-corrupt-cfg");
    let fault = format!("corrupt:kind=config:cell={CELL}");
    let output = sweep(GRID, &["--jobs", "2", "--fault", &fault], &dir);
    assert_ok(&output, "sweep with corrupted config fingerprint");
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("merge incomplete") && stderr.contains(CELL),
        "supervisor should name the corrupt cell before rerunning it:\n{stderr}"
    );
    assert!(
        dir.join(format!("{CELL}.config.toml.quarantine")).exists(),
        "mismatching fingerprint must be quarantined"
    );
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "corrupt fingerprint must not change the merged bytes");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Silent summary corruption: clean exit, unparseable summary.json.
/// Caught at merge, quarantined, recomputed.
#[test]
fn corrupt_summary_is_caught_by_merge() {
    let (ref_dir, ref_json, ref_csv) = reference("fault-corrupt-sum-ref", GRID);
    let dir = tmp_dir("fault-corrupt-sum");
    let fault = format!("corrupt:kind=summary:cell={CELL}");
    let output = sweep(GRID, &["--jobs", "2", "--fault", &fault], &dir);
    assert_ok(&output, "sweep with corrupted summary");
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("[quarantine]") && stderr.contains(&format!("{CELL}.summary.json")),
        "merge should quarantine the corrupt summary by name:\n{stderr}"
    );
    assert!(dir.join(format!("{CELL}.summary.json.quarantine")).exists());
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "corrupt summary must not change the merged bytes");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupt grid manifest: the merge's ordering/completeness authority
/// itself is unparseable. It is quarantined and every shard reruns
/// (cheaply, via resume) to regenerate it.
#[test]
fn corrupt_manifest_is_quarantined_and_regenerated() {
    let (ref_dir, ref_json, ref_csv) = reference("fault-corrupt-man-ref", GRID);
    let dir = tmp_dir("fault-corrupt-man");
    let output = sweep(GRID, &["--jobs", "2", "--fault", "corrupt:kind=manifest"], &dir);
    assert_ok(&output, "sweep with corrupted manifest");
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("manifest missing or quarantined"),
        "supervisor should explain the full rerun:\n{stderr}"
    );
    assert!(dir.join("sweep.manifest.json.quarantine").exists());
    // The regenerated manifest must match the reference's bytes.
    assert_eq!(
        std::fs::read_to_string(dir.join("sweep.manifest.json")).unwrap(),
        std::fs::read_to_string(ref_dir.join("sweep.manifest.json")).unwrap()
    );
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json);
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupt merged report: the next sweep over the same --out must
/// quarantine the torn campaign.json on resume, skip every finished
/// cell, and rewrite the report bit-identically.
#[test]
fn corrupt_merged_report_is_quarantined_on_resume() {
    let (ref_dir, ref_json, ref_csv) = reference("fault-corrupt-rep-ref", GRID);
    let dir = tmp_dir("fault-corrupt-rep");
    assert_ok(
        &sweep(GRID, &["--jobs", "1", "--fault", "corrupt:kind=campaign"], &dir),
        "sweep with corrupted merged report",
    );
    // write_report writes the JSON first; the corrupt clause latches on
    // that first write, so the .json is the mangled artifact.
    let torn = std::fs::read_to_string(dir.join("sweep.campaign.json")).unwrap();
    assert_ne!(torn, ref_json, "the fault must actually corrupt the report");

    let output = sweep(GRID, &["--jobs", "1"], &dir);
    assert_ok(&output, "resume over a corrupt merged report");
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("[quarantine]") && stderr.contains("campaign.json"),
        "resume should quarantine the torn report by name:\n{stderr}"
    );
    assert!(
        stderr.contains("8/8 grid cells already complete"),
        "per-cell summaries were intact — nothing should recompute:\n{stderr}"
    );
    assert!(dir.join("sweep.campaign.json.quarantine").exists());
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "the report must regenerate bit-identically");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupt trace: a sweep does not read traces back, so the damage
/// surfaces in `eafl trace summarize` — which must quarantine the bad
/// file and say so, never panic or silently skip it.
#[test]
fn corrupt_trace_is_quarantined_by_trace_summarize() {
    let dir = tmp_dir("fault-corrupt-trace");
    let traces = dir.join("traces");
    let fault = format!("corrupt:kind=trace:cell={CELL}");
    assert_ok(
        &sweep(
            GRID,
            &["--jobs", "1", "--trace", traces.to_str().unwrap(), "--fault", &fault],
            &dir,
        ),
        "sweep with corrupted trace",
    );
    let trace = traces.join(format!("{CELL}.trace.jsonl"));
    let output = eafl(&["trace", "summarize", trace.to_str().unwrap()]);
    assert!(
        !output.status.success(),
        "summarizing a corrupt trace must fail, got:\n{}",
        String::from_utf8_lossy(&output.stdout)
    );
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("torn/corrupt trace event"),
        "the error should say what is wrong with the file:\n{stderr}"
    );
    assert!(stderr.contains("[quarantine]"), "and announce the quarantine:\n{stderr}");
    assert!(!stderr.contains("panicked"), "clean error, not a panic:\n{stderr}");
    assert!(!trace.exists(), "the corrupt trace must be moved aside");
    assert!(trace.with_file_name(format!("{CELL}.trace.jsonl.quarantine")).exists());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A fault armed on *every* attempt defeats the retry budget: the
/// supervisor must give up with exit code 4 and name the culprits.
#[test]
fn retries_exhausted_exits_4_and_names_the_culprit() {
    let dir = tmp_dir("fault-exhausted");
    let output = sweep(
        GRID,
        &["--jobs", "2", "--max-retries", "1", "--fault", "crash:after-cells=1:attempt=all"],
        &dir,
    );
    assert_eq!(
        output.status.code(),
        Some(4),
        "exhausted retries have their own exit code:\n{}",
        stderr_of(&output)
    );
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("retries exhausted") && stderr.contains("shard"),
        "the error should say which shards gave up:\n{stderr}"
    );
    assert!(!stderr.contains("panicked"), "clean error, not a panic:\n{stderr}");
    // No merged report may masquerade as a finished campaign.
    assert!(!dir.join("sweep.campaign.json").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A deterministic cell failure (here: the PJRT runtime is absent) is
/// NOT retried — rerunning it burns the budget to fail identically.
/// The supervisor relays the child's exit code 3 as its own.
#[test]
fn deterministic_cell_failure_exits_3_and_is_not_retried() {
    let dir = tmp_dir("fault-exit3");
    let no_mock = &GRID[1..]; // drop --mock: load_runtime must fail
    let mut cmd = Command::new(BIN);
    cmd.arg("sweep")
        .args(no_mock)
        .args(["--jobs", "2"])
        .arg("--out")
        .arg(&dir)
        // Guard against builds with the xla feature: point the runtime
        // at a directory that cannot exist.
        .env("EAFL_ARTIFACTS", dir.join("no-such-artifacts"));
    let output = cmd.output().expect("spawning eafl sweep");
    assert_eq!(
        output.status.code(),
        Some(3),
        "deterministic cell failures exit 3:\n{}",
        stderr_of(&output)
    );
    let stderr = stderr_of(&output);
    assert!(
        stderr.contains("not retried"),
        "the supervisor should explain why it gave up immediately:\n{stderr}"
    );
    assert!(!stderr.contains("retrying shard"), "exit 3 must not be retried:\n{stderr}");
    assert!(!stderr.contains("panicked"), "clean error, not a panic:\n{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}
