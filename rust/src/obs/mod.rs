//! Observability: the deterministic round-event bus and the wall-time
//! profiling channel.
//!
//! Two strictly separated channels (see ROADMAP "Observability"):
//!
//! 1. **Deterministic events** ([`RoundEvent`] via [`EventSink`]) —
//!    emitted from the engine's phase seams, the registry's lifecycle
//!    choke point (FL drain deaths, the background death wheel, and
//!    recharge revivals all flow through one mirror-sync hook), and
//!    the campaign runner. Payloads are pure functions of (config,
//!    seed, simulated time), so a `--trace` file is byte-identical at
//!    any `EAFL_WORKERS`, any `--shard` split, and lazy vs
//!    `EAFL_EAGER_DRAIN=1` (`rust/tests/trace_determinism.rs`).
//! 2. **Wall-time profile** ([`PhaseProfiler`]) — per-phase spans and
//!    counters. Inherently non-deterministic, written to a separate
//!    `*.profile.json`, excluded from all byte-compares.
//!
//! `eafl trace summarize` ([`summarize`]) folds trace files back into
//! the paper's figures and reproduces the run summary exactly from
//! events alone. The future `eafl serve` coordinator reuses the same
//! bus: observers subscribe as additional [`EventSink`]s.

pub mod event;
pub mod profile;
pub mod sink;
pub mod summarize;

/// Schema tag on the first line of every trace file.
pub const TRACE_SCHEMA: &str = "eafl-trace-v1";

pub use event::{DropCause, RoundEvent};
pub use profile::{PhaseProfiler, PROFILE_SCHEMA};
pub use sink::{EventSink, JsonlSink, MemorySink, NullSink};
pub use summarize::{read_trace, write_outputs, TraceSummary};
