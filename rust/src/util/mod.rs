//! In-tree substrates that would normally come from crates.io — this
//! build is fully offline (only the `xla` PJRT bridge and `anyhow` are
//! vendored), so per DESIGN.md §2 we implement them from scratch:
//!
//!  - [`rng`]  — deterministic xoshiro256++ RNG + the distributions the
//!    trace generators need (uniform, Bernoulli, normal, log-normal,
//!    Fisher–Yates shuffle).
//!  - [`json`] — minimal JSON parser/writer (manifest + summaries).
//!  - [`toml`] — TOML-subset parser/writer (experiment configs).
//!  - [`prop`] — tiny property-testing harness (randomized cases with
//!    seed reporting on failure) used by the invariant tests.
//!  - [`fixed`] — exact fixed-point accumulator backing the registry's
//!    incrementally maintained population aggregates.
//!  - [`index_set`] — O(1) dense/sparse index set (the liveness and
//!    below-capacity indices in the client pool).
//!  - [`wheel`] — coarse-bucket time wheel (the lazy-drain death wheel
//!    and availability wake wheel).

pub mod fixed;
pub mod index_set;
pub mod json;
pub mod prop;
pub mod rng;
pub mod toml;
pub mod wheel;
