//! Non-training round-path throughput at deployment scale: one
//! plan → select → record pass per iteration, fast path vs the
//! pre-refactor baseline, at 10k / 100k / 1M / 10M clients under the
//! steady and diurnal scenarios — plus background-maintenance and
//! full-round rows that time the lazy drain ledger (per-class cumsums
//! + death wheel, see `coordinator::registry`) against the eager
//! settle-every-epoch sweep it replaced, and candidate-build-only rows
//! that time the incrementally patched eligible arena
//! (`Registry::refresh_eligible`, O(changed) per round) against the
//! from-scratch `fill_candidates` walk (O(N)) it replaced.
//!
//! The fast path is what the engine runs today: SoA pool filtered into
//! a reused candidate arena, band-partition + Fenwick selection, O(1)
//! metrics from the incremental aggregates, and a background epoch
//! that touches only participants and due deaths. The baseline
//! reproduces the pre-refactor behaviour — allocate + recompute every
//! projection via `Registry::candidates`, full sort of the explored
//! pool, O(k·N) linear weighted draws, and five O(N) scans for the
//! metrics row — so the speedup is measured against the real old code
//! path, not a straw man. The eager rows re-materialize every battery
//! every epoch (`settle_all`), which is exactly the round shape
//! `EAFL_EAGER_DRAIN=1` runs.
//!
//! Run: cargo bench --bench plan_path_throughput -- \
//!        [--clients 10000,100000,1000000,10000000] \
//!        [--scenarios steady,diurnal] [--out BENCH_plan.json] [--smoke]
//!
//! Malformed flags exit 2 with a one-line error on stderr. Always
//! writes the `eafl-bench-v1` JSON document (results + derived
//! per-size speedups) to `--out`; `make bench` targets the repo root's
//! `BENCH_plan.json`.

use anyhow::Result;

use eafl::benchkit::{bb, parse_count_list, parse_name_list, require_value, Bench};
use eafl::config::{ExperimentConfig, SelectorConfig, SelectorKind};
use eafl::coordinator::{AvailabilityView, Registry};
use eafl::metrics::{jain_index, jain_index_from_moments};
use eafl::scenario::{Scenario, ScenarioEnv, WakeWheel};
use eafl::selection::utility::{
    eafl_reward, min_max_normalize, oort_utility, power_term, staleness_bonus,
};
use eafl::selection::{make_selector, percentile, Candidate, Selector};
use eafl::sim::ParticipantPlan;
use eafl::util::rng::Rng;

const K: usize = 10;
const CLOCK_H: f64 = 12.0;

struct Args {
    clients: Vec<usize>,
    scenarios: Vec<String>,
    out: String,
    smoke: bool,
}

/// Flag parsing is fallible, not panicking: `main` turns the error
/// into a one-line stderr message and exit code 2, so a typo'd count
/// never shows a backtrace.
fn parse_args() -> Result<Args> {
    let mut args = Args {
        clients: vec![10_000, 100_000, 1_000_000, 10_000_000],
        scenarios: vec!["steady".to_string(), "diurnal".to_string()],
        out: "BENCH_plan.json".to_string(),
        smoke: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--clients" => {
                args.clients =
                    parse_count_list("--clients", &require_value("--clients", it.next())?)?;
            }
            "--scenarios" => {
                args.scenarios =
                    parse_name_list("--scenarios", &require_value("--scenarios", it.next())?)?;
            }
            "--out" => args.out = require_value("--out", it.next())?,
            "--smoke" => args.smoke = true,
            // cargo bench may forward its own flags (e.g. --bench);
            // ignore anything we don't recognize.
            _ => {}
        }
    }
    for name in &args.scenarios {
        anyhow::ensure!(
            Scenario::preset(name).is_some(),
            "unknown scenario preset {name:?} for --scenarios (try steady, diurnal)"
        );
    }
    Ok(args)
}

/// Population with a realistic mix of explored/unexplored clients and
/// tiny data shards (the plan path never touches samples).
fn build_registry(n: usize) -> (ExperimentConfig, Registry) {
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.federation.num_clients = n;
    cfg.federation.participants_per_round = K;
    cfg.data.min_samples = 1;
    cfg.data.max_samples = 2;
    cfg.data.test_samples = 16;
    let mut registry = Registry::build(&cfg, 35, 1000);
    let mut rng = Rng::seed_from_u64(99);
    for id in 0..n {
        if rng.gen_bool(0.7) {
            let stat_util = Some(rng.gen_range_f64(1.0, 400.0));
            let duration = Some(rng.gen_range_f64(60.0, 900.0));
            let last = rng.gen_range_usize(0, 50) as u64;
            let times = rng.gen_range_usize(0, 20) as u64;
            let mut s = registry.stats_mut(id);
            s.stat_util = stat_util;
            s.measured_duration_s = duration;
            s.last_selected_round = last;
            s.times_selected = times;
        }
    }
    (cfg, registry)
}

// ---------------------------------------------------------------------------
// Baseline: the pre-refactor plan+select+record path, reproduced.
// ---------------------------------------------------------------------------

/// Pre-refactor EAFL selection: full sort of the explored pool plus
/// O(k·N) linear weighted draws with per-pick total recomputation.
fn baseline_select_eafl(
    cfg: &SelectorConfig,
    round: u64,
    candidates: &[Candidate],
    k: usize,
    deadline: f64,
    rng: &mut Rng,
) -> Vec<usize> {
    if candidates.is_empty() || k == 0 {
        return Vec::new();
    }
    let eps = (cfg.explore_init * cfg.explore_decay.powi(round.saturating_sub(1) as i32))
        .max(cfg.min_explore);
    let (unexplored, explored): (Vec<&Candidate>, Vec<&Candidate>) =
        candidates.iter().partition(|c| c.stat_util.is_none());

    fn linear_weighted_pick(
        pool: &mut Vec<(usize, f64)>,
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let mut picked = Vec::with_capacity(k);
        while picked.len() < k && !pool.is_empty() {
            let total: f64 = pool.iter().map(|(_, w)| w.max(1e-12)).sum();
            let mut r = rng.gen_f64() * total;
            let mut idx = pool.len() - 1;
            for (i, (_, w)) in pool.iter().enumerate() {
                r -= w.max(1e-12);
                if r <= 0.0 {
                    idx = i;
                    break;
                }
            }
            picked.push(pool.swap_remove(idx).0);
        }
        picked
    }

    let k_explore =
        ((eps * k as f64).round() as usize).min(unexplored.len()).min(k);
    let mut pool: Vec<(usize, f64)> = unexplored
        .iter()
        .map(|c| (c.id, power_term(c.battery_frac, c.projected_drain_frac).max(1e-6)))
        .collect();
    let mut selected = linear_weighted_pick(&mut pool, k_explore, rng);

    let k_exploit = k - selected.len();
    if k_exploit > 0 && !explored.is_empty() {
        let utils: Vec<f64> = explored
            .iter()
            .map(|c| {
                let duration = c.measured_duration_s.unwrap_or(c.expected_duration_s);
                oort_utility(c.stat_util.unwrap_or(0.0), deadline, duration, cfg.alpha)
            })
            .collect();
        let normed = min_max_normalize(&utils);
        let mut scored: Vec<(usize, f64)> = explored
            .iter()
            .zip(&normed)
            .map(|(c, &u)| {
                let power = power_term(c.battery_frac, c.projected_drain_frac);
                let reward = eafl_reward(cfg.eafl_f, u, power)
                    + staleness_bonus(round, c.last_selected_round, cfg.ucb_weight) * 0.25;
                (c.id, reward.max(1e-9))
            })
            .collect();
        scored.sort_by(|a, b| b.1.total_cmp(&a.1));
        let band = ((k_exploit as f64) * 3.0).ceil() as usize;
        scored.truncate(band.max(k_exploit));
        selected.extend(linear_weighted_pick(&mut scored, k_exploit, rng));
    } else if k_exploit > 0 {
        let mut rest: Vec<usize> = unexplored
            .iter()
            .map(|c| c.id)
            .filter(|id| !selected.contains(id))
            .collect();
        rng.shuffle(&mut rest);
        selected.extend(rest.into_iter().take(k_exploit));
    }
    selected
}

/// Pre-refactor record pass: the ~5 full population scans the old
/// RecordPhase performed (dead_count and alive_fraction each rescanned
/// independently, mean-battery collected into a fresh Vec).
fn baseline_record(registry: &Registry) -> (f64, usize, f64, f64, f64) {
    let counts = registry.selection_counts();
    let fairness = jain_index(&counts);
    let dead = registry.len()
        - registry.clients().iter().filter(|c| c.battery.is_alive()).count();
    let alive = registry.clients().iter().filter(|c| c.battery.is_alive()).count();
    let alive_batt: Vec<f64> = registry
        .clients()
        .iter()
        .filter(|c| c.battery.is_alive())
        .map(|c| c.battery.fraction())
        .collect();
    let mean_battery = if alive_batt.is_empty() {
        0.0
    } else {
        alive_batt.iter().sum::<f64>() / alive_batt.len() as f64
    };
    let total_fl: f64 = registry.clients().iter().map(|c| c.battery.fl_energy_j).sum();
    (fairness, dead, alive as f64 / registry.len().max(1) as f64, mean_battery, total_fl)
}

fn baseline_round(
    cfg: &ExperimentConfig,
    registry: &Registry,
    env: &ScenarioEnv,
    round: u64,
    rng: &mut Rng,
) -> usize {
    let mut candidates = registry.candidates(
        round,
        cfg.selector.min_battery_frac,
        cfg.training.local_steps,
        cfg.data.batch_size,
    );
    candidates.retain(|c| env.availability.available(c.id, CLOCK_H));
    // The old selector computed the deadline inside select() AND the
    // old PlanPhase asked for it again afterwards — keep both passes.
    let durations: Vec<f64> = candidates
        .iter()
        .map(|c| c.measured_duration_s.unwrap_or(c.expected_duration_s))
        .collect();
    let deadline = percentile(&durations, cfg.selector.pacer_percentile).max(1.0);
    let selected =
        baseline_select_eafl(&cfg.selector, round, &candidates, K, deadline, rng);
    let durations2: Vec<f64> = candidates
        .iter()
        .map(|c| c.measured_duration_s.unwrap_or(c.expected_duration_s))
        .collect();
    bb(percentile(&durations2, cfg.selector.pacer_percentile).max(1.0));
    let plans: Vec<ParticipantPlan> = selected
        .iter()
        .map(|&id| {
            let c = registry.client(id);
            let energy = c
                .projected_energy(
                    registry.payload_bytes(),
                    cfg.training.local_steps,
                    cfg.data.batch_size,
                )
                .total();
            ParticipantPlan {
                id,
                download_s: c.link.download_secs(registry.payload_bytes()),
                compute_s: c.compute_secs(cfg.training.local_steps, cfg.data.batch_size),
                upload_s: c.link.upload_secs(registry.payload_bytes()),
                round_energy_j: energy,
                charge_j: c.battery.charge_joules(),
            }
        })
        .collect();
    let record = baseline_record(registry);
    bb(&record);
    bb(&plans);
    selected.len()
}

// ---------------------------------------------------------------------------
// Fast path: what the engine actually runs now.
// ---------------------------------------------------------------------------

fn fast_round(
    cfg: &ExperimentConfig,
    registry: &Registry,
    env: &ScenarioEnv,
    selector: &mut dyn Selector,
    arena: &mut Vec<Candidate>,
    round: u64,
    rng: &mut Rng,
) -> Vec<usize> {
    if env.availability.is_always_available() {
        registry.fill_candidates(round, cfg.selector.min_battery_frac, |_| true, arena);
    } else {
        let availability = &env.availability;
        registry.fill_candidates(
            round,
            cfg.selector.min_battery_frac,
            |id| availability.available(id, CLOCK_H),
            arena,
        );
    }
    let (selected, deadline) = selector.plan(round, arena, K, rng);
    bb(deadline);
    let pool = registry.pool();
    let plans: Vec<ParticipantPlan> = selected
        .iter()
        .map(|&id| ParticipantPlan {
            id,
            download_s: pool.download_s[id],
            compute_s: pool.compute_s[id],
            upload_s: pool.upload_s[id],
            round_energy_j: pool.round_energy_j[id],
            // The raw mirror can lag under lazy drain; plans must carry
            // the drain-effective charge, exactly like the engine does.
            charge_j: registry.effective_charge_j(id),
        })
        .collect();
    let agg = registry.aggregates();
    let record = (
        jain_index_from_moments(registry.len(), agg.selected_sum, agg.selected_sum_sq),
        registry.dead_count(),
        registry.alive_count() as f64 / registry.len().max(1) as f64,
        registry.mean_battery_alive(),
        registry.total_fl_energy_j(),
    );
    bb(&record);
    bb(&plans);
    selected.len()
}

fn mean_of(bench: &Bench, name: &str) -> f64 {
    bench.results().iter().find(|s| s.name == name).map(|s| s.mean_ns).unwrap_or(f64::NAN)
}

/// Background-epoch drain rates for the lazy/eager rows. Deliberately
/// tiny — cumulative drain stays around 10⁻³ of capacity even across
/// tens of millions of measured epochs — so the rows time the
/// steady-idle-fleet maintenance cost itself; a realistic rate would
/// turn the measurement into a mass-death event partway through.
const MAINT_IDLE_PER_H: f64 = 1e-9;
const MAINT_BUSY_PER_H: f64 = 2e-9;
const MAINT_EPOCH_H: f64 = 0.1;

fn main() {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!(
                "usage: cargo bench --bench plan_path_throughput -- \
                 [--clients N,N,...] [--scenarios NAME,...] [--out PATH] [--smoke]"
            );
            std::process::exit(2);
        }
    };
    let mut bench = if args.smoke { Bench::smoke() } else { Bench::new() };
    // (key, value) rows for the derived section of the JSON doc.
    let mut derived: Vec<(String, f64)> = Vec::new();

    for &n in &args.clients {
        let (cfg, mut registry) = build_registry(n);
        println!("== population {n} built ==");

        // --- Maintenance-only rows: one background epoch over the
        // whole fleet, scenario-independent. The lazy row is the
        // sub-O(alive) claim itself — two cumsum bumps, a wheel probe,
        // and the O(1) closed-form record; the eager row adds the
        // settle-every-battery sweep the ledger replaced. The drain
        // clock only ever moves forward, so these rows and the
        // full-round rows below share one monotonic `clock`.
        let lazy_maint = format!("lazy background epoch N={n}");
        let eager_maint = format!("eager background epoch N={n}");
        let mut clock = 0.0f64;
        bench.run(&lazy_maint, || {
            clock += MAINT_EPOCH_H;
            registry.advance_background(
                &[],
                MAINT_IDLE_PER_H,
                MAINT_BUSY_PER_H,
                MAINT_EPOCH_H,
                clock,
            );
            bb(registry.mean_battery_alive());
        });
        // The eager sweep at 1M+ is tens of ms per epoch; one measured
        // pass is the honest budget, same rule as the plan rows.
        if n >= 1_000_000 && !args.smoke {
            bench.run_once(&eager_maint, || {
                clock += MAINT_EPOCH_H;
                registry.advance_background(
                    &[],
                    MAINT_IDLE_PER_H,
                    MAINT_BUSY_PER_H,
                    MAINT_EPOCH_H,
                    clock,
                );
                registry.settle_all();
                registry.mean_battery_alive()
            });
        } else {
            bench.run(&eager_maint, || {
                clock += MAINT_EPOCH_H;
                registry.advance_background(
                    &[],
                    MAINT_IDLE_PER_H,
                    MAINT_BUSY_PER_H,
                    MAINT_EPOCH_H,
                    clock,
                );
                registry.settle_all();
                bb(registry.mean_battery_alive());
            });
        }
        let lazy_maint_ns = mean_of(&bench, &lazy_maint);
        let maint_speedup = mean_of(&bench, &eager_maint) / lazy_maint_ns;
        println!(
            "--> N={n}: background epoch {lazy_maint_ns:.0} ns lazy, \
             {maint_speedup:.1}x vs eager"
        );
        derived.push((format!("lazy_maintenance_ns_{n}"), lazy_maint_ns));
        derived.push((format!("maintenance_speedup_{n}"), maint_speedup));

        for scenario_name in &args.scenarios {
            let scenario =
                Scenario::preset(scenario_name).expect("presets are validated in parse_args");
            let env = scenario.build_env(7, n, &cfg.devices);
            let label = format!("N={n} {scenario_name}");

            let mut selector = make_selector(&cfg.selector);
            let mut arena: Vec<Candidate> = Vec::new();
            let mut rng = Rng::seed_from_u64(11);
            let mut round = 100u64; // past the ε-decay knee: exploit-heavy
            let fast_name = format!("fast plan+select+record {label}");
            let base_name = format!("baseline plan+select+record {label}");

            // 1M rounds are seconds-long on the baseline; a single
            // measured pass per variant is the honest budget there.
            if n >= 1_000_000 && !args.smoke {
                bench.run_once(&fast_name, || {
                    round += 1;
                    fast_round(
                        &cfg,
                        &registry,
                        &env,
                        selector.as_mut(),
                        &mut arena,
                        round,
                        &mut rng,
                    )
                });
                bench.run_once(&base_name, || {
                    round += 1;
                    baseline_round(&cfg, &registry, &env, round, &mut rng)
                });
            } else {
                bench.run(&fast_name, || {
                    round += 1;
                    bb(fast_round(
                        &cfg,
                        &registry,
                        &env,
                        selector.as_mut(),
                        &mut arena,
                        round,
                        &mut rng,
                    ));
                });
                bench.run(&base_name, || {
                    round += 1;
                    bb(baseline_round(&cfg, &registry, &env, round, &mut rng));
                });
            }

            let speedup = mean_of(&bench, &base_name) / mean_of(&bench, &fast_name);
            println!("--> {label}: speedup {speedup:.1}x");
            derived.push((format!("speedup_{scenario_name}_{n}"), speedup));

            // --- Candidate-build-only rows: the incrementally patched
            // eligible arena (`refresh_eligible`) against the
            // from-scratch `fill_candidates` walk it replaced. One
            // untimed refresh first — the initial build (and any
            // floor/view switch) is O(N) by design — so the timed row
            // measures the steady-state O(changed) patch cost. The
            // clock is pinned at CLOCK_H like the plan rows, so the
            // row isolates the pure bookkeeping floor: no availability
            // flips, no floor crossings, just the dirty-drain + merge.
            let inc_name = format!("incremental candidate build {label}");
            let reb_name = format!("rebuild candidate build {label}");
            let floor = cfg.selector.min_battery_frac;
            let cand_wake = (!env.availability.is_always_available())
                .then(|| WakeWheel::new(env.availability.as_ref(), n, CLOCK_H));
            let refresh = |registry: &mut Registry, round: u64| match cand_wake.as_ref() {
                None => registry.refresh_eligible(round, floor, AvailabilityView::AlwaysOn),
                Some(w) => registry.refresh_eligible(
                    round,
                    floor,
                    AvailabilityView::Cached { bits: w.avail(), changed: w.changed() },
                ),
            };
            round += 1;
            refresh(&mut registry, round);
            bench.run(&inc_name, || {
                round += 1;
                refresh(&mut registry, round);
                bb(registry.eligible().len());
            });
            // The rebuild walk is O(N): at 1M+ a single measured pass
            // is the honest budget, same rule as the plan rows.
            let mut cand_scratch: Vec<Candidate> = Vec::new();
            if n >= 1_000_000 && !args.smoke {
                bench.run_once(&reb_name, || {
                    round += 1;
                    match cand_wake.as_ref() {
                        None => {
                            registry.fill_candidates(round, floor, |_| true, &mut cand_scratch)
                        }
                        Some(w) => {
                            let bits = w.avail();
                            registry.fill_candidates(
                                round,
                                floor,
                                |id| bits[id],
                                &mut cand_scratch,
                            );
                        }
                    }
                    cand_scratch.len()
                });
            } else {
                bench.run(&reb_name, || {
                    round += 1;
                    match cand_wake.as_ref() {
                        None => {
                            registry.fill_candidates(round, floor, |_| true, &mut cand_scratch)
                        }
                        Some(w) => {
                            let bits = w.avail();
                            registry.fill_candidates(
                                round,
                                floor,
                                |id| bits[id],
                                &mut cand_scratch,
                            );
                        }
                    }
                    bb(cand_scratch.len());
                });
            }
            let inc_ns = mean_of(&bench, &inc_name);
            let cand_speedup = mean_of(&bench, &reb_name) / inc_ns;
            println!(
                "--> {label}: candidate build {inc_ns:.0} ns incremental, \
                 {cand_speedup:.1}x vs rebuild"
            );
            derived.push((format!("candidate_build_ns_{scenario_name}_{n}"), inc_ns));
            derived.push((format!("candidate_speedup_{scenario_name}_{n}"), cand_speedup));

            // --- Full non-training round, lazy vs eager drain: the
            // plan+select+record pass plus one background epoch. The
            // eager variant adds the `settle_all` sweep — the round
            // shape `EAFL_EAGER_DRAIN=1` runs — so the ratio is the
            // end-to-end win of deferring materialization.
            let lazy_round_name = format!("lazy round {label}");
            let eager_round_name = format!("eager round {label}");
            let mut scratch: Vec<usize> = Vec::new();
            if n >= 1_000_000 && !args.smoke {
                bench.run_once(&lazy_round_name, || {
                    round += 1;
                    clock += MAINT_EPOCH_H;
                    let selected = fast_round(
                        &cfg,
                        &registry,
                        &env,
                        selector.as_mut(),
                        &mut arena,
                        round,
                        &mut rng,
                    );
                    scratch.clear();
                    scratch.extend_from_slice(&selected);
                    scratch.sort_unstable();
                    registry.advance_background(
                        &scratch,
                        MAINT_IDLE_PER_H,
                        MAINT_BUSY_PER_H,
                        MAINT_EPOCH_H,
                        clock,
                    );
                    selected.len()
                });
                bench.run_once(&eager_round_name, || {
                    round += 1;
                    clock += MAINT_EPOCH_H;
                    let selected = fast_round(
                        &cfg,
                        &registry,
                        &env,
                        selector.as_mut(),
                        &mut arena,
                        round,
                        &mut rng,
                    );
                    scratch.clear();
                    scratch.extend_from_slice(&selected);
                    scratch.sort_unstable();
                    registry.advance_background(
                        &scratch,
                        MAINT_IDLE_PER_H,
                        MAINT_BUSY_PER_H,
                        MAINT_EPOCH_H,
                        clock,
                    );
                    registry.settle_all();
                    selected.len()
                });
            } else {
                bench.run(&lazy_round_name, || {
                    round += 1;
                    clock += MAINT_EPOCH_H;
                    let selected = fast_round(
                        &cfg,
                        &registry,
                        &env,
                        selector.as_mut(),
                        &mut arena,
                        round,
                        &mut rng,
                    );
                    scratch.clear();
                    scratch.extend_from_slice(&selected);
                    scratch.sort_unstable();
                    registry.advance_background(
                        &scratch,
                        MAINT_IDLE_PER_H,
                        MAINT_BUSY_PER_H,
                        MAINT_EPOCH_H,
                        clock,
                    );
                    bb(selected.len());
                });
                bench.run(&eager_round_name, || {
                    round += 1;
                    clock += MAINT_EPOCH_H;
                    let selected = fast_round(
                        &cfg,
                        &registry,
                        &env,
                        selector.as_mut(),
                        &mut arena,
                        round,
                        &mut rng,
                    );
                    scratch.clear();
                    scratch.extend_from_slice(&selected);
                    scratch.sort_unstable();
                    registry.advance_background(
                        &scratch,
                        MAINT_IDLE_PER_H,
                        MAINT_BUSY_PER_H,
                        MAINT_EPOCH_H,
                        clock,
                    );
                    registry.settle_all();
                    bb(selected.len());
                });
            }
            let lazy_round_ns = mean_of(&bench, &lazy_round_name);
            let lazy_vs_eager = mean_of(&bench, &eager_round_name) / lazy_round_ns;
            println!(
                "--> {label}: lazy round {lazy_round_ns:.0} ns, \
                 {lazy_vs_eager:.1}x vs eager"
            );
            derived.push((format!("lazy_round_ns_{scenario_name}_{n}"), lazy_round_ns));
            derived.push((format!("lazy_vs_eager_{scenario_name}_{n}"), lazy_vs_eager));
        }
    }

    let derived_refs: Vec<(&str, f64)> =
        derived.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let path = std::path::Path::new(&args.out);
    bench
        .write_json("plan_path_throughput", &derived_refs, path)
        .expect("writing bench JSON");
    println!("wrote {}", path.display());
}
