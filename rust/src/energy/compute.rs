//! Computation energy (paper §4.2): `E_comp = P × t`, with `P` the
//! tier's average training power (Table 2) and `t` the time spent in
//! local training — plus the background idle/busy model the paper uses
//! for unselected devices.


use crate::device::DeviceSpec;
use crate::energy::comm::{comm_energy_joules, CommDirection};
use crate::network::LinkProfile;

/// Energy (J) for `train_secs` of on-device training on `spec`.
pub fn compute_energy_joules(spec: &DeviceSpec, train_secs: f64) -> f64 {
    spec.avg_power_w * train_secs.max(0.0)
}

/// Background energy (J) for an *unselected* device over `hours`.
///
/// `drain_frac_per_hour` is expressed as battery-fraction/hour (config
/// knob), so the joules depend on the device's own capacity — bigger
/// batteries spend more joules for the same fractional drain, matching
/// how per-hour percentage figures are quoted in practice.
pub fn background_energy_joules(
    spec: &DeviceSpec,
    drain_frac_per_hour: f64,
    hours: f64,
) -> f64 {
    spec.battery_joules() * drain_frac_per_hour.max(0.0) * hours.max(0.0)
}

/// Full energy breakdown for one client's participation in one round.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundEnergy {
    pub download_j: f64,
    pub compute_j: f64,
    pub upload_j: f64,
}

impl RoundEnergy {
    /// Energy for: download model (`payload_bytes`), train `train_secs`,
    /// upload update (`payload_bytes`) — the paper's step 1/2/3 costs.
    pub fn for_participation(
        spec: &DeviceSpec,
        link: &LinkProfile,
        payload_bytes: usize,
        train_secs: f64,
    ) -> Self {
        let down_secs = link.download_secs(payload_bytes);
        let up_secs = link.upload_secs(payload_bytes);
        Self {
            download_j: comm_energy_joules(link.medium, CommDirection::Download, down_secs),
            compute_j: compute_energy_joules(spec, train_secs),
            upload_j: comm_energy_joules(link.medium, CommDirection::Upload, up_secs),
        }
    }

    pub fn total(&self) -> f64 {
        self.download_j + self.compute_j + self.upload_j
    }
}

/// Convenience: energy split at an interruption `frac` of the way
/// through the round (download → compute → upload order). Used when a
/// battery dies mid-round to attribute partial energy.
pub fn partial_round_energy(e: &RoundEnergy, frac: f64) -> f64 {
    e.total() * frac.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Tier;
    use crate::network::Medium;

    fn link() -> LinkProfile {
        LinkProfile { medium: Medium::Wifi, down_mbps: 8.0, up_mbps: 4.0 }
    }

    #[test]
    fn compute_energy_is_p_times_t() {
        let hi = DeviceSpec::for_tier(Tier::High);
        // 6.33 W for 100 s = 633 J
        assert!((compute_energy_joules(&hi, 100.0) - 633.0).abs() < 1e-9);
        assert_eq!(compute_energy_joules(&hi, -5.0), 0.0);
    }

    #[test]
    fn high_tier_burns_more_than_low_for_same_time() {
        let hi = DeviceSpec::for_tier(Tier::High);
        let lo = DeviceSpec::for_tier(Tier::Low);
        assert!(compute_energy_joules(&hi, 60.0) > compute_energy_joules(&lo, 60.0));
    }

    #[test]
    fn background_scales_with_capacity_and_time() {
        let hi = DeviceSpec::for_tier(Tier::High);
        let lo = DeviceSpec::for_tier(Tier::Low);
        let e_hi = background_energy_joules(&hi, 0.01, 2.0);
        let e_lo = background_energy_joules(&lo, 0.01, 2.0);
        assert!(e_hi > e_lo);
        assert!((e_hi - hi.battery_joules() * 0.02).abs() < 1e-9);
    }

    #[test]
    fn round_energy_components_positive() {
        let spec = DeviceSpec::for_tier(Tier::Mid);
        // 280 KB model payload, 5 minutes of training.
        let e = RoundEnergy::for_participation(&spec, &link(), 280_000, 300.0);
        assert!(e.compute_j > 0.0);
        assert!(e.download_j >= 0.0 && e.upload_j >= 0.0);
        assert!((e.compute_j - 5.44 * 300.0).abs() < 1e-9);
        assert!(e.total() >= e.compute_j);
    }

    #[test]
    fn partial_energy_clamped() {
        let e = RoundEnergy { download_j: 10.0, compute_j: 80.0, upload_j: 10.0 };
        assert_eq!(partial_round_energy(&e, 0.5), 50.0);
        assert_eq!(partial_round_energy(&e, 2.0), 100.0);
        assert_eq!(partial_round_energy(&e, -1.0), 0.0);
    }
}
