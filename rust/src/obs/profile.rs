//! The non-deterministic profiling channel: wall-time per engine phase.
//!
//! Deliberately separate from the event stream — wall-clock spans vary
//! with worker count, machine load, and drain mode, so they would break
//! trace byte-compares if interleaved. A `--trace FILE` run writes this
//! channel next to the trace as `FILE`'s sibling `*.profile.json`
//! (schema `eafl-profile-v1`), and CI byte-compares traces only.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Schema tag for the profile JSON document.
pub const PROFILE_SCHEMA: &str = "eafl-profile-v1";

#[derive(Debug, Clone, Copy, Default)]
struct PhaseStat {
    calls: u64,
    total: Duration,
    max: Duration,
}

/// Accumulates per-phase wall-time spans and counters; the coordinator
/// records one span per phase per round when a profiler is attached.
#[derive(Debug, Default)]
pub struct PhaseProfiler {
    phases: BTreeMap<&'static str, PhaseStat>,
    counters: BTreeMap<&'static str, u64>,
    out: Option<PathBuf>,
}

impl PhaseProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Profiler that writes its JSON document to `path` when the run
    /// finishes ([`Self::write`]).
    pub fn with_output(path: PathBuf) -> Self {
        Self { out: Some(path), ..Self::default() }
    }

    pub fn record(&mut self, phase: &'static str, elapsed: Duration) {
        let s = self.phases.entry(phase).or_default();
        s.calls += 1;
        s.total += elapsed;
        s.max = s.max.max(elapsed);
    }

    pub fn count(&mut self, counter: &'static str, n: u64) {
        *self.counters.entry(counter).or_default() += n;
    }

    /// Total recorded wall time across all phases.
    pub fn total(&self) -> Duration {
        self.phases.values().map(|s| s.total).sum()
    }

    pub fn calls(&self, phase: &str) -> u64 {
        self.phases.get(phase).map(|s| s.calls).unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let ms = |d: Duration| d.as_secs_f64() * 1e3;
        let mut phases = BTreeMap::new();
        for (name, s) in &self.phases {
            let mut p = BTreeMap::new();
            p.insert("calls".to_string(), Json::Num(s.calls as f64));
            p.insert("total_ms".to_string(), Json::Num(ms(s.total)));
            p.insert(
                "mean_ms".to_string(),
                Json::Num(if s.calls > 0 { ms(s.total) / s.calls as f64 } else { 0.0 }),
            );
            p.insert("max_ms".to_string(), Json::Num(ms(s.max)));
            phases.insert(name.to_string(), Json::Obj(p));
        }
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| (k.to_string(), Json::Num(*v as f64)))
            .collect();
        let mut doc = BTreeMap::new();
        doc.insert("schema".to_string(), Json::Str(PROFILE_SCHEMA.to_string()));
        doc.insert("phases".to_string(), Json::Obj(phases));
        doc.insert("counters".to_string(), Json::Obj(counters));
        Json::Obj(doc)
    }

    /// Write the profile document to the configured output path, if
    /// any. Returns the path written.
    pub fn write(&self) -> Result<Option<&Path>> {
        let Some(path) = self.out.as_deref() else { return Ok(None) };
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .with_context(|| format!("writing phase profile {}", path.display()))?;
        Ok(Some(path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_spans_and_counters() {
        let mut p = PhaseProfiler::new();
        p.record("plan", Duration::from_millis(2));
        p.record("plan", Duration::from_millis(4));
        p.record("exec", Duration::from_millis(10));
        p.count("events_emitted", 7);
        p.count("events_emitted", 3);
        assert_eq!(p.calls("plan"), 2);
        assert_eq!(p.calls("exec"), 1);
        assert_eq!(p.total(), Duration::from_millis(16));
        let j = p.to_json();
        assert_eq!(j.field("schema").unwrap().as_str(), Some(PROFILE_SCHEMA));
        let plan = j.field("phases").unwrap().field("plan").unwrap();
        assert_eq!(plan.field("calls").unwrap().as_usize(), Some(2));
        assert!((plan.field("mean_ms").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-9);
        assert!((plan.field("max_ms").unwrap().as_f64().unwrap() - 4.0).abs() < 1e-9);
        let c = j.field("counters").unwrap().field("events_emitted").unwrap();
        assert_eq!(c.as_usize(), Some(10));
    }

    #[test]
    fn write_without_output_path_is_a_no_op() {
        let p = PhaseProfiler::new();
        assert!(p.write().unwrap().is_none());
    }
}
