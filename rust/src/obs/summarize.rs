//! `eafl trace summarize` — fold `eafl-trace-v1` files into the
//! paper's figures, from events alone.
//!
//! Outputs (with `--out DIR`):
//! - `summary.json` — per-trace run summary reproduced purely from the
//!   event stream; numbers match the run's own `*.summary.json`
//!   exactly (same floats through the same writer).
//! - `time_to_accuracy.csv` — Fig. 3: accuracy per committed round on
//!   the simulated wall-time axis.
//! - `dropouts.csv` — Fig. 4: cumulative dead trajectory per round,
//!   cut by scenario × selector via the name columns.
//! - `participation.csv` — histogram of per-client selection counts.
//! - `energy_hist.csv` — histogram of per-client FL energy spent.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::event::RoundEvent;
use super::TRACE_SCHEMA;

/// Parse a trace file: schema header line, then one event per line.
///
/// A file whose *header* is wrong (not JSON, wrong schema, empty) was
/// never ours and errors in place. A file with a valid header but a
/// torn or corrupt event line — a trace half-written by a killed shard,
/// or bit rot — is **quarantined** (moved to `<file>.quarantine`, see
/// [`crate::report::quarantine`]) and the error names the line and the
/// quarantine destination, so a retried sweep regenerates the trace
/// instead of tripping over the wreck forever.
pub fn read_trace(path: &Path) -> Result<Vec<RoundEvent>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace file {}", path.display()))?;
    let mut lines = text.lines().enumerate();
    let Some((_, header)) = lines.next() else {
        bail!("{}: empty trace file", path.display());
    };
    let header = Json::parse(header)
        .with_context(|| format!("{}: malformed trace header", path.display()))?;
    match header.get("schema").and_then(Json::as_str) {
        Some(TRACE_SCHEMA) => {}
        Some(other) => bail!(
            "{}: unsupported trace schema {other:?} (expected {TRACE_SCHEMA:?})",
            path.display()
        ),
        None => bail!("{}: trace header has no \"schema\" tag", path.display()),
    }
    let mut events = Vec::new();
    for (i, line) in lines {
        if line.trim().is_empty() {
            continue;
        }
        let event = Json::parse(line)
            .and_then(|j| RoundEvent::from_json(&j))
            .map_err(|e| {
                let dest = crate::report::quarantine(
                    path,
                    &format!("torn/corrupt trace event at line {}", i + 1),
                );
                let moved = match dest {
                    Some(d) => format!(" — quarantined to {}", d.display()),
                    None => String::new(),
                };
                e.context(format!(
                    "{}: torn/corrupt trace event at line {}{moved}",
                    path.display(),
                    i + 1
                ))
            })?;
        events.push(event);
    }
    Ok(events)
}

/// Everything `summarize` derives from one trace.
#[derive(Debug, Clone)]
pub struct TraceSummary {
    pub name: String,
    pub selector: String,
    pub scenario: String,
    pub seed: u64,
    pub clients: usize,
    /// Rounds played (one `RoundCommitted` per round, pass or fail).
    pub rounds: u64,
    pub committed_rounds: u64,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    /// Net depleted (depletions − revivals) as of the last round —
    /// equals the run summary's `total_dropouts`.
    pub total_dropouts: i64,
    pub total_fl_energy_j: f64,
    pub wall_clock_h: f64,
    /// (round, wall_clock_h, accuracy) per committed round — Fig. 3.
    pub time_to_accuracy: Vec<(u64, f64, f64)>,
    /// (round, wall_clock_h, cumulative_dead) per round — Fig. 4.
    pub dropout_curve: Vec<(u64, f64, i64)>,
    /// Per-client selection counts (participating clients only).
    pub participation: BTreeMap<usize, u64>,
    /// Per-client FL energy spent (reported + dropped), joules.
    pub energy_by_client: BTreeMap<usize, f64>,
}

impl TraceSummary {
    pub fn from_file(path: &Path) -> Result<Self> {
        let events = read_trace(path)?;
        Self::fold(&events).with_context(|| format!("summarizing {}", path.display()))
    }

    /// Fold an event stream. The per-round ordering contract (lifecycle
    /// events drained before `RoundCommitted`) makes the running
    /// depleted−revived count at each commit equal the engine's
    /// `cumulative_dead`.
    pub fn fold(events: &[RoundEvent]) -> Result<Self> {
        let mut name = String::new();
        let mut selector = String::new();
        let mut scenario = String::new();
        let mut seed = 0u64;
        let mut clients = 0usize;
        let mut identified = false;
        let mut cumulative_dead = 0i64;
        let mut out = Self {
            name: String::new(),
            selector: String::new(),
            scenario: String::new(),
            seed: 0,
            clients: 0,
            rounds: 0,
            committed_rounds: 0,
            final_accuracy: 0.0,
            best_accuracy: 0.0,
            total_dropouts: 0,
            total_fl_energy_j: 0.0,
            wall_clock_h: 0.0,
            time_to_accuracy: Vec::new(),
            dropout_curve: Vec::new(),
            participation: BTreeMap::new(),
            energy_by_client: BTreeMap::new(),
        };
        for ev in events {
            match ev {
                RoundEvent::RunStarted {
                    name: n, selector: sel, scenario: sc, clients: c, seed: s, ..
                } => {
                    // A CampaignCell head (always first in campaign
                    // traces) is more specific — don't clobber it.
                    if !identified {
                        name = n.clone();
                        selector = sel.clone();
                        scenario = sc.clone();
                        seed = *s;
                        clients = *c;
                        identified = true;
                    }
                }
                RoundEvent::CampaignCell {
                    cell, selector: sel, scenario: sc, seed: s, clients: c, ..
                } => {
                    name = cell.clone();
                    selector = sel.clone();
                    scenario = sc.clone();
                    seed = *s;
                    clients = *c;
                    identified = true;
                }
                RoundEvent::ClientSelected { id, .. } => {
                    *out.participation.entry(*id).or_default() += 1;
                }
                RoundEvent::ClientReported { id, energy_j, .. }
                | RoundEvent::ClientDropped { id, energy_j, .. } => {
                    *out.energy_by_client.entry(*id).or_default() += energy_j;
                }
                RoundEvent::BatteryDepleted { .. } => cumulative_dead += 1,
                RoundEvent::BatteryRevived { .. } => cumulative_dead -= 1,
                RoundEvent::RoundPlanned { .. } => {}
                RoundEvent::RoundCommitted {
                    round,
                    committed,
                    accuracy,
                    energy_j,
                    wall_clock_h,
                    ..
                } => {
                    out.rounds += 1;
                    if *committed {
                        out.committed_rounds += 1;
                        out.time_to_accuracy.push((*round, *wall_clock_h, *accuracy));
                    }
                    out.dropout_curve.push((*round, *wall_clock_h, cumulative_dead));
                    out.final_accuracy = *accuracy;
                    out.best_accuracy = out.best_accuracy.max(*accuracy);
                    out.total_fl_energy_j = *energy_j;
                    out.wall_clock_h = *wall_clock_h;
                    out.total_dropouts = cumulative_dead;
                }
                // Terminal marker only; the preceding RoundCommitted
                // already carries the final numbers.
                RoundEvent::BudgetExhausted { .. } => {}
            }
        }
        // A RunStarted/CampaignCell head is how we identify the run; a
        // trace without one (or without any rounds) is not a run trace.
        if !identified {
            bail!("trace has no run_started/campaign_cell event");
        }
        if out.rounds == 0 {
            bail!("trace has no round_committed events");
        }
        out.name = name;
        out.selector = selector;
        out.scenario = scenario;
        out.seed = seed;
        out.clients = clients;
        Ok(out)
    }

    /// One console line per trace.
    pub fn render_line(&self) -> String {
        format!(
            "{:<28} sel={:<8} scen={:<10} acc={:.4} best={:.4} dropouts={} rounds={}/{} wall={:.2}h energy={:.1}J",
            self.name,
            self.selector,
            self.scenario,
            self.final_accuracy,
            self.best_accuracy,
            self.total_dropouts,
            self.committed_rounds,
            self.rounds,
            self.wall_clock_h,
            self.total_fl_energy_j,
        )
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("selector".to_string(), Json::Str(self.selector.clone()));
        m.insert("scenario".to_string(), Json::Str(self.scenario.clone()));
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        m.insert("clients".to_string(), Json::Num(self.clients as f64));
        m.insert("rounds".to_string(), Json::Num(self.rounds as f64));
        m.insert("committed_rounds".to_string(), Json::Num(self.committed_rounds as f64));
        m.insert("final_accuracy".to_string(), Json::Num(self.final_accuracy));
        m.insert("best_accuracy".to_string(), Json::Num(self.best_accuracy));
        m.insert("total_dropouts".to_string(), Json::Num(self.total_dropouts as f64));
        m.insert("total_fl_energy_j".to_string(), Json::Num(self.total_fl_energy_j));
        m.insert("wall_clock_h".to_string(), Json::Num(self.wall_clock_h));
        Json::Obj(m)
    }
}

/// Number of buckets in the per-client energy histogram.
const ENERGY_BUCKETS: usize = 16;

/// Write the figure files for a batch of summarized traces.
pub fn write_outputs(dir: &Path, summaries: &[TraceSummary]) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating summarize output dir {}", dir.display()))?;

    let doc = Json::Arr(summaries.iter().map(TraceSummary::to_json).collect());
    write_file(&dir.join("summary.json"), &(doc.to_string_pretty() + "\n"))?;

    let mut tta = String::from("name,selector,scenario,seed,round,wall_clock_h,accuracy\n");
    let mut drops = String::from("name,selector,scenario,seed,round,wall_clock_h,cumulative_dead\n");
    let mut part = String::from("name,times_selected,clients\n");
    let mut energy = String::from("name,bucket_lo_j,bucket_hi_j,clients\n");
    for s in summaries {
        for (round, wall_h, acc) in &s.time_to_accuracy {
            let _ = writeln!(
                tta,
                "{},{},{},{},{round},{wall_h:.6},{acc:.6}",
                s.name, s.selector, s.scenario, s.seed
            );
        }
        for (round, wall_h, dead) in &s.dropout_curve {
            let _ = writeln!(
                drops,
                "{},{},{},{},{round},{wall_h:.6},{dead}",
                s.name, s.selector, s.scenario, s.seed
            );
        }
        // Selection-count histogram, including the never-selected mass.
        let mut by_count: BTreeMap<u64, usize> = BTreeMap::new();
        for &times in s.participation.values() {
            *by_count.entry(times).or_default() += 1;
        }
        let never = s.clients.saturating_sub(s.participation.len());
        if never > 0 {
            *by_count.entry(0).or_default() += never;
        }
        for (times, n) in &by_count {
            let _ = writeln!(part, "{},{times},{n}", s.name);
        }
        // Energy histogram over participating clients.
        let max_e = s.energy_by_client.values().cloned().fold(0.0f64, f64::max);
        if !s.energy_by_client.is_empty() {
            let width = if max_e > 0.0 { max_e / ENERGY_BUCKETS as f64 } else { 1.0 };
            let mut buckets = [0usize; ENERGY_BUCKETS];
            for &e in s.energy_by_client.values() {
                let i = ((e / width) as usize).min(ENERGY_BUCKETS - 1);
                buckets[i] += 1;
            }
            for (i, n) in buckets.iter().enumerate() {
                if *n > 0 {
                    let _ = writeln!(
                        energy,
                        "{},{:.6},{:.6},{n}",
                        s.name,
                        width * i as f64,
                        width * (i + 1) as f64
                    );
                }
            }
        }
    }
    write_file(&dir.join("time_to_accuracy.csv"), &tta)?;
    write_file(&dir.join("dropouts.csv"), &drops)?;
    write_file(&dir.join("participation.csv"), &part)?;
    write_file(&dir.join("energy_hist.csv"), &energy)?;
    Ok(())
}

fn write_file(path: &Path, text: &str) -> Result<()> {
    std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::DropCause;

    fn committed(round: u64, acc: f64, wall: f64, energy: f64, ok: bool) -> RoundEvent {
        RoundEvent::RoundCommitted {
            round,
            committed: ok,
            completed: if ok { 2 } else { 0 },
            accuracy: acc,
            train_loss: 1.0,
            energy_j: energy,
            wall_clock_h: wall,
            budget_remaining_j: f64::NAN,
        }
    }

    fn sample_events() -> Vec<RoundEvent> {
        vec![
            RoundEvent::RunStarted {
                name: "run-eafl".into(),
                selector: "eafl".into(),
                scenario: "diurnal".into(),
                clients: 4,
                rounds: 3,
                seed: 9,
            },
            RoundEvent::RoundPlanned {
                round: 1,
                clock_h: 0.0,
                eligible: 4,
                selected: 2,
                deadline_s: 600.0,
            },
            RoundEvent::ClientSelected { round: 1, id: 0, score: 0.0, battery_frac: 0.9 },
            RoundEvent::ClientSelected { round: 1, id: 1, score: 0.0, battery_frac: 0.8 },
            RoundEvent::ClientReported { round: 1, id: 0, duration_s: 100.0, energy_j: 5.0 },
            RoundEvent::ClientDropped {
                round: 1,
                id: 1,
                cause: DropCause::Death,
                at_h: 0.05,
                energy_j: 3.0,
            },
            RoundEvent::BatteryDepleted { id: 1, at_h: 0.05 },
            committed(1, 0.25, 0.2, 8.0, true),
            RoundEvent::RoundPlanned {
                round: 2,
                clock_h: 0.2,
                eligible: 3,
                selected: 1,
                deadline_s: 600.0,
            },
            RoundEvent::ClientSelected { round: 2, id: 0, score: 0.5, battery_frac: 0.7 },
            RoundEvent::ClientReported { round: 2, id: 0, duration_s: 90.0, energy_j: 5.0 },
            RoundEvent::BatteryRevived { id: 1, at_h: 0.4, battery_frac: 0.3 },
            committed(2, 0.5, 0.4, 13.0, true),
            committed(3, 0.5, 0.6, 13.0, false),
        ]
    }

    #[test]
    fn fold_reproduces_summary_numbers() {
        let s = TraceSummary::fold(&sample_events()).unwrap();
        assert_eq!(s.name, "run-eafl");
        assert_eq!(s.selector, "eafl");
        assert_eq!(s.scenario, "diurnal");
        assert_eq!(s.seed, 9);
        assert_eq!(s.clients, 4);
        assert_eq!(s.rounds, 3);
        assert_eq!(s.committed_rounds, 2);
        assert_eq!(s.final_accuracy, 0.5);
        assert_eq!(s.best_accuracy, 0.5);
        assert_eq!(s.total_dropouts, 0, "depleted then revived nets out");
        assert_eq!(s.total_fl_energy_j, 13.0);
        assert_eq!(s.wall_clock_h, 0.6);
        assert_eq!(s.time_to_accuracy, vec![(1, 0.2, 0.25), (2, 0.4, 0.5)]);
        assert_eq!(s.dropout_curve, vec![(1, 0.2, 1), (2, 0.4, 0), (3, 0.6, 0)]);
        assert_eq!(s.participation.get(&0), Some(&2));
        assert_eq!(s.participation.get(&1), Some(&1));
        assert_eq!(s.energy_by_client.get(&0), Some(&10.0));
        assert_eq!(s.energy_by_client.get(&1), Some(&3.0));
    }

    #[test]
    fn campaign_cell_identity_wins_over_run_started() {
        let mut events = sample_events();
        events.insert(
            0,
            RoundEvent::CampaignCell {
                cell: "camp-eafl-diurnal-n4-f0.5-s9".into(),
                selector: "eafl".into(),
                scenario: "diurnal".into(),
                seed: 9,
                f: 0.5,
                clients: 4,
            },
        );
        let s = TraceSummary::fold(&events).unwrap();
        assert_eq!(s.name, "camp-eafl-diurnal-n4-f0.5-s9");
    }

    #[test]
    fn headless_or_empty_traces_are_errors() {
        assert!(TraceSummary::fold(&[]).is_err());
        let only_head = vec![RoundEvent::RunStarted {
            name: "x".into(),
            selector: "s".into(),
            scenario: "sc".into(),
            clients: 1,
            rounds: 1,
            seed: 0,
        }];
        assert!(TraceSummary::fold(&only_head).is_err());
    }

    #[test]
    fn write_outputs_emits_all_figures() {
        let dir = std::env::temp_dir().join(format!("eafl-sum-{}", std::process::id()));
        let s = TraceSummary::fold(&sample_events()).unwrap();
        write_outputs(&dir, std::slice::from_ref(&s)).unwrap();
        for f in [
            "summary.json",
            "time_to_accuracy.csv",
            "dropouts.csv",
            "participation.csv",
            "energy_hist.csv",
        ] {
            assert!(dir.join(f).exists(), "{f} missing");
        }
        let part = std::fs::read_to_string(dir.join("participation.csv")).unwrap();
        // 1 client selected twice, 1 selected once, 2 never selected.
        assert!(part.contains("run-eafl,0,2"), "{part}");
        assert!(part.contains("run-eafl,1,1"), "{part}");
        assert!(part.contains("run-eafl,2,1"), "{part}");
        let json = std::fs::read_to_string(dir.join("summary.json")).unwrap();
        assert!(json.contains("\"final_accuracy\": 0.5"), "{json}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn read_trace_rejects_bad_headers() {
        let dir = std::env::temp_dir().join(format!("eafl-rt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.jsonl");
        std::fs::write(&bad, "not json\n").unwrap();
        assert!(read_trace(&bad).is_err());
        std::fs::write(&bad, "{\"schema\": \"other-v9\"}\n").unwrap();
        let err = read_trace(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("eafl-trace-v1"), "{err:#}");
        std::fs::write(&bad, "").unwrap();
        assert!(read_trace(&bad).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn read_trace_quarantines_torn_event_lines() {
        let dir = std::env::temp_dir().join(format!("eafl-rtq-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let torn = dir.join("torn.trace.jsonl");
        // Valid header, then an event cut mid-write.
        std::fs::write(
            &torn,
            format!("{{\"schema\": \"{TRACE_SCHEMA}\"}}\n{{\"ev\": \"round_com"),
        )
        .unwrap();
        let err = format!("{:#}", read_trace(&torn).unwrap_err());
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("quarantine"), "{err}");
        assert!(!torn.exists(), "torn trace should be moved aside");
        assert!(dir.join("torn.trace.jsonl.quarantine").exists());
        // A *header* problem is not quarantined — the file was never a
        // trace of ours to begin with.
        let alien = dir.join("alien.jsonl");
        std::fs::write(&alien, "{\"schema\": \"other\"}\n").unwrap();
        assert!(read_trace(&alien).is_err());
        assert!(alien.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
