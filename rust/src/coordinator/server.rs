//! The FL server loop (paper Fig. 1 / Fig. 2).
//!
//! Per round: build candidates → selector picks K → event-driven round
//! simulation (timing, battery deaths, stragglers) → REAL local SGD via
//! the AOT runtime for completing clients → aggregate (YoGi/FedAvg) →
//! drain batteries (participants per simulation, bystanders background)
//! → update utilities, metrics, clock. Rounds with fewer than
//! `min_report_fraction·K` completions fail and are not aggregated
//! (FedScale semantics); their time still elapses.

use anyhow::Result;
use crate::util::rng::Rng;

use crate::aggregation::{make_aggregator, Aggregator, ClientUpdate};
use crate::config::ExperimentConfig;
use crate::data::SyntheticSpeech;
use crate::metrics::{jain_index, MetricsLog, RoundRecord};
use crate::runtime::ModelRuntime;
use crate::selection::{make_selector, ParticipantOutcome, RoundFeedback, Selector};
use crate::sim::{simulate_round, ParticipantPlan};
use crate::training::{Trainer, TrainerBufs};

use super::registry::Registry;

/// Consecutive deadline misses before a client is benched.
const MISS_BLACKLIST_THRESHOLD: u32 = 3;
/// Rounds a benched client stays ineligible.
const MISS_BLACKLIST_COOLDOWN: u64 = 10;

/// The coordinator owns the full experiment state.
pub struct Coordinator<'r> {
    cfg: ExperimentConfig,
    runtime: &'r dyn ModelRuntime,
    registry: Registry,
    selector: Box<dyn Selector>,
    aggregator: Box<dyn Aggregator>,
    data: SyntheticSpeech,
    global_params: Vec<f32>,
    /// Simulated wall clock, hours.
    clock_h: f64,
    rng: Rng,
    log: MetricsLog,
    /// Reused batch buffers (§Perf L3: no per-round allocation).
    trainer_bufs: TrainerBufs,
    /// Carried between eval points.
    last_accuracy: f64,
    last_test_loss: f64,
}

impl<'r> Coordinator<'r> {
    pub fn new(cfg: ExperimentConfig, runtime: &'r dyn ModelRuntime) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(
            cfg.data.batch_size == runtime.train_batch(),
            "config batch_size ({}) must match the AOT artifact's train batch ({})",
            cfg.data.batch_size,
            runtime.train_batch()
        );
        let data = SyntheticSpeech::new(
            runtime.input_hw(),
            runtime.num_classes(),
            cfg.data.noise_std,
            cfg.data.seed,
        );
        let registry = Registry::build(&cfg, runtime.num_classes(), runtime.param_count());
        let selector = make_selector(&cfg.selector);
        let aggregator = make_aggregator(
            cfg.federation.aggregator,
            runtime.param_count(),
            cfg.training.server_learning_rate,
        );
        let global_params = runtime.init_params(cfg.training.init_seed)?;
        let trainer_bufs = TrainerBufs::new(runtime);
        let rng = Rng::seed_from_u64(cfg.data.seed ^ cfg.devices.seed ^ 0x5EED);
        let log = MetricsLog::new(cfg.name.clone());
        Ok(Self {
            cfg,
            runtime,
            registry,
            selector,
            aggregator,
            data,
            global_params,
            clock_h: 0.0,
            rng,
            log,
            trainer_bufs,
            last_accuracy: 0.0,
            last_test_loss: f64::NAN,
        })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn clock_h(&self) -> f64 {
        self.clock_h
    }

    pub fn global_params(&self) -> &[f32] {
        &self.global_params
    }

    /// Run the configured number of rounds; returns the metrics log.
    pub fn run(mut self) -> Result<MetricsLog> {
        let rounds = self.cfg.federation.rounds;
        for round in 1..=rounds as u64 {
            self.run_round(round)?;
            if self.registry.alive_count() == 0 {
                eprintln!("[eafl] round {round}: entire population dead; stopping early");
                break;
            }
        }
        Ok(self.log)
    }

    /// Execute one round end to end.
    pub fn run_round(&mut self, round: u64) -> Result<()> {
        let fed = &self.cfg.federation;
        let k = fed.participants_per_round;
        let local_steps = self.cfg.training.local_steps;
        let batch = self.cfg.data.batch_size;

        let candidates = self.registry.candidates(
            round,
            self.cfg.selector.min_battery_frac,
            local_steps,
            batch,
        );
        let selected = self.selector.select(round, &candidates, k, &mut self.rng);
        let deadline_s = self.selector.deadline_s(&candidates);

        // --- Event-driven round simulation -------------------------------
        let plans: Vec<ParticipantPlan> = selected
            .iter()
            .map(|&id| {
                let c = &self.registry.clients[id];
                let energy = c
                    .projected_energy(self.registry.payload_bytes, local_steps, batch)
                    .total();
                ParticipantPlan {
                    id,
                    download_s: c.link.download_secs(self.registry.payload_bytes),
                    compute_s: c.compute_secs(local_steps, batch),
                    upload_s: c.link.upload_secs(self.registry.payload_bytes),
                    round_energy_j: energy,
                    charge_j: c.battery.charge_joules(),
                }
            })
            .collect();
        let sim = simulate_round(&plans, deadline_s);
        // An empty round still advances time by the deadline (the server
        // waits before concluding nobody is coming).
        let round_duration_s =
            if selected.is_empty() { deadline_s.max(1.0) } else { sim.duration_s.max(1.0) };
        let round_hours = round_duration_s / 3600.0;
        let end_clock_h = self.clock_h + round_hours;

        // --- Real local training for completing clients ------------------
        let mut trainer = Trainer::with_bufs(
            self.runtime,
            &self.data,
            std::mem::replace(&mut self.trainer_bufs, TrainerBufs::empty()),
        );
        let mut updates: Vec<ClientUpdate> = Vec::with_capacity(selected.len());
        let mut outcomes: Vec<ParticipantOutcome> = Vec::with_capacity(selected.len());
        let mut train_loss_sum = 0.0f64;
        let mut dropped = 0usize;
        let mut deadline_missed = 0usize;

        for (r, plan) in sim.results.iter().zip(&plans) {
            let client = &self.registry.clients[r.id];
            let mut stat_util = None;
            if r.completed {
                let res = trainer.train_client(
                    &self.global_params,
                    &client.shard,
                    self.cfg.training.learning_rate,
                    local_steps,
                    round,
                )?;
                train_loss_sum += res.final_loss as f64;
                stat_util = Some(res.stat_util);
                updates.push(ClientUpdate { params: res.params, weight: res.weight });
            } else {
                match r.failure {
                    Some(crate::sim::FailureKind::BatteryDeath) => dropped += 1,
                    _ => deadline_missed += 1,
                }
            }
            // For deadline misses report the client's TRUE round
            // duration (not the deadline-clamped active time) so Oort's
            // Eq. (2) straggler penalty sees t_i > T.
            let duration_s = match r.failure {
                Some(crate::sim::FailureKind::DeadlineMiss) => plan.total_duration_s(),
                _ => r.active_s,
            };
            outcomes.push(ParticipantOutcome {
                id: r.id,
                stat_util,
                duration_s,
                completed: r.completed,
            });
        }

        // --- Commit or fail the round ------------------------------------
        let required =
            ((k as f64) * fed.min_report_fraction).ceil().max(1.0) as usize;
        let committed = updates.len() >= required.min(selected.len().max(1));
        if committed && !updates.is_empty() {
            self.aggregator.aggregate(&mut self.global_params, &updates)?;
        }

        // --- Battery accounting -------------------------------------------
        for r in &sim.results {
            let c = &mut self.registry.clients[r.id];
            let death_time_h = self.clock_h + r.active_s / 3600.0;
            c.battery.drain_fl(r.energy_spent_j, death_time_h);
        }
        let selected_set: std::collections::HashSet<usize> =
            selected.iter().copied().collect();
        for c in &mut self.registry.clients {
            if selected_set.contains(&c.id) || !c.battery.is_alive() {
                continue;
            }
            let rate = if c.device.background_busy {
                self.cfg.devices.busy_drain_per_hour
            } else {
                self.cfg.devices.idle_drain_per_hour
            };
            let e = crate::energy::background_energy_joules(&c.device.spec, rate, round_hours);
            c.battery.drain_background(e, end_clock_h);
        }

        // --- Optional recharge model ---------------------------------------
        if self.cfg.devices.recharge_after_hours > 0.0 {
            let after = self.cfg.devices.recharge_after_hours;
            let to = self.cfg.devices.recharge_to_fraction;
            for c in &mut self.registry.clients {
                if let Some(died) = c.battery.died_at_h {
                    if end_clock_h - died >= after {
                        c.battery.recharge_to(to);
                    }
                }
            }
        }

        // --- Stats + selector feedback -------------------------------------
        for o in &outcomes {
            let stats = &mut self.registry.clients[o.id].stats;
            stats.times_selected += 1;
            stats.last_selected_round = round;
            stats.measured_duration_s = Some(o.duration_s);
            if o.completed {
                stats.times_completed += 1;
                stats.stat_util = o.stat_util;
                stats.consecutive_misses = 0;
            } else {
                // Oort-style blacklist: repeated deadline misses bench
                // the client for a cooldown window.
                stats.consecutive_misses += 1;
                if stats.consecutive_misses >= MISS_BLACKLIST_THRESHOLD {
                    stats.banned_until_round = round + MISS_BLACKLIST_COOLDOWN;
                    stats.consecutive_misses = 0;
                }
            }
        }
        self.selector.feedback(&RoundFeedback { round, outcomes: &outcomes });

        // --- Evaluation -----------------------------------------------------
        if committed && (round % fed.eval_interval as u64 == 0 || round == 1) {
            let test = self.data.test_set(self.cfg.data.test_samples);
            let ev = trainer.evaluate(&self.global_params, &test)?;
            self.last_accuracy = ev.accuracy;
            self.last_test_loss = ev.mean_loss;
            // (eval accuracy is recorded in the round metrics below)
        }

        self.trainer_bufs = trainer.into_bufs();

        // --- Record ---------------------------------------------------------
        self.clock_h = end_clock_h;
        let completed = updates.len();
        self.log.push(RoundRecord {
            round,
            wall_clock_h: self.clock_h,
            round_duration_s,
            selected: selected.len(),
            completed,
            dropped,
            deadline_missed,
            committed,
            train_loss: if completed > 0 {
                train_loss_sum / completed as f64
            } else {
                f64::NAN
            },
            test_accuracy: self.last_accuracy,
            test_loss: self.last_test_loss,
            fairness: jain_index(&self.registry.selection_counts()),
            cumulative_dead: self.registry.dead_count(),
            alive_fraction: self.registry.alive_count() as f64
                / self.registry.len().max(1) as f64,
            mean_battery: self.registry.mean_battery_alive(),
            total_fl_energy_j: self.registry.total_fl_energy_j(),
        });
        Ok(())
    }
}
