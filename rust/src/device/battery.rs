//! Battery state machine.
//!
//! Tracks charge as a fraction of the device's capacity (Table 2 mAh →
//! joules). Drain sources: training compute (E = P·t), wireless
//! transfers (Table 1 models via `energy::comm`), and background
//! idle/busy usage for unselected devices. A device whose battery hits
//! zero is `Dead` — the paper's client drop-out condition — and stays
//! dead unless the (optional) recharge model revives it.


use super::tier::DeviceSpec;

/// Liveness state of a device's battery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatteryState {
    /// Charge above the dead threshold; device can participate.
    Alive,
    /// Battery exhausted; device is unavailable (drop-out).
    Dead,
}

/// A device battery with charge tracked in joules.
#[derive(Debug, Clone)]
pub struct Battery {
    capacity_j: f64,
    charge_j: f64,
    state: BatteryState,
    /// Cumulative energy drained through FL work (compute + comm), J.
    pub fl_energy_j: f64,
    /// Cumulative energy drained through background usage, J.
    pub background_energy_j: f64,
    /// Simulation hour at which the battery died (if it did).
    pub died_at_h: Option<f64>,
}

impl Battery {
    /// New battery for `spec`, charged to `fraction` (clamped to [0,1]).
    pub fn new(spec: &DeviceSpec, fraction: f64) -> Self {
        let capacity_j = spec.battery_joules();
        let charge_j = capacity_j * fraction.clamp(0.0, 1.0);
        Self {
            capacity_j,
            charge_j,
            state: if charge_j > 0.0 { BatteryState::Alive } else { BatteryState::Dead },
            fl_energy_j: 0.0,
            background_energy_j: 0.0,
            died_at_h: None,
        }
    }

    pub fn state(&self) -> BatteryState {
        self.state
    }

    pub fn is_alive(&self) -> bool {
        self.state == BatteryState::Alive
    }

    /// Remaining charge as a fraction of capacity in [0, 1].
    pub fn fraction(&self) -> f64 {
        (self.charge_j / self.capacity_j).clamp(0.0, 1.0)
    }

    pub fn charge_joules(&self) -> f64 {
        self.charge_j
    }

    pub fn capacity_joules(&self) -> f64 {
        self.capacity_j
    }

    /// Whether the battery currently holds at least `energy_j`.
    pub fn can_supply(&self, energy_j: f64) -> bool {
        self.is_alive() && self.charge_j >= energy_j
    }

    /// Drain `energy_j` of FL work at simulation time `now_h`.
    ///
    /// Returns the fraction of the request that was actually supplied
    /// (< 1.0 means the battery died partway — the paper's mid-round
    /// drop-out). Negative requests are treated as zero.
    pub fn drain_fl(&mut self, energy_j: f64, now_h: f64) -> f64 {
        self.drain(energy_j, now_h, true)
    }

    /// Drain background (idle/busy) energy at time `now_h`.
    pub fn drain_background(&mut self, energy_j: f64, now_h: f64) -> f64 {
        self.drain(energy_j, now_h, false)
    }

    fn drain(&mut self, energy_j: f64, now_h: f64, fl: bool) -> f64 {
        if self.state == BatteryState::Dead {
            return 0.0;
        }
        let req = energy_j.max(0.0);
        let supplied = req.min(self.charge_j);
        self.charge_j -= supplied;
        if fl {
            self.fl_energy_j += supplied;
        } else {
            self.background_energy_j += supplied;
        }
        if self.charge_j <= f64::EPSILON {
            self.charge_j = 0.0;
            self.state = BatteryState::Dead;
            self.died_at_h = Some(now_h);
        }
        if req == 0.0 {
            1.0
        } else {
            supplied / req
        }
    }

    /// Materialize lazily accrued background drain: set the charge to
    /// the closed-form `new_charge_j` computed by the registry's drain
    /// ledger, booking the difference as background energy. `now_h` is
    /// the ledger's current round clock and becomes the death timestamp
    /// when the settled charge crosses the dead threshold — the same
    /// end-of-round instant the eager sweep stamps.
    ///
    /// Unlike [`Battery::drain_background`], which drains a requested
    /// *amount*, this sets an absolute level: the ledger has already
    /// resolved elapsed time × drain rate into a target charge, and the
    /// settle must land on those exact bits in both lazy and eager
    /// modes.
    pub fn settle_background(&mut self, new_charge_j: f64, now_h: f64) {
        if self.state == BatteryState::Dead {
            return;
        }
        let target = new_charge_j.max(0.0);
        debug_assert!(target <= self.charge_j + 1e-9, "settle must not add charge");
        self.background_energy_j += self.charge_j - target;
        self.charge_j = target;
        if self.charge_j <= f64::EPSILON {
            // Drop (don't book) the sub-epsilon residual — exactly what
            // the legacy drain path does at death.
            self.charge_j = 0.0;
            self.state = BatteryState::Dead;
            self.died_at_h = Some(now_h);
        }
    }

    /// Add `energy_j` of charge, clamped at capacity. A dead battery
    /// that receives charge revives — the wall-clock recharge policies'
    /// (overnight window, solar trace) entry point, where charging is a
    /// rate over time rather than a jump to a fixed level.
    pub fn charge_add(&mut self, energy_j: f64) {
        if energy_j <= 0.0 {
            return;
        }
        self.charge_j = (self.charge_j + energy_j).min(self.capacity_j);
        if self.charge_j > 0.0 {
            self.state = BatteryState::Alive;
            self.died_at_h = None;
        }
    }

    /// Recharge to `fraction` of capacity and revive (recharge model).
    pub fn recharge_to(&mut self, fraction: f64) {
        self.charge_j = self.capacity_j * fraction.clamp(0.0, 1.0);
        if self.charge_j > 0.0 {
            self.state = BatteryState::Alive;
            self.died_at_h = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::tier::Tier;

    fn batt(frac: f64) -> Battery {
        Battery::new(&DeviceSpec::for_tier(Tier::Low), frac)
    }

    #[test]
    fn full_drain_kills_device() {
        let mut b = batt(1.0);
        let cap = b.capacity_joules();
        assert_eq!(b.drain_fl(cap * 2.0, 5.0), 0.5); // only half supplied
        assert_eq!(b.state(), BatteryState::Dead);
        assert_eq!(b.died_at_h, Some(5.0));
        assert_eq!(b.fraction(), 0.0);
    }

    #[test]
    fn partial_drain_keeps_alive() {
        let mut b = batt(1.0);
        let cap = b.capacity_joules();
        assert_eq!(b.drain_fl(cap * 0.25, 1.0), 1.0);
        assert!(b.is_alive());
        assert!((b.fraction() - 0.75).abs() < 1e-12);
        assert!((b.fl_energy_j - cap * 0.25).abs() < 1e-9);
    }

    #[test]
    fn dead_battery_supplies_nothing() {
        let mut b = batt(0.0);
        assert_eq!(b.state(), BatteryState::Dead);
        assert_eq!(b.drain_fl(10.0, 0.0), 0.0);
        assert_eq!(b.drain_background(10.0, 0.0), 0.0);
    }

    #[test]
    fn background_and_fl_accounted_separately() {
        let mut b = batt(1.0);
        b.drain_fl(100.0, 0.0);
        b.drain_background(50.0, 0.0);
        assert_eq!(b.fl_energy_j, 100.0);
        assert_eq!(b.background_energy_j, 50.0);
    }

    #[test]
    fn recharge_revives() {
        let mut b = batt(0.01);
        b.drain_fl(b.capacity_joules(), 2.0);
        assert!(!b.is_alive());
        b.recharge_to(0.8);
        assert!(b.is_alive());
        assert!((b.fraction() - 0.8).abs() < 1e-12);
        assert_eq!(b.died_at_h, None);
    }

    #[test]
    fn charge_add_accumulates_caps_and_revives() {
        let mut b = batt(0.5);
        let cap = b.capacity_joules();
        b.charge_add(cap * 0.25);
        assert!((b.fraction() - 0.75).abs() < 1e-12);
        b.charge_add(cap); // overshoot clamps at capacity
        assert!((b.fraction() - 1.0).abs() < 1e-12);

        b.drain_fl(cap * 2.0, 3.0);
        assert!(!b.is_alive());
        b.charge_add(-5.0); // negative is a no-op, stays dead
        assert!(!b.is_alive());
        b.charge_add(cap * 0.1);
        assert!(b.is_alive());
        assert_eq!(b.died_at_h, None);
        assert!((b.fraction() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn settle_background_books_consumed_energy_and_kills_at_zero() {
        let mut b = batt(1.0);
        let cap = b.capacity_joules();
        b.settle_background(cap * 0.6, 4.0);
        assert!(b.is_alive());
        assert_eq!(b.charge_joules(), cap * 0.6);
        assert_eq!(b.background_energy_j, cap - cap * 0.6);
        // Settling to (clamped) zero kills at the ledger clock.
        b.settle_background(-1.0, 7.25);
        assert_eq!(b.state(), BatteryState::Dead);
        assert_eq!(b.died_at_h, Some(7.25));
        assert_eq!(b.fraction(), 0.0);
        assert!((b.background_energy_j - cap).abs() < 1e-9);
        // Dead batteries ignore further settles.
        let booked = b.background_energy_j;
        b.settle_background(0.0, 9.0);
        assert_eq!(b.background_energy_j, booked);
        assert_eq!(b.died_at_h, Some(7.25));
    }

    #[test]
    fn settle_background_matches_drain_background_charge_bits() {
        // Settling to `charge - consumed` must land the *charge* on the
        // same bits as draining `consumed` — the charge level is what
        // feeds selection, death predicates and the report. (The booked
        // background energy may differ in the last ulp because the two
        // paths sum it in a different association; the determinism tier
        // compares runs of the same mode, never drain-vs-settle.)
        let mut settled = batt(0.8);
        let mut drained = batt(0.8);
        let consumed = settled.capacity_joules() * 0.037;
        drained.drain_background(consumed, 2.0);
        settled.settle_background(settled.charge_joules() - consumed, 2.0);
        assert_eq!(settled.charge_joules(), drained.charge_joules());
        assert!((settled.background_energy_j - drained.background_energy_j).abs() < 1e-9);
    }

    #[test]
    fn negative_request_is_noop() {
        let mut b = batt(0.5);
        let before = b.charge_joules();
        assert_eq!(b.drain_fl(-5.0, 0.0), 1.0);
        assert_eq!(b.charge_joules(), before);
    }
}
