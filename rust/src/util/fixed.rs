//! Exact fixed-point accumulator for incrementally maintained float
//! aggregates.
//!
//! The fast-path registry keeps population sums (alive battery
//! fraction, total FL energy) up to date at every mutation site instead
//! of rescanning N clients per round. Plain `f64 += delta` accumulation
//! is order-dependent, so an incrementally maintained sum would drift
//! away from a fresh recomputation and the invariant "incremental ==
//! brute force" could only be checked up to an epsilon. [`FixedSum`]
//! sidesteps that: every contribution is quantized to a 2⁻³² grid and
//! accumulated in an `i128`, where addition is exact and associative —
//! so add/remove sequences in *any* order land on bit-identical state,
//! and the aggregate-consistency property tests can assert strict
//! equality against a from-scratch rebuild.
//!
//! Resolution: 2⁻³² ≈ 2.3e-10 absolute — far below anything the metrics
//! pipeline rounds to. Range: |Σ| up to 2⁹⁵ ≈ 4e28 in quantized units,
//! i.e. ~9e18 in value — population energy sums sit ten orders of
//! magnitude under that.

/// Exact running sum over a multiset of f64 contributions.
///
/// The contract: `sub(v)` with the *same* `v` previously passed to
/// `add(v)` cancels exactly, and the final state equals a fresh
/// `FixedSum` fed the surviving contributions in any order.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FixedSum(i128);

impl FixedSum {
    /// Quantization scale: 2³² grid steps per unit.
    const SCALE: f64 = (1u64 << 32) as f64;

    /// A contribution's exact grid representation.
    fn quantize(v: f64) -> i128 {
        debug_assert!(v.is_finite(), "FixedSum contribution must be finite");
        (v * Self::SCALE).round() as i128
    }

    /// Add a contribution.
    pub fn add(&mut self, v: f64) {
        self.0 += Self::quantize(v);
    }

    /// Remove a previously added contribution (exact inverse of `add`
    /// for the same value).
    pub fn sub(&mut self, v: f64) {
        self.0 -= Self::quantize(v);
    }

    /// The sum as f64 (quantized to the 2⁻³² grid).
    pub fn value(&self) -> f64 {
        self.0 as f64 / Self::SCALE
    }

    /// Raw grid units — what the property tests compare for strict
    /// equality.
    pub fn raw(&self) -> i128 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn add_sub_cancels_exactly() {
        let mut s = FixedSum::default();
        for v in [0.1, 1e-7, 12345.6789, 3.0e9] {
            s.add(v);
            s.sub(v);
        }
        assert_eq!(s, FixedSum::default());
        assert_eq!(s.value(), 0.0);
    }

    #[test]
    fn order_independent() {
        let mut rng = Rng::seed_from_u64(17);
        let values: Vec<f64> = (0..500).map(|_| rng.gen_range_f64(-100.0, 5000.0)).collect();
        let mut forward = FixedSum::default();
        for &v in &values {
            forward.add(v);
        }
        let mut backward = FixedSum::default();
        for &v in values.iter().rev() {
            backward.add(v);
        }
        assert_eq!(forward, backward);
        // Interleaved add/remove of extra values ends at the same state.
        let mut churned = FixedSum::default();
        for (i, &v) in values.iter().enumerate() {
            churned.add(v);
            let noise = values[(i * 7) % values.len()];
            churned.add(noise);
            churned.sub(noise);
        }
        assert_eq!(churned, forward);
    }

    #[test]
    fn value_tracks_float_sum_closely() {
        let mut s = FixedSum::default();
        let mut reference = 0.0f64;
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let v = rng.gen_range_f64(0.0, 1.0);
            s.add(v);
            reference += v;
        }
        assert!((s.value() - reference).abs() < 1e-5);
    }
}
