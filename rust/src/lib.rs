//! # EAFL — Energy-Aware Federated Learning on Battery-Powered Clients
//!
//! Rust + JAX + Pallas reproduction of *"EAFL: Towards Energy-Aware
//! Federated Learning on Battery-Powered Edge Devices"* (Arouj &
//! Abdelmoniem, FedEdge @ MobiCom'22).
//!
//! Three-layer architecture (see `DESIGN.md`):
//!  - **Layer 3 (this crate)** — the FL coordinator: client selection
//!    (Random / Oort / EAFL / Budget), event-driven device simulation,
//!    energy and battery accounting, aggregation (FedAvg / YoGi),
//!    metrics.
//!  - **Layer 2** — JAX speech-CNN fwd/bwd, AOT-lowered to HLO text at
//!    build time (`make artifacts`), executed here via PJRT.
//!  - **Layer 1** — Pallas kernels (fused dense, fused softmax-xent)
//!    inlined into the Layer-2 HLO.
//!
//! Python never runs on the request path: the `eafl` binary is
//! self-contained once `artifacts/` exists (build with `--features xla`;
//! the default offline build substitutes a stub and runs on the
//! analytic mock runtime via `--mock`).
//!
//! ## The staged RoundEngine
//!
//! A training round is six explicit phases with typed inputs/outputs
//! ([`coordinator::PlanPhase`] … [`coordinator::RecordPhase`]), wired
//! together by [`Coordinator::run_round`]:
//!
//! ```text
//! PlanPhase ──RoundPlan──► SimPhase ──SimulatedRound──► ExecPhase
//!  (candidates,             (event-driven               (parallel local
//!   selector picks K,        timing, deaths,             SGD, per-worker
//!   deadline T)              stragglers)                 TrainerBufs)
//!                                                            │
//!                                                   ExecutionOutcome
//!                                                            ▼
//! RecordPhase ◄── FeedbackPhase ◄── BatteryAccounting ◄── CommitPhase
//!  (metrics row)   (stats, miss      + RechargePolicy      (quorum rule,
//!                   blacklist,       (participants,         YoGi/FedAvg
//!                   selector fb)     bystanders, revival)   aggregate)
//! ```
//!
//! The execution phase trains the round's completing clients across
//! worker threads (`EAFL_WORKERS`, default = available parallelism)
//! and commits results in simulation order, so seeded runs are
//! bit-identical at any worker count.
//!
//! ## The million-client fast path
//!
//! The non-training round path (plan → select → account → record) is
//! sized for populations in the millions — the cross-device regimes
//! AutoFL-style systems operate in. Per-round complexity, N = clients,
//! E = eligible candidates, k = participants:
//!
//! | stage                | before                                   | after                                    |
//! |----------------------|------------------------------------------|------------------------------------------|
//! | candidate build      | O(N) recompute + fresh `Vec<Candidate>`  | O(changed) patched eligible arena: selected + floor crossings + ban releases + availability flips |
//! | selection (Oort/EAFL)| O(E log E) full sort + O(k·E) linear draws | O(E) band partition + O(k·log band) Fenwick draws |
//! | selection (Random)   | O(E) full shuffle                        | O(k) partial Fisher–Yates                |
//! | participant drain    | O(k)                                     | O(k) (through aggregate guards)          |
//! | background drain     | O(N) sweep of every battery, every round | O(k + due deaths) lazy ledger (per-class cumsums + death wheel) |
//! | availability gate    | O(N) dynamic model calls per round       | O(changed clients) wake wheel, cached bitmap |
//! | recharge revival scan| O(N) liveness probe per round            | O(dead) / O(below-capacity) via liveness index sets |
//! | metrics record       | ~5 × O(N) scans + counts Vec             | O(1) from incremental aggregates         |
//!
//! **The lazy-drain invariant:** background drain is *deferred, never
//! dropped*. Each client's charge is an anchor plus a closed-form
//! function of the per-class drained-fraction cumsums, so aggregates
//! and candidate projections always reflect drain **as of the round
//! clock**, applied on touch; a bucketed death wheel fires expirations
//! on the exact round their effective charge reaches zero. The result
//! is bit-identical to materializing every battery every epoch —
//! property-tested in `rust/tests/lazy_drain.rs`, and enforceable at
//! runtime with the `EAFL_EAGER_DRAIN=1` escape hatch (ci.sh runs the
//! whole suite and a campaign byte-compare under it).
//!
//! **The wake-wheel contract:** an availability model reports a *sound
//! lower bound* on its next change time (`next_change_h`): the
//! availability bit is constant on `[clock_h, next)`. The
//! [`scenario::WakeWheel`] re-evaluates only clients whose bound has
//! come due, so the plan gate reads a cached bitmap — and surfaces the
//! ids whose bit actually flipped as a sorted change list. Early
//! wake-ups cost a redundant re-evaluation, never a stale bit.
//!
//! **The eligible-arena invariant:** the per-round candidate set is an
//! incrementally-maintained mirror, not a scan. The registry keeps an
//! arena whose membership is always exactly alive ∧ strictly above the
//! battery floor ([`selection::battery_floor_admits`], one shared
//! predicate at every site) ∧ not banned ∧ available:
//! battery-floor-crossing wheels (death-wheel machinery at threshold
//! `min_battery_frac`, riding the same lazy-drain cumsums), a
//! ban-release wheel, the wake wheel's change lists, and dirty marks
//! from the guard choke points feed `Registry::refresh_eligible`, so
//! `PlanPhase` patches in O(changed) instead of rewalking all N slots.
//! Patched == rebuilt bit-equality is property-tested in
//! `rust/tests/candidate_arena.rs`, and the `EAFL_REBUILD_CANDIDATES=1`
//! escape hatch forces the full rebuild (ci.sh runs the whole suite
//! plus campaign and trace byte-compares under it).
//!
//! The machinery (see [`coordinator::Registry`]):
//!
//!  - **SoA `ClientPool`** — per-client projections (transfer times,
//!    compute time, round energy, drain fraction) cached at build time;
//!    static entries recompute only when a client's device/link state
//!    actually changes (`refresh_projection` / `link_mut`).
//!  - **Incremental `PoolAggregates`** — alive count, Σ alive-battery
//!    fraction, Σ FL energy and the Σc/Σc² Jain moments maintained at
//!    the mutation sites (`drain_fl`, `charge_add`, feedback stats)
//!    through guard types. Float sums use exact i128 fixed-point
//!    (`util::fixed::FixedSum`), so incremental state is bit-identical
//!    to brute-force recomputation — property-tested in
//!    `rust/tests/pool_aggregates.rs`.
//!  - **Pool invariants** — every battery/stats mutation goes through
//!    `Registry::battery_mut` / `stats_mut` guards; `clients` is
//!    private, so pool mirrors and aggregates can never drift. The
//!    eligible arena is one more guarded mirror: the same choke points
//!    mark its entries dirty, so arena membership can never drift from
//!    the eligibility predicate either.
//!  - **Fenwick sampler** — one weighted-draw implementation
//!    ([`selection::FenwickSampler`]) for Oort exploitation and EAFL
//!    exploration, provably identical to the linear-scan reference on
//!    the same RNG stream (quantized integer weights make prefix sums
//!    exact), at O(log n) per draw.
//!
//! `benches/plan_path_throughput.rs` measures the whole path at
//! 10k/100k/1M/10M clients (steady + diurnal), keeps the pre-refactor
//! baseline, an eager-drain sweep, and a from-scratch candidate
//! rebuild alongside for honest speedups, and emits machine-readable
//! `BENCH_plan.json` (`eafl-bench-v1` schema via [`benchkit`]);
//! `make bench` writes it at the repo root and ci.sh smoke-checks it.
//!
//! ## Scenarios
//!
//! The environment is data, not code: a [`scenario::Scenario`] bundles
//! an availability model (consumed by the plan phase), a network model
//! (consumed by the sim phase), a recharge policy and optional device
//! overrides. Select one with `--scenario NAME|FILE` (or the
//! `scenario` config key); `eafl scenarios` lists the presets:
//!
//! | preset       | availability            | network                  | recharge            |
//! |--------------|-------------------------|--------------------------|---------------------|
//! | `steady`     | always-on               | static                   | from device config  |
//! | `diurnal`    | sine wave, peak 20:00   | static                   | from device config  |
//! | `commuter`   | Markov on/off traces    | 17–21h congestion 0.35×  | overnight 22–6h     |
//! | `solar-edge` | always-on               | 30% tail at 0.25×        | solar daylight trace|
//!
//! Custom scenarios are TOML files on the same schema
//! (`eafl scenarios --show NAME` prints a template):
//!
//! ```text
//! name = "night-shift"
//! [availability]
//! kind = "diurnal"          # always-on | diurnal | trace
//! peak_hour = 2
//! min_available = 0.1
//! max_available = 0.9
//! [network]
//! kind = "degraded-tail"    # static | degraded-tail | congestion
//! fraction = 0.4
//! factor = 0.2
//! [recharge]
//! kind = "overnight"        # from-config | none | overnight | solar
//! start_hour = 8
//! end_hour = 16
//! rate_frac_per_h = 0.3
//! [overrides]
//! idle_drain_per_hour = 0.01
//! ```
//!
//! Every model is a pure function of (seed, client, simulated time), so
//! scenarios preserve worker-count invariance: seeded campaigns stay
//! byte-identical at any `EAFL_WORKERS` / `--jobs` setting.
//!
//! ## Observability: deterministic events + wall-time profile
//!
//! Two strictly separated telemetry channels (module [`obs`]):
//!
//! 1. **Deterministic round events** — a typed [`obs::RoundEvent`]
//!    stream (`run_started`, `round_planned`, `client_selected`,
//!    `client_reported`, `client_dropped`, `battery_depleted`,
//!    `battery_revived`, `round_committed`, `campaign_cell`) emitted
//!    through an [`obs::EventSink`] from the engine's phase seams and
//!    the registry's lifecycle choke point. Payloads are pure
//!    functions of (config, seed, simulated time), so `eafl run
//!    --trace FILE` writes an `eafl-trace-v1` JSONL whose **bytes are
//!    identical** at any `EAFL_WORKERS`, any `--shard` split, and lazy
//!    vs `EAFL_EAGER_DRAIN=1` — the same determinism tiers the metrics
//!    CSVs already honor (`rust/tests/trace_determinism.rs`).
//! 2. **Wall-time phase profile** — [`obs::PhaseProfiler`] spans
//!    (plan/sim/exec/commit/account/feedback/eval/record) written to a
//!    sibling `*.profile.json`. Inherently machine-dependent, so it
//!    never shares a file with the event channel and is excluded from
//!    byte-compares.
//!
//! `eafl trace summarize TRACE... [--out DIR]` folds traces back into
//! the paper's figures (time-to-accuracy on the wall-clock axis,
//! drop-out trajectories, participation/energy histograms) and
//! reproduces the run summary exactly from events alone. With no sink
//! attached the seams cost one `Option` branch per phase — the
//! `plan_path_throughput` bench runs sink-free and is unaffected.
//!
//! ## Energy budgets: the selector family and the campaign ledger
//!
//! The paper's premise is that FL energy is a *scarce resource* on
//! battery-powered fleets. Two mechanisms make that budget a
//! first-class experiment axis (see [`selection::BudgetSelector`]):
//!
//!  - **The `budget` selector family** — `--selector budget` with
//!    `[selector] budget_j` and `budget_policy` picks clients under an
//!    explicit campaign energy envelope. `hard-cap` greedily packs
//!    cheap-per-utility clients but never plans past the remaining
//!    envelope; `amortized` paces spend at `remaining /
//!    remaining_rounds` per round so the budget survives the whole
//!    campaign; `deadline-aware` multiplies the amortized allowance by
//!    `budget_spend_ahead` while the EAFL pacer is relaxed, buying
//!    accuracy early when the deadline has slack.
//!  - **The engine [`coordinator::EnergyLedger`]** — selector-agnostic
//!    bookkeeping in the commit path: per-round *projected* plan energy
//!    is reconciled against *actual* simulated spend, every
//!    `round_committed` trace event carries `budget_remaining_j`
//!    (`null` on unlimited runs), and when a finite `budget_j` is spent
//!    the run stops with a terminal `budget_exhausted` event — for any
//!    selector, budget-aware or not.
//!
//! Both honor the determinism contract (byte-identical traces at any
//! `EAFL_WORKERS`, shard split, or drain mode), and
//! `rust/tests/budget_invariants.rs` proves the hard-cap bound
//! (Σ actual spend ≤ budget, by induction over per-round envelopes) and
//! the monotone budget/accuracy frontier.
//!
//! ## Campaigns
//!
//! The paper's figures are grids, not runs. [`campaign`] expands
//! selectors × scenarios × seeds × f-values × client-counts × budgets
//! against a base config and runs the experiments across threads,
//! merging the summaries into one `campaign.json` + `campaign.csv`;
//! re-running into the same `--out` directory resumes a partial
//! campaign by skipping grid cells that already have summaries. A
//! `--budget-j` list adds the energy-budget axis (cells tagged
//! `-b<J>`), and the merged CSV gains the frontier columns `budget_j`,
//! `energy_spent_j` and final/best accuracy — the paper's
//! energy/accuracy trade-off curve falls straight out of one sweep:
//!
//! ```text
//! eafl sweep --mock --selectors eafl,oort,random --seeds 1,2,3 \
//!            --scenario steady,diurnal --rounds 150 --out results/campaign
//! eafl sweep --mock --selectors budget,random --budget-j 2e4,5e4,1e5 \
//!            --seeds 1,2,3 --rounds 150 --out results/frontier
//! ```
//!
//! ## Sharded campaigns (the shard/merge protocol)
//!
//! One process is not the ceiling: a campaign can be **sharded** across
//! processes (or hosts sharing a filesystem) with zero coordination,
//! because every piece of the protocol is a pure function of the grid:
//!
//!  - **Partition** — grid cell names are deterministic
//!    (`<campaign>-<selector>-<scenario>-n<clients>-f<f>[-b<J>]-s<seed>`
//!    encodes every coordinate; the `-b` tag appears only when the
//!    budget axis is explicit); shard `I` of `N` owns exactly the
//!    cells with `fnv1a64(name) % N == I` ([`campaign::shard_of`]).
//!    `eafl sweep --shard I/N` runs just those cells.
//!  - **Manifest** — every sweep with an output directory writes
//!    `<name>.manifest.json`: the *full* grid in expansion order with a
//!    per-cell config-fingerprint hash. All shards derive it from the
//!    same grid, so their manifest bytes are identical.
//!  - **Merge** — `eafl merge <out-dir>...`
//!    ([`report::merge_dirs`]) reassembles the campaign: cells are
//!    emitted in manifest order (never shard or completion order), each
//!    cell's `<name>.config.toml` must hash to the manifest's recorded
//!    fingerprint, and missing cells fail loudly. Summaries round-trip
//!    through JSON bit-exactly, so the merged `campaign.json` /
//!    `campaign.csv` are **byte-identical** to a single-process sweep.
//!  - **Resume** — a killed shard is rerun with the same `--shard I/N`;
//!    the PR 2 resume machinery reloads its finished cells (fingerprint-
//!    checked) and recomputes only the rest. Torn files from the kill
//!    read as "not finished" and are recomputed.
//!  - **Self-orchestration** — `eafl sweep --jobs P` spawns `P` shard
//!    child processes (`--shard 0/P` … `--shard P-1/P`) over one output
//!    directory and merges when they all finish.
//!
//! `rust/tests/campaign_sharding.rs` pins the whole contract across
//! real processes: any shard count, any completion order, separate or
//! shared output directories, and kill-then-resume all produce the
//! byte-identical merged report.
//!
//! ## Failure semantics
//!
//! The campaign layer assumes a *hostile machine*, not just a hostile
//! fleet. Three pieces (new in PR 8):
//!
//!  - **Supervisor** — `eafl sweep --jobs P` runs its shard children
//!    under [`campaign::supervisor`]: children are reaped as they exit
//!    (never serially in spawn order), every child writes an atomic
//!    `<out>/shard-<I>.progress.json` heartbeat
//!    (`eafl-shard-progress-v1`: cells `done`/`owned`, a monotonic
//!    `seq`, the writer `pid`), a child whose heartbeat stops changing
//!    for `--stall-timeout-s` is killed, and crashed/stalled/killed
//!    shards are restarted up to `--max-retries` times (default 2)
//!    with deterministic exponential backoff (100 ms · 2^round, capped
//!    at 2 s). Restarts lean on the fingerprint-checked cell resume,
//!    so a retry recomputes only what the dead child didn't finish —
//!    and the merged output stays **byte-identical** to a fault-free
//!    single-process sweep. On any failure path the surviving siblings
//!    are killed and reaped: no orphan keeps writing into `--out`.
//!
//!  - **Exit codes** — `eafl` classifies its exits: `0` success; `1`
//!    internal error; `2` usage/config error (bad flags, malformed
//!    `--fault`/`--max-retries`/`--stall-timeout-s`); `3` a
//!    deterministic cell failure (retrying cannot help — the culprit
//!    cell is named on stderr and siblings are stopped); `4` retries
//!    exhausted (the culprit shards and their unfinished cells are
//!    named; rerun the same sweep to resume); `70` an injected fault
//!    crash (test-only, see below).
//!
//!  - **Quarantine** — every artifact-reading path
//!    ([`report::merge_with_detail`], the sweep resume,
//!    `eafl trace summarize`) treats a torn, truncated or
//!    fingerprint-mismatched `summary.json` / `config.toml` /
//!    manifest / trace as evidence, not a crash: the file is moved
//!    aside to `<file>.quarantine` (named on stderr via
//!    [`report::quarantine`]), and the cell is recomputed or reported.
//!    Never a panic, never a silent skip — and `eafl merge` reports
//!    **all** invalid cells in one pass with per-cell reasons.
//!
//! The machinery is testable because faults are *injected*, not
//! awaited: [`fault`] parses `--fault SPEC` / `EAFL_FAULT` into a
//! [`fault::FaultPlan`] (grammar: comma-separated clauses
//! `crash:after-cells=N`, `stall:ms=M`, `torn-write:kind=K`,
//! `corrupt:kind=K` with optional `cell=`/`shard=`/`attempt=`
//! selectors) whose sites cost one relaxed atomic load + branch when
//! unarmed — `plan_path_throughput` is unaffected. The supervisor
//! scopes clauses by restart attempt (`EAFL_FAULT_ATTEMPT`), so a
//! fault that killed attempt 0 does not re-fire on the retry; the
//! fault matrix in `rust/tests/campaign_sharding.rs` pins
//! crash/stall/torn-write/corrupt at every site converging to the
//! fault-free bytes.

pub mod aggregation;
pub mod benchkit;
pub mod campaign;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod energy;
pub mod fault;
pub mod metrics;
pub mod network;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod selection;
pub mod sim;
pub mod training;
pub mod util;

pub use config::ExperimentConfig;
pub use coordinator::Coordinator;
