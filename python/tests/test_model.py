"""L2 correctness: speech-CNN model — shapes, packing, learning signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def flat0():
    return model.init_params(jnp.uint32(7))


def _batch(key, n=20):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(k1, (n, model.INPUT_HW, model.INPUT_HW, 1), jnp.float32)
    y = jax.random.randint(k2, (n,), 0, model.NUM_CLASSES, jnp.int32)
    return x, y


def test_param_count_matches_spec(flat0):
    assert flat0.shape == (model.PARAM_COUNT,)
    total = sum(int(np.prod(s)) for _, s in model.PARAM_SPEC)
    assert total == model.PARAM_COUNT == 69123


def test_flatten_unflatten_roundtrip(flat0):
    params = model.unflatten(flat0)
    assert set(params) == {n for n, _ in model.PARAM_SPEC}
    for name, shape in model.PARAM_SPEC:
        assert params[name].shape == shape
    np.testing.assert_array_equal(model.flatten(params), flat0)


def test_init_deterministic_and_seed_sensitive():
    a = model.init_params(jnp.uint32(1))
    b = model.init_params(jnp.uint32(1))
    c = model.init_params(jnp.uint32(2))
    np.testing.assert_array_equal(a, b)
    assert float(jnp.max(jnp.abs(a - c))) > 0.0


def test_biases_init_to_zero(flat0):
    params = model.unflatten(flat0)
    for name, _ in model.PARAM_SPEC:
        if name.endswith("_b"):
            np.testing.assert_array_equal(params[name], 0.0)


def test_forward_shapes(flat0):
    x, _ = _batch(0)
    logits = model.forward(flat0, x)
    assert logits.shape == (20, model.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_log_c(flat0):
    """Fresh random init ~= uniform predictor => mean loss ~ log(35)."""
    x, y = _batch(1, n=64)
    _, loss = model.eval_step(flat0, x, y)
    assert 0.3 * np.log(35) < float(loss) < 4.0 * np.log(35)


def test_train_step_decreases_loss(flat0):
    x, y = _batch(2)
    flat, lr = flat0, jnp.float32(0.05)
    flat, first, _ = model.train_step(flat, x, y, lr)
    for _ in range(15):
        flat, loss, per_ex = model.train_step(flat, x, y, lr)
    assert float(loss) < float(first) * 0.7
    assert per_ex.shape == (20,)
    np.testing.assert_allclose(float(jnp.mean(per_ex)), float(loss), rtol=1e-5)


def test_train_step_overfits_tiny_batch(flat0):
    """Real learning signal: memorize 8 samples to near-zero loss."""
    x, y = _batch(3, n=20)
    flat = flat0
    for _ in range(120):
        flat, loss, _ = model.train_step(flat, x, y, jnp.float32(0.1))
    assert float(loss) < 0.2
    correct, _ = model.eval_step(flat, x, y)
    assert int(correct) >= 18


def test_eval_step_counts_correct(flat0):
    x, y = _batch(4, n=128)
    correct, loss = model.eval_step(flat0, x, y)
    assert 0 <= int(correct) <= 128
    assert float(loss) > 0.0


def test_per_example_losses_nonnegative(flat0):
    x, y = _batch(5)
    per_ex = model.per_example_losses(flat0, x, y)
    assert per_ex.shape == (20,)
    assert bool(jnp.all(per_ex >= 0.0))


def test_gradient_is_descent_direction(flat0):
    """One SGD step with small lr strictly reduces loss on the same batch."""
    x, y = _batch(6)
    flat1, loss0, _ = model.train_step(flat0, x, y, jnp.float32(0.01))
    _, loss1, _ = model.train_step(flat1, x, y, jnp.float32(0.01))
    assert float(loss1) < float(loss0)
