//! Device substrate: hardware tiers (paper Table 2), battery state, and
//! the AI-Benchmark-substitute trace generator (DESIGN.md §2).

mod battery;
mod tier;
mod traces;

pub use battery::{Battery, BatteryState};
pub use tier::{DeviceSpec, Tier, ALL_TIERS};
pub use traces::{generate_profiles, DeviceProfile};
