//! YoGi server optimizer (Reddi et al., "Adaptive Federated
//! Optimization"; used in production FL per Ramaswamy et al. — the
//! paper's §5 aggregation algorithm).
//!
//! Treats the round's (weighted-mean-update − global) difference as a
//! pseudo-gradient Δ and applies the YoGi adaptive rule:
//!
//!   m ←  β₁ m + (1−β₁) Δ
//!   v ←  v − (1−β₂) Δ² · sign(v − Δ²)        (YoGi's additive variant)
//!   w ←  w + η · m / (√v + τ)
//!
//! YoGi's v-update is the key difference from Adam: v moves toward Δ²
//! additively, which keeps the effective LR stable under the sparse /
//! heterogeneous client updates typical of FL.

use anyhow::{ensure, Result};

use super::{weighted_mean, Aggregator, ClientUpdate};

/// YoGi state: first/second moment per parameter.
pub struct Yogi {
    m: Vec<f32>,
    v: Vec<f32>,
    /// Server learning rate η.
    pub eta: f32,
    pub beta1: f32,
    pub beta2: f32,
    /// Adaptivity floor τ.
    pub tau: f32,
    scratch: Vec<f32>,
}

impl Yogi {
    pub fn new(param_count: usize, eta: f32) -> Self {
        Self {
            m: vec![0.0; param_count],
            // Reddi et al. initialize v to τ² (adaptivity floor squared).
            v: vec![1e-6; param_count],
            eta,
            beta1: 0.9,
            beta2: 0.99,
            tau: 1e-3,
            scratch: vec![0.0; param_count],
        }
    }
}

impl Aggregator for Yogi {
    fn aggregate(&mut self, global: &mut [f32], updates: &[ClientUpdate]) -> Result<()> {
        ensure!(!updates.is_empty(), "YoGi needs at least one update");
        ensure!(global.len() == self.m.len(), "YoGi state/param length mismatch");
        for u in updates {
            ensure!(u.params.len() == global.len(), "update length mismatch");
        }
        weighted_mean(updates, &mut self.scratch);
        for i in 0..global.len() {
            let delta = self.scratch[i] - global[i]; // pseudo-gradient
            let d2 = delta * delta;
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * delta;
            self.v[i] -= (1.0 - self.beta2) * d2 * (self.v[i] - d2).signum();
            global[i] += self.eta * self.m[i] / (self.v[i].max(0.0).sqrt() + self.tau);
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "yogi"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(params: Vec<f32>) -> ClientUpdate {
        ClientUpdate { params, weight: 1.0 }
    }

    #[test]
    fn moves_toward_client_consensus() {
        let mut y = Yogi::new(2, 0.5);
        let mut global = vec![0.0, 0.0];
        for _ in 0..200 {
            y.aggregate(&mut global, &[upd(vec![1.0, -1.0])]).unwrap();
        }
        assert!(global[0] > 0.5, "global {global:?} should approach +1");
        assert!(global[1] < -0.5, "global {global:?} should approach -1");
    }

    #[test]
    fn zero_delta_is_stationary_with_zero_momentum() {
        let mut y = Yogi::new(1, 0.5);
        let mut global = vec![2.0];
        y.aggregate(&mut global, &[upd(vec![2.0])]).unwrap();
        // Δ = 0 ⇒ m stays 0 ⇒ no movement.
        assert!((global[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn momentum_carries_past_updates() {
        let mut y = Yogi::new(1, 0.1);
        let mut global = vec![0.0];
        y.aggregate(&mut global, &[upd(vec![1.0])]).unwrap();
        let after_first = global[0];
        // Client now agrees with server; momentum still pushes.
        let frozen = global.clone();
        y.aggregate(&mut global, &[upd(frozen)]).unwrap();
        assert!(global[0] > after_first);
    }

    #[test]
    fn v_stays_nonnegative_under_alternating_deltas() {
        let mut y = Yogi::new(1, 0.1);
        let mut global = vec![0.0];
        for i in 0..100 {
            let target = if i % 2 == 0 { 5.0 } else { -5.0 };
            y.aggregate(&mut global, &[upd(vec![target])]).unwrap();
            assert!(y.v[0] >= 0.0, "v must stay non-negative");
            assert!(global[0].is_finite());
        }
    }

    #[test]
    fn rejects_mismatched_lengths() {
        let mut y = Yogi::new(3, 0.1);
        let mut global = vec![0.0; 2];
        assert!(y.aggregate(&mut global, &[upd(vec![0.0, 0.0])]).is_err());
    }
}
