//! AI-Benchmark-substitute trace generator (DESIGN.md §2).
//!
//! The paper assigns learners "real-world device profiles from the AI
//! Benchmark" and clusters them into the three Table-2 tiers. We sample
//! tiers from configured fractions and draw each client's training
//! throughput (samples/sec) around its tier's relative speed with
//! log-normal jitter — preserving the property the selection algorithms
//! care about: a heavy-tailed, tier-correlated speed distribution.

use crate::util::rng::Rng;

use crate::config::DeviceConfig;

use super::tier::{DeviceSpec, Tier, ALL_TIERS};

/// Training throughput of the LOW tier, samples/second. Other tiers
/// scale by Table 2's perf-derived relative speed. The absolute number
/// anchors round durations at the few-minutes scale of on-device
/// ResNet training (paper's Fig. 4b; ~0.5 samples/s on a low-end SoC),
/// which in turn puts 500-round experiments at the tens-of-hours
/// wall-clock scale of the paper's Figs. 3-4 x-axes.
pub const LOW_TIER_SAMPLES_PER_SEC: f64 = 0.5;

/// Per-client intra-tier speed jitter (log-normal sigma).
const SPEED_SIGMA: f64 = 0.25;

/// Static per-client device profile.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub tier: Tier,
    pub spec: DeviceSpec,
    /// Local-training throughput, samples/second.
    pub samples_per_sec: f64,
    /// Initial battery charge as a fraction of capacity.
    pub init_battery_frac: f64,
    /// Whether this (unselected) device runs in the busy/normal-usage
    /// background state rather than idle.
    pub background_busy: bool,
}

/// Deterministically generate `n` device profiles from the config seed.
pub fn generate_profiles(cfg: &DeviceConfig, n: usize) -> Vec<DeviceProfile> {
    let mut rng = Rng::seed_from_u64(cfg.seed);
    (0..n)
        .map(|_| {
            let tier = sample_tier(&mut rng, &cfg.tier_fractions);
            let spec = DeviceSpec::for_tier(tier);
            let samples_per_sec =
                LOW_TIER_SAMPLES_PER_SEC * spec.relative_speed() * rng.lognormal(1.0, SPEED_SIGMA);
            let init_battery_frac =
                rng.gen_range_f64(cfg.min_init_battery, cfg.max_init_battery);
            let background_busy = rng.gen_bool(cfg.busy_probability);
            DeviceProfile { tier, spec, samples_per_sec, init_battery_frac, background_busy }
        })
        .collect()
}

fn sample_tier(rng: &mut Rng, fractions: &[f64; 3]) -> Tier {
    let r: f64 = rng.gen_f64();
    let mut acc = 0.0;
    for (tier, frac) in ALL_TIERS.iter().zip(fractions) {
        acc += frac;
        if r < acc {
            return *tier;
        }
    }
    Tier::Low
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_under_seed() {
        let cfg = DeviceConfig::default();
        let a = generate_profiles(&cfg, 30);
        let b = generate_profiles(&cfg, 30);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tier, y.tier);
            assert_eq!(x.samples_per_sec, y.samples_per_sec);
            assert_eq!(x.init_battery_frac, y.init_battery_frac);
        }
    }

    #[test]
    fn tier_fractions_approximately_respected() {
        let mut cfg = DeviceConfig::default();
        cfg.tier_fractions = [0.5, 0.3, 0.2];
        let profiles = generate_profiles(&cfg, 5000);
        let frac = |t: Tier| {
            profiles.iter().filter(|p| p.tier == t).count() as f64 / profiles.len() as f64
        };
        assert!((frac(Tier::High) - 0.5).abs() < 0.05);
        assert!((frac(Tier::Mid) - 0.3).abs() < 0.05);
        assert!((frac(Tier::Low) - 0.2).abs() < 0.05);
    }

    #[test]
    fn speeds_correlate_with_tier() {
        let cfg = DeviceConfig::default();
        let profiles = generate_profiles(&cfg, 3000);
        let mean_speed = |t: Tier| {
            let v: Vec<f64> = profiles
                .iter()
                .filter(|p| p.tier == t)
                .map(|p| p.samples_per_sec)
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(mean_speed(Tier::High) > mean_speed(Tier::Mid));
        assert!(mean_speed(Tier::Mid) > mean_speed(Tier::Low));
    }

    #[test]
    fn battery_within_configured_range() {
        let mut cfg = DeviceConfig::default();
        cfg.min_init_battery = 0.4;
        cfg.max_init_battery = 0.9;
        for p in generate_profiles(&cfg, 500) {
            assert!((0.4..=0.9).contains(&p.init_battery_frac));
            assert!(p.samples_per_sec > 0.0);
        }
    }
}
