"""AOT compile path: lower the L2 jax functions (with their L1 Pallas
kernels inlined) to HLO TEXT artifacts for the Rust runtime.

HLO *text* — NOT ``lowered.compile()`` / ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONLY here, at build time (`make artifacts`); the Rust binary
is self-contained afterwards.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

#: Batch sizes baked into the artifacts. XLA executables are
#: shape-monomorphic, so the Rust side pads partial batches up to these.
TRAIN_BATCH = 20   # paper §5: batch size 20
EVAL_BATCH = 128   # held-out evaluation, larger batch amortizes dispatch


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts():
    """Return {filename: hlo_text} for every exported entry point."""
    p = _spec((model.PARAM_COUNT,), jnp.float32)
    xt = _spec((TRAIN_BATCH, model.INPUT_HW, model.INPUT_HW, 1), jnp.float32)
    yt = _spec((TRAIN_BATCH,), jnp.int32)
    xe = _spec((EVAL_BATCH, model.INPUT_HW, model.INPUT_HW, 1), jnp.float32)
    ye = _spec((EVAL_BATCH,), jnp.int32)
    lr = _spec((), jnp.float32)
    seed = _spec((), jnp.uint32)

    return {
        "train_step.hlo.txt": to_hlo_text(
            jax.jit(model.train_step).lower(p, xt, yt, lr)
        ),
        "eval_step.hlo.txt": to_hlo_text(
            jax.jit(model.eval_step).lower(p, xe, ye)
        ),
        "init_params.hlo.txt": to_hlo_text(
            jax.jit(model.init_params).lower(seed)
        ),
    }


def manifest() -> dict:
    """Shape/packing contract consumed by rust/src/runtime/artifacts.rs."""
    return {
        "param_count": model.PARAM_COUNT,
        "num_classes": model.NUM_CLASSES,
        "input_hw": model.INPUT_HW,
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "param_spec": [
            {"name": n, "shape": list(s)} for n, s in model.PARAM_SPEC
        ],
        "artifacts": {
            "train_step": "train_step.hlo.txt",
            "eval_step": "eval_step.hlo.txt",
            "init_params": "init_params.hlo.txt",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    arts = build_artifacts()
    for fname, text in arts.items():
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:12]
        print(f"wrote {path} ({len(text)} chars, sha256:{digest})")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote {mpath} (P={model.PARAM_COUNT})")


if __name__ == "__main__":
    main()
