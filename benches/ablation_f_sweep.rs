//! Ablation bench over EAFL's Eq. (1) blend weight f — the design
//! choice DESIGN.md calls out (§3.1 Q2 trade-off). f = 1 degenerates to
//! Oort-like utility chasing, f = 0 to pure battery chasing; the paper
//! operates at f = 0.25.
//!
//! Built on the campaign runner: the whole sweep is ONE campaign whose
//! f axis spans the blend, run across threads — the bench therefore
//! also measures the campaign layer's parallel speedup over the
//! sequential equivalent.
//!
//! Run: cargo bench --bench ablation_f_sweep

use eafl::benchkit::Bench;
use eafl::campaign::{run_campaign, CampaignGrid, CampaignSpec};
use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::runtime::MockRuntime;

const F_VALUES: [f64; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];
const ROUNDS: usize = 150;

fn spec(jobs: usize) -> CampaignSpec {
    let mut cfg = ExperimentConfig::paper_default(SelectorKind::Eafl);
    cfg.federation.rounds = ROUNDS;
    cfg.federation.num_clients = 100;
    cfg.devices.min_init_battery = 0.10;
    cfg.devices.max_init_battery = 0.6;
    let mut spec = CampaignSpec::new("f-ablation", cfg);
    spec.grid = CampaignGrid {
        selectors: vec![SelectorKind::Eafl],
        scenarios: Vec::new(),
        seeds: vec![7],
        f_values: F_VALUES.to_vec(),
        client_counts: Vec::new(),
    };
    spec.jobs = jobs;
    spec
}

fn main() {
    let runtime = MockRuntime::default();
    let mut bench = Bench::heavy();

    let sequential = bench.run_once(
        &format!("f-sweep campaign jobs=1 ({} runs x {ROUNDS} rounds, mock)", F_VALUES.len()),
        || run_campaign(&spec(1), &runtime, None).unwrap(),
    );
    let jobs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let parallel = bench.run_once(
        &format!("f-sweep campaign jobs={jobs} (same grid)"),
        || run_campaign(&spec(jobs), &runtime, None).unwrap(),
    );

    // Campaign determinism: job count must not move a single number.
    for (a, b) in sequential.runs.iter().zip(&parallel.runs) {
        assert_eq!(a.summary.final_accuracy, b.summary.final_accuracy);
        assert_eq!(a.summary.total_dropouts, b.summary.total_dropouts);
        assert_eq!(a.summary.wall_clock_h, b.summary.wall_clock_h);
    }

    println!("\n=== Eq. (1) f ablation ===");
    println!(
        "{:<6} {:>9} {:>10} {:>10} {:>13} {:>12}",
        "f", "acc", "dropouts", "fairness", "mean_rnd(s)", "energy(kJ)"
    );
    for r in &sequential.runs {
        let s = &r.summary;
        println!(
            "{:<6} {:>9.4} {:>10} {:>10.3} {:>13.1} {:>12.1}",
            r.f,
            s.final_accuracy,
            s.total_dropouts,
            s.final_fairness,
            s.mean_round_duration_s,
            s.total_fl_energy_j / 1000.0
        );
    }

    // Shape check: battery-heavier blends (smaller f) must not drop
    // MORE clients than the pure-utility extreme.
    let d0 = sequential.runs.first().unwrap().summary.total_dropouts; // f = 0
    let d1 = sequential.runs.last().unwrap().summary.total_dropouts; // f = 1
    println!(
        "\nshape: dropouts(f=0)={d0} <= dropouts(f=1)={d1}: {}",
        if d0 <= d1 { "HOLDS" } else { "VIOLATED" }
    );
}
