//! Client registry: per-client device + link + battery + data shard +
//! utility statistics. The coordinator's source of truth — selectors
//! see read-only [`Candidate`] projections built here (paper Fig. 2:
//! the coordinator "registers each client's profile ... and forwards
//! the characteristics to the server running EAFL").
//!
//! ## The million-client fast path
//!
//! At deployment scale (the regimes AutoFL and global-energy-budget FL
//! operate in) the per-round cost of this module is what bounds the
//! whole simulator, so the registry is structured as two synchronized
//! views:
//!
//!  - `clients: Vec<ClientState>` — the authoritative array-of-structs
//!    state (device, link, battery, shard, stats). Private: every
//!    mutation goes through [`Registry::battery_mut`] /
//!    [`Registry::stats_mut`] guards (or the convenience wrappers), so
//!    the derived views below can never go stale.
//!  - [`ClientPool`] — a struct-of-arrays cache of everything the plan
//!    path reads per round. The *static* projections (link transfer
//!    times, compute time, projected round energy/drain — invariant
//!    under a static network) are computed once at build time and only
//!    recomputed for a client whose device/link state actually changes
//!    ([`Registry::refresh_projection`]); the *dynamic* mirrors
//!    (battery fraction, liveness, selection stats) are updated by the
//!    mutation guards.
//!  - [`PoolAggregates`] — population sums maintained incrementally at
//!    the mutation sites, so the per-round metrics row is O(1) instead
//!    of five O(N) scans: alive count, Σ battery fraction over alive
//!    clients, Σ FL energy, and the Σc / Σc² moments Jain's fairness
//!    index needs. Float sums use [`FixedSum`] (exact i128 fixed-point)
//!    so the incremental state is *bit-identical* to a brute-force
//!    rebuild after any mutation sequence — see
//!    `rust/tests/pool_aggregates.rs`.
//!
//! [`Registry::fill_candidates`] filters the pool into a caller-owned
//! candidate arena with zero allocation and zero energy-model
//! recomputation; the allocating [`Registry::candidates`] recomputes
//! everything from the AoS state and is kept as the reference (and as
//! the pre-refactor baseline in `benches/plan_path_throughput.rs`).

use std::ops::{Deref, DerefMut};

use crate::config::ExperimentConfig;
use crate::data::{partition_clients, ClientShard};
use crate::device::{generate_profiles, Battery, DeviceProfile};
use crate::energy::RoundEnergy;
use crate::network::{generate_links, LinkProfile};
use crate::selection::Candidate;
use crate::util::fixed::FixedSum;

/// Mutable per-client selection statistics.
#[derive(Debug, Clone, Default)]
pub struct ClientStats {
    /// Last measured Oort statistical utility (None = unexplored).
    pub stat_util: Option<f64>,
    /// Last measured participation duration, seconds.
    pub measured_duration_s: Option<f64>,
    /// Round of last selection (0 = never).
    pub last_selected_round: u64,
    pub times_selected: u64,
    pub times_completed: u64,
    /// Consecutive deadline misses (Oort-style blacklist trigger).
    pub consecutive_misses: u32,
    /// Client is ineligible until this round (exclusive).
    pub banned_until_round: u64,
}

/// One registered client.
#[derive(Debug, Clone)]
pub struct ClientState {
    pub id: usize,
    pub device: DeviceProfile,
    pub link: LinkProfile,
    pub battery: Battery,
    pub shard: ClientShard,
    pub stats: ClientStats,
}

impl ClientState {
    /// Seconds of local compute for `local_steps` steps of `batch`.
    pub fn compute_secs(&self, local_steps: usize, batch: usize) -> f64 {
        (local_steps * batch) as f64 / self.device.samples_per_sec
    }

    /// Estimated full-round duration: download + compute + upload.
    pub fn expected_duration_s(
        &self,
        payload_bytes: usize,
        local_steps: usize,
        batch: usize,
    ) -> f64 {
        self.link.download_secs(payload_bytes)
            + self.compute_secs(local_steps, batch)
            + self.link.upload_secs(payload_bytes)
    }

    /// Projected energy of the next round's participation.
    pub fn projected_energy(
        &self,
        payload_bytes: usize,
        local_steps: usize,
        batch: usize,
    ) -> RoundEnergy {
        RoundEnergy::for_participation(
            &self.device.spec,
            &self.link,
            payload_bytes,
            self.compute_secs(local_steps, batch),
        )
    }
}

/// Struct-of-arrays projection cache — everything the plan path reads,
/// one contiguous array per field (all indexed by client id).
///
/// Invariant: entry `i` always equals what a fresh recomputation from
/// `clients[i]` (with the registry's build-time `local_steps` / `batch`
/// / `payload_bytes`) would produce. Static fields change only through
/// [`Registry::refresh_projection`]; dynamic fields are written by the
/// mutation guards.
#[derive(Debug, Clone, Default)]
pub struct ClientPool {
    // --- static projections (build time / refresh_projection) ---
    pub download_s: Vec<f64>,
    pub compute_s: Vec<f64>,
    pub upload_s: Vec<f64>,
    pub expected_duration_s: Vec<f64>,
    /// Total projected participation energy for one round, joules.
    pub round_energy_j: Vec<f64>,
    /// `round_energy_j / capacity` — the candidate's projected drain.
    pub drain_frac: Vec<f64>,
    // --- dynamic mirrors (mutation guards) ---
    pub alive: Vec<bool>,
    pub battery_frac: Vec<f64>,
    pub charge_j: Vec<f64>,
    pub stat_util: Vec<Option<f64>>,
    pub measured_duration_s: Vec<Option<f64>>,
    pub last_selected_round: Vec<u64>,
    pub banned_until_round: Vec<u64>,
}

impl ClientPool {
    fn with_capacity(n: usize) -> Self {
        let mut p = Self::default();
        macro_rules! reserve {
            ($($f:ident),*) => { $( p.$f.reserve_exact(n); )* };
        }
        reserve!(
            download_s,
            compute_s,
            upload_s,
            expected_duration_s,
            round_energy_j,
            drain_frac,
            alive,
            battery_frac,
            charge_j,
            stat_util,
            measured_duration_s,
            last_selected_round,
            banned_until_round
        );
        p
    }
}

/// Population aggregates maintained incrementally at every mutation
/// site; the O(1) source for the per-round metrics row.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolAggregates {
    /// Clients whose battery is currently alive.
    pub alive: usize,
    /// Σ battery fraction over *alive* clients (exact fixed-point).
    pub battery_frac_sum: FixedSum,
    /// Σ cumulative FL energy over all clients, joules (exact).
    pub fl_energy_j: FixedSum,
    /// Σ times_selected over all clients (Jain numerator moment).
    pub selected_sum: u64,
    /// Σ times_selected² over all clients (Jain denominator moment).
    pub selected_sum_sq: u128,
}

impl PoolAggregates {
    /// Brute-force rebuild from per-client state — the reference the
    /// incremental state must equal *exactly* (FixedSum makes the float
    /// sums order-independent, so `==` is the right comparison).
    pub fn recompute(registry: &Registry) -> Self {
        let mut agg = Self::default();
        for c in registry.clients() {
            if c.battery.is_alive() {
                agg.alive += 1;
                agg.battery_frac_sum.add(c.battery.fraction());
            }
            agg.fl_energy_j.add(c.battery.fl_energy_j);
            agg.selected_sum += c.stats.times_selected;
            agg.selected_sum_sq += (c.stats.times_selected as u128).pow(2);
        }
        agg
    }
}

/// The full client population.
pub struct Registry {
    clients: Vec<ClientState>,
    pool: ClientPool,
    aggregates: PoolAggregates,
    /// Model payload exchanged each round (flat params as f32 bytes).
    /// Private like `clients`: it feeds every cached projection, so
    /// mutating it without a pool rebuild would silently stale the
    /// transfer-time and energy entries.
    payload_bytes: usize,
    /// Local steps the cached projections were built for.
    local_steps: usize,
    /// Batch size the cached projections were built for.
    batch: usize,
}

impl Registry {
    /// Build the population from the experiment config: device traces,
    /// link traces and the non-IID partition are all seeded and merged
    /// 1:1 by client index. Per-client projections are cached in the
    /// SoA pool for the config's `training.local_steps` ×
    /// `data.batch_size` workload.
    pub fn build(cfg: &ExperimentConfig, num_classes: usize, param_count: usize) -> Self {
        let n = cfg.federation.num_clients;
        let devices = generate_profiles(&cfg.devices, n);
        let links = generate_links(&cfg.network, n);
        let partition = partition_clients(&cfg.data, num_classes, n);
        let clients: Vec<ClientState> = devices
            .into_iter()
            .zip(links)
            .zip(partition.shards)
            .enumerate()
            .map(|(id, ((device, link), shard))| {
                let battery = Battery::new(&device.spec, device.init_battery_frac);
                ClientState { id, device, link, battery, shard, stats: ClientStats::default() }
            })
            .collect();
        let mut registry = Self {
            clients,
            // Placeholder only: rebuild_pool constructs the real pool.
            pool: ClientPool::default(),
            aggregates: PoolAggregates::default(),
            payload_bytes: param_count * 4,
            local_steps: cfg.training.local_steps,
            batch: cfg.data.batch_size,
        };
        registry.rebuild_pool();
        registry
    }

    /// Populate the SoA pool and the aggregates from scratch.
    fn rebuild_pool(&mut self) {
        let (payload, steps, batch) = (self.payload_bytes, self.local_steps, self.batch);
        let mut pool = ClientPool::with_capacity(self.clients.len());
        for c in &self.clients {
            let energy = c.projected_energy(payload, steps, batch).total();
            pool.download_s.push(c.link.download_secs(payload));
            pool.compute_s.push(c.compute_secs(steps, batch));
            pool.upload_s.push(c.link.upload_secs(payload));
            pool.expected_duration_s.push(c.expected_duration_s(payload, steps, batch));
            pool.round_energy_j.push(energy);
            pool.drain_frac.push(energy / c.battery.capacity_joules());
            pool.alive.push(c.battery.is_alive());
            pool.battery_frac.push(c.battery.fraction());
            pool.charge_j.push(c.battery.charge_joules());
            pool.stat_util.push(c.stats.stat_util);
            pool.measured_duration_s.push(c.stats.measured_duration_s);
            pool.last_selected_round.push(c.stats.last_selected_round);
            pool.banned_until_round.push(c.stats.banned_until_round);
        }
        self.pool = pool;
        self.aggregates = PoolAggregates::recompute(self);
    }

    /// Recompute one client's *static* projections after its device or
    /// link profile changed (a scenario hot-swapping hardware, a future
    /// link-migration event). The static network assumption makes this
    /// the only place static pool entries are ever rewritten — O(1) per
    /// changed client instead of an O(N) rebuild.
    pub fn refresh_projection(&mut self, id: usize) {
        let (payload, steps, batch) = (self.payload_bytes, self.local_steps, self.batch);
        let c = &self.clients[id];
        let energy = c.projected_energy(payload, steps, batch).total();
        let download_s = c.link.download_secs(payload);
        let compute_s = c.compute_secs(steps, batch);
        let upload_s = c.link.upload_secs(payload);
        let expected = c.expected_duration_s(payload, steps, batch);
        let drain_frac = energy / c.battery.capacity_joules();
        let p = &mut self.pool;
        p.download_s[id] = download_s;
        p.compute_s[id] = compute_s;
        p.upload_s[id] = upload_s;
        p.expected_duration_s[id] = expected;
        p.round_energy_j[id] = energy;
        p.drain_frac[id] = drain_frac;
    }

    /// Mutable access to a client's link profile; the projection cache
    /// entry is refreshed when the guard drops.
    pub fn link_mut(&mut self, id: usize) -> LinkMut<'_> {
        LinkMut { registry: self, id }
    }

    pub fn len(&self) -> usize {
        self.clients.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    /// Read-only view of one client.
    pub fn client(&self, id: usize) -> &ClientState {
        &self.clients[id]
    }

    /// Read-only view of the whole population.
    pub fn clients(&self) -> &[ClientState] {
        &self.clients
    }

    /// Model payload exchanged each round (flat params as f32 bytes).
    pub fn payload_bytes(&self) -> usize {
        self.payload_bytes
    }

    /// The SoA projection cache (read-only; kept in sync by the
    /// mutation guards).
    pub fn pool(&self) -> &ClientPool {
        &self.pool
    }

    /// The incrementally maintained population aggregates.
    pub fn aggregates(&self) -> &PoolAggregates {
        &self.aggregates
    }

    // --- mutation guards ---------------------------------------------------

    /// Mutable access to a client's battery. Aggregates and pool
    /// mirrors are re-synced when the guard drops, so arbitrary battery
    /// mutations (drain, charge, revive) stay consistent.
    pub fn battery_mut(&mut self, id: usize) -> BatteryMut<'_> {
        let b = &self.clients[id].battery;
        BatteryMut {
            was_alive: b.is_alive(),
            old_frac: b.fraction(),
            old_fl_energy: b.fl_energy_j,
            registry: self,
            id,
        }
    }

    /// Mutable access to a client's selection statistics. The Jain
    /// moments (Σc, Σc²) and pool mirrors are re-synced on drop.
    pub fn stats_mut(&mut self, id: usize) -> StatsMut<'_> {
        let old_times_selected = self.clients[id].stats.times_selected;
        StatsMut { old_times_selected, registry: self, id }
    }

    /// Drain `energy_j` of FL work from client `id` at simulation time
    /// `now_h`; returns the supplied fraction (see
    /// [`Battery::drain_fl`]).
    pub fn drain_fl(&mut self, id: usize, energy_j: f64, now_h: f64) -> f64 {
        self.battery_mut(id).drain_fl(energy_j, now_h)
    }

    /// Drain background (idle/busy) energy from client `id`.
    pub fn drain_background(&mut self, id: usize, energy_j: f64, now_h: f64) -> f64 {
        self.battery_mut(id).drain_background(energy_j, now_h)
    }

    /// Add charge to client `id` (revives a dead battery with charge).
    pub fn charge_add(&mut self, id: usize, energy_j: f64) {
        self.battery_mut(id).charge_add(energy_j);
    }

    /// Recharge client `id` to `fraction` of capacity and revive it.
    pub fn recharge_to(&mut self, id: usize, fraction: f64) {
        self.battery_mut(id).recharge_to(fraction);
    }

    fn sync_battery(&mut self, id: usize, was_alive: bool, old_frac: f64, old_fl: f64) {
        let b = &self.clients[id].battery;
        let (alive, frac, fl) = (b.is_alive(), b.fraction(), b.fl_energy_j);
        let agg = &mut self.aggregates;
        if was_alive {
            agg.alive -= 1;
            agg.battery_frac_sum.sub(old_frac);
        }
        if alive {
            agg.alive += 1;
            agg.battery_frac_sum.add(frac);
        }
        agg.fl_energy_j.sub(old_fl);
        agg.fl_energy_j.add(fl);
        self.pool.alive[id] = alive;
        self.pool.battery_frac[id] = frac;
        self.pool.charge_j[id] = b.charge_joules();
    }

    fn sync_stats(&mut self, id: usize, old_times_selected: u64) {
        let s = &self.clients[id].stats;
        let agg = &mut self.aggregates;
        agg.selected_sum = agg.selected_sum - old_times_selected + s.times_selected;
        agg.selected_sum_sq = agg.selected_sum_sq - (old_times_selected as u128).pow(2)
            + (s.times_selected as u128).pow(2);
        self.pool.stat_util[id] = s.stat_util;
        self.pool.measured_duration_s[id] = s.measured_duration_s;
        self.pool.last_selected_round[id] = s.last_selected_round;
        self.pool.banned_until_round[id] = s.banned_until_round;
    }

    // --- O(1) population metrics (incremental aggregates) ------------------

    /// Clients currently alive (battery not dead). O(1).
    pub fn alive_count(&self) -> usize {
        self.aggregates.alive
    }

    /// Clients whose battery has died so far (Fig. 4a's cumulative
    /// drop-out count). O(1).
    pub fn dead_count(&self) -> usize {
        self.len() - self.alive_count()
    }

    /// Mean battery fraction over alive clients; **0.0 when none are
    /// alive** (an exhausted fleet reports zero usable charge). O(1).
    pub fn mean_battery_alive(&self) -> f64 {
        if self.aggregates.alive == 0 {
            0.0
        } else {
            self.aggregates.battery_frac_sum.value() / self.aggregates.alive as f64
        }
    }

    /// Total FL energy drawn across the population, joules. O(1).
    pub fn total_fl_energy_j(&self) -> f64 {
        self.aggregates.fl_energy_j.value()
    }

    /// Per-client selection counts (allocating; kept for tests and
    /// offline analysis — the metrics row reads the Jain moments from
    /// [`Registry::aggregates`] instead).
    pub fn selection_counts(&self) -> Vec<u64> {
        self.clients.iter().map(|c| c.stats.times_selected).collect()
    }

    // --- candidate construction --------------------------------------------

    /// Fast path: filter eligible clients into `out` (cleared first)
    /// straight from the SoA pool — no allocation in steady state, no
    /// energy-model recomputation. `available` gates on the scenario's
    /// availability model; eligibility is alive ∧ above the battery
    /// floor ∧ not blacklisted. Produces exactly what
    /// [`Registry::candidates`] (with the registry's build-time
    /// steps/batch) followed by an availability `retain` would.
    pub fn fill_candidates<F: FnMut(usize) -> bool>(
        &self,
        round: u64,
        min_battery_frac: f64,
        mut available: F,
        out: &mut Vec<Candidate>,
    ) {
        out.clear();
        let p = &self.pool;
        for id in 0..self.clients.len() {
            if !p.alive[id]
                || p.battery_frac[id] <= min_battery_frac
                || p.banned_until_round[id] > round
                || !available(id)
            {
                continue;
            }
            out.push(Candidate {
                id,
                stat_util: p.stat_util[id],
                measured_duration_s: p.measured_duration_s[id],
                expected_duration_s: p.expected_duration_s[id],
                last_selected_round: p.last_selected_round[id],
                battery_frac: p.battery_frac[id],
                projected_drain_frac: p.drain_frac[id],
            });
        }
    }

    /// Reference path: build selector candidates by recomputing every
    /// projection from the AoS state. Semantically identical to
    /// [`Registry::fill_candidates`] when called with the registry's
    /// build-time `local_steps`/`batch`; kept allocating and
    /// recomputing on purpose as the property-test reference and the
    /// pre-refactor baseline in `benches/plan_path_throughput.rs`.
    pub fn candidates(
        &self,
        round: u64,
        min_battery_frac: f64,
        local_steps: usize,
        batch: usize,
    ) -> Vec<Candidate> {
        self.clients
            .iter()
            .filter(|c| {
                c.battery.is_alive()
                    && c.battery.fraction() > min_battery_frac
                    && c.stats.banned_until_round <= round
            })
            .map(|c| {
                let energy =
                    c.projected_energy(self.payload_bytes, local_steps, batch).total();
                Candidate {
                    id: c.id,
                    stat_util: c.stats.stat_util,
                    measured_duration_s: c.stats.measured_duration_s,
                    expected_duration_s: c.expected_duration_s(
                        self.payload_bytes,
                        local_steps,
                        batch,
                    ),
                    last_selected_round: c.stats.last_selected_round,
                    battery_frac: c.battery.fraction(),
                    projected_drain_frac: energy / c.battery.capacity_joules(),
                }
            })
            .collect()
    }
}

/// Guard for battery mutation: dereferences to [`Battery`]; re-syncs
/// the pool mirrors and aggregates when dropped.
pub struct BatteryMut<'a> {
    registry: &'a mut Registry,
    id: usize,
    was_alive: bool,
    old_frac: f64,
    old_fl_energy: f64,
}

impl Deref for BatteryMut<'_> {
    type Target = Battery;
    fn deref(&self) -> &Battery {
        &self.registry.clients[self.id].battery
    }
}

impl DerefMut for BatteryMut<'_> {
    fn deref_mut(&mut self) -> &mut Battery {
        &mut self.registry.clients[self.id].battery
    }
}

impl Drop for BatteryMut<'_> {
    fn drop(&mut self) {
        self.registry.sync_battery(self.id, self.was_alive, self.old_frac, self.old_fl_energy);
    }
}

/// Guard for stats mutation: dereferences to [`ClientStats`]; re-syncs
/// the Jain moments and pool mirrors when dropped.
pub struct StatsMut<'a> {
    registry: &'a mut Registry,
    id: usize,
    old_times_selected: u64,
}

impl Deref for StatsMut<'_> {
    type Target = ClientStats;
    fn deref(&self) -> &ClientStats {
        &self.registry.clients[self.id].stats
    }
}

impl DerefMut for StatsMut<'_> {
    fn deref_mut(&mut self) -> &mut ClientStats {
        &mut self.registry.clients[self.id].stats
    }
}

impl Drop for StatsMut<'_> {
    fn drop(&mut self) {
        self.registry.sync_stats(self.id, self.old_times_selected);
    }
}

/// Guard for link-profile mutation: dereferences to [`LinkProfile`];
/// recomputes the client's static projections when dropped.
pub struct LinkMut<'a> {
    registry: &'a mut Registry,
    id: usize,
}

impl Deref for LinkMut<'_> {
    type Target = LinkProfile;
    fn deref(&self) -> &LinkProfile {
        &self.registry.clients[self.id].link
    }
}

impl DerefMut for LinkMut<'_> {
    fn deref_mut(&mut self) -> &mut LinkProfile {
        &mut self.registry.clients[self.id].link
    }
}

impl Drop for LinkMut<'_> {
    fn drop(&mut self) {
        self.registry.refresh_projection(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SelectorKind;

    fn registry() -> Registry {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        Registry::build(&cfg, 35, 1000)
    }

    #[test]
    fn build_merges_profiles_one_to_one() {
        let r = registry();
        assert_eq!(r.len(), 40);
        assert_eq!(r.payload_bytes(), 4000);
        for (i, c) in r.clients().iter().enumerate() {
            assert_eq!(c.id, i);
            assert!(!c.shard.samples.is_empty());
            assert!(c.battery.is_alive());
        }
        assert_eq!(r.alive_count(), 40);
    }

    #[test]
    fn expected_duration_decomposes() {
        let r = registry();
        let c = r.client(0);
        let d = c.expected_duration_s(r.payload_bytes(), 5, 20);
        let manual = c.link.download_secs(r.payload_bytes())
            + c.compute_secs(5, 20)
            + c.link.upload_secs(r.payload_bytes());
        assert!((d - manual).abs() < 1e-12);
        assert!(d > 0.0);
    }

    #[test]
    fn candidates_respect_battery_floor() {
        let mut r = registry();
        // Kill half the clients.
        let cap = r.client(0).battery.capacity_joules();
        for id in 0..20 {
            r.drain_fl(id, cap * 2.0, 0.0);
        }
        let cands = r.candidates(1, 0.02, 5, 20);
        assert!(cands.len() <= 20);
        assert!(cands.iter().all(|c| c.battery_frac > 0.02));
        assert_eq!(r.dead_count(), 20);
    }

    #[test]
    fn projections_are_positive_fractions() {
        let r = registry();
        for cand in r.candidates(1, 0.0, 5, 20) {
            assert!(cand.projected_drain_frac > 0.0);
            assert!(cand.projected_drain_frac < 1.0, "one round must not eat a full battery");
            assert!((0.0..=1.0).contains(&cand.battery_frac));
        }
    }

    #[test]
    fn selection_counts_track_stats() {
        let mut r = registry();
        r.stats_mut(3).times_selected = 7;
        let counts = r.selection_counts();
        assert_eq!(counts[3], 7);
        assert_eq!(counts.iter().sum::<u64>(), 7);
        assert_eq!(r.aggregates().selected_sum, 7);
        assert_eq!(r.aggregates().selected_sum_sq, 49);
    }

    #[test]
    fn fill_candidates_matches_reference() {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        let mut r = Registry::build(&cfg, 35, 1000);
        // Perturb state: kill some, ban some, give some stats.
        let cap = r.client(0).battery.capacity_joules();
        r.drain_fl(2, cap * 2.0, 1.0);
        r.drain_fl(5, cap * 0.6, 1.0);
        r.stats_mut(7).banned_until_round = 9;
        {
            let mut s = r.stats_mut(11);
            s.stat_util = Some(42.0);
            s.measured_duration_s = Some(120.0);
            s.last_selected_round = 3;
            s.times_selected = 2;
        }
        let reference =
            r.candidates(4, 0.01, cfg.training.local_steps, cfg.data.batch_size);
        let mut fast = Vec::new();
        r.fill_candidates(4, 0.01, |_| true, &mut fast);
        assert_eq!(fast.len(), reference.len());
        for (a, b) in fast.iter().zip(&reference) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.stat_util, b.stat_util);
            assert_eq!(a.measured_duration_s, b.measured_duration_s);
            assert_eq!(a.expected_duration_s, b.expected_duration_s);
            assert_eq!(a.last_selected_round, b.last_selected_round);
            assert_eq!(a.battery_frac, b.battery_frac);
            assert_eq!(a.projected_drain_frac, b.projected_drain_frac);
        }
        // Availability gate filters within the fast path.
        let mut gated = Vec::new();
        r.fill_candidates(4, 0.01, |id| id % 2 == 0, &mut gated);
        assert!(gated.iter().all(|c| c.id % 2 == 0));
        assert!(gated.len() < fast.len());
    }

    #[test]
    fn mean_battery_alive_is_zero_when_none_alive() {
        let mut r = registry();
        for id in 0..r.len() {
            let cap = r.client(id).battery.capacity_joules();
            r.drain_fl(id, cap * 2.0, 0.0);
        }
        assert_eq!(r.alive_count(), 0);
        // Documented contract: an exhausted fleet reports 0.0 usable
        // charge, not the vacuous 1.0.
        assert_eq!(r.mean_battery_alive(), 0.0);
    }

    #[test]
    fn aggregates_follow_mutations_exactly() {
        let mut r = registry();
        let cap = r.client(0).battery.capacity_joules();
        r.drain_fl(0, cap * 0.5, 1.0);
        r.drain_background(1, cap * 0.25, 1.0);
        r.charge_add(1, cap * 0.1);
        r.drain_fl(3, cap * 5.0, 2.0); // kills client 3
        r.recharge_to(3, 0.8);
        r.stats_mut(4).times_selected = 3;
        r.stats_mut(9).times_selected = 1;
        assert_eq!(*r.aggregates(), PoolAggregates::recompute(&r));
        assert_eq!(r.aggregates().selected_sum, 4);
        assert_eq!(r.aggregates().selected_sum_sq, 10);
    }

    #[test]
    fn link_mut_refreshes_projection() {
        let mut r = registry();
        let before = r.pool().expected_duration_s[5];
        {
            let mut link = r.link_mut(5);
            link.down_mbps *= 0.5;
            link.up_mbps *= 0.5;
        }
        let after = r.pool().expected_duration_s[5];
        assert!(after > before, "halved bandwidth must lengthen the projection");
        // And the pool matches a fresh reference projection.
        let cands = r.candidates(1, 0.0, r.local_steps, r.batch);
        let c5 = cands.iter().find(|c| c.id == 5).unwrap();
        assert_eq!(c5.expected_duration_s, after);
    }
}
