//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Scope: everything `artifacts/manifest.json` and our own summary
//! emission need — objects, arrays, strings (with escapes), numbers,
//! bools, null. Not a general-purpose library: no streaming, documents
//! are small (KBs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic when re-emitting.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // --- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Field access that errors with the path name (manifest parsing).
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    // --- writer --------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Single-line rendering for JSONL records (trace events). Same
    /// number/escape rules as the pretty writer — `", "` and `": "`
    /// separators, just no newlines — so values round-trip through
    /// either form with identical digits.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null | Json::Bool(_) | Json::Num(_) | Json::Str(_) => self.write(out, 0),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) if a.is_empty() => out.push_str("[]"),
            Json::Arr(a) => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&pad_in);
                    v.write(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) if m.is_empty() => out.push_str("{}"),
            Json::Obj(m) => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint {code}"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] got {other:?} at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {other:?} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "param_count": 69123,
            "artifacts": {"train_step": "train_step.hlo.txt"},
            "param_spec": [{"name": "w", "shape": [3, 3, 1, 8]}],
            "ok": true, "none": null, "f": -1.5e2
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.field("param_count").unwrap().as_usize(), Some(69123));
        assert_eq!(
            j.field("artifacts").unwrap().field("train_step").unwrap().as_str(),
            Some("train_step.hlo.txt")
        );
        let spec = j.field("param_spec").unwrap().as_arr().unwrap();
        let dims: Vec<usize> = spec[0]
            .field("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(dims, vec![3, 3, 1, 8]);
        assert_eq!(j.get("f").unwrap().as_f64(), Some(-150.0));
        assert_eq!(j.get("none"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te".into());
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn writer_then_parser_roundtrip() {
        let mut obj = BTreeMap::new();
        obj.insert("n".to_string(), Json::Num(3.0));
        obj.insert("s".to_string(), Json::Str("hi".into()));
        obj.insert("a".to_string(), Json::Arr(vec![Json::Bool(true), Json::Null]));
        let j = Json::Obj(obj);
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn compact_writer_is_single_line_and_roundtrips() {
        let mut obj = BTreeMap::new();
        obj.insert("b".to_string(), Json::Bool(false));
        obj.insert("n".to_string(), Json::Num(1.5));
        obj.insert("i".to_string(), Json::Num(7.0));
        obj.insert("s".to_string(), Json::Str("x\ny".into()));
        obj.insert("a".to_string(), Json::Arr(vec![Json::Num(1.0), Json::Null]));
        obj.insert("e".to_string(), Json::Obj(BTreeMap::new()));
        let j = Json::Obj(obj);
        let line = j.to_string_compact();
        assert!(!line.contains('\n'), "compact output must be one line: {line:?}");
        assert_eq!(
            line,
            r#"{"a": [1, null], "b": false, "e": {}, "i": 7, "n": 1.5, "s": "x\ny"}"#
        );
        assert_eq!(Json::parse(&line).unwrap(), j);
    }

    #[test]
    fn as_bool_accessor() {
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::Num(1.0).as_bool(), None);
    }

    #[test]
    fn as_usize_rejects_negatives_and_fractions() {
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
