//! Cross-process determinism tier: the shard/merge protocol's contract
//! is that sharding a campaign across real OS processes changes *how*
//! the grid is computed, never *what* lands on disk. Every test here
//! drives the actual `eafl` binary (CARGO_BIN_EXE_eafl) and compares
//! the merged `campaign.json` / `campaign.csv` **bytes** against a
//! single-process `eafl sweep` reference:
//!
//!  - any shard count (N ∈ {1, 2, 4}), run in any completion order;
//!  - shards sharing one --out directory or scattered across several;
//!  - `--jobs P` self-orchestration (P child processes + auto-merge);
//!  - a shard killed mid-campaign and resumed afterwards;
//!  - and `eafl merge` refusing to pass off a partial grid as done.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use eafl::campaign::shard_of;
use eafl::util::json::Json;

const BIN: &str = env!("CARGO_BIN_EXE_eafl");

/// The test grid: 2 selectors x 2 scenarios x 2 seeds = 8 cells.
/// Chosen so the FNV name partition is non-degenerate: mod 2 splits
/// 4/4, mod 4 splits 1/1/3/3 (asserted in `partition_is_usable`).
const GRID: &[&str] = &[
    "--mock",
    "--rounds",
    "4",
    "--clients",
    "12",
    "--selectors",
    "random,eafl",
    "--scenario",
    "steady,diurnal",
    "--seeds",
    "1,2",
];

/// The 8 cell names the grid above expands to (cell names are the
/// sharding protocol's stable identity, so spelling them out here also
/// pins the naming scheme).
fn cell_names(clients: usize) -> Vec<String> {
    let mut names = Vec::new();
    for selector in ["random", "eafl"] {
        for scenario in ["steady", "diurnal"] {
            for seed in [1, 2] {
                names.push(format!("sweep-{selector}-{scenario}-n{clients}-f0.25-s{seed}"));
            }
        }
    }
    names
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eafl-shard-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn eafl(args: &[&str]) -> Output {
    Command::new(BIN).args(args).output().expect("spawning eafl")
}

fn sweep(grid: &[&str], extra: &[&str], out: &Path) -> Output {
    let mut cmd = Command::new(BIN);
    cmd.arg("sweep").args(grid).args(extra).arg("--out").arg(out);
    cmd.output().expect("spawning eafl sweep")
}

fn assert_ok(output: &Output, what: &str) {
    assert!(
        output.status.success(),
        "{what} failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        output.status,
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr)
    );
}

/// The two merged artifacts whose bytes the whole tier compares.
fn merged_bytes(dir: &Path) -> (String, String) {
    let json = std::fs::read_to_string(dir.join("sweep.campaign.json"))
        .unwrap_or_else(|e| panic!("no merged campaign.json in {dir:?}: {e}"));
    let csv = std::fs::read_to_string(dir.join("sweep.campaign.csv"))
        .unwrap_or_else(|e| panic!("no merged campaign.csv in {dir:?}: {e}"));
    (json, csv)
}

/// Single-process reference sweep into a fresh directory.
fn reference(tag: &str, grid: &[&str]) -> (PathBuf, String, String) {
    let dir = tmp_dir(tag);
    assert_ok(&sweep(grid, &["--jobs", "1"], &dir), "reference sweep");
    let (json, csv) = merged_bytes(&dir);
    (dir, json, csv)
}

#[test]
fn partition_is_usable_for_this_grid() {
    // The other tests lean on every shard owning at least one cell (so
    // "shard completion order" and "missing shard" mean something).
    // This is a property of the fixed cell names — deterministic, but
    // worth failing loudly if the grid is ever edited.
    for count in [2usize, 4] {
        let mut owned = vec![0usize; count];
        for name in cell_names(12) {
            owned[shard_of(&name, count)] += 1;
        }
        assert!(
            owned.iter().all(|&n| n > 0),
            "grid leaves an empty shard at N={count} ({owned:?}); pick a different grid"
        );
    }
}

#[test]
fn single_process_sweep_is_reproducible_and_writes_the_manifest() {
    let (dir_a, json_a, csv_a) = reference("ref-a", GRID);
    let (dir_b, json_b, csv_b) = reference("ref-b", GRID);
    assert_eq!(json_a, json_b, "same grid, same bytes");
    assert_eq!(csv_a, csv_b);

    let parsed = Json::parse(&json_a).unwrap();
    assert_eq!(parsed.field("total_runs").unwrap().as_usize(), Some(8));
    assert_eq!(csv_a.lines().count(), 9, "header + 8 grid cells");

    // Every sweep with an --out writes the grid manifest — and both
    // processes write identical manifest bytes.
    let manifest_a = std::fs::read_to_string(dir_a.join("sweep.manifest.json")).unwrap();
    let manifest_b = std::fs::read_to_string(dir_b.join("sweep.manifest.json")).unwrap();
    assert_eq!(manifest_a, manifest_b);
    assert_eq!(
        Json::parse(&manifest_a).unwrap().field("total_cells").unwrap().as_usize(),
        Some(8)
    );

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

/// The acceptance criterion: `--shard I/N` for N ∈ {1, 2, 4}, shards
/// run in *reverse* order (worst case for any accidental order
/// dependence), sharing one --out; `eafl merge` must reproduce the
/// single-process bytes exactly.
#[test]
fn any_shard_count_merges_byte_identical_in_any_completion_order() {
    let (ref_dir, ref_json, ref_csv) = reference("count-ref", GRID);
    for count in [1usize, 2, 4] {
        let dir = tmp_dir(&format!("count-{count}"));
        // Reverse completion order: shard N-1 finishes first, shard 0
        // last. (Sequential spawning makes the order deterministic.)
        for index in (0..count).rev() {
            let shard = format!("{index}/{count}");
            assert_ok(
                &sweep(GRID, &["--jobs", "1", "--shard", &shard], &dir),
                &format!("shard {shard}"),
            );
        }
        let dir_str = dir.to_str().unwrap();
        assert_ok(&eafl(&["merge", dir_str]), &format!("merge N={count}"));
        let (json, csv) = merged_bytes(&dir);
        assert_eq!(json, ref_json, "N={count}: merged JSON must match single-process");
        assert_eq!(csv, ref_csv, "N={count}: merged CSV must match single-process");
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&ref_dir);
}

/// Shards do not need to share a directory: each can write to its own
/// --out (different hosts, different scratch disks) and `eafl merge
/// DIR...` — in any argument order — reassembles the campaign.
#[test]
fn shards_in_separate_dirs_merge_across_directories() {
    let (ref_dir, ref_json, ref_csv) = reference("dirs-ref", GRID);
    let d0 = tmp_dir("dirs-0");
    let d1 = tmp_dir("dirs-1");
    assert_ok(&sweep(GRID, &["--jobs", "1", "--shard", "0/2"], &d0), "shard 0/2");
    assert_ok(&sweep(GRID, &["--jobs", "1", "--shard", "1/2"], &d1), "shard 1/2");

    // Merge with the directories in *reverse* order, into a third dir.
    let out = tmp_dir("dirs-merged");
    assert_ok(
        &eafl(&[
            "merge",
            d1.to_str().unwrap(),
            d0.to_str().unwrap(),
            "--out",
            out.to_str().unwrap(),
        ]),
        "cross-directory merge",
    );
    let (json, csv) = merged_bytes(&out);
    assert_eq!(json, ref_json);
    assert_eq!(csv, ref_csv);
    for d in [&ref_dir, &d0, &d1, &out] {
        let _ = std::fs::remove_dir_all(d);
    }
}

/// `eafl sweep --jobs P` is the one-command version: P shard child
/// processes over one --out, merged on completion — still byte-stable.
#[test]
fn jobs_flag_self_orchestrates_shard_processes() {
    let (ref_dir, ref_json, ref_csv) = reference("jobs-ref", GRID);
    let dir = tmp_dir("jobs-3");
    let output = sweep(GRID, &["--jobs", "3"], &dir);
    assert_ok(&output, "self-orchestrated sweep --jobs 3");
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(
        stdout.contains("sharding across 3 processes"),
        "expected the orchestration banner, got:\n{stdout}"
    );
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "--jobs 3 must be byte-identical to --jobs 1");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill a shard mid-campaign, then resume it: whatever partial state
/// the kill left behind (torn JSON, missing fingerprints, half the
/// cells done), rerunning the same `--shard I/N` into the same --out
/// must converge to the same merged bytes.
#[test]
fn killed_shard_resumes_to_identical_bytes() {
    // A heavier grid so the shard is plausibly mid-flight when killed
    // (the test is valid — just weaker — if the child wins the race).
    let grid: &[&str] = &[
        "--mock",
        "--rounds",
        "30",
        "--clients",
        "48",
        "--selectors",
        "random,eafl",
        "--scenario",
        "steady,diurnal",
        "--seeds",
        "1,2",
    ];
    let (ref_dir, ref_json, ref_csv) = reference("kill-ref", grid);

    let dir = tmp_dir("kill");
    let mut child = Command::new(BIN)
        .arg("sweep")
        .args(grid)
        .args(["--jobs", "1", "--shard", "0/2"])
        .arg("--out")
        .arg(&dir)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning shard to kill");
    std::thread::sleep(std::time::Duration::from_millis(40));
    let _ = child.kill();
    let _ = child.wait();

    // Resume the killed shard, run its sibling, merge.
    assert_ok(&sweep(grid, &["--jobs", "1", "--shard", "0/2"], &dir), "resumed shard 0/2");
    assert_ok(&sweep(grid, &["--jobs", "1", "--shard", "1/2"], &dir), "shard 1/2");
    assert_ok(&eafl(&["merge", dir.to_str().unwrap()]), "merge after kill+resume");
    let (json, csv) = merged_bytes(&dir);
    assert_eq!(json, ref_json, "kill+resume must not change a single byte");
    assert_eq!(csv, ref_csv);
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A merge over an incomplete campaign must fail loudly and name the
/// missing cells — never emit a partial report that looks complete.
#[test]
fn merge_refuses_a_partial_campaign() {
    let dir = tmp_dir("partial");
    assert_ok(&sweep(GRID, &["--jobs", "1", "--shard", "0/2"], &dir), "shard 0/2");
    let output = eafl(&["merge", dir.to_str().unwrap()]);
    assert!(
        !output.status.success(),
        "merge of half a campaign must fail, got:\n{}",
        String::from_utf8_lossy(&output.stdout)
    );
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(stderr.contains("merge incomplete"), "unhelpful merge error:\n{stderr}");
    // At least one shard-1 cell is named (shard 1/2 owns >= 1 cell —
    // see partition_is_usable_for_this_grid).
    assert!(
        cell_names(12)
            .into_iter()
            .filter(|name| shard_of(name.as_str(), 2) == 1)
            .any(|name| stderr.contains(&name)),
        "error should name a missing cell:\n{stderr}"
    );
    assert!(!stderr.contains("panicked"), "clean error, not a panic:\n{stderr}");
    // And no merged artifacts appeared.
    assert!(!dir.join("sweep.campaign.json").exists());
    let _ = std::fs::remove_dir_all(&dir);
}
