//! Tiny property-testing harness (the offline stand-in for proptest).
//!
//! `forall(cases, |rng| { ... })` runs the closure under `cases`
//! independent seeded RNGs; on panic it re-raises with the failing seed
//! embedded so the case is reproducible with `forall_seed`.

use super::rng::Rng;

/// Default number of cases for invariant properties.
pub const DEFAULT_CASES: u64 = 128;

/// Run `property` under `cases` seeded RNG streams. Panics (with the
/// seed) on the first failing case.
pub fn forall<F: FnMut(&mut Rng)>(cases: u64, mut property: F) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seed_from_u64(seed);
            property(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Re-run a single failing case.
pub fn forall_seed<F: FnOnce(&mut Rng)>(seed: u64, property: F) {
    let mut rng = Rng::seed_from_u64(seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(16, |rng| {
            count += 1;
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
        });
        assert_eq!(count, 16);
    }

    #[test]
    fn failing_property_reports_seed() {
        let err = std::panic::catch_unwind(|| {
            forall(8, |rng| {
                // Fails for every seed.
                assert!(rng.gen_f64() > 2.0, "impossible");
            });
        })
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("seed 0"), "got {msg:?}");
    }

    #[test]
    fn forall_seed_reproduces_stream() {
        let mut a = 0.0;
        forall_seed(5, |rng| a = rng.gen_f64());
        let mut b = 0.0;
        forall_seed(5, |rng| b = rng.gen_f64());
        assert_eq!(a, b);
    }
}
