//! `eafl` — leader entrypoint & CLI (hand-rolled arg parsing; the
//! build is offline, see DESIGN.md §2).
//!
//! Subcommands:
//!   run          one experiment (selector × config) → CSV + summary JSON
//!   compare      EAFL vs Oort vs Random under one seed (the paper's
//!                headline comparison, Figs. 3 & 4)
//!   sweep        a whole campaign: selectors × seeds × f × clients grid
//!                run across shard processes (--jobs) or as one shard of
//!                a multi-host campaign (--shard I/N), merged into
//!                campaign.json/.csv
//!   merge        order-stable merge of sweep output directories into
//!                the campaign.json/.csv a single-process sweep writes
//!   trace        fold `--trace` event files into the paper's figures
//!   trend        render BENCH_history.jsonl into a per-commit table
//!   gen-config   write the paper-default TOML config
//!   energy-table print the Table 1 / Table 2 reproduction
//!
//! Python never runs here: the binary loads `artifacts/*.hlo.txt`
//! produced once by `make artifacts`.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use eafl::campaign::supervisor::{self, SupervisorSpec};
use eafl::campaign::{run_campaign, CampaignGrid, CampaignReport, CampaignSpec};
use eafl::config::{ExperimentConfig, SelectorKind, ShardSpec};
use eafl::coordinator::Coordinator;
use eafl::device::{DeviceSpec, ALL_TIERS};
use eafl::energy::{comm_energy_percent, CommDirection};
use eafl::metrics::Summary;
use eafl::network::Medium;
use eafl::obs::{self, JsonlSink, PhaseProfiler, TraceSummary};
use eafl::report::MergeDetail;
use eafl::runtime::{MockRuntime, ModelRuntime, XlaRuntime};
use eafl::scenario::Scenario;

const USAGE: &str = "\
eafl — energy-aware federated learning (MobiCom'22 FedEdge reproduction)

USAGE:
  eafl run [--config FILE] [--selector random|oort|eafl|budget]
           [--rounds N] [--clients N] [--f F] [--budget-j J]
           [--scenario NAME|FILE] [--out DIR] [--trace FILE] [--mock]
  eafl compare [--config FILE] [--rounds N] [--clients N]
           [--scenario NAME|FILE] [--out DIR] [--mock]
  eafl sweep [--config FILE] [--selectors LIST] [--scenario LIST]
             [--seeds LIST] [--f LIST] [--clients LIST]
             [--budget-j LIST] [--rounds N] [--jobs N] [--shard I/N]
             [--fresh] [--out DIR] [--trace DIR] [--max-retries N]
             [--stall-timeout-s S] [--fault SPEC] [--mock]
  eafl merge DIR [DIR...] [--out DIR]
  eafl trace summarize TRACE [TRACE...] [--out DIR]
  eafl trend [--history FILE] [--csv] [--out FILE]
  eafl scenarios [--show NAME]
  eafl gen-config [--out FILE]
  eafl energy-table
  eafl help

  sweep runs the full LIST-product as one campaign (LIST is comma-
  separated, e.g. --selectors eafl,oort,random --seeds 1,2,3 --f
  0.0,0.25,1.0 --scenario steady,diurnal); defaults to the headline grid
  of all three selectors x seeds 1,2,3. Per-run CSVs plus the merged
  campaign summary land in --out (default results/campaign).
  Re-running into the same --out resumes a partial campaign by skipping
  grid cells that already have summaries; --fresh recomputes everything.

  sweep scales out by sharding: --jobs P (P > 1) spawns P shard child
  processes over one --out directory and merges when they finish; with
  no --jobs it runs the grid across threads in-process. Both are
  byte-identical. For multi-host campaigns, run `eafl sweep --shard I/N`
  (0-based shard I of N) per host — each shard deterministically owns
  the grid cells whose name hashes to it, so shards need no
  coordination — then `eafl merge` the output director(ies) once all
  shards are done. merge is order-stable: the result is byte-identical
  to a single-process sweep, whatever the shard count, completion
  order, or directory layout.

  --jobs sweeps run under a fault-tolerant supervisor: each shard child
  heartbeats <out>/shard-I.progress.json, a child whose heartbeat stops
  changing for --stall-timeout-s seconds is killed, and crashed/stalled
  shards restart with exponential backoff up to --max-retries times
  (default 2), resuming finished cells. Torn or corrupt artifacts are
  moved aside to *.quarantine and recomputed. Exit codes: 0 ok, 1
  internal error, 2 usage error, 3 deterministic cell failure (named on
  stderr, not retried), 4 retries exhausted (culprit shards/cells
  named). --fault SPEC injects deterministic faults for testing, e.g.
  crash:after-cells=N, stall:ms=M[:cell=NAME], torn-write:kind=summary,
  corrupt:kind=config (kinds: summary|config|manifest|trace|campaign;
  selectors cell=/shard=/attempt=).

  --budget-j sets a campaign energy budget in joules (0 = unlimited):
  the coordinator's energy ledger reconciles each round's projected and
  actual spend and stops the run — whatever the selector — once the
  budget is exhausted (a budget_exhausted trace event marks the cut).
  The `budget` selector additionally plans *within* the envelope:
  hard-cap never schedules past the remaining budget, amortized spreads
  it evenly over the remaining rounds, deadline-aware spends ahead when
  round utility stalls (selector.budget_policy / budget_spend_ahead in
  the config). Under sweep, --budget-j is a LIST axis applied to every
  selector; its runs are tagged -b{budget} and the merged CSV carries
  the energy/accuracy frontier columns (budget_j, energy_spent_j,
  final_accuracy).

  Scenarios are declarative environment models (availability churn,
  degraded/congested networks, wall-clock recharge policies) plugged
  into the round engine's phase seams. --scenario takes a preset name
  (`eafl scenarios` lists them) or a TOML scenario file
  (`eafl scenarios --show NAME` prints a template).

  --trace writes the deterministic `eafl-trace-v1` round-event stream
  (JSONL; one file per run, or per grid cell under a sweep's trace
  directory) — byte-identical at any EAFL_WORKERS / shard split / drain
  mode. run additionally writes a sibling *.profile.json with
  non-deterministic per-phase wall times (never part of byte compares).
  `eafl trace summarize` folds traces back into figure data:
  time-to-accuracy on the wall-clock axis, drop-out trajectories, and
  participation / energy histograms (CSV + summary.json under --out).

  `eafl trend` renders scripts/bench.sh's BENCH_history.jsonl into a
  per-commit benchmark table (markdown, or CSV with --csv).

  EAFL_WORKERS=N sets the per-round parallel-training worker count for
  run/compare (seeded results are bit-identical at any N).

  --mock uses the analytic mock runtime instead of the PJRT artifacts
  (fast; coordinator dynamics only — no real SGD).
";

/// Parse a comma-separated flag value into a typed list.
fn parse_list<T: std::str::FromStr>(raw: Option<&str>, flag: &str) -> Result<Option<Vec<T>>>
where
    T::Err: std::fmt::Display,
{
    let Some(raw) = raw else { return Ok(None) };
    let mut out = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(
            part.parse::<T>()
                .map_err(|e| anyhow::anyhow!("invalid --{flag} element {part:?}: {e}"))?,
        );
    }
    anyhow::ensure!(!out.is_empty(), "--{flag} needs at least one element");
    Ok(Some(out))
}

/// Tiny flag parser: `--key value` pairs plus boolean switches.
struct Args {
    flags: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

impl Args {
    fn parse(argv: &[String], switch_names: &[&str]) -> Result<Self> {
        let (args, positionals) = Self::parse_with_positionals(argv, switch_names)?;
        if let Some(arg) = positionals.first() {
            bail!("unexpected positional argument {arg:?}\n\n{USAGE}");
        }
        Ok(args)
    }

    /// Like [`Args::parse`], but collects non-flag arguments (the merge
    /// subcommand takes its directories positionally).
    fn parse_with_positionals(
        argv: &[String],
        switch_names: &[&str],
    ) -> Result<(Self, Vec<String>)> {
        let mut flags = std::collections::HashMap::new();
        let mut switches = std::collections::HashSet::new();
        let mut positionals = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let Some(name) = arg.strip_prefix("--") else {
                positionals.push(arg.clone());
                i += 1;
                continue;
            };
            if switch_names.contains(&name) {
                switches.insert(name.to_string());
                i += 1;
            } else {
                let value = argv
                    .get(i + 1)
                    .with_context(|| format!("--{name} requires a value"))?;
                flags.insert(name.to_string(), value.clone());
                i += 2;
            }
        }
        Ok((Self { flags, switches }, positionals))
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("invalid --{name} {v:?}: {e}")),
        }
    }

    fn has(&self, name: &str) -> bool {
        self.switches.contains(name)
    }
}

fn load_runtime(mock: bool) -> Result<Box<dyn ModelRuntime>> {
    if mock {
        Ok(Box::new(MockRuntime::default()))
    } else {
        Ok(Box::new(XlaRuntime::load(&XlaRuntime::default_dir())?))
    }
}

fn base_config(args: &Args, kind: SelectorKind) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(p) => ExperimentConfig::from_toml_file(&PathBuf::from(p))?,
        None => ExperimentConfig::paper_default(kind),
    };
    if let Some(r) = args.get_parsed::<usize>("rounds")? {
        cfg.federation.rounds = r;
    }
    if let Some(n) = args.get_parsed::<usize>("clients")? {
        cfg.federation.num_clients = n;
    }
    if let Some(f) = args.get_parsed::<f64>("f")? {
        cfg.selector.eafl_f = f;
    }
    if let Some(b) = args.get_parsed::<f64>("budget-j")? {
        cfg.selector.budget_j = b;
    }
    if let Some(s) = args.get("scenario") {
        cfg.scenario = s.to_string();
    }
    // Fail fast on a bad scenario (before any training starts).
    Scenario::resolve(&cfg.scenario)?;
    Ok(cfg)
}

fn run_one(
    cfg: ExperimentConfig,
    runtime: &dyn ModelRuntime,
    out: &PathBuf,
    trace: Option<&Path>,
) -> Result<Summary> {
    std::fs::create_dir_all(out)?;
    let name = cfg.name.clone();
    let mut coordinator = Coordinator::new(cfg, runtime)?;
    if let Some(path) = trace {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating trace dir {dir:?}"))?;
        }
        coordinator.set_sink(Box::new(JsonlSink::create(path)?));
        // Wall-time phases go to a sibling file, never into the trace:
        // the trace is byte-deterministic, wall time is not.
        coordinator.set_profiler(PhaseProfiler::with_output(path.with_extension("profile.json")));
    }
    let log = coordinator.run()?;
    log.write_csv(&out.join(format!("{name}.csv")))?;
    log.write_summary_json(&out.join(format!("{name}.summary.json")))?;
    Ok(log.summary())
}

/// The end-of-sweep console report, shared by the in-process path, the
/// self-orchestrated multi-process path, and `eafl merge`.
fn print_campaign_results(report: &CampaignReport, scenario_axis_len: usize) {
    println!("\n=== campaign results ===");
    for run in &report.runs {
        print_summary(&run.summary);
    }
    println!("\nmean final accuracy by selector:");
    for (kind, acc) in report.mean_accuracy_by_selector() {
        println!("  {kind:<8} {acc:.4}");
    }
    if scenario_axis_len > 1 {
        println!("\ntotal drop-outs by scenario x selector:");
        for (scenario, kind, drops) in report.dropouts_by_scenario() {
            println!("  {scenario:<12} {kind:<8} {drops}");
        }
    }
}

/// The sweep argv minus orchestration/supervision flags — what the
/// supervisor forwards verbatim to its `--shard` children, so every
/// child derives the identical campaign manifest. Fault plans reach
/// children via the inherited `EAFL_FAULT` environment (scoped per
/// attempt through `EAFL_FAULT_ATTEMPT`), never via argv.
fn forwarded_shard_args(rest: &[String]) -> Vec<String> {
    let mut forwarded: Vec<String> = Vec::new();
    let mut i = 0;
    while i < rest.len() {
        match rest[i].as_str() {
            // All value-taking flags the supervisor owns; --out is
            // re-appended explicitly (last occurrence wins in the flag
            // parser).
            "--jobs" | "--shard" | "--out" | "--fault" | "--max-retries"
            | "--stall-timeout-s" => i += 2,
            other => {
                forwarded.push(other.to_string());
                i += 1;
            }
        }
    }
    forwarded
}

/// A classified CLI failure: the process exit code plus the error to
/// print. The vendored `anyhow` has no downcasting, so classification
/// happens where errors are raised, not where they surface.
struct Failure {
    code: i32,
    error: anyhow::Error,
}

impl From<anyhow::Error> for Failure {
    fn from(error: anyhow::Error) -> Self {
        Self { code: 1, error }
    }
}

impl Failure {
    /// Bad flags/config/spec — fix the invocation (exit 2).
    fn usage(error: anyhow::Error) -> Self {
        Self { code: supervisor::EXIT_USAGE, error }
    }

    /// A deterministic run/cell failure — retrying cannot help (exit 3).
    fn cell_failure(error: anyhow::Error) -> Self {
        Self { code: supervisor::EXIT_CELL_FAILURE, error }
    }
}

fn print_summary(s: &Summary) {
    println!(
        "{:<16} acc={:.4} best={:.4} loss={:.4} fairness={:.3} dropouts={} \
         rounds={}({} ok) mean_round={:.1}s wall={:.2}h energy={:.1}kJ",
        s.name,
        s.final_accuracy,
        s.best_accuracy,
        s.final_train_loss,
        s.final_fairness,
        s.total_dropouts,
        s.rounds,
        s.committed_rounds,
        s.mean_round_duration_s,
        s.wall_clock_h,
        s.total_fl_energy_j / 1000.0,
    );
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(f) = run_cli(&argv) {
        eprintln!("error: {:#}", f.error);
        std::process::exit(f.code);
    }
}

fn run_cli(argv: &[String]) -> Result<(), Failure> {
    let Some(command) = argv.first().map(String::as_str) else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match command {
        "run" => {
            // Parse/validate first (usage errors, exit 2), run second
            // (deterministic cell failures, exit 3).
            let (cfg, out, trace, mock) = (|| -> Result<_> {
                let args = Args::parse(rest, &["mock"])?;
                let kind = args
                    .get_parsed::<SelectorKind>("selector")?
                    .unwrap_or(SelectorKind::Eafl);
                let mut cfg = base_config(&args, kind)?;
                cfg.selector.kind = kind;
                if args.get("config").is_none() {
                    cfg.name = format!("run-{kind}");
                }
                cfg.validate()?;
                let out = PathBuf::from(args.get("out").unwrap_or("results"));
                let trace = args.get("trace").map(PathBuf::from);
                Ok((cfg, out, trace, args.has("mock")))
            })()
            .map_err(Failure::usage)?;
            let runtime = load_runtime(mock).map_err(Failure::cell_failure)?;
            let s = run_one(cfg, runtime.as_ref(), &out, trace.as_deref())
                .map_err(Failure::cell_failure)?;
            print_summary(&s);
        }
        "compare" => {
            let (cfgs, out, mock) = (|| -> Result<_> {
                let args = Args::parse(rest, &["mock"])?;
                let out = PathBuf::from(args.get("out").unwrap_or("results"));
                let mut cfgs = Vec::new();
                for kind in [SelectorKind::Eafl, SelectorKind::Oort, SelectorKind::Random] {
                    let mut cfg = base_config(&args, kind)?;
                    cfg.selector.kind = kind;
                    cfg.name = format!("compare-{kind}");
                    cfg.validate()?;
                    cfgs.push(cfg);
                }
                Ok((cfgs, out, args.has("mock")))
            })()
            .map_err(Failure::usage)?;
            let runtime = load_runtime(mock).map_err(Failure::cell_failure)?;
            let mut summaries = Vec::new();
            for cfg in cfgs {
                summaries
                    .push(run_one(cfg, runtime.as_ref(), &out, None).map_err(Failure::cell_failure)?);
            }
            println!("\n=== EAFL vs Oort vs Random ===");
            for s in &summaries {
                print_summary(s);
            }
        }
        "sweep" => {
            let (spec, out, total, jobs_flag, mock, max_retries, stall_timeout) =
                (|| -> Result<_> {
                    let args = Args::parse(rest, &["mock", "fresh"])?;
                    let mut base = match args.get("config") {
                        Some(p) => ExperimentConfig::from_toml_file(&PathBuf::from(p))?,
                        None => ExperimentConfig::paper_default(SelectorKind::Eafl),
                    };
                    if let Some(r) = args.get_parsed::<usize>("rounds")? {
                        base.federation.rounds = r;
                    }
                    let mut spec = CampaignSpec::new("sweep", base);
                    let defaults = CampaignGrid::default();
                    spec.grid = CampaignGrid {
                        selectors: parse_list::<SelectorKind>(args.get("selectors"), "selectors")?
                            .unwrap_or(defaults.selectors),
                        scenarios: parse_list::<String>(args.get("scenario"), "scenario")?
                            .unwrap_or_default(),
                        seeds: parse_list::<u64>(args.get("seeds"), "seeds")?
                            .unwrap_or(defaults.seeds),
                        f_values: parse_list::<f64>(args.get("f"), "f")?.unwrap_or_default(),
                        client_counts: parse_list::<usize>(args.get("clients"), "clients")?
                            .unwrap_or_default(),
                        budgets: parse_list::<f64>(args.get("budget-j"), "budget-j")?
                            .unwrap_or_default(),
                    };
                    let jobs_flag = args.get_parsed::<usize>("jobs")?;
                    if let Some(j) = jobs_flag {
                        spec.jobs = j.max(1);
                    }
                    spec.shard = args.get_parsed::<ShardSpec>("shard")?;
                    spec.resume = !args.has("fresh");
                    // Forwarded verbatim to shard children (the
                    // supervisor only strips its own flags): shards own
                    // disjoint cells, so they share one trace directory
                    // without racing.
                    spec.trace_dir = args.get("trace").map(PathBuf::from);
                    // Fail fast on a bad scenario axis (before hours of
                    // runs).
                    Scenario::resolve(&spec.base.scenario)?;
                    for s in &spec.grid.scenarios {
                        Scenario::resolve(s)?;
                    }
                    // A fault plan is validated here (a typo'd spec is a
                    // usage error) and then handed to this process — and
                    // its shard children, which inherit the environment
                    // — via EAFL_FAULT.
                    if let Some(fault_spec) = args.get("fault") {
                        eafl::fault::FaultPlan::parse(fault_spec)
                            .with_context(|| format!("invalid --fault {fault_spec:?}"))?;
                        std::env::set_var("EAFL_FAULT", fault_spec);
                    } else if let Ok(env_spec) = std::env::var("EAFL_FAULT") {
                        if !env_spec.trim().is_empty() {
                            eafl::fault::FaultPlan::parse(&env_spec)
                                .with_context(|| format!("invalid EAFL_FAULT {env_spec:?}"))?;
                        }
                    }
                    let max_retries = args
                        .get_parsed::<usize>("max-retries")?
                        .unwrap_or(supervisor::DEFAULT_MAX_RETRIES);
                    let stall_timeout = match args.get_parsed::<f64>("stall-timeout-s")? {
                        None => None,
                        Some(s) => {
                            anyhow::ensure!(
                                s.is_finite() && s > 0.0,
                                "--stall-timeout-s must be a positive number of seconds, got {s}"
                            );
                            Some(Duration::from_secs_f64(s))
                        }
                    };
                    let out = PathBuf::from(args.get("out").unwrap_or("results/campaign"));
                    let total = eafl::campaign::expand(&spec).len();
                    Ok((spec, out, total, jobs_flag, args.has("mock"), max_retries, stall_timeout))
                })()
                .map_err(Failure::usage)?;
            // Not printed as a product: the f axis only applies to the
            // EAFL selector, so total is usually less than the naive
            // cross of the axis sizes.
            println!(
                "campaign: {total} runs over {} selectors, {} scenario(s), {} seeds, \
                 {} f value(s) (EAFL only), {} client count(s), {} budget(s) -> {}",
                spec.grid.selectors.len(),
                spec.grid.scenarios.len().max(1),
                spec.grid.seeds.len(),
                spec.grid.f_values.len().max(1),
                spec.grid.client_counts.len().max(1),
                spec.grid.budgets.len().max(1),
                out.display()
            );
            // Process scale-out is an explicit ask (--jobs P): a plain
            // `eafl sweep` keeps the in-process work-stealing pool,
            // which balances uneven cells dynamically and loads the
            // runtime once. Sharding trades that for multi-process (and
            // multi-host) composition — byte-identical either way.
            if spec.shard.is_none() && jobs_flag.map_or(false, |j| j > 1) && total > 1 {
                let procs = spec.jobs.min(total);
                println!("sharding across {procs} processes ({procs} x --shard i/{procs})");
                let exe = std::env::current_exe()
                    .context("locating the eafl binary for shard spawn")?;
                let sup = SupervisorSpec {
                    exe,
                    forwarded: forwarded_shard_args(rest),
                    out: out.clone(),
                    procs,
                    max_retries,
                    stall_timeout,
                };
                // The supervisor reaps, restarts and (on success)
                // merges; its error carries the exit-code class.
                let report = supervisor::supervise(&sup)
                    .map_err(|e| Failure { code: e.exit_code, error: anyhow::anyhow!("{e}") })?;
                eafl::report::write_report(&out, &report)?;
                print_campaign_results(&report, spec.grid.scenarios.len());
                println!(
                    "\nmerged summary: {}",
                    out.join(format!("{}.campaign.json", report.name)).display()
                );
            } else {
                let runtime = load_runtime(mock).map_err(Failure::cell_failure)?;
                let report = run_campaign(&spec, runtime.as_ref(), Some(&out))
                    .map_err(Failure::cell_failure)?;
                print_campaign_results(&report, spec.grid.scenarios.len());
                match spec.shard {
                    Some(shard) if shard.count > 1 => println!(
                        "\nshard {shard} complete: {} of {total} grid cells in {} — run \
                         `eafl merge {}` once every shard has finished",
                        report.runs.len(),
                        out.display(),
                        out.display()
                    ),
                    _ => println!(
                        "\nmerged summary: {}",
                        out.join(format!("{}.campaign.json", report.name)).display()
                    ),
                }
            }
        }
        "merge" => {
            let (args, dirs) = Args::parse_with_positionals(rest, &[]).map_err(Failure::usage)?;
            if dirs.is_empty() {
                return Err(Failure::usage(anyhow::anyhow!(
                    "merge needs at least one sweep output directory\n\n{USAGE}"
                )));
            }
            let dirs: Vec<PathBuf> = dirs.iter().map(PathBuf::from).collect();
            // The detail verdict quarantines bad artifacts on sight and
            // names *every* problem cell with its reason in one pass.
            let (report, manifest_text) = match eafl::report::merge_with_detail(&dirs)? {
                MergeDetail::Complete { report, manifest_text } => (report, manifest_text),
                MergeDetail::NoManifest { quarantined } => {
                    return Err(eafl::report::no_manifest_error(&dirs, quarantined).into())
                }
                MergeDetail::Incomplete { problems, total } => {
                    return Err(eafl::report::incomplete_error(&problems, total).into())
                }
            };
            let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| dirs[0].clone());
            std::fs::create_dir_all(&out).with_context(|| format!("creating {out:?}"))?;
            let (json_path, csv_path) = eafl::report::write_report(&out, &report)?;
            // Carry the manifest along so the merged directory is
            // self-describing like any sweep output: it records which
            // campaign/grid the report covers. Identical bytes by
            // construction (all source manifests agreed).
            std::fs::write(
                out.join(format!("{}.manifest.json", report.name)),
                manifest_text,
            )
            .with_context(|| format!("writing manifest into {out:?}"))?;
            let scenario_axis_len = {
                let mut scenarios: Vec<&str> =
                    report.runs.iter().map(|r| r.scenario.as_str()).collect();
                scenarios.sort_unstable();
                scenarios.dedup();
                scenarios.len()
            };
            print_campaign_results(&report, scenario_axis_len);
            println!(
                "\nmerged {} grid cells -> {} + {}",
                report.runs.len(),
                json_path.display(),
                csv_path.display()
            );
        }
        "trace" => {
            let (args, positionals) =
                Args::parse_with_positionals(rest, &[]).map_err(Failure::usage)?;
            let Some(("summarize", files)) = positionals
                .split_first()
                .map(|(action, files)| (action.as_str(), files))
            else {
                return Err(Failure::usage(anyhow::anyhow!(
                    "trace needs an action: eafl trace summarize TRACE...\n\n{USAGE}"
                )));
            };
            if files.is_empty() {
                return Err(Failure::usage(anyhow::anyhow!(
                    "trace summarize needs at least one trace file\n\n{USAGE}"
                )));
            }
            let mut summaries = Vec::with_capacity(files.len());
            for file in files {
                let summary = TraceSummary::from_file(Path::new(file))?;
                println!("{}", summary.render_line());
                summaries.push(summary);
            }
            if let Some(out) = args.get("out") {
                let dir = PathBuf::from(out);
                obs::write_outputs(&dir, &summaries)?;
                println!(
                    "\nwrote figure data from {} trace(s) -> {}",
                    summaries.len(),
                    dir.display()
                );
            }
        }
        "trend" => {
            let args = Args::parse(rest, &["csv"]).map_err(Failure::usage)?;
            let history = PathBuf::from(args.get("history").unwrap_or("BENCH_history.jsonl"));
            let text = std::fs::read_to_string(&history)
                .with_context(|| format!("reading bench history {}", history.display()))?;
            let format = if args.has("csv") {
                eafl::benchkit::TrendFormat::Csv
            } else {
                eafl::benchkit::TrendFormat::Markdown
            };
            let rendered = eafl::benchkit::render_trend(&text, format)?;
            match args.get("out") {
                Some(p) => {
                    std::fs::write(p, &rendered)
                        .with_context(|| format!("writing trend table {p}"))?;
                    println!("wrote {p}");
                }
                None => print!("{rendered}"),
            }
        }
        "scenarios" => {
            let args = Args::parse(rest, &[]).map_err(Failure::usage)?;
            if let Some(name) = args.get("show") {
                let s = Scenario::resolve(name).map_err(Failure::usage)?;
                print!("{}", s.to_toml());
            } else {
                println!(
                    "built-in scenario presets (use with --scenario NAME or a TOML file):\n"
                );
                for s in Scenario::presets() {
                    println!("  {:<12} {}", s.name, s.description);
                }
                println!(
                    "\n  `eafl scenarios --show NAME` prints a preset as TOML — a \
                     template for custom scenario files."
                );
            }
        }
        "gen-config" => {
            let args = Args::parse(rest, &[]).map_err(Failure::usage)?;
            let cfg = ExperimentConfig::paper_default(SelectorKind::Eafl);
            let text = cfg.to_toml();
            match args.get("out") {
                Some(p) => {
                    std::fs::write(p, &text)?;
                    println!("wrote {p}");
                }
                None => print!("{text}"),
            }
        }
        "energy-table" => {
            println!("Table 1 — comm energy (battery-% after 1 h on medium):");
            for (m, name) in [(Medium::Wifi, "WiFi"), (Medium::Cell3G, "3G  ")] {
                let d = comm_energy_percent(m, CommDirection::Download, 1.0);
                let u = comm_energy_percent(m, CommDirection::Upload, 1.0);
                println!("  {name}  download={d:6.2}%  upload={u:6.2}%");
            }
            println!("\nTable 2 — device tiers:");
            for t in ALL_TIERS {
                let s = DeviceSpec::for_tier(t);
                println!(
                    "  {:?}: {} — {:.2} W, {:.2} fps/W, {:.0} GB RAM, {:.0} mAh ({:.0} kJ)",
                    t,
                    s.model,
                    s.avg_power_w,
                    s.perf_per_watt,
                    s.ram_gb,
                    s.battery_mah,
                    s.battery_joules() / 1000.0
                );
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            return Err(Failure::usage(anyhow::anyhow!("unknown command {other:?}\n\n{USAGE}")))
        }
    }
    Ok(())
}
