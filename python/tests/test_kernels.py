"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and the activation switch) and asserts
allclose against ref.py — the core correctness signal for the kernels
that end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dense import dense, dense_fwd_kernel, matmul_kernel, pick_blocks
from compile.kernels.softmax_xent import softmax_xent, softmax_xent_fwd_kernel

jax.config.update("jax_platform_name", "cpu")

_SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# --- dense ------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 160),
    n=st.integers(1, 90),
    act=st.sampled_from(["id", "relu"]),
)
def test_dense_matches_ref(m, k, n, act):
    x, w, b = _rand(0, (m, k)), _rand(1, (k, n)), _rand(2, (n,))
    got = dense_fwd_kernel(x, w, b, activation=act)
    want = ref.dense_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(**_SETTINGS)
@given(m=st.integers(1, 40), k=st.integers(1, 128), n=st.integers(1, 70))
def test_matmul_matches_ref(m, k, n):
    x, w = _rand(3, (m, k)), _rand(4, (k, n))
    np.testing.assert_allclose(
        matmul_kernel(x, w), ref.matmul_ref(x, w), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("act", ["id", "relu"])
def test_dense_gradients_match_ref(act):
    x, w, b = _rand(5, (20, 96)), _rand(6, (96, 48)), _rand(7, (48,))

    def f_kernel(x, w, b):
        return jnp.sum(dense(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.dense_ref(x, w, b, act) ** 2)

    g = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(g, gr):
        np.testing.assert_allclose(a, e, rtol=5e-4, atol=5e-4)


def test_dense_model_shapes_exact():
    """The exact shapes the speech CNN uses (1024->64, 64->35)."""
    for (m, k, n) in [(20, 1024, 64), (20, 64, 35), (128, 1024, 64)]:
        x, w, b = _rand(8, (m, k)), _rand(9, (k, n), 0.05), _rand(10, (n,))
        np.testing.assert_allclose(
            dense_fwd_kernel(x, w, b, activation="relu"),
            ref.dense_ref(x, w, b, "relu"),
            rtol=2e-5, atol=2e-5,
        )


def test_pick_blocks_vmem_budget():
    """Chosen tiles keep the f32 working set within the 4 MiB budget."""
    for (m, n, k) in [(20, 64, 1024), (128, 35, 64), (512, 512, 2048), (8, 8, 8)]:
        bm, bn = pick_blocks(m, n, k)
        assert bm >= 1 and bn >= 1
        working_set = (bm * k + k * bn + bm * bn) * 4
        assert working_set <= 4 * 1024 * 1024, (m, n, k, bm, bn)


def test_dense_relu_clamps_negative():
    x = -jnp.ones((4, 4), jnp.float32)
    w = jnp.eye(4, dtype=jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    assert float(jnp.max(dense_fwd_kernel(x, w, b, activation="relu"))) == 0.0


# --- softmax_xent -----------------------------------------------------------


@settings(**_SETTINGS)
@given(b=st.integers(1, 64), c=st.integers(2, 200))
def test_softmax_xent_matches_ref(b, c):
    logits = _rand(11, (b, c), 3.0)
    labels = jnp.arange(b, dtype=jnp.int32) % c
    onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    got = softmax_xent_fwd_kernel(logits, onehot)
    want = ref.softmax_xent_ref(logits, onehot)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_softmax_xent_extreme_logits_stable():
    """Max-subtraction keeps large-magnitude logits finite."""
    logits = jnp.array([[1e4, -1e4, 0.0], [-1e4, 1e4, 1e4]], jnp.float32)
    onehot = jax.nn.one_hot(jnp.array([0, 1]), 3, dtype=jnp.float32)
    loss = softmax_xent_fwd_kernel(logits, onehot)
    assert bool(jnp.all(jnp.isfinite(loss)))
    # A perfectly-confident correct prediction has ~0 loss.
    assert float(loss[0]) < 1e-3


def test_softmax_xent_gradient_matches_ref():
    logits = _rand(12, (20, 35), 2.0)
    onehot = jax.nn.one_hot(jnp.arange(20) % 35, 35, dtype=jnp.float32)
    g = jax.grad(lambda l: jnp.mean(softmax_xent(l, onehot)))(logits)
    gr = jax.grad(lambda l: jnp.mean(ref.softmax_xent_ref(l, onehot)))(logits)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-5)


def test_softmax_xent_uniform_logits_is_log_c():
    """Zero logits => loss = log(C) exactly (uniform prediction)."""
    for c in (5, 35, 128):
        logits = jnp.zeros((3, c), jnp.float32)
        onehot = jax.nn.one_hot(jnp.array([0, 1, 2]) % c, c, dtype=jnp.float32)
        loss = softmax_xent_fwd_kernel(logits, onehot)
        np.testing.assert_allclose(loss, jnp.full((3,), jnp.log(c)), rtol=1e-6)
