#!/usr/bin/env bash
# Offline verification pipeline (what `make verify` runs).
#
# Order matters: the cheap compile gate first, then the test suite,
# then lints. clippy/rustfmt are optional components of a toolchain, so
# their absence downgrades to a loud skip instead of a hard failure —
# everything else is strict.

set -euo pipefail
cd "$(dirname "$0")"

# Never touch the network: every dependency is vendored in-tree.
export CARGO_NET_OFFLINE=true

# autotests=false means an unregistered test file is silently never
# compiled or run — catch the orphan before it rots.
echo "==> test-target guard (rust/tests/*.rs all registered)"
for t in rust/tests/*.rs; do
  grep -qF "path = \"$t\"" Cargo.toml \
    || { echo "FAIL: $t has no [[test]] target in Cargo.toml (autotests=false would skip it)"; exit 1; }
done

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# Worker-count invariance is a contract, not a convention: the whole
# suite must pass again with 8 execution workers forced, so every test
# (goldens, campaign bytes, cross-process sharding) enforces it on
# every commit — not only the dedicated determinism tests. The first
# pass may bless missing golden files; this pass then pins them.
echo "==> cargo test -q (EAFL_WORKERS=8)"
EAFL_WORKERS=8 cargo test -q

# Drain-mode invariance is the same kind of contract: with the lazy
# background-drain ledger forced into its eager escape hatch
# (settle every battery every epoch), every golden and campaign byte
# must come out identical — the ledger is an optimization, never a
# semantic.
echo "==> cargo test -q (EAFL_EAGER_DRAIN=1)"
EAFL_EAGER_DRAIN=1 cargo test -q

# Candidate-build invariance, same contract again: with the
# incrementally patched eligible arena forced back to the per-round
# full-pool rebuild, every pick, golden, campaign byte and trace byte
# must come out identical — the arena is an optimization, never a
# semantic.
echo "==> cargo test -q (EAFL_REBUILD_CANDIDATES=1)"
EAFL_REBUILD_CANDIDATES=1 cargo test -q

# Benches must always compile, even though CI never runs the heavy ones.
echo "==> cargo bench --no-run"
cargo bench --no-run

# Scenario sweep smoke: 2 rounds over two scenarios x two selectors on
# the mock runtime must produce a merged CSV with a scenario column and
# exactly header + 4 rows (2 selectors x 2 scenarios x 1 seed). With
# --jobs 2 this now runs through the sharded scale-out path: two shard
# child processes over one --out, auto-merged on completion.
echo "==> scenario sweep smoke (2 shard processes)"
SMOKE_OUT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT"' EXIT
./target/release/eafl sweep --mock --scenario steady,diurnal \
  --selectors random,eafl --seeds 1 --rounds 2 --clients 16 --jobs 2 \
  --out "$SMOKE_OUT" >/dev/null
SMOKE_CSV="$SMOKE_OUT/sweep.campaign.csv"
head -1 "$SMOKE_CSV" | grep -q "^selector,scenario," \
  || { echo "FAIL: merged CSV is missing the scenario column"; exit 1; }
rows="$(wc -l < "$SMOKE_CSV")"
[ "$rows" -eq 5 ] \
  || { echo "FAIL: expected 5 CSV lines (header + 4 runs), got $rows"; exit 1; }
[ -f "$SMOKE_OUT/sweep.manifest.json" ] \
  || { echo "FAIL: sweep did not write the campaign manifest"; exit 1; }
# An explicit re-merge must be a no-op: byte-identical merged CSV.
cp "$SMOKE_CSV" "$SMOKE_OUT/before-merge.csv"
./target/release/eafl merge "$SMOKE_OUT" >/dev/null
cmp -s "$SMOKE_CSV" "$SMOKE_OUT/before-merge.csv" \
  || { echo "FAIL: eafl merge changed the merged CSV bytes"; exit 1; }
echo "    sweep smoke OK ($rows lines in $(basename "$SMOKE_CSV"), merge stable)"

# The same sweep under the eager-drain escape hatch must reproduce the
# lazy run byte for byte: campaign output cannot depend on when battery
# state is materialized.
echo "==> eager-drain sweep cross-check"
EAGER_OUT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT" "$EAGER_OUT"' EXIT
EAFL_EAGER_DRAIN=1 ./target/release/eafl sweep --mock \
  --scenario steady,diurnal --selectors random,eafl --seeds 1 --rounds 2 \
  --clients 16 --jobs 2 --out "$EAGER_OUT" >/dev/null
cmp -s "$SMOKE_CSV" "$EAGER_OUT/sweep.campaign.csv" \
  || { echo "FAIL: EAFL_EAGER_DRAIN=1 changed the campaign CSV bytes"; exit 1; }
echo "    eager-drain cross-check OK (campaign bytes identical)"

# And once more with the eligible arena forced back to per-round
# rebuilds: the incremental patch path must be byte-invisible in
# campaign output too.
echo "==> rebuild-candidates sweep cross-check"
REBUILD_OUT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT" "$EAGER_OUT" "$REBUILD_OUT"' EXIT
EAFL_REBUILD_CANDIDATES=1 ./target/release/eafl sweep --mock \
  --scenario steady,diurnal --selectors random,eafl --seeds 1 --rounds 2 \
  --clients 16 --jobs 2 --out "$REBUILD_OUT" >/dev/null
cmp -s "$SMOKE_CSV" "$REBUILD_OUT/sweep.campaign.csv" \
  || { echo "FAIL: EAFL_REBUILD_CANDIDATES=1 changed the campaign CSV bytes"; exit 1; }
echo "    rebuild-candidates cross-check OK (campaign bytes identical)"

# Budget-axis sweep smoke: three budgets x two selectors over the mock
# must tag run names with -b{budget}, emit the energy/accuracy frontier
# columns in the merged CSV, and stay byte-identical across the 2-shard
# split, EAFL_WORKERS=8 and the eager-drain escape hatch — the ledger
# is part of the determinism contract, not an exception to it.
echo "==> budget-axis sweep smoke (frontier columns, byte-compares)"
BUDGET_OUT="$(mktemp -d)"
BUDGET_SHARD="$(mktemp -d)"
BUDGET_W8="$(mktemp -d)"
BUDGET_EAGER="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT" "$EAGER_OUT" "$REBUILD_OUT" "$BUDGET_OUT" "$BUDGET_SHARD" "$BUDGET_W8" "$BUDGET_EAGER"' EXIT
budget_sweep() {
  ./target/release/eafl sweep --mock --scenario steady \
    --selectors random,eafl --seeds 1 --rounds 2 --clients 16 \
    --budget-j 4000,40000,400000 "$@" >/dev/null
}
budget_sweep --out "$BUDGET_OUT"
BUDGET_CSV="$BUDGET_OUT/sweep.campaign.csv"
for col in budget_j energy_spent_j final_accuracy; do
  head -1 "$BUDGET_CSV" | grep -q "$col" \
    || { echo "FAIL: merged CSV is missing the $col frontier column"; exit 1; }
done
rows="$(wc -l < "$BUDGET_CSV")"
[ "$rows" -eq 7 ] \
  || { echo "FAIL: expected 7 CSV lines (header + 2 selectors x 3 budgets), got $rows"; exit 1; }
grep -q -- "-b4000-s1" "$BUDGET_OUT/sweep.manifest.json" \
  || { echo "FAIL: budget axis did not tag run names with -b{budget}"; exit 1; }
budget_sweep --jobs 2 --out "$BUDGET_SHARD"
cmp -s "$BUDGET_CSV" "$BUDGET_SHARD/sweep.campaign.csv" \
  || { echo "FAIL: 2-shard split changed the budget campaign CSV bytes"; exit 1; }
EAFL_WORKERS=8 budget_sweep --out "$BUDGET_W8"
cmp -s "$BUDGET_CSV" "$BUDGET_W8/sweep.campaign.csv" \
  || { echo "FAIL: EAFL_WORKERS=8 changed the budget campaign CSV bytes"; exit 1; }
EAFL_EAGER_DRAIN=1 budget_sweep --out "$BUDGET_EAGER"
cmp -s "$BUDGET_CSV" "$BUDGET_EAGER/sweep.campaign.csv" \
  || { echo "FAIL: EAFL_EAGER_DRAIN=1 changed the budget campaign CSV bytes"; exit 1; }
echo "    budget smoke OK ($rows lines, frontier columns, shard/worker/drain stable)"

# Fault-injection smoke: the same grid with an injected crash in every
# shard child plus a silently corrupted config fingerprint must still
# converge — the supervisor retries the crashed shards, resume
# quarantines the corrupt bytes (preserved as *.quarantine), and the
# merged CSV is byte-identical to the clean run above. The target cell
# is first in grid order, so it runs (and is corrupted) before the
# after-cells=1 crash fires.
echo "==> fault-injection sweep smoke (crash + corrupt config)"
FAULT_OUT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT" "$EAGER_OUT" "$REBUILD_OUT" "$BUDGET_OUT" "$BUDGET_SHARD" "$BUDGET_W8" "$BUDGET_EAGER" "$FAULT_OUT"' EXIT
FAULT_CELL="sweep-random-steady-n16-f0.25-s1"
./target/release/eafl sweep --mock --scenario steady,diurnal \
  --selectors random,eafl --seeds 1 --rounds 2 --clients 16 --jobs 2 \
  --fault "crash:after-cells=1,corrupt:kind=config:cell=$FAULT_CELL" \
  --out "$FAULT_OUT" >/dev/null 2>"$FAULT_OUT/stderr.log" \
  || { echo "FAIL: fault-injected sweep failed"; cat "$FAULT_OUT/stderr.log"; exit 1; }
grep -q "retrying shard" "$FAULT_OUT/stderr.log" \
  || { echo "FAIL: supervisor never retried the crashed shards"; \
       cat "$FAULT_OUT/stderr.log"; exit 1; }
grep -q "\[quarantine\]" "$FAULT_OUT/stderr.log" \
  || { echo "FAIL: corrupt fingerprint was not quarantined"; \
       cat "$FAULT_OUT/stderr.log"; exit 1; }
ls "$FAULT_OUT"/*.quarantine >/dev/null 2>&1 \
  || { echo "FAIL: no .quarantine file preserved the corrupt bytes"; exit 1; }
cmp -s "$SMOKE_CSV" "$FAULT_OUT/sweep.campaign.csv" \
  || { echo "FAIL: fault-injected sweep changed the campaign CSV bytes"; exit 1; }
echo "    fault smoke OK (retried, quarantined, bytes identical)"

# Trace smoke: a traced 10-round run must emit a schema-tagged
# eafl-trace-v1 JSONL whose bytes are invariant across worker counts,
# drain modes and the candidate-rebuild escape hatch, on two scenarios;
# `eafl trace summarize` must then reproduce the run's own summary
# numbers from the events alone.
echo "==> trace smoke (2 scenarios, worker/drain/rebuild byte-compares)"
TRACE_OUT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT" "$EAGER_OUT" "$REBUILD_OUT" "$BUDGET_OUT" "$BUDGET_SHARD" "$BUDGET_W8" "$BUDGET_EAGER" "$FAULT_OUT" "$TRACE_OUT"' EXIT
for scenario in diurnal steady; do
  EAFL_WORKERS=1 ./target/release/eafl run --mock --selector eafl \
    --rounds 10 --clients 24 --scenario "$scenario" \
    --out "$TRACE_OUT/$scenario" \
    --trace "$TRACE_OUT/$scenario-w1.trace.jsonl" >/dev/null
  head -1 "$TRACE_OUT/$scenario-w1.trace.jsonl" \
    | grep -q '"schema": "eafl-trace-v1"' \
    || { echo "FAIL: $scenario trace missing schema header"; exit 1; }
  grep -q '"ev": "round_committed"' "$TRACE_OUT/$scenario-w1.trace.jsonl" \
    || { echo "FAIL: $scenario trace has no round_committed events"; exit 1; }
  EAFL_WORKERS=8 ./target/release/eafl run --mock --selector eafl \
    --rounds 10 --clients 24 --scenario "$scenario" \
    --out "$TRACE_OUT/$scenario" \
    --trace "$TRACE_OUT/$scenario-w8.trace.jsonl" >/dev/null
  cmp -s "$TRACE_OUT/$scenario-w1.trace.jsonl" \
         "$TRACE_OUT/$scenario-w8.trace.jsonl" \
    || { echo "FAIL: $scenario trace bytes depend on EAFL_WORKERS"; exit 1; }
  EAFL_WORKERS=1 EAFL_EAGER_DRAIN=1 ./target/release/eafl run --mock \
    --selector eafl --rounds 10 --clients 24 --scenario "$scenario" \
    --out "$TRACE_OUT/$scenario" \
    --trace "$TRACE_OUT/$scenario-eager.trace.jsonl" >/dev/null
  cmp -s "$TRACE_OUT/$scenario-w1.trace.jsonl" \
         "$TRACE_OUT/$scenario-eager.trace.jsonl" \
    || { echo "FAIL: $scenario trace bytes depend on EAFL_EAGER_DRAIN"; exit 1; }
  EAFL_WORKERS=1 EAFL_REBUILD_CANDIDATES=1 ./target/release/eafl run --mock \
    --selector eafl --rounds 10 --clients 24 --scenario "$scenario" \
    --out "$TRACE_OUT/$scenario" \
    --trace "$TRACE_OUT/$scenario-rebuild.trace.jsonl" >/dev/null
  cmp -s "$TRACE_OUT/$scenario-w1.trace.jsonl" \
         "$TRACE_OUT/$scenario-rebuild.trace.jsonl" \
    || { echo "FAIL: $scenario trace bytes depend on EAFL_REBUILD_CANDIDATES"; exit 1; }
done
./target/release/eafl trace summarize \
  "$TRACE_OUT/diurnal-w1.trace.jsonl" --out "$TRACE_OUT/figures" >/dev/null
for key in final_accuracy best_accuracy total_dropouts committed_rounds \
           total_fl_energy_j; do
  want="$(grep -o "\"$key\": [^,}]*" "$TRACE_OUT/diurnal/run-eafl.summary.json")"
  got="$(grep -o "\"$key\": [^,}]*" "$TRACE_OUT/figures/summary.json")"
  [ -n "$want" ] && [ "$want" = "$got" ] \
    || { echo "FAIL: summarize $key mismatch (run: $want, trace: $got)"; exit 1; }
done
echo "    trace smoke OK (byte-stable traces, summarize matches run summary)"

# Plan-path bench smoke: a 10k-client pass must run and emit a
# machine-readable eafl-bench-v1 JSON with the expected shape.
echo "==> plan-path bench smoke (10k clients)"
BENCH_JSON="$SMOKE_OUT/BENCH_plan.json"
cargo bench --bench plan_path_throughput -- \
  --smoke --clients 10000 --scenarios steady --out "$BENCH_JSON" >/dev/null
grep -q '"schema": "eafl-bench-v1"' "$BENCH_JSON" \
  || { echo "FAIL: bench JSON missing schema tag"; exit 1; }
grep -q '"bench": "plan_path_throughput"' "$BENCH_JSON" \
  || { echo "FAIL: bench JSON missing bench name"; exit 1; }
for key in results derived mean_ns median_ns min_ns p95_ns iterations; do
  grep -q "\"$key\"" "$BENCH_JSON" \
    || { echo "FAIL: bench JSON missing \"$key\""; exit 1; }
done
grep -q '"speedup_steady_10000"' "$BENCH_JSON" \
  || { echo "FAIL: bench JSON missing derived speedup"; exit 1; }
grep -q '"candidate_speedup_steady_10000"' "$BENCH_JSON" \
  || { echo "FAIL: bench JSON missing derived candidate-build speedup"; exit 1; }
echo "    bench smoke OK ($(basename "$BENCH_JSON"))"

if cargo clippy --version >/dev/null 2>&1; then
  echo "==> cargo clippy --all-targets -- -D warnings"
  cargo clippy --all-targets -- -D warnings
else
  echo "==> SKIP clippy (component not installed)"
fi

if cargo fmt --version >/dev/null 2>&1; then
  echo "==> cargo fmt --check"
  cargo fmt --check
else
  echo "==> SKIP rustfmt (component not installed)"
fi

echo "==> verify OK"
