//! Campaign report emission and the byte-stable shard merge.
//!
//! A campaign's merged artifacts (`<name>.campaign.json` / `.csv`) used
//! to be written inline by `campaign::run_campaign`; sharded campaigns
//! (`eafl sweep --shard I/N`) need the same emission *after the fact*,
//! over per-run files produced by several processes — possibly in
//! several output directories. This module is that seam:
//!
//!  - [`CampaignReport`] / [`CampaignRun`] — the merged result and its
//!    JSON/CSV encodings (moved here from `campaign`, which re-exports
//!    them);
//!  - [`Manifest`] — the full grid in expansion order, written as
//!    `<name>.manifest.json` by every sweep that has an output
//!    directory. All shards of one campaign derive the manifest from
//!    the same grid, so they write byte-identical files and need no
//!    coordination;
//!  - [`merge_dirs`] — the order-stable merge: cells are emitted in
//!    *manifest* order (= single-process grid order), never in shard or
//!    completion order, and each cell's `<name>.config.toml`
//!    fingerprint must hash to the manifest's recorded value. Summaries
//!    round-trip through JSON bit-exactly (see `metrics::Summary`), so
//!    a shard-then-merge campaign reproduces a single-process
//!    `eafl sweep` byte for byte — the contract
//!    `rust/tests/campaign_sharding.rs` pins across real processes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::config::SelectorKind;
use crate::metrics::Summary;
use crate::util::json::Json;

/// Manifest schema tag (bumped on incompatible layout changes).
pub const MANIFEST_SCHEMA: &str = "eafl-campaign-manifest-v1";

/// FNV-1a 64-bit — the stable hash behind both the shard partition
/// (`campaign::shard_of`) and the manifest's config fingerprints. Tiny,
/// dependency-free, and fully specified, so any process (or language)
/// can recompute the partition.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One finished run: its grid coordinates plus the end-of-run summary.
#[derive(Debug, Clone)]
pub struct CampaignRun {
    pub selector: SelectorKind,
    pub scenario: String,
    pub seed: u64,
    pub f: f64,
    pub clients: usize,
    pub summary: Summary,
}

/// The merged campaign result, in grid order.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub name: String,
    pub runs: Vec<CampaignRun>,
}

impl CampaignReport {
    /// Merged summary as JSON (in-tree codec; offline build, no serde).
    pub fn to_json(&self) -> Json {
        let runs: Vec<Json> = self
            .runs
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("selector".to_string(), Json::Str(r.selector.to_string()));
                m.insert("scenario".to_string(), Json::Str(r.scenario.clone()));
                m.insert("seed".to_string(), Json::Num(r.seed as f64));
                m.insert("f".to_string(), Json::Num(r.f));
                m.insert("clients".to_string(), Json::Num(r.clients as f64));
                m.insert("summary".to_string(), r.summary.to_json());
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("campaign".to_string(), Json::Str(self.name.clone()));
        top.insert("total_runs".to_string(), Json::Num(self.runs.len() as f64));
        top.insert("runs".to_string(), Json::Arr(runs));
        Json::Obj(top)
    }

    /// One CSV row per run (the merged table the plots consume).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "selector,scenario,seed,f,clients,rounds,committed_rounds,final_accuracy,\
             best_accuracy,final_fairness,total_dropouts,mean_round_duration_s,\
             wall_clock_h,total_fl_energy_j\n",
        );
        for r in &self.runs {
            let s = &r.summary;
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{},{:.3},{:.6},{:.3}\n",
                r.selector,
                r.scenario,
                r.seed,
                r.f,
                r.clients,
                s.rounds,
                s.committed_rounds,
                s.final_accuracy,
                s.best_accuracy,
                s.final_fairness,
                s.total_dropouts,
                s.mean_round_duration_s,
                s.wall_clock_h,
                s.total_fl_energy_j,
            ));
        }
        out
    }

    /// Mean final accuracy per selector (quick cross-seed aggregate).
    pub fn mean_accuracy_by_selector(&self) -> Vec<(SelectorKind, f64)> {
        let mut acc: Vec<(SelectorKind, f64, usize)> = Vec::new();
        for r in &self.runs {
            match acc.iter_mut().find(|(k, _, _)| *k == r.selector) {
                Some(slot) => {
                    slot.1 += r.summary.final_accuracy;
                    slot.2 += 1;
                }
                None => acc.push((r.selector, r.summary.final_accuracy, 1)),
            }
        }
        acc.into_iter().map(|(k, sum, n)| (k, sum / n as f64)).collect()
    }

    /// Total drop-outs per (scenario, selector) — the environment-
    /// differentiation signal (does `diurnal` kill a different number
    /// of clients than `steady` under the same seeds?).
    pub fn dropouts_by_scenario(&self) -> Vec<(String, SelectorKind, usize)> {
        let mut acc: Vec<(String, SelectorKind, usize)> = Vec::new();
        for r in &self.runs {
            match acc
                .iter_mut()
                .find(|(s, k, _)| *s == r.scenario && *k == r.selector)
            {
                Some(slot) => slot.2 += r.summary.total_dropouts,
                None => acc.push((r.scenario.clone(), r.selector, r.summary.total_dropouts)),
            }
        }
        acc
    }
}

/// Write the merged `<name>.campaign.json` / `<name>.campaign.csv` into
/// `dir`. The one emission path for single-process sweeps, shard merges
/// and `eafl merge` — byte-stability of the merge reduces to "same
/// [`CampaignReport`] in, same bytes out".
pub fn write_report(dir: &Path, report: &CampaignReport) -> Result<(PathBuf, PathBuf)> {
    let json_path = dir.join(format!("{}.campaign.json", report.name));
    std::fs::write(&json_path, report.to_json().to_string_pretty())
        .with_context(|| format!("writing {json_path:?}"))?;
    let csv_path = dir.join(format!("{}.campaign.csv", report.name));
    std::fs::write(&csv_path, report.to_csv())
        .with_context(|| format!("writing {csv_path:?}"))?;
    Ok((json_path, csv_path))
}

/// One grid cell's identity inside a [`Manifest`]: the coordinates that
/// name it plus the FNV-1a hash of its resolved config fingerprint
/// (the `<name>.config.toml` contents a finished run leaves behind).
#[derive(Debug, Clone, PartialEq)]
pub struct CellMeta {
    pub name: String,
    pub selector: SelectorKind,
    pub scenario: String,
    pub seed: u64,
    pub f: f64,
    pub clients: usize,
    /// `fnv1a64` of the cell's config fingerprint text, hex-encoded in
    /// JSON (u64 does not survive an f64 JSON number).
    pub fingerprint_fnv: u64,
}

/// The full expanded grid of one campaign, in expansion order — the
/// merge's ordering and completeness authority. Every shard derives it
/// from the same grid, so all shards of one campaign write identical
/// `<name>.manifest.json` bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub campaign: String,
    pub cells: Vec<CellMeta>,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(c.name.clone()));
                m.insert("selector".to_string(), Json::Str(c.selector.to_string()));
                m.insert("scenario".to_string(), Json::Str(c.scenario.clone()));
                // Decimal string, not a JSON number: a u64 seed above
                // 2^53 would round through f64 and break the merged
                // report's byte-identity with a single-process sweep.
                m.insert("seed".to_string(), Json::Str(c.seed.to_string()));
                m.insert("f".to_string(), Json::Num(c.f));
                m.insert("clients".to_string(), Json::Num(c.clients as f64));
                m.insert(
                    "fingerprint_fnv".to_string(),
                    Json::Str(format!("{:016x}", c.fingerprint_fnv)),
                );
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("schema".to_string(), Json::Str(MANIFEST_SCHEMA.to_string()));
        top.insert("campaign".to_string(), Json::Str(self.campaign.clone()));
        top.insert("total_cells".to_string(), Json::Num(self.cells.len() as f64));
        top.insert("cells".to_string(), Json::Arr(cells));
        Json::Obj(top)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let schema = j.field("schema")?.as_str().unwrap_or("");
        ensure!(
            schema == MANIFEST_SCHEMA,
            "unsupported manifest schema {schema:?} (expected {MANIFEST_SCHEMA})"
        );
        let campaign = j
            .field("campaign")?
            .as_str()
            .context("manifest campaign is not a string")?
            .to_string();
        let mut cells = Vec::new();
        for c in j.field("cells")?.as_arr().context("manifest cells is not an array")? {
            let str_field = |key: &str| -> Result<String> {
                Ok(c.field(key)?
                    .as_str()
                    .with_context(|| format!("manifest cell field {key:?} is not a string"))?
                    .to_string())
            };
            let num_field = |key: &str| -> Result<f64> {
                c.field(key)?
                    .as_f64()
                    .with_context(|| format!("manifest cell field {key:?} is not a number"))
            };
            cells.push(CellMeta {
                name: str_field("name")?,
                selector: str_field("selector")?.parse()?,
                scenario: str_field("scenario")?,
                seed: str_field("seed")?
                    .parse()
                    .context("manifest cell seed is not a u64")?,
                f: num_field("f")?,
                clients: num_field("clients")? as usize,
                fingerprint_fnv: u64::from_str_radix(&str_field("fingerprint_fnv")?, 16)
                    .context("manifest fingerprint_fnv is not hex")?,
            });
        }
        Ok(Self { campaign, cells })
    }

    /// The manifest's path inside an output directory.
    pub fn path_in(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.manifest.json", self.campaign))
    }

    /// Write `<campaign>.manifest.json` into `dir`, atomically (write
    /// to a temp file, then rename) so concurrent shards never expose a
    /// torn manifest. Identical content is left untouched; different
    /// content (the grid changed since a previous sweep into this
    /// directory) is overwritten with a warning — per-cell fingerprints
    /// keep stale summaries from leaking into the new campaign.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let path = self.path_in(dir);
        let text = self.to_json().to_string_pretty();
        if let Ok(existing) = std::fs::read_to_string(&path) {
            if existing == text {
                return Ok(path);
            }
            eprintln!(
                "[campaign] grid changed: overwriting stale manifest {}",
                path.display()
            );
        }
        let tmp = dir.join(format!(
            ".{}.manifest.{}.tmp",
            self.campaign,
            std::process::id()
        ));
        std::fs::write(&tmp, &text).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
        Ok(path)
    }
}

/// Locate the single `*.manifest.json` in `dir`; returns its path and
/// raw bytes (the merge compares manifests byte-for-byte across dirs,
/// and `eafl merge --out` copies them into the merged directory).
pub fn find_manifest(dir: &Path) -> Result<(PathBuf, String)> {
    let mut found: Vec<PathBuf> = Vec::new();
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading directory {dir:?}"))?;
    for entry in entries {
        let path = entry?.path();
        if path
            .file_name()
            .and_then(|n| n.to_str())
            .map_or(false, |n| n.ends_with(".manifest.json") && !n.starts_with('.'))
        {
            found.push(path);
        }
    }
    found.sort();
    match found.as_slice() {
        [] => bail!(
            "no campaign manifest (*.manifest.json) in {} — was this directory \
             produced by `eafl sweep`?",
            dir.display()
        ),
        [one] => {
            let text = std::fs::read_to_string(one)
                .with_context(|| format!("reading manifest {one:?}"))?;
            Ok((one.clone(), text))
        }
        many => bail!(
            "multiple campaign manifests in {}: {} — merge one campaign at a time",
            dir.display(),
            many.iter()
                .map(|p| p.display().to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    }
}

/// Load one cell's summary from `dir` if present *and* provably from
/// this campaign: the summary must parse and the cell's
/// `<name>.config.toml` fingerprint must hash to the manifest's value.
/// Anything else — missing files, torn JSON from a killed shard, stale
/// artifacts from an older grid — reads as "not here".
fn load_cell(dir: &Path, cell: &CellMeta) -> Option<Summary> {
    let fp = std::fs::read_to_string(dir.join(format!("{}.config.toml", cell.name))).ok()?;
    if fnv1a64(fp.as_bytes()) != cell.fingerprint_fnv {
        eprintln!(
            "[merge] {}: config fingerprint mismatch in {} (stale cell from a \
             different campaign?) — skipping",
            cell.name,
            dir.display()
        );
        return None;
    }
    let text = std::fs::read_to_string(dir.join(format!("{}.summary.json", cell.name))).ok()?;
    Json::parse(&text).ok().and_then(|j| Summary::from_json(&j).ok())
}

/// The order-stable merge: combine per-run artifacts from one or more
/// sweep output directories into the full [`CampaignReport`].
///
/// Rules (the shard/merge protocol, see the crate docs):
///  1. every directory must hold the *byte-identical* manifest — shards
///     of the same campaign always do; anything else is a user error;
///  2. cells are emitted in manifest order (= grid expansion order),
///     regardless of which shard ran them, in which directory they
///     landed, or when they finished;
///  3. a cell counts only if its summary parses and its config
///     fingerprint hashes to the manifest's value; directories are
///     searched in argument order and the first valid copy wins (all
///     copies are bit-identical by the determinism contract anyway);
///  4. missing cells fail the merge loudly — rerun the owning shards
///     (resume skips the finished cells) and merge again.
pub fn merge_dirs(dirs: &[PathBuf]) -> Result<CampaignReport> {
    ensure!(!dirs.is_empty(), "merge needs at least one directory");
    let (first_path, manifest_text) = find_manifest(&dirs[0])?;
    for dir in &dirs[1..] {
        let (path, text) = find_manifest(dir)?;
        ensure!(
            text == manifest_text,
            "campaign manifests disagree: {} vs {} — these directories hold \
             different campaigns (or different grids of one campaign)",
            first_path.display(),
            path.display()
        );
    }
    let manifest = Manifest::from_json(
        &Json::parse(&manifest_text)
            .with_context(|| format!("parsing manifest {first_path:?}"))?,
    )?;

    let mut runs = Vec::with_capacity(manifest.cells.len());
    let mut missing: Vec<&str> = Vec::new();
    for cell in &manifest.cells {
        match dirs.iter().find_map(|d| load_cell(d, cell)) {
            Some(summary) => runs.push(CampaignRun {
                selector: cell.selector,
                scenario: cell.scenario.clone(),
                seed: cell.seed,
                f: cell.f,
                clients: cell.clients,
                summary,
            }),
            None => missing.push(&cell.name),
        }
    }
    if !missing.is_empty() {
        let shown = missing.iter().take(8).cloned().collect::<Vec<_>>().join(", ");
        let more = missing.len().saturating_sub(8);
        let suffix = if more > 0 { format!(" (+{more} more)") } else { String::new() };
        bail!(
            "merge incomplete: {}/{} grid cells have no finished summary: {shown}{suffix} \
             — rerun the owning shards into the same --out (resume skips finished \
             cells), then merge again",
            missing.len(),
            manifest.cells.len()
        );
    }
    Ok(CampaignReport { name: manifest.campaign, runs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MetricsLog;

    fn run(scenario: &str, selector: SelectorKind, dropouts: usize) -> CampaignRun {
        let mut summary = MetricsLog::new("x").summary();
        summary.total_dropouts = dropouts;
        CampaignRun { selector, scenario: scenario.into(), seed: 1, f: 0.25, clients: 10, summary }
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a test vectors — the partition must never
        // silently change across refactors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        assert_ne!(fnv1a64(b"cell-1"), fnv1a64(b"cell-2"));
    }

    #[test]
    fn report_csv_has_one_row_per_run_plus_header() {
        let report = CampaignReport {
            name: "t".into(),
            runs: vec![run("steady", SelectorKind::Eafl, 0)],
        };
        let csv = report.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("selector,scenario,seed,f,clients,"));
        assert!(csv.lines().nth(1).unwrap().starts_with("eafl,steady,1,"));
        let parsed = Json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert_eq!(parsed.field("total_runs").unwrap().as_usize(), Some(1));
        let run0 = &parsed.field("runs").unwrap().as_arr().unwrap()[0];
        assert_eq!(run0.field("scenario").unwrap().as_str(), Some("steady"));
    }

    #[test]
    fn dropouts_by_scenario_groups_cells() {
        let report = CampaignReport {
            name: "t".into(),
            runs: vec![
                run("steady", SelectorKind::Eafl, 3),
                run("steady", SelectorKind::Eafl, 4),
                run("diurnal", SelectorKind::Eafl, 9),
            ],
        };
        let groups = report.dropouts_by_scenario();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], ("steady".to_string(), SelectorKind::Eafl, 7));
        assert_eq!(groups[1], ("diurnal".to_string(), SelectorKind::Eafl, 9));
    }

    fn manifest() -> Manifest {
        Manifest {
            campaign: "m".into(),
            cells: vec![CellMeta {
                name: "m-eafl-steady-n10-f0.25-s1".into(),
                selector: SelectorKind::Eafl,
                scenario: "steady".into(),
                seed: 1,
                f: 0.25,
                clients: 10,
                fingerprint_fnv: fnv1a64(b"cfg"),
            }],
        }
    }

    #[test]
    fn manifest_roundtrips_through_json() {
        let mut m = manifest();
        // Seeds are arbitrary u64s; above 2^53 they no longer fit an
        // f64 JSON number exactly, which is why the manifest encodes
        // them as decimal strings.
        m.cells[0].seed = u64::MAX - 1;
        let back = Manifest::from_json(&Json::parse(&m.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back, m);
        assert_eq!(back.cells[0].seed, u64::MAX - 1);
    }

    #[test]
    fn manifest_rejects_wrong_schema() {
        let mut j = manifest().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::Str("bogus".into()));
        }
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn manifest_write_is_idempotent_and_detects_grid_changes() {
        let dir = std::env::temp_dir().join(format!("eafl-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m = manifest();
        let path = m.write(&dir).unwrap();
        let bytes = std::fs::read_to_string(&path).unwrap();
        // Re-writing the same manifest leaves the bytes untouched.
        m.write(&dir).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), bytes);
        // A changed grid overwrites (with a stderr warning).
        let mut m2 = m.clone();
        m2.cells[0].seed = 2;
        m2.write(&dir).unwrap();
        assert_ne!(std::fs::read_to_string(&path).unwrap(), bytes);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_requires_manifest_and_complete_cells() {
        let dir = std::env::temp_dir().join(format!("eafl-merge-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        // No manifest at all.
        let err = merge_dirs(&[dir.clone()]).unwrap_err().to_string();
        assert!(err.contains("manifest"), "{err}");

        // Manifest but no cell artifacts: the missing cell is named.
        let m = manifest();
        m.write(&dir).unwrap();
        let err = merge_dirs(&[dir.clone()]).unwrap_err().to_string();
        assert!(err.contains("m-eafl-steady-n10-f0.25-s1"), "{err}");

        // Cell artifacts with the right fingerprint merge cleanly.
        let summary = MetricsLog::new("m-eafl-steady-n10-f0.25-s1").summary();
        std::fs::write(
            dir.join("m-eafl-steady-n10-f0.25-s1.summary.json"),
            summary.to_json().to_string_pretty(),
        )
        .unwrap();
        std::fs::write(dir.join("m-eafl-steady-n10-f0.25-s1.config.toml"), "cfg").unwrap();
        let report = merge_dirs(&[dir.clone()]).unwrap();
        assert_eq!(report.name, "m");
        assert_eq!(report.runs.len(), 1);
        assert_eq!(report.runs[0].scenario, "steady");

        // A wrong fingerprint makes the cell invisible again.
        std::fs::write(dir.join("m-eafl-steady-n10-f0.25-s1.config.toml"), "other").unwrap();
        assert!(merge_dirs(&[dir.clone()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_rejects_disagreeing_manifests() {
        let base = std::env::temp_dir().join(format!("eafl-mergedis-{}", std::process::id()));
        let d0 = base.join("a");
        let d1 = base.join("b");
        std::fs::create_dir_all(&d0).unwrap();
        std::fs::create_dir_all(&d1).unwrap();
        let m = manifest();
        m.write(&d0).unwrap();
        let mut m2 = m.clone();
        m2.cells[0].seed = 9;
        m2.write(&d1).unwrap();
        let err = merge_dirs(&[d0, d1]).unwrap_err().to_string();
        assert!(err.contains("disagree"), "{err}");
        std::fs::remove_dir_all(&base).ok();
    }
}
