"""Layer-1 Pallas kernel: fused, tile-blocked `act(x @ w + b)`.

This is the model's FLOP hot spot (the two dense layers of the speech
CNN). The kernel is written TPU-idiomatically even though this image can
only run it under ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls — see DESIGN.md §Hardware-Adaptation):

 - the grid tiles the output over (M/bm, N/bn); each program instance
   holds one (bm, K) x-panel, one (K, bn) w-panel and its (bm, bn) output
   tile in VMEM — the BlockSpec index maps ARE the HBM->VMEM schedule;
 - the contraction runs on the MXU path (``preferred_element_type=f32``
   accumulation);
 - block sizes default to MXU/VPU-friendly multiples (8 sublanes x 128
   lanes) and inputs are zero-padded up to tile boundaries, then the
   result is sliced back.

Because ``pallas_call`` has no autodiff rule, ``dense`` is wrapped in a
``jax.custom_vjp`` whose backward pass reuses the same kernel (bias-less,
no activation) for dx = g @ w^T and dw = x^T @ g, so the Pallas code path
is exercised by *both* the forward and backward HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU lane / sublane granularity on TPU; used to pick tile sizes.
_SUBLANE = 8
_LANE = 128


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def pick_blocks(m: int, n: int, k: int) -> tuple[int, int]:
    """Choose (bm, bn) output-tile sizes.

    Keeps the working set (x-panel + w-panel + out-tile, f32) within a
    conservative VMEM budget while using hardware-aligned tile shapes.

    Perf note (EXPERIMENTS.md §Perf, L1 iteration 1): bn is aligned to
    64 rather than the full 128-lane vreg width. For this model's
    narrow dense layers (n = 64 and n = 35) padding N up to 128 doubles
    the tile FLOPs for zero output; a 64-wide MXU pass trades a lane
    relayout for half the padded work — occupancy on the training-shape
    dense1 (20x1024x64) rises 0.42 -> 0.83.
    """
    bm = min(_round_up(m, _SUBLANE), 128)
    bn = min(_round_up(n, 64), 256)
    # VMEM budget ~= 4 MiB of the ~16 MiB/core, leaving room for
    # double-buffering by the pipeline.
    budget = 4 * 1024 * 1024
    while (bm * k + k * bn + bm * bn) * 4 > budget and bm > _SUBLANE:
        bm //= 2
    while (bm * k + k * bn + bm * bn) * 4 > budget and bn > 64:
        bn //= 2
    return max(bm, _SUBLANE), max(bn, min(64, _round_up(n, _SUBLANE)))


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One (bm, bn) output tile: act(x_panel @ w_panel + b_tile)."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("activation", "interpret"))
def dense_fwd_kernel(x, w, b, activation: str = "id", interpret: bool = True):
    """Raw (non-differentiable) fused dense kernel: act(x @ w + b)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn = pick_blocks(m, n, k)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))
    out = pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def matmul_kernel(x, w, interpret: bool = True):
    """Bias-less, activation-less Pallas matmul (backward-pass worker)."""
    zeros = jnp.zeros((w.shape[1],), jnp.float32)
    return dense_fwd_kernel(x, w, zeros, activation="id", interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation: str = "id"):
    """Differentiable fused dense layer: act(x @ w + b), Pallas fwd+bwd."""
    return dense_fwd_kernel(x, w, b, activation=activation)


def _dense_vjp_fwd(x, w, b, activation):
    y = dense_fwd_kernel(x, w, b, activation=activation)
    return y, (x, w, y)


def _dense_vjp_bwd(activation, res, g):
    x, w, y = res
    if activation == "relu":
        # y is the post-relu output; its positivity mask is the relu grad.
        g = g * (y > 0.0).astype(g.dtype)
    dx = matmul_kernel(g, w.T)
    dw = matmul_kernel(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_vjp_fwd, _dense_vjp_bwd)
