//! Jain's fairness index over per-client selection counts (Fig. 3c):
//!
//! J(x) = (Σ x_i)² / (n · Σ x_i²),  J ∈ [1/n, 1]
//!
//! J = 1 when every client has participated equally; J → 1/n as
//! participation concentrates on a single client. The paper plots J
//! over the whole population as training unwinds.

/// Jain's fairness index of `counts`. Returns 1.0 for an empty or
/// all-zero population (vacuously fair).
pub fn jain_index(counts: &[u64]) -> f64 {
    if counts.is_empty() {
        return 1.0;
    }
    let sum: f64 = counts.iter().map(|&c| c as f64).sum();
    if sum == 0.0 {
        return 1.0;
    }
    let sum_sq: f64 = counts.iter().map(|&c| (c as f64) * (c as f64)).sum();
    (sum * sum) / (counts.len() as f64 * sum_sq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfectly_equal_is_one() {
        assert!((jain_index(&[3, 3, 3, 3]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_hog_is_one_over_n() {
        let j = jain_index(&[10, 0, 0, 0, 0]);
        assert!((j - 0.2).abs() < 1e-12);
    }

    #[test]
    fn bounds_hold() {
        let counts = [7, 1, 0, 4, 2, 9];
        let j = jain_index(&counts);
        assert!(j > 1.0 / counts.len() as f64 && j < 1.0);
    }

    #[test]
    fn empty_and_zero_are_vacuously_fair() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0, 0, 0]), 1.0);
    }

    #[test]
    fn more_even_is_fairer() {
        assert!(jain_index(&[5, 5, 4, 6]) > jain_index(&[1, 9, 0, 10]));
    }
}
