//! Lazy-drain equivalence properties: the registry's deferred
//! background drain (per-class cumsums + death wheel + settle-on-touch)
//! must be observably *bit-identical* to the eager mode that
//! materializes every battery every epoch — under arbitrary
//! interleavings of epoch advances, FL drains, charges, revivals and
//! direct guard touches, including mid-interval deaths and deaths
//! landing exactly on wheel bucket boundaries.
//!
//! "Observably" means everything downstream of the registry can see:
//! effective charges, liveness, death timestamps, FL energy, the
//! closed-form alive-mean, the incremental aggregates, and the raw
//! charge bits after a full materialization. (Per-client *background*
//! energy is compared with a tolerance instead: the two modes sum the
//! same drain in different associations, which may differ in the last
//! ulp — and nothing exported ever reads it, see `report.rs`.)

use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::{
    AvailabilityView, CooldownRecharge, PoolAggregates, RechargePolicy, Registry,
};
use eafl::util::prop::forall;
use eafl::util::rng::Rng;

fn build_pair(rng: &mut Rng) -> (ExperimentConfig, Registry, Registry) {
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.federation.num_clients = rng.gen_range_usize(5, 40);
    cfg.devices.seed = rng.next_u64();
    cfg.network.seed = rng.next_u64();
    cfg.data.seed = rng.next_u64();
    cfg.data.min_samples = 3;
    cfg.data.max_samples = 8;
    let lazy = Registry::build(&cfg, 35, 1000);
    let eager = Registry::build(&cfg, 35, 1000);
    (cfg, lazy, eager)
}

/// Every registry observable the engine consumes must agree bit for bit
/// between the lazy registry and its eagerly-settled twin.
fn assert_equivalent(lazy: &Registry, eager: &Registry, ctx: &str) {
    assert_eq!(lazy.len(), eager.len());
    for id in 0..lazy.len() {
        let (a, b) = (&lazy.client(id).battery, &eager.client(id).battery);
        assert_eq!(
            lazy.effective_charge_j(id).to_bits(),
            eager.effective_charge_j(id).to_bits(),
            "{ctx}: effective charge diverged at id {id} ({} vs {})",
            lazy.effective_charge_j(id),
            eager.effective_charge_j(id)
        );
        assert_eq!(
            lazy.effective_battery_frac(id).to_bits(),
            eager.effective_battery_frac(id).to_bits(),
            "{ctx}: effective fraction diverged at id {id}"
        );
        assert_eq!(a.is_alive(), b.is_alive(), "{ctx}: liveness diverged at id {id}");
        assert_eq!(a.died_at_h, b.died_at_h, "{ctx}: death stamp diverged at id {id}");
        assert_eq!(
            a.fl_energy_j.to_bits(),
            b.fl_energy_j.to_bits(),
            "{ctx}: FL energy diverged at id {id}"
        );
        assert!(
            (a.background_energy_j - b.background_energy_j).abs() < 1e-6,
            "{ctx}: background energy drifted beyond ulp noise at id {id}"
        );
    }
    assert_eq!(lazy.alive_count(), eager.alive_count(), "{ctx}: alive count");
    assert_eq!(
        lazy.mean_battery_alive().to_bits(),
        eager.mean_battery_alive().to_bits(),
        "{ctx}: closed-form alive-mean diverged ({} vs {})",
        lazy.mean_battery_alive(),
        eager.mean_battery_alive()
    );
    assert_eq!(lazy.background_cumsum(), eager.background_cumsum(), "{ctx}: cumsums");
}

/// Randomized interleavings: the lazy registry defers, the eager twin
/// settles the whole population after every epoch advance; every
/// observable must stay bitwise in lockstep the whole way.
#[test]
fn prop_lazy_equals_eager_under_random_interleavings() {
    forall(48, |rng| {
        let (_cfg, mut lazy, mut eager) = build_pair(rng);
        let n = lazy.len();
        let mut clock = 0.0f64;
        let steps = rng.gen_range_usize(10, 80);
        for step in 0..steps {
            match rng.gen_range_usize(0, 8) {
                // Epoch advance — the one place the modes differ in
                // mechanism (deferred vs. swept) and must not differ in
                // outcome.
                0 | 1 | 2 => {
                    let hours = [0.25, 0.5, 1.0, 1.0 / 1024.0, 0.37][rng.gen_range_usize(0, 4)];
                    let idle = rng.gen_range_f64(0.0, 0.05);
                    let busy = rng.gen_range_f64(0.0, 0.1);
                    let participants: Vec<usize> =
                        (0..n).filter(|_| rng.gen_bool(0.2)).collect();
                    clock += hours;
                    lazy.advance_background(&participants, idle, busy, hours, clock);
                    eager.advance_background(&participants, idle, busy, hours, clock);
                    eager.settle_all();
                }
                // FL drain — sometimes lethal mid-epoch.
                3 => {
                    let id = rng.gen_range_usize(0, n - 1);
                    let e = lazy.client(id).battery.capacity_joules()
                        * rng.gen_range_f64(0.0, 1.5);
                    lazy.drain_fl(id, e, clock);
                    eager.drain_fl(id, e, clock);
                }
                // Per-id guard drain (legacy API) — a touch that
                // settles-then-drains in lazy mode.
                4 => {
                    let id = rng.gen_range_usize(0, n - 1);
                    let e = lazy.client(id).battery.capacity_joules()
                        * rng.gen_range_f64(0.0, 0.2);
                    lazy.drain_background(id, e, clock);
                    eager.drain_background(id, e, clock);
                }
                5 => {
                    let id = rng.gen_range_usize(0, n - 1);
                    let e = lazy.client(id).battery.capacity_joules()
                        * rng.gen_range_f64(0.0, 0.6);
                    lazy.charge_add(id, e);
                    eager.charge_add(id, e);
                }
                // Revive / set level — small targets set up future
                // wheel deaths.
                6 => {
                    let id = rng.gen_range_usize(0, n - 1);
                    let f = if rng.gen_bool(0.5) {
                        rng.gen_range_f64(0.0, 0.02)
                    } else {
                        rng.gen_f64()
                    };
                    lazy.recharge_to(id, f);
                    eager.recharge_to(id, f);
                }
                // Materializing the lazy side is semantically a no-op:
                // equivalence must survive it at any point.
                _ => lazy.settle_all(),
            }
            assert_equivalent(&lazy, &eager, &format!("step {step}"));
            assert_eq!(*eager.aggregates(), PoolAggregates::recompute(&eager));
        }
        // Full materialization lands the raw batteries on the eager
        // twin's exact bits, and the lazy aggregates match brute force.
        lazy.settle_all();
        for id in 0..n {
            assert_eq!(
                lazy.client(id).battery.charge_joules().to_bits(),
                eager.client(id).battery.charge_joules().to_bits(),
                "settled raw charge diverged at id {id}"
            );
        }
        assert_eq!(*lazy.aggregates(), PoolAggregates::recompute(&lazy));
    });
}

fn fixed_pair(n: usize) -> (Registry, Registry) {
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.federation.num_clients = n;
    cfg.data.min_samples = 3;
    cfg.data.max_samples = 8;
    (Registry::build(&cfg, 35, 1000), Registry::build(&cfg, 35, 1000))
}

/// Deaths landing *exactly* on wheel bucket boundaries: charges and
/// rates are binary fractions, so client `id`'s remaining lifetime is
/// exactly `id+1` epochs and its effective charge hits exactly 0.0 at
/// that epoch — the wheel must kill it on that advance (not a bucket
/// early, not one late), identically in both modes.
#[test]
fn bucket_boundary_deaths_fire_on_the_exact_epoch() {
    let n = 8;
    let (mut lazy, mut eager) = fixed_pair(n);
    for id in 0..n {
        let f = (id + 1) as f64 / 1024.0; // exact binary fraction
        lazy.recharge_to(id, f);
        eager.recharge_to(id, f);
    }
    let rate = 1.0 / 1024.0; // fraction of capacity per hour, exact
    for epoch in 1..=n as u64 + 2 {
        let clock = epoch as f64;
        lazy.advance_background(&[], rate, rate, 1.0, clock);
        eager.advance_background(&[], rate, rate, 1.0, clock);
        eager.settle_all();
        assert_equivalent(&lazy, &eager, &format!("epoch {epoch}"));
        for id in 0..n {
            let lifetime = id as u64 + 1;
            let b = &lazy.client(id).battery;
            assert_eq!(
                b.is_alive(),
                epoch < lifetime,
                "client {id} must die exactly at epoch {lifetime}, epoch={epoch}"
            );
            if epoch >= lifetime {
                assert_eq!(b.died_at_h, Some(lifetime as f64), "client {id}");
                assert_eq!(lazy.effective_charge_j(id), 0.0);
            }
        }
    }
    assert_eq!(lazy.alive_count(), 0);
}

/// A battery that runs dry strictly *inside* an epoch is stamped dead
/// at the epoch's end clock in both modes — background drain is applied
/// at round granularity, so end-of-round is the authoritative instant.
#[test]
fn mid_interval_deaths_stamp_the_epoch_end_in_both_modes() {
    let (mut lazy, mut eager) = fixed_pair(3);
    // 1.5/1024 of charge at 1/1024 per hour: dies halfway through the
    // second 1 h epoch.
    for r in [&mut lazy, &mut eager] {
        r.recharge_to(0, 1.5 / 1024.0);
    }
    let rate = 1.0 / 1024.0;
    for epoch in 1..=2u64 {
        let clock = epoch as f64;
        lazy.advance_background(&[], rate, rate, 1.0, clock);
        eager.advance_background(&[], rate, rate, 1.0, clock);
        eager.settle_all();
    }
    assert_equivalent(&lazy, &eager, "mid-interval death");
    assert!(!lazy.client(0).battery.is_alive());
    assert_eq!(lazy.client(0).battery.died_at_h, Some(2.0), "stamped at epoch end");
    assert_eq!(lazy.effective_charge_j(0), 0.0, "sub-zero residual clamps");
}

/// Refresh the incremental arena and require it to match a from-scratch
/// `fill_candidates` rebuild (ids, order, drain-effective fractions).
fn assert_arena_matches(r: &mut Registry, round: u64, floor: f64, ctx: &str) {
    r.refresh_eligible(round, floor, AvailabilityView::AlwaysOn);
    let mut reference = Vec::new();
    r.fill_candidates(round, floor, |_| true, &mut reference);
    let got = r.eligible();
    assert_eq!(got.len(), reference.len(), "{ctx}: candidate count");
    for (a, b) in got.iter().zip(&reference) {
        assert_eq!(a.id, b.id, "{ctx}: membership/order");
        assert_eq!(
            a.battery_frac.to_bits(),
            b.battery_frac.to_bits(),
            "{ctx}: drain-effective fraction at id {}",
            a.id
        );
        assert_eq!(
            a.expected_duration_s.to_bits(),
            b.expected_duration_s.to_bits(),
            "{ctx}: projection at id {}",
            a.id
        );
    }
}

/// A `CooldownRecharge` revival re-enters the incremental eligible
/// arena *in the same round it revives*: the recharge flows through the
/// battery guard, whose mirror sync dirty-marks the arena, so the very
/// next `refresh_eligible` re-admits the client in O(changed) — no
/// rebuild, no extra round of latency — identically in both drain
/// modes (eager emulated with an explicit per-epoch `settle_all`, as
/// the `EAFL_EAGER_DRAIN=1` latch is process-wide).
#[test]
fn cooldown_revival_is_immediately_eligible_in_the_patched_arena() {
    let floor = 0.05;
    let policy = CooldownRecharge { after_hours: 1.0, to_fraction: 0.8 };
    let (mut lazy, mut eager) = fixed_pair(6);

    for (name, r, eager_mode) in [("lazy", &mut lazy, false), ("eager", &mut eager, true)] {
        // Round 1: arena built with everyone alive; client 2 then dies
        // of FL work and the fleet pays a background epoch.
        assert_arena_matches(r, 1, floor, name);
        assert!(r.eligible().iter().any(|c| c.id == 2));
        let cap = r.client(2).battery.capacity_joules();
        r.drain_fl(2, cap * 2.0, 1.0);
        r.advance_background(&[], 0.001, 0.002, 1.0, 1.0);
        if eager_mode {
            r.settle_all();
        }

        // Round 2: dead ⇒ evicted from the patched arena.
        assert_arena_matches(r, 2, floor, name);
        assert!(r.eligible().iter().all(|c| c.id != 2), "{name}: dead client evicted");

        // The cooldown elapses over round 2's window and the policy
        // revives client 2 at its end — exactly where the engine runs
        // recharge, between drain and the next round's plan.
        r.advance_background(&[], 0.001, 0.002, 1.5, 2.5);
        if eager_mode {
            r.settle_all();
        }
        policy.apply(r, 1.0, 2.5);
        assert!(r.client(2).battery.is_alive(), "{name}: revived");

        // Round 3: the revival's guard sync already queued client 2, so
        // the patch pass re-admits it with its recharged fraction.
        assert_arena_matches(r, 3, floor, name);
        let revived = r
            .eligible()
            .iter()
            .find(|c| c.id == 2)
            .unwrap_or_else(|| panic!("{name}: revived client eligible in the same round"));
        assert!((revived.battery_frac - 0.8).abs() < 1e-12, "{name}: recharged level");
    }
}

/// Participants of a round are exempt from that round's background
/// epoch — their anchors move to the new cumsum without paying it — and
/// both modes agree on the resulting charges.
#[test]
fn participant_exemption_is_mode_independent() {
    let (mut lazy, mut eager) = fixed_pair(6);
    let participants = [1usize, 4];
    for epoch in 1..=5u64 {
        let clock = epoch as f64 * 0.5;
        lazy.advance_background(&participants, 0.01, 0.02, 0.5, clock);
        eager.advance_background(&participants, 0.01, 0.02, 0.5, clock);
        eager.settle_all();
        assert_equivalent(&lazy, &eager, &format!("epoch {epoch}"));
    }
    // Participants were exempt every epoch: still at full charge.
    lazy.settle_all();
    for id in participants {
        assert_eq!(
            lazy.client(id).battery.background_energy_j,
            0.0,
            "participant {id} must not pay background drain"
        );
    }
}
