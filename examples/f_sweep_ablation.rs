//! Ablation over EAFL's f (Eq. 1 blend weight) — the paper's §3.1 Q2
//! trade-off between model quality and energy efficiency.
//!
//! Sweeps f ∈ {0, 0.25, 0.5, 0.75, 1.0} under identical seeds:
//!  - f = 0    → pure battery chasing (selection ignores utility),
//!  - f = 0.25 → the paper's operating point,
//!  - f = 1    → pure Oort (battery-oblivious).
//!
//! Expected shape: drop-outs increase with f; time-to-accuracy improves
//! with f until drop-outs erase the gain.
//!
//! Run: cargo run --release --example f_sweep_ablation -- [--mock] [--rounds N]

use anyhow::Result;

use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::runtime::{MockRuntime, ModelRuntime, XlaRuntime};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let use_mock = args.iter().any(|a| a == "--mock");
    let rounds = args
        .iter()
        .position(|a| a == "--rounds")
        .and_then(|i| args.get(i + 1))
        .map(|v| v.parse::<usize>().expect("--rounds N"))
        .unwrap_or(if use_mock { 150 } else { 60 });

    let runtime: Box<dyn ModelRuntime> = if use_mock {
        Box::new(MockRuntime::default())
    } else {
        Box::new(XlaRuntime::load(&XlaRuntime::default_dir())?)
    };

    println!(
        "{:<6} {:>9} {:>9} {:>10} {:>12} {:>10} {:>12}",
        "f", "acc", "fairness", "dropouts", "mean_rnd(s)", "wall(h)", "energy(kJ)"
    );
    for f in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let mut cfg = ExperimentConfig::paper_default(SelectorKind::Eafl);
        cfg.name = format!("fsweep-{f}");
        cfg.federation.rounds = rounds;
        cfg.federation.num_clients = 100;
        cfg.selector.eafl_f = f;
        // Battery-tight scenario so the energy term has bite.
        cfg.devices.min_init_battery = 0.15;
        cfg.devices.max_init_battery = 0.7;
        let log = Coordinator::new(cfg, runtime.as_ref())?.run()?;
        let s = log.summary();
        println!(
            "{:<6} {:>9.4} {:>9.3} {:>10} {:>12.1} {:>10.2} {:>12.1}",
            f,
            s.final_accuracy,
            s.final_fairness,
            s.total_dropouts,
            s.mean_round_duration_s,
            s.wall_clock_h,
            s.total_fl_energy_j / 1000.0
        );
    }
    Ok(())
}
