//! Multi-experiment campaign runner — the paper's figures are grids,
//! not single runs (Figs. 3–4 are selector × seed sweeps, the ablation
//! is an f sweep), so the unit of work here is a whole *campaign*:
//!
//!  1. [`CampaignGrid`] expands selectors × scenarios × seeds ×
//!     f-values × client counts against a base [`ExperimentConfig`]
//!     into named run configs (empty axes inherit the base value);
//!  2. [`run_campaign`] executes the runs across `jobs` worker threads
//!     — experiments are embarrassingly parallel, each gets its own
//!     [`Coordinator`] pinned to 1 execution worker so threads × runs
//!     don't oversubscribe — sharing one `&dyn ModelRuntime`;
//!  3. per-run CSV/summary files plus a merged `campaign.json` and
//!     `campaign.csv` land in the output directory.
//!
//! Deterministic: a run's seeds derive only from its grid coordinates,
//! so any subset of a campaign reproduces bit-identically, at any job
//! count, in any execution order. That is also what makes **resume**
//! sound: when the output directory already holds a partial campaign
//! (a merged campaign.json and/or per-run summary.json files), grid
//! cells whose names match are reloaded instead of recomputed — the
//! cell name encodes every coordinate, and summaries round-trip through
//! JSON bit-exactly.
//!
//! The same property scales campaigns past one process: a
//! [`config::ShardSpec`] on the [`CampaignSpec`] restricts execution to
//! the grid cells whose *name* hashes to this shard ([`shard_of`] —
//! FNV-1a of the cell name mod shard count, so any process computes the
//! same partition with zero coordination). Shards write the same
//! per-run artifacts plus a shared grid manifest
//! ([`report::Manifest`]); [`report::merge_dirs`] then reassembles the
//! full campaign in grid order, byte-identical to a single-process
//! sweep.

pub mod supervisor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use anyhow::{Context, Result};

use crate::config::{ExperimentConfig, SelectorKind, ShardSpec};
use crate::coordinator::Coordinator;
use crate::fault::{self, ArtifactKind};
use crate::metrics::Summary;
use crate::report::{fnv1a64, CellMeta, Manifest};
use crate::runtime::ModelRuntime;
use crate::util::json::Json;

pub use crate::report::{CampaignReport, CampaignRun};

/// The sweep axes. Empty `scenarios` / `f_values` / `client_counts` /
/// `budgets` inherit the base config's value (a single grid point on
/// that axis).
#[derive(Debug, Clone)]
pub struct CampaignGrid {
    pub selectors: Vec<SelectorKind>,
    /// Scenario names or TOML file paths (see `scenario::Scenario`).
    pub scenarios: Vec<String>,
    pub seeds: Vec<u64>,
    pub f_values: Vec<f64>,
    pub client_counts: Vec<usize>,
    /// Campaign energy budgets in joules (`selector.budget_j`); 0 means
    /// unlimited. An explicit axis tags run names with `-b{budget}` so
    /// the energy/accuracy frontier's cells stay uniquely named; an
    /// empty axis inherits the base value and leaves names untouched —
    /// budget-less campaigns keep byte-identical artifacts.
    pub budgets: Vec<f64>,
}

impl Default for CampaignGrid {
    /// The headline comparison grid: all three selectors × three seeds
    /// at the base config's scenario, f and population.
    fn default() -> Self {
        Self {
            selectors: vec![SelectorKind::Eafl, SelectorKind::Oort, SelectorKind::Random],
            scenarios: Vec::new(),
            seeds: vec![1, 2, 3],
            f_values: Vec::new(),
            client_counts: Vec::new(),
            budgets: Vec::new(),
        }
    }
}

/// A whole campaign: base config + grid + parallelism.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Campaign name (used in the merged output file names).
    pub name: String,
    pub base: ExperimentConfig,
    pub grid: CampaignGrid,
    /// Experiments to run concurrently.
    pub jobs: usize,
    /// Execution-phase worker threads inside each experiment (the
    /// campaign default of 1 makes experiments the parallel unit).
    pub workers_per_run: usize,
    /// Skip grid cells the output directory already holds summaries
    /// for (on by default; `--fresh` recomputes everything).
    pub resume: bool,
    /// Run only the grid cells this shard owns (`None` = the whole
    /// grid). Partitioning is by [`shard_of`] over the cell name, so
    /// shards compose without coordination; a shard with `count > 1`
    /// writes per-run artifacts and the grid manifest but *not* the
    /// merged report — that is `report::merge_dirs`'s job once every
    /// shard has finished.
    pub shard: Option<ShardSpec>,
    /// Write a per-cell `eafl-trace-v1` event trace
    /// (`<cell>.trace.jsonl`) into this directory. Cells are traced as
    /// they *run*: resumed cells are loaded from their summaries and do
    /// not re-emit a trace. Because sharding partitions cells by name,
    /// shards sharing one trace directory write disjoint files, and the
    /// per-cell bytes are identical to a single-process sweep's.
    pub trace_dir: Option<PathBuf>,
}

impl CampaignSpec {
    pub fn new(name: impl Into<String>, base: ExperimentConfig) -> Self {
        Self {
            name: name.into(),
            base,
            grid: CampaignGrid::default(),
            jobs: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            workers_per_run: 1,
            resume: true,
            shard: None,
            trace_dir: None,
        }
    }
}

/// Which shard of `count` owns the grid cell named `name`: a stable
/// FNV-1a hash of the name, mod the shard count. Properties the
/// sharding protocol rests on: (1) deterministic — any process, any
/// host, computes the same owner; (2) a function of the *name* only, so
/// it survives grid reorderings and axis insertions as long as the cell
/// itself (whose name encodes every coordinate) is unchanged.
pub fn shard_of(name: &str, count: usize) -> usize {
    if count <= 1 {
        return 0;
    }
    (fnv1a64(name.as_bytes()) % count as u64) as usize
}

/// Build the campaign's grid [`Manifest`]: every cell of the *full*
/// expanded grid, in expansion order, with its config-fingerprint hash.
/// Shards all derive this from the same spec, so their manifest bytes
/// are identical — which is exactly what `report::merge_dirs` checks.
pub fn build_manifest(spec: &CampaignSpec, runs: &[RunSpec]) -> Result<Manifest> {
    let mut cells = Vec::with_capacity(runs.len());
    for run in runs {
        cells.push(CellMeta {
            name: run.cfg.name.clone(),
            selector: run.selector,
            scenario: run.scenario.clone(),
            seed: run.seed,
            f: run.f,
            clients: run.clients,
            budget_j: run.budget_j,
            fingerprint_fnv: fnv1a64(cell_fingerprint(&run.cfg)?.as_bytes()),
        });
    }
    Ok(Manifest { campaign: spec.name.clone(), cells })
}

/// One grid point: the coordinates plus the fully resolved config.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub selector: SelectorKind,
    pub scenario: String,
    pub seed: u64,
    pub f: f64,
    pub clients: usize,
    /// Campaign energy budget in joules (0 = unlimited).
    pub budget_j: f64,
    pub cfg: ExperimentConfig,
}

/// Derive every per-run RNG stream from the grid seed so seeds — not
/// incidental config state — pin the run.
fn apply_seed(cfg: &mut ExperimentConfig, seed: u64) {
    cfg.data.seed = seed;
    cfg.devices.seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    cfg.network.seed = seed.wrapping_mul(0xBF58_476D_1CE4_E5B9).wrapping_add(2);
    cfg.training.init_seed = (seed as u32).wrapping_mul(2_654_435_761).wrapping_add(3);
}

/// Expand the grid into fully resolved, uniquely named run configs.
/// Order: selector (outermost) → scenario → clients → f → budget →
/// seed; the f axis only applies to EAFL (other selectors ignore f and
/// get a single point), the budget axis applies to every selector (the
/// energy ledger gates the round loop engine-side). Scenario file paths
/// are carried verbatim into `cfg.scenario` but their display name
/// (file stem) goes into the run name.
pub fn expand(spec: &CampaignSpec) -> Vec<RunSpec> {
    let scenarios: Vec<String> = if spec.grid.scenarios.is_empty() {
        vec![spec.base.scenario.clone()]
    } else {
        spec.grid.scenarios.clone()
    };
    let f_values: Vec<f64> = if spec.grid.f_values.is_empty() {
        vec![spec.base.selector.eafl_f]
    } else {
        spec.grid.f_values.clone()
    };
    let client_counts: Vec<usize> = if spec.grid.client_counts.is_empty() {
        vec![spec.base.federation.num_clients]
    } else {
        spec.grid.client_counts.clone()
    };
    // Only an *explicit* budget axis tags run names: budget-less
    // campaigns (and ones whose base config carries a budget) must keep
    // the exact names earlier releases produced, or resume and sharded
    // merges of existing output directories would recompute everything.
    let explicit_budgets = !spec.grid.budgets.is_empty();
    let budgets: Vec<f64> = if explicit_budgets {
        spec.grid.budgets.clone()
    } else {
        vec![spec.base.selector.budget_j]
    };
    // Labels must be unique per scenario axis value: two files that
    // share a stem (configs/a/night.toml, configs/b/night.toml) would
    // otherwise collide on run names and overwrite each other's output.
    let labels: Vec<String> = {
        let mut seen: Vec<String> = Vec::new();
        scenarios
            .iter()
            .map(|s| {
                let base = scenario_label(s);
                let mut label = base.clone();
                let mut n = 2;
                while seen.contains(&label) {
                    label = format!("{base}-{n}");
                    n += 1;
                }
                seen.push(label.clone());
                label
            })
            .collect()
    };
    let mut runs = Vec::new();
    for &selector in &spec.grid.selectors {
        // f only parameterizes EAFL's Eq. (1) reward; Oort and Random
        // never read it, so for them the axis collapses to one point —
        // otherwise every extra f value would repeat identical runs.
        let selector_f: &[f64] = if selector == SelectorKind::Eafl {
            &f_values
        } else {
            &f_values[..1]
        };
        for (scenario, label) in scenarios.iter().zip(&labels) {
            for &clients in &client_counts {
                for &f in selector_f {
                    for &budget in &budgets {
                        for &seed in &spec.grid.seeds {
                            let mut cfg = spec.base.clone();
                            cfg.selector.kind = selector;
                            cfg.selector.eafl_f = f;
                            cfg.selector.budget_j = budget;
                            cfg.scenario = scenario.clone();
                            cfg.federation.num_clients = clients;
                            cfg.federation.participants_per_round =
                                cfg.federation.participants_per_round.min(clients);
                            apply_seed(&mut cfg, seed);
                            cfg.name = if explicit_budgets {
                                format!(
                                    "{}-{selector}-{label}-n{clients}-f{f}-b{budget}-s{seed}",
                                    spec.name
                                )
                            } else {
                                format!(
                                    "{}-{selector}-{label}-n{clients}-f{f}-s{seed}",
                                    spec.name
                                )
                            };
                            runs.push(RunSpec {
                                selector,
                                scenario: label.clone(),
                                seed,
                                f,
                                clients,
                                budget_j: budget,
                                cfg,
                            });
                        }
                    }
                }
            }
        }
    }
    runs
}

/// Display label for a scenario axis value: preset names pass through,
/// file paths collapse to their stem so run names stay filesystem-safe.
fn scenario_label(scenario: &str) -> String {
    Path::new(scenario)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or(scenario)
        .to_string()
}

/// Byte-exact identity of a grid cell: the run's config plus the
/// *resolved* scenario. A file-based scenario contributes its contents,
/// not just its path, so editing the file invalidates cached cells.
fn cell_fingerprint(cfg: &ExperimentConfig) -> Result<String> {
    let scenario = crate::scenario::Scenario::resolve(&cfg.scenario)?;
    Ok(format!(
        "{}\n# --- resolved scenario ---\n{}",
        cfg.to_toml(),
        scenario.to_toml()
    ))
}

fn run_one(
    run: &RunSpec,
    runtime: &dyn ModelRuntime,
    out_dir: Option<&Path>,
    workers_per_run: usize,
    trace_dir: Option<&Path>,
) -> Result<CampaignRun> {
    let cfg = run.cfg.clone();
    let name = cfg.name.clone();
    // The coordinator (and with it the trace sink, which flushes at end
    // of run and finalizes on drop) goes out of scope before any other
    // artifact is written: "summary on disk" must imply "trace complete
    // on disk", or a crash between the two would let resume keep a
    // finished summary next to a torn trace.
    let log = {
        let mut coordinator = Coordinator::new(cfg, runtime)
            .with_context(|| format!("building coordinator for {name}"))?
            .with_workers(workers_per_run);
        if let Some(dir) = trace_dir {
            // Each grid cell gets its own trace file; the campaign_cell
            // header line (before run_started, which set_sink emits) ties
            // the trace back to its grid coordinates.
            let mut sink =
                crate::obs::JsonlSink::create(&dir.join(format!("{name}.trace.jsonl")))?;
            crate::obs::EventSink::emit(
                &mut sink,
                &crate::obs::RoundEvent::CampaignCell {
                    cell: name.clone(),
                    selector: run.selector.to_string(),
                    scenario: run.scenario.clone(),
                    seed: run.seed,
                    f: run.f,
                    clients: run.clients,
                },
            );
            coordinator.set_sink(Box::new(sink));
        }
        coordinator.run().with_context(|| format!("running {name}"))?
    };
    if let Some(dir) = trace_dir {
        fault::on_trace_written(&name, &dir.join(format!("{name}.trace.jsonl")));
    }
    if let Some(dir) = out_dir {
        log.write_csv(&dir.join(format!("{name}.csv")))?;
        // Same bytes as MetricsLog::write_summary_json, routed through
        // the artifact fault site.
        fault::write_artifact(
            ArtifactKind::Summary,
            Some(&name),
            &dir.join(format!("{name}.summary.json")),
            &log.summary().to_json().to_string_pretty(),
        )
        .with_context(|| format!("writing summary for {name}"))?;
        // The resolved config + scenario is the cell's fingerprint:
        // resume only reuses a summary whose stored fingerprint matches
        // byte-for-byte, so editing any knob — including the contents
        // of a scenario file — invalidates the cache. Written *after*
        // the summary: a crash between the two leaves summary-without-
        // fingerprint, which resume and merge treat as unfinished.
        fault::write_artifact(
            ArtifactKind::Config,
            Some(&name),
            &dir.join(format!("{name}.config.toml")),
            &cell_fingerprint(&run.cfg)?,
        )
        .with_context(|| format!("writing config fingerprint for {name}"))?;
    }
    Ok(CampaignRun {
        selector: run.selector,
        scenario: run.scenario.clone(),
        seed: run.seed,
        f: run.f,
        clients: run.clients,
        budget_j: run.budget_j,
        summary: log.summary(),
    })
}

/// Summaries a previous (partial) campaign already produced in `dir`,
/// keyed by run name: the merged campaign.json when present, and — for
/// campaigns killed mid-grid, before the merge was written — each
/// run's own `<name>.summary.json`.
fn load_finished(dir: &Path, campaign: &str, runs: &[RunSpec]) -> HashMap<String, Summary> {
    let mut out = HashMap::new();
    let merged_path = dir.join(format!("{campaign}.campaign.json"));
    if let Ok(text) = std::fs::read_to_string(&merged_path) {
        match Json::parse(&text) {
            Ok(json) => {
                if let Some(merged) = json.get("runs").and_then(|r| r.as_arr()) {
                    for r in merged {
                        if let Some(s) =
                            r.get("summary").and_then(|s| Summary::from_json(s).ok())
                        {
                            out.insert(s.name.clone(), s);
                        }
                    }
                }
            }
            // A crash mid-report leaves a torn merged file: set it
            // aside (never silently skip it) and fall back to the
            // per-cell summaries, which regenerate it bit-identically.
            Err(_) => {
                crate::report::quarantine(&merged_path, "torn/unparseable merged campaign.json");
            }
        }
    }
    for run in runs {
        if out.contains_key(&run.cfg.name) {
            continue;
        }
        let path = dir.join(format!("{}.summary.json", run.cfg.name));
        if let Ok(text) = std::fs::read_to_string(&path) {
            match Json::parse(&text).and_then(|j| Summary::from_json(&j)) {
                Ok(s) => {
                    out.insert(run.cfg.name.clone(), s);
                }
                // Torn by a crash mid-cell or rotted on disk — either
                // way the cell is not finished; quarantine the bytes
                // and recompute.
                Err(_) => {
                    crate::report::quarantine(&path, "torn/unparseable summary.json on resume");
                }
            }
        }
    }
    out
}

/// Run the whole campaign; `out_dir` (if given) receives per-run CSVs,
/// the grid manifest, and — when the spec covers the full grid — the
/// merged `<name>.campaign.json` / `<name>.campaign.csv`.
/// With `spec.resume` (the default), grid cells whose summaries already
/// exist in `out_dir` are reloaded instead of recomputed — the
/// deterministic grid order and bit-exact summary round-trip make the
/// merged report identical to a from-scratch run.
///
/// With `spec.shard = Some(I/N)`, only the cells [`shard_of`] assigns
/// to shard I are executed (and returned); the merged report write is
/// skipped for N > 1 so a partial shard can never masquerade as the
/// whole campaign — `report::merge_dirs` assembles it once all shards
/// are done.
pub fn run_campaign(
    spec: &CampaignSpec,
    runtime: &dyn ModelRuntime,
    out_dir: Option<&Path>,
) -> Result<CampaignReport> {
    let full_grid = expand(spec);
    // The manifest records the FULL grid (not this shard's slice): it
    // is the merge's ordering/completeness authority, and every shard
    // writing identical bytes is what lets shards share an output
    // directory with zero coordination. Built before the shard filter
    // consumes the grid (each RunSpec carries a whole config — don't
    // deep-clone thousands of them just to keep the Vec alive).
    let manifest = match out_dir {
        Some(_) => Some(build_manifest(spec, &full_grid)?),
        None => None,
    };
    let runs: Vec<RunSpec> = match spec.shard {
        Some(shard) if shard.count > 1 => full_grid
            .into_iter()
            .filter(|r| shard_of(&r.cfg.name, shard.count) == shard.index)
            .collect(),
        _ => full_grid,
    };
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        manifest
            .expect("manifest built whenever out_dir is set")
            .write(dir)?;
    }
    if let Some(dir) = &spec.trace_dir {
        std::fs::create_dir_all(dir).with_context(|| format!("creating trace dir {dir:?}"))?;
    }

    let mut results: Vec<Option<Result<CampaignRun>>> = Vec::new();
    results.resize_with(runs.len(), || None);
    if spec.resume {
        if let Some(dir) = out_dir {
            let finished = load_finished(dir, &spec.name, &runs);
            if !finished.is_empty() {
                for (slot, run) in results.iter_mut().zip(&runs) {
                    if let Some(summary) = finished.get(&run.cfg.name).cloned() {
                        // The cell name only encodes selector/scenario/
                        // clients/f/seed; the stored fingerprint covers
                        // every other knob (rounds, learning rates,
                        // device mix, scenario-file contents, ...). A
                        // missing or mismatched fingerprint means the
                        // summary came from a different experiment —
                        // recompute.
                        let path = dir.join(format!("{}.config.toml", run.cfg.name));
                        let same_config = match cell_fingerprint(&run.cfg) {
                            Ok(expected) => match std::fs::read_to_string(&path) {
                                Ok(text) if text == expected => true,
                                // Present but wrong bytes: a different
                                // grid, or corruption. Preserve the
                                // evidence out of band; the recompute
                                // overwrites both files.
                                Ok(_) => {
                                    crate::report::quarantine(
                                        &path,
                                        "config fingerprint mismatch on resume \
                                         (stale or corrupt cell)",
                                    );
                                    false
                                }
                                Err(_) => false,
                            },
                            Err(_) => false,
                        };
                        if !same_config {
                            continue;
                        }
                        *slot = Some(Ok(CampaignRun {
                            selector: run.selector,
                            scenario: run.scenario.clone(),
                            seed: run.seed,
                            f: run.f,
                            clients: run.clients,
                            budget_j: run.budget_j,
                            summary,
                        }));
                    }
                }
                let done = results.iter().filter(|r| r.is_some()).count();
                if done > 0 {
                    eprintln!(
                        "[campaign] resume: {done}/{} grid cells already complete in {}; \
                         skipping them",
                        runs.len(),
                        dir.display()
                    );
                }
            }
        }
    }

    let pending: Vec<usize> = (0..runs.len()).filter(|&i| results[i].is_none()).collect();
    let jobs = spec.jobs.max(1).min(pending.len().max(1));

    // Shard processes heartbeat `<out>/shard-<I>.progress.json` so a
    // supervisor (or a human on another host) can see cells done/owned
    // and detect stalls. No background ticker thread: progress moves
    // exactly when cells finish, which is what stall detection must
    // observe. Scope the fault plan to this shard too.
    let progress = match (spec.shard, out_dir) {
        (Some(shard), Some(dir)) => {
            fault::set_shard(shard.index);
            let done = runs.len() - pending.len();
            Some(supervisor::ShardProgress::create(dir, &spec.name, shard, runs.len(), done))
        }
        _ => None,
    };
    let progress = progress.as_ref();

    // First failure aborts the rest of the grid: experiments can take
    // hours each, so nobody wants 26 more runs after run 1 errored.
    let failed = AtomicBool::new(false);
    let collected: Vec<(usize, Result<CampaignRun>)> = if pending.is_empty() {
        Vec::new()
    } else if jobs <= 1 {
        let mut out = Vec::new();
        for &i in &pending {
            fault::on_cell_start(&runs[i].cfg.name);
            let res =
                run_one(&runs[i], runtime, out_dir, spec.workers_per_run, spec.trace_dir.as_deref());
            let is_err = res.is_err();
            if !is_err {
                if let Some(p) = progress {
                    p.cell_done();
                }
                fault::on_cell_finished(&runs[i].cfg.name);
            }
            out.push((i, res));
            if is_err {
                break;
            }
        }
        out
    } else {
        // Work-stealing over an atomic cursor; each worker accumulates
        // (index, result) locally, merged and re-ordered after join —
        // scheduling order never touches results.
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..jobs)
                .map(|_| {
                    scope.spawn(|| {
                        let mut local = Vec::new();
                        loop {
                            if failed.load(Ordering::Relaxed) {
                                break;
                            }
                            let p = next.fetch_add(1, Ordering::Relaxed);
                            let Some(&i) = pending.get(p) else { break };
                            fault::on_cell_start(&runs[i].cfg.name);
                            let res = run_one(
                                &runs[i],
                                runtime,
                                out_dir,
                                spec.workers_per_run,
                                spec.trace_dir.as_deref(),
                            );
                            if res.is_err() {
                                failed.store(true, Ordering::Relaxed);
                            } else {
                                if let Some(p) = progress {
                                    p.cell_done();
                                }
                                fault::on_cell_finished(&runs[i].cfg.name);
                            }
                            local.push((i, res));
                        }
                        local
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("campaign worker panicked"))
                .collect()
        })
    };
    for (i, res) in collected {
        results[i] = Some(res);
    }

    let mut finished = Vec::with_capacity(runs.len());
    for (i, slot) in results.into_iter().enumerate() {
        match slot {
            Some(Ok(run)) => finished.push(run),
            Some(Err(e)) => return Err(e),
            // Only reachable when an earlier cell failed and aborted
            // the grid — and that error returns first (the cursor pops
            // indices in order), so this is a defensive backstop.
            None => anyhow::bail!(
                "campaign aborted before grid cell {i} ({}) ran",
                runs[i].cfg.name
            ),
        }
    }
    let report = CampaignReport { name: spec.name.clone(), runs: finished };
    // A true shard (count > 1) holds only its slice of the grid; the
    // merged artifacts must always describe the whole campaign, so
    // their emission waits for `eafl merge` / `report::merge_dirs`.
    if spec.shard.map_or(true, |s| s.count == 1) {
        if let Some(dir) = out_dir {
            crate::report::write_report(dir, &report)?;
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        cfg.federation.rounds = 3;
        cfg.federation.num_clients = 12;
        cfg.federation.participants_per_round = 4;
        cfg.data.min_samples = 5;
        cfg.data.max_samples = 15;
        cfg
    }

    #[test]
    fn expand_is_the_product_with_f_only_for_eafl() {
        let mut spec = CampaignSpec::new("t", base());
        spec.grid = CampaignGrid {
            selectors: vec![SelectorKind::Eafl, SelectorKind::Random],
            scenarios: Vec::new(),
            seeds: vec![7, 8],
            f_values: vec![0.25, 0.5],
            client_counts: vec![10, 20],
            budgets: Vec::new(),
        };
        let runs = expand(&spec);
        // EAFL gets the full 2 clients x 2 f x 2 seeds; Random ignores
        // f so its axis collapses: 2 clients x 1 f x 2 seeds.
        assert_eq!(runs.len(), 8 + 4);
        // Outermost axis is the selector.
        assert!(runs[..8].iter().all(|r| r.selector == SelectorKind::Eafl));
        assert!(runs[8..].iter().all(|r| r.selector == SelectorKind::Random));
        assert!(runs[8..].iter().all(|r| r.f == 0.25), "non-EAFL pins f to the first value");
        // Names are unique.
        let mut names: Vec<&str> = runs.iter().map(|r| r.cfg.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), runs.len());
        // Seeds land in the config.
        assert!(runs.iter().all(|r| r.cfg.data.seed == r.seed));
        // The scenario axis inherits the base config.
        assert!(runs.iter().all(|r| r.scenario == "steady"));
        assert!(runs.iter().all(|r| r.cfg.scenario == "steady"));
        // K is clamped to the population.
        assert!(runs
            .iter()
            .all(|r| r.cfg.federation.participants_per_round <= r.cfg.federation.num_clients));
        for r in &runs {
            r.cfg.validate().unwrap();
        }
    }

    #[test]
    fn scenario_axis_multiplies_the_grid() {
        let mut spec = CampaignSpec::new("t", base());
        spec.grid = CampaignGrid {
            selectors: vec![SelectorKind::Random, SelectorKind::Eafl],
            scenarios: vec!["steady".into(), "diurnal".into()],
            seeds: vec![1],
            f_values: Vec::new(),
            client_counts: Vec::new(),
            budgets: Vec::new(),
        };
        let runs = expand(&spec);
        assert_eq!(runs.len(), 4, "2 selectors x 2 scenarios x 1 seed");
        // Scenario is inside selector in the nesting order.
        assert_eq!(runs[0].scenario, "steady");
        assert_eq!(runs[1].scenario, "diurnal");
        assert!(runs[..2].iter().all(|r| r.selector == SelectorKind::Random));
        // The scenario lands in each run's config and name.
        for r in &runs {
            assert_eq!(r.cfg.scenario, r.scenario);
            assert!(r.cfg.name.contains(&format!("-{}-", r.scenario)), "{}", r.cfg.name);
        }
    }

    #[test]
    fn scenario_file_paths_collapse_to_stems_in_names() {
        assert_eq!(scenario_label("steady"), "steady");
        assert_eq!(scenario_label("configs/night-shift.toml"), "night-shift");
        let mut spec = CampaignSpec::new("t", base());
        spec.base.scenario = "some/dir/custom.toml".into();
        let runs = expand(&spec);
        assert!(runs.iter().all(|r| r.scenario == "custom"));
        assert!(
            runs.iter().all(|r| r.cfg.scenario == "some/dir/custom.toml"),
            "the config keeps the full path for resolution"
        );
    }

    #[test]
    fn colliding_scenario_stems_get_disambiguated_labels() {
        let mut spec = CampaignSpec::new("t", base());
        spec.grid = CampaignGrid {
            selectors: vec![SelectorKind::Random],
            scenarios: vec!["configs/a/night.toml".into(), "configs/b/night.toml".into()],
            seeds: vec![1],
            f_values: Vec::new(),
            client_counts: Vec::new(),
            budgets: Vec::new(),
        };
        let runs = expand(&spec);
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].scenario, "night");
        assert_eq!(runs[1].scenario, "night-2");
        assert_ne!(runs[0].cfg.name, runs[1].cfg.name, "no output-file collisions");
        assert_eq!(runs[1].cfg.scenario, "configs/b/night.toml");
    }

    #[test]
    fn empty_axes_inherit_base() {
        let spec = CampaignSpec::new("t", base());
        let runs = expand(&spec);
        assert_eq!(runs.len(), 3 * 3); // default grid: 3 selectors × 3 seeds
        assert!(runs.iter().all(|r| r.f == spec.base.selector.eafl_f));
        assert!(runs.iter().all(|r| r.clients == spec.base.federation.num_clients));
        assert!(runs.iter().all(|r| r.scenario == spec.base.scenario));
        // Budget inherits the base too, and — critically — leaves run
        // names untouched: ci.sh and existing output directories pin
        // the budget-less naming scheme.
        assert!(runs.iter().all(|r| r.budget_j == spec.base.selector.budget_j));
        assert!(runs.iter().all(|r| !r.cfg.name.contains("-b")), "no -b tag without an axis");
        assert_eq!(runs[0].cfg.name, "t-eafl-steady-n12-f0.25-s1");
    }

    #[test]
    fn budget_axis_multiplies_the_grid_and_tags_names() {
        let mut spec = CampaignSpec::new("t", base());
        spec.grid = CampaignGrid {
            selectors: vec![SelectorKind::Random, SelectorKind::Eafl],
            scenarios: Vec::new(),
            seeds: vec![1, 2],
            f_values: Vec::new(),
            client_counts: Vec::new(),
            budgets: vec![500.0, 1000.0, 0.0],
        };
        let runs = expand(&spec);
        // Unlike f, the budget axis applies to every selector: the
        // ledger gates the round loop engine-side.
        assert_eq!(runs.len(), 2 * 3 * 2, "2 selectors x 3 budgets x 2 seeds");
        // Budget sits between f and seed in the nesting order.
        let random: Vec<f64> = runs[..6].iter().map(|r| r.budget_j).collect();
        assert_eq!(random, vec![500.0, 500.0, 1000.0, 1000.0, 0.0, 0.0]);
        // Each run's config carries its budget, and the name tags it.
        for r in &runs {
            assert_eq!(r.cfg.selector.budget_j, r.budget_j);
            assert!(
                r.cfg.name.contains(&format!("-b{}-s{}", r.budget_j, r.seed)),
                "{}",
                r.cfg.name
            );
        }
        assert_eq!(runs[0].cfg.name, "t-random-steady-n12-f0.25-b500-s1");
        // Names stay unique across the axis.
        let mut names: Vec<&str> = runs.iter().map(|r| r.cfg.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), runs.len());
    }

    #[test]
    fn shard_partition_is_total_disjoint_and_stable() {
        let spec = CampaignSpec::new("t", base());
        let runs = expand(&spec);
        for count in [1usize, 2, 3, 4, 7] {
            let mut owned = vec![0usize; count];
            for r in &runs {
                let shard = shard_of(&r.cfg.name, count);
                assert!(shard < count, "owner out of range");
                // Stable: recomputation never moves a cell.
                assert_eq!(shard, shard_of(&r.cfg.name, count));
                owned[shard] += 1;
            }
            // Every cell is owned by exactly one shard (totality +
            // disjointness follow from shard_of being a function).
            assert_eq!(owned.iter().sum::<usize>(), runs.len());
        }
        assert_eq!(shard_of("anything", 0), 0, "degenerate counts collapse to shard 0");
        assert_eq!(shard_of("anything", 1), 0);
    }

    #[test]
    fn sharded_specs_expand_to_the_full_grid_but_run_their_slice() {
        let runtime = crate::runtime::MockRuntime::default();
        let mut cfg = base();
        cfg.federation.rounds = 2;
        let mut spec = CampaignSpec::new("t", cfg);
        spec.grid.seeds = vec![1, 2];
        spec.jobs = 1;
        let full = run_campaign(&spec, &runtime, None).unwrap();
        assert_eq!(full.runs.len(), 6, "3 selectors x 2 seeds");

        let mut union: Vec<(SelectorKind, u64)> = Vec::new();
        for index in 0..2 {
            let mut shard_spec = spec.clone();
            shard_spec.shard = Some(ShardSpec { index, count: 2 });
            let part = run_campaign(&shard_spec, &runtime, None).unwrap();
            assert!(part.runs.len() <= full.runs.len());
            for run in &part.runs {
                // Shard results are bit-identical to the full campaign's
                // same cell (same config ⇒ same seeded trajectory).
                let reference = full
                    .runs
                    .iter()
                    .find(|r| r.selector == run.selector && r.seed == run.seed)
                    .expect("shard ran a cell outside the grid");
                assert_eq!(reference.summary.wall_clock_h, run.summary.wall_clock_h);
                assert_eq!(reference.summary.final_accuracy, run.summary.final_accuracy);
                union.push((run.selector, run.seed));
            }
        }
        union.sort_by_key(|(k, s)| (k.to_string(), *s));
        union.dedup();
        assert_eq!(union.len(), full.runs.len(), "shards cover the grid exactly once");
    }

    #[test]
    fn manifest_covers_the_full_grid_in_expansion_order() {
        let spec = CampaignSpec::new("t", base());
        let runs = expand(&spec);
        let manifest = build_manifest(&spec, &runs).unwrap();
        assert_eq!(manifest.campaign, "t");
        assert_eq!(manifest.cells.len(), runs.len());
        for (cell, run) in manifest.cells.iter().zip(&runs) {
            assert_eq!(cell.name, run.cfg.name);
            assert_eq!(cell.selector, run.selector);
            assert_eq!(cell.seed, run.seed);
            // The recorded hash is the hash of the fingerprint the run
            // will write — what merge verifies per cell.
            assert_eq!(
                cell.fingerprint_fnv,
                fnv1a64(cell_fingerprint(&run.cfg).unwrap().as_bytes())
            );
        }
        // Deterministic: rebuilding yields identical bytes.
        assert_eq!(
            manifest.to_json().to_string_pretty(),
            build_manifest(&spec, &runs).unwrap().to_json().to_string_pretty()
        );
    }
}
