//! Participant selection — the paper's contribution surface.
//!
//! Four policies behind one [`Selector`] trait:
//!  - [`RandomSelector`] — uniform over eligible clients.
//!  - [`OortSelector`]  — Oort's guided selection (Lai et al., OSDI'21):
//!    statistical×system utility (Eq. 2), exploration/exploitation,
//!    UCB staleness bonus, and a pacer controlling the deadline T.
//!  - [`EaflSelector`]  — EAFL (Eq. 1): Oort's utility blended with the
//!    remaining-battery term, `reward = f·Util + (1−f)·power`.
//!  - [`BudgetSelector`] — EAFL's reward ranking constrained by a
//!    campaign-wide energy budget (hard-cap / amortized /
//!    deadline-aware policies), fed per-round by the coordinator's
//!    energy ledger through [`Selector::set_budget`].
//!
//! The coordinator builds one [`Candidate`] per *eligible* client each
//! round (alive, above the battery floor) and the selector returns at
//! most K of them. Selector feedback (measured losses/durations) flows
//! back through [`RoundFeedback`].

mod budget;
mod eafl;
mod oort;
mod random;
pub mod sampler;
pub mod utility;

pub use budget::BudgetSelector;
pub use eafl::EaflSelector;
pub use oort::OortSelector;
pub use random::RandomSelector;
pub use sampler::{weighted_sample_linear, FenwickSampler};

use crate::util::rng::Rng;

use crate::config::{SelectorConfig, SelectorKind};

/// The battery-floor admission convention, stated once for every site
/// that gates on `min_battery_frac`: a client is admitted iff its
/// effective battery fraction is **strictly above** the floor. The
/// interval of eligible fractions is the open-below `(floor, 1.0]` —
/// at exactly `frac == floor` the client is *excluded* (it could not
/// survive even an infinitesimal additional drain without dipping
/// under the floor). The registry's `fill_candidates` fast path, the
/// allocating `candidates` reference, and the incremental eligible
/// arena's floor wheel all call this one predicate, so the boundary
/// can never drift between them.
#[inline]
pub fn battery_floor_admits(battery_frac: f64, min_battery_frac: f64) -> bool {
    battery_frac > min_battery_frac
}

/// Everything a selector may know about one eligible client this round.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// Registry index of the client.
    pub id: usize,
    /// Oort statistical utility from the client's last participation
    /// (|B_i|·sqrt(mean loss²)); None if never yet measured.
    pub stat_util: Option<f64>,
    /// Measured wall duration of the client's last participation, s.
    pub measured_duration_s: Option<f64>,
    /// Coordinator-estimated duration of the NEXT round for this client
    /// (download + compute + upload from its profiles), seconds.
    pub expected_duration_s: f64,
    /// Round number of the client's last selection; `None` if never
    /// selected. (The SoA pool stores this as a `u64` column with
    /// `u64::MAX` as the never-selected sentinel; the projection into
    /// candidates converts to the honest `Option`.)
    pub last_selected_round: Option<u64>,
    /// Remaining battery fraction in [0, 1]. Drain-effective: the
    /// registry fills this from the lazy ledger's closed form, so it
    /// reflects background drain as of the round clock even when the
    /// raw battery hasn't been materialized yet.
    pub battery_frac: f64,
    /// Projected battery cost of participating in the next round, as a
    /// fraction of this client's capacity.
    pub projected_drain_frac: f64,
    /// Projected energy cost of participating in the next round, in
    /// absolute joules (the SoA pool's cached `round_energy`
    /// projection) — what the budget selector's knapsack spends
    /// against the campaign energy ledger.
    pub round_energy_j: f64,
}

/// Post-round feedback for one participant.
#[derive(Debug, Clone, Copy)]
pub struct ParticipantOutcome {
    pub id: usize,
    /// Oort statistical utility measured this round (None if the client
    /// dropped out before reporting).
    pub stat_util: Option<f64>,
    /// Measured duration, seconds.
    pub duration_s: f64,
    /// Completed within the deadline and reported an update.
    pub completed: bool,
}

/// Feedback the coordinator hands back after every round.
#[derive(Debug, Clone)]
pub struct RoundFeedback<'a> {
    pub round: u64,
    pub outcomes: &'a [ParticipantOutcome],
}

/// A participant-selection policy.
pub trait Selector: Send {
    /// Choose at most `k` clients from `candidates`. `round` is
    /// 1-based. Must be deterministic given (`rng`, inputs).
    fn select(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize>;

    /// Observe the outcome of the round this selector picked.
    fn feedback(&mut self, fb: &RoundFeedback<'_>);

    /// The straggler deadline T (seconds) this selector wants for the
    /// upcoming round, given candidate timing estimates. Also the T in
    /// Oort's Eq. (2) system penalty. Takes `&mut self` so
    /// implementations can reuse an internal scratch buffer for the
    /// percentile computation instead of allocating a durations Vec per
    /// call (measurable at 100k-client populations — see
    /// `benches/selection_micro.rs`).
    fn deadline_s(&mut self, candidates: &[Candidate]) -> f64;

    /// Selection and deadline in one call — the engine's per-round
    /// entry point. The default composes `select` + `deadline_s` and is
    /// correct for any selector; Oort/EAFL override it so the pacer
    /// percentile (an O(E) pass over the candidate pool) runs once per
    /// round instead of twice.
    fn plan(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        rng: &mut Rng,
    ) -> (Vec<usize>, f64) {
        let selected = self.select(round, candidates, k, rng);
        let deadline_s = self.deadline_s(candidates);
        (selected, deadline_s)
    }

    /// The coordinator's energy ledger, pushed down before every
    /// `plan`/`select` call when a campaign budget is configured:
    /// joules left in the campaign envelope and rounds left in the
    /// schedule. Default: ignore (only the budget family plans against
    /// it; the coordinator-side hard stop covers every selector).
    fn set_budget(&mut self, _remaining_j: f64, _remaining_rounds: u64) {}

    /// Whether the selector has concluded the remaining budget cannot
    /// fund any further participant (checked by the coordinator after
    /// each round as a terminal condition). Default: never.
    fn budget_exhausted(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

/// Build the configured selector.
pub fn make_selector(cfg: &SelectorConfig) -> Box<dyn Selector> {
    match cfg.kind {
        SelectorKind::Random => Box::new(RandomSelector::new(cfg.clone())),
        SelectorKind::Oort => Box::new(OortSelector::new(cfg.clone())),
        SelectorKind::Eafl => Box::new(EaflSelector::new(cfg.clone())),
        SelectorKind::Budget => Box::new(BudgetSelector::new(cfg.clone())),
    }
}

/// Percentile (0..=1) of an unsorted slice; linear interpolation.
///
/// Convenience wrapper that clones into a scratch buffer; the per-round
/// hot paths call [`percentile_in_place`] on buffers they already own.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    percentile_in_place(&mut values.to_vec(), p)
}

/// Keep only the top `band` entries of `scored` by (score desc, id
/// asc), sorted in that order — the selectors' exploitation-band
/// primitive. A full sort of the explored pool is O(E log E); this
/// partitions the top band out with `select_nth_unstable_by` (O(E))
/// and only orders the band itself (O(band log band), band ≈ 1.5–3 k).
/// The composite key is a strict total order (ids are distinct), so
/// the result is exactly what a full stable sort of an id-ascending
/// pool would keep — input order no longer matters at all.
pub(crate) fn rank_top_band(scored: &mut Vec<(usize, f64)>, band: usize) {
    let cmp =
        |a: &(usize, f64), b: &(usize, f64)| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0));
    if band < scored.len() && band > 0 {
        scored.select_nth_unstable_by(band - 1, cmp);
        scored.truncate(band);
    }
    scored.sort_unstable_by(cmp);
}

/// Percentile (0..=1) via `select_nth_unstable_by` — O(n) instead of
/// the former clone + full O(n log n) sort on every selection call.
/// Reorders `values` (partitioned around the order statistic); returns
/// the same interpolated value a sort-based implementation would.
pub fn percentile_in_place(values: &mut [f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let pos = p.clamp(0.0, 1.0) * (values.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let (_, &mut lo_val, above) = values.select_nth_unstable_by(lo, f64::total_cmp);
    if pos == lo as f64 {
        return lo_val;
    }
    // hi = lo + 1: the minimum of the partition above the lo-th order
    // statistic (non-empty here, since pos < len-1 when it's fractional).
    let hi_val = above.iter().copied().fold(f64::INFINITY, f64::min);
    lo_val + (hi_val - lo_val) * (pos - lo as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 1.0), 40.0);
        assert!((percentile(&v, 0.5) - 25.0).abs() < 1e-12);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.9), 7.0);
    }

    #[test]
    fn percentile_in_place_matches_sort_based_reference() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(99);
        for n in [1usize, 2, 3, 7, 100, 1001] {
            let values: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-50.0, 900.0)).collect();
            for p in [0.0, 0.1, 0.25, 0.5, 0.8, 0.95, 1.0] {
                let reference = {
                    let mut v = values.clone();
                    v.sort_by(f64::total_cmp);
                    let pos = p * (v.len() - 1) as f64;
                    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
                    if lo == hi { v[lo] } else { v[lo] + (v[hi] - v[lo]) * (pos - lo as f64) }
                };
                let mut scratch = values.clone();
                let got = percentile_in_place(&mut scratch, p);
                assert_eq!(got, reference, "n={n} p={p}");
                // The buffer is reordered, never mutated as a set.
                let mut a = scratch;
                let mut b = values.clone();
                a.sort_by(f64::total_cmp);
                b.sort_by(f64::total_cmp);
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn factory_builds_each_kind() {
        for (kind, name) in [
            (SelectorKind::Random, "random"),
            (SelectorKind::Oort, "oort"),
            (SelectorKind::Eafl, "eafl"),
            (SelectorKind::Budget, "budget"),
        ] {
            let mut cfg = SelectorConfig::default();
            cfg.kind = kind;
            cfg.budget_j = 1_000.0;
            assert_eq!(make_selector(&cfg).name(), name);
        }
    }
}
