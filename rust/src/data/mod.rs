//! Data substrate: the Google-Speech-Commands substitute (DESIGN.md §2)
//! and the paper's §5 non-IID partition (each learner holds a random
//! ~10% of the labels — 4 of 35 — with uniform sample counts).

mod partition;
mod synthetic;

pub use partition::{partition_clients, ClientShard, Partition};
pub use synthetic::SyntheticSpeech;

/// A sample reference: (class label, per-class sample index). Features
/// are generated on demand — the dataset is procedural, nothing is
/// stored.
pub type SampleRef = (u16, u32);
