//! Uniform random participant selection — the paper's "Random"
//! baseline. Battery- and utility-oblivious: every eligible client is
//! equally likely, which spreads energy cost across the population but
//! ignores both statistical value and device speed.

use crate::util::rng::Rng;

use crate::config::SelectorConfig;

use super::{percentile_in_place, Candidate, RoundFeedback, Selector};

pub struct RandomSelector {
    cfg: SelectorConfig,
    /// Reusable percentile buffer for `deadline_s` (no per-round Vec).
    scratch: Vec<f64>,
    /// Reusable id buffer for `select` (no per-round Vec).
    ids: Vec<usize>,
}

impl RandomSelector {
    pub fn new(cfg: SelectorConfig) -> Self {
        Self { cfg, scratch: Vec::new(), ids: Vec::new() }
    }
}

impl Selector for RandomSelector {
    fn select(
        &mut self,
        _round: u64,
        candidates: &[Candidate],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        self.ids.clear();
        self.ids.extend(candidates.iter().map(|c| c.id));
        let n = self.ids.len();
        let k = k.min(n);
        // Partial Fisher–Yates: a uniform k-prefix costs k draws, not
        // the E−1 a full shuffle of the candidate pool would.
        for i in 0..k {
            let j = rng.gen_range_usize(i, n - 1);
            self.ids.swap(i, j);
        }
        self.ids[..k].to_vec()
    }

    fn feedback(&mut self, _fb: &RoundFeedback<'_>) {}

    fn deadline_s(&mut self, candidates: &[Candidate]) -> f64 {
        // Random has no pacer; it waits for (almost) everyone — the
        // paper's Fig. 4b shows its rounds are the longest. Deadline is
        // the slow tail of the expected-duration distribution.
        self.scratch.clear();
        self.scratch.extend(candidates.iter().map(|c| c.expected_duration_s));
        percentile_in_place(&mut self.scratch, 0.95).max(self.cfg.pacer_step_s)
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    
    fn cands(n: usize) -> Vec<Candidate> {
        (0..n)
            .map(|id| Candidate {
                id,
                stat_util: None,
                measured_duration_s: None,
                expected_duration_s: 100.0 + id as f64,
                last_selected_round: None,
                battery_frac: 1.0,
                projected_drain_frac: 0.01,
                round_energy_j: 50.0,
            })
            .collect()
    }

    #[test]
    fn selects_exactly_k_distinct() {
        let mut s = RandomSelector::new(SelectorConfig::default());
        let mut rng = Rng::seed_from_u64(1);
        let picked = s.select(1, &cands(50), 10, &mut rng);
        assert_eq!(picked.len(), 10);
        let mut dedup = picked.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 10);
    }

    #[test]
    fn short_population_returns_all() {
        let mut s = RandomSelector::new(SelectorConfig::default());
        let mut rng = Rng::seed_from_u64(1);
        assert_eq!(s.select(1, &cands(3), 10, &mut rng).len(), 3);
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let mut s = RandomSelector::new(SelectorConfig::default());
        let a = s.select(1, &cands(30), 5, &mut Rng::seed_from_u64(9));
        let b = s.select(1, &cands(30), 5, &mut Rng::seed_from_u64(9));
        assert_eq!(a, b);
    }

    #[test]
    fn coverage_is_roughly_uniform() {
        let mut s = RandomSelector::new(SelectorConfig::default());
        let mut rng = Rng::seed_from_u64(3);
        let mut counts = vec![0u32; 20];
        for r in 0..2000 {
            for id in s.select(r, &cands(20), 4, &mut rng) {
                counts[id] += 1;
            }
        }
        // Expected 400 each; allow generous tolerance.
        assert!(counts.iter().all(|&c| (250..=550).contains(&c)), "{counts:?}");
    }

    #[test]
    fn deadline_covers_slow_tail() {
        let mut s = RandomSelector::new(SelectorConfig::default());
        let d = s.deadline_s(&cands(100));
        assert!(d >= 190.0, "95th percentile of 100..200 ≈ 195, got {d}");
        // The scratch buffer makes repeated calls allocation-free and
        // identical.
        assert_eq!(s.deadline_s(&cands(100)), d);
    }
}
