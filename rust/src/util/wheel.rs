//! Coarse-bucket time wheel: a monotone priority queue over f64 keys,
//! backing the registry's lazy-drain death wheel, the availability
//! wake wheel, and the eligible arena's battery-floor-crossing and
//! ban-release wheels (the floor wheels run on the same drained-
//! fraction cumsums as the death wheel, just at threshold
//! `min_battery_frac` instead of zero; the ban wheel keys on the
//! release round as f64, where integer keys coincide with bucket
//! starts, so releases fire on the exact round).
//!
//! Entries are `(id, gen)` pairs registered at a non-negative key (a
//! cumulative drained fraction, or a simulated clock hour). Keys are
//! quantized to buckets of a fixed `width`; [`BucketWheel::pop_due`]
//! drains every bucket whose *start* is ≤ the current threshold, so an
//! entry fires at most one bucket-width *early*, never late. Callers
//! therefore re-check the exact predicate on each fired entry and
//! re-register the survivors — the wheel is a candidate filter, not an
//! oracle.
//!
//! Staleness is handled by lazy deletion: the caller bumps a per-id
//! generation counter whenever an entry's registration becomes obsolete
//! (e.g. a battery anchor moved), and discards fired entries whose
//! `gen` no longer matches. Nothing is ever removed from the middle of
//! a bucket, so insert and pop are amortized O(log buckets).
//!
//! Buckets are a `BTreeMap` rather than a ring because the key domain
//! is unbounded (cumulative drain grows without reset) and typically
//! sparse — only buckets that contain at least one entry exist.

use std::collections::BTreeMap;

/// Bucketed monotone queue of `(id, gen)` entries keyed by f64 ≥ 0.
#[derive(Debug, Clone)]
pub struct BucketWheel {
    width: f64,
    buckets: BTreeMap<u64, Vec<(u32, u32)>>,
}

impl BucketWheel {
    /// Empty wheel with the given bucket width (> 0, finite).
    pub fn new(width: f64) -> Self {
        assert!(width.is_finite() && width > 0.0, "bucket width must be positive");
        Self { width, buckets: BTreeMap::new() }
    }

    /// Bucket index for a key (negative keys clamp to bucket 0).
    fn bucket_of(&self, key: f64) -> u64 {
        let b = (key / self.width).floor();
        if b <= 0.0 {
            0
        } else {
            b as u64
        }
    }

    /// Register `(id, gen)` to fire when the threshold reaches `key`
    /// (possibly up to one bucket-width sooner).
    pub fn insert(&mut self, key: f64, id: u32, gen: u32) {
        self.buckets.entry(self.bucket_of(key)).or_default().push((id, gen));
    }

    /// Drain every entry in buckets whose start is ≤ `threshold` into
    /// `out` (appended; not cleared). Entries at keys strictly above
    /// `threshold` but in a due bucket fire early — callers re-check.
    pub fn pop_due(&mut self, threshold: f64, out: &mut Vec<(u32, u32)>) {
        if threshold < 0.0 {
            return;
        }
        // A bucket b spans [b·width, (b+1)·width); it is due when its
        // start is ≤ threshold, i.e. b ≤ floor(threshold / width).
        let last_due = (threshold / self.width).floor() as u64;
        while let Some((&b, _)) = self.buckets.iter().next() {
            if b > last_due {
                break;
            }
            let mut entries = self.buckets.remove(&b).expect("bucket exists");
            out.append(&mut entries);
        }
    }

    /// Total registered entries (including stale generations).
    pub fn len(&self) -> usize {
        self.buckets.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(w: &mut BucketWheel, threshold: f64) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        w.pop_due(threshold, &mut out);
        out.sort_unstable();
        out
    }

    #[test]
    fn fires_at_or_before_key_never_after() {
        let mut w = BucketWheel::new(0.5);
        w.insert(1.7, 7, 0); // bucket 3: [1.5, 2.0)
        assert!(drain(&mut w, 1.4).is_empty(), "bucket start 1.5 > 1.4");
        assert_eq!(drain(&mut w, 1.5), vec![(7, 0)], "fires at bucket start (early)");
        assert!(drain(&mut w, 10.0).is_empty(), "popped entries are gone");
    }

    #[test]
    fn pops_all_due_buckets_in_one_call() {
        let mut w = BucketWheel::new(1.0);
        w.insert(0.2, 1, 0);
        w.insert(1.9, 2, 3);
        w.insert(2.5, 3, 0);
        w.insert(9.0, 4, 0);
        assert_eq!(drain(&mut w, 2.6), vec![(1, 0), (2, 3), (3, 0)]);
        assert_eq!(w.len(), 1);
        assert_eq!(drain(&mut w, 9.0), vec![(4, 0)]);
        assert!(w.is_empty());
    }

    #[test]
    fn negative_keys_clamp_to_bucket_zero() {
        let mut w = BucketWheel::new(0.25);
        w.insert(-3.0, 5, 1);
        assert_eq!(drain(&mut w, 0.0), vec![(5, 1)]);
    }

    #[test]
    fn reinsertion_lands_in_a_later_bucket() {
        let mut w = BucketWheel::new(0.5);
        w.insert(0.1, 9, 0);
        let fired = drain(&mut w, 0.1);
        assert_eq!(fired, vec![(9, 0)]);
        // Caller decides the entry isn't ripe and re-registers further out.
        w.insert(3.3, 9, 0);
        assert!(drain(&mut w, 2.9).is_empty());
        assert_eq!(drain(&mut w, 3.0), vec![(9, 0)], "bucket [3.0, 3.5) due at 3.0");
    }
}
