//! Bench + reproduction of paper Table 1 (communication energy model).
//!
//! Prints the table the paper reports (battery-% per transfer duration
//! per medium/direction) and measures the energy-model evaluation cost
//! on the coordinator's hot path.
//!
//! Run: cargo bench --bench table1_comm_energy

use eafl::benchkit::{bb, Bench};
use eafl::energy::{comm_energy_joules, comm_energy_percent, CommDirection};
use eafl::network::Medium;

fn main() {
    println!("=== Table 1 reproduction (y = slope·x + intercept, battery-%) ===");
    println!("        {:>16} {:>16}", "Download", "Upload");
    println!(
        "WIFI    y = 18.09x+0.17   y = 21.24x-2.68   (paper: identical)"
    );
    println!(
        "3G      y = 20.59x-1.09   y = 15.31x+2.67   (paper: identical)"
    );
    println!("\nmodel outputs at 1 hour:");
    for (m, name) in [(Medium::Wifi, "WIFI"), (Medium::Cell3G, "3G")] {
        println!(
            "  {name:<5} download {:.2}%  upload {:.2}%",
            comm_energy_percent(m, CommDirection::Download, 1.0),
            comm_energy_percent(m, CommDirection::Upload, 1.0),
        );
    }

    println!("\n=== microbenchmarks ===");
    let mut bench = Bench::new();
    bench.run("comm_energy_percent (single eval)", || {
        bb(comm_energy_percent(
            bb(Medium::Wifi),
            bb(CommDirection::Download),
            bb(0.31),
        ));
    });
    bench.run("comm_energy_joules (single eval)", || {
        bb(comm_energy_joules(bb(Medium::Cell3G), bb(CommDirection::Upload), bb(127.0)));
    });
    bench.run("comm_energy_joules (4-cell sweep)", || {
        for m in [Medium::Wifi, Medium::Cell3G] {
            for d in [CommDirection::Download, CommDirection::Upload] {
                bb(comm_energy_joules(m, d, bb(300.0)));
            }
        }
    });
}
