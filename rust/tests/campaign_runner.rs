//! Campaign runner integration: grid expansion, threaded execution,
//! determinism across job counts, and the merged on-disk artifacts.

use eafl::campaign::{expand, run_campaign, CampaignGrid, CampaignSpec};
use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::runtime::MockRuntime;
use eafl::util::json::Json;

fn tiny_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.federation.rounds = 6;
    cfg.federation.num_clients = 16;
    cfg.federation.participants_per_round = 4;
    cfg.federation.eval_interval = 3;
    cfg.data.min_samples = 5;
    cfg.data.max_samples = 15;
    cfg.data.test_samples = 256;
    cfg
}

fn spec(jobs: usize) -> CampaignSpec {
    let mut spec = CampaignSpec::new("itest", tiny_base());
    spec.grid = CampaignGrid {
        selectors: vec![SelectorKind::Eafl, SelectorKind::Oort, SelectorKind::Random],
        scenarios: Vec::new(),
        seeds: vec![1, 2, 3],
        f_values: Vec::new(),
        client_counts: Vec::new(),
        budgets: Vec::new(),
    };
    spec.jobs = jobs;
    spec
}

#[test]
fn full_grid_runs_every_cell() {
    let runtime = MockRuntime::default();
    let report = run_campaign(&spec(4), &runtime, None).unwrap();
    assert_eq!(report.runs.len(), 9, "3 selectors x 3 seeds");
    for run in &report.runs {
        assert_eq!(run.summary.rounds, 6, "{}: every run completes", run.selector);
    }
    // Every grid cell is distinct.
    let mut cells: Vec<(String, u64)> =
        report.runs.iter().map(|r| (r.selector.to_string(), r.seed)).collect();
    cells.sort();
    cells.dedup();
    assert_eq!(cells.len(), 9);
}

#[test]
fn job_count_does_not_change_results() {
    let runtime = MockRuntime::default();
    let sequential = run_campaign(&spec(1), &runtime, None).unwrap();
    let parallel = run_campaign(&spec(4), &runtime, None).unwrap();
    assert_eq!(sequential.runs.len(), parallel.runs.len());
    for (a, b) in sequential.runs.iter().zip(&parallel.runs) {
        assert_eq!(a.selector, b.selector);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.summary.final_accuracy, b.summary.final_accuracy);
        assert_eq!(a.summary.total_dropouts, b.summary.total_dropouts);
        assert_eq!(a.summary.wall_clock_h, b.summary.wall_clock_h);
        assert_eq!(a.summary.total_fl_energy_j, b.summary.total_fl_energy_j);
    }
    assert_eq!(sequential.to_csv(), parallel.to_csv());
}

#[test]
fn seeds_actually_vary_the_runs() {
    let runtime = MockRuntime::default();
    let mut s = spec(2);
    s.grid.selectors = vec![SelectorKind::Eafl];
    let report = run_campaign(&s, &runtime, None).unwrap();
    assert_eq!(report.runs.len(), 3);
    // Different seeds must not all produce the same trajectory.
    let walls: Vec<f64> = report.runs.iter().map(|r| r.summary.wall_clock_h).collect();
    assert!(
        walls.windows(2).any(|w| w[0] != w[1]),
        "three seeds produced identical wall clocks: {walls:?}"
    );
}

#[test]
fn merged_artifacts_land_on_disk() {
    let dir = std::env::temp_dir().join(format!("eafl-campaign-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let runtime = MockRuntime::default();
    let mut s = spec(2);
    s.grid.seeds = vec![5];
    let report = run_campaign(&s, &runtime, Some(&dir)).unwrap();
    assert_eq!(report.runs.len(), 3);

    // Merged JSON parses and counts the runs.
    let json_text = std::fs::read_to_string(dir.join("itest.campaign.json")).unwrap();
    let parsed = Json::parse(&json_text).unwrap();
    assert_eq!(parsed.field("total_runs").unwrap().as_usize(), Some(3));
    assert_eq!(parsed.field("runs").unwrap().as_arr().unwrap().len(), 3);

    // Merged CSV: header + one row per run.
    let csv = std::fs::read_to_string(dir.join("itest.campaign.csv")).unwrap();
    assert_eq!(csv.lines().count(), 4);

    // Per-run series files exist under the campaign's naming scheme
    // (selector-scenario-clients-f-seed).
    for run in &report.runs {
        let per_run = dir.join(format!("itest-{}-steady-n16-f0.25-s5.csv", run.selector));
        assert!(per_run.exists(), "missing {per_run:?}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn expansion_order_is_stable_for_resume_tooling() {
    let s = spec(1);
    let a: Vec<String> = expand(&s).into_iter().map(|r| r.cfg.name).collect();
    let b: Vec<String> = expand(&s).into_iter().map(|r| r.cfg.name).collect();
    assert_eq!(a, b);
    assert!(a[0].starts_with("itest-eafl-"), "selector is the outermost axis: {}", a[0]);
}
