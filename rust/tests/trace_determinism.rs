//! Trace determinism tier: `--trace` event files are part of the
//! byte-determinism contract the metrics CSVs already honor. Every test
//! drives the real `eafl` binary and compares trace **bytes**:
//!
//!  - EAFL_WORKERS=1 vs 8 (exec commits in simulation order);
//!  - lazy vs EAFL_EAGER_DRAIN=1 (wheel deaths and revivals fire
//!    identically in both drain modes);
//!  - a single-process sweep vs the same grid sharded across processes
//!    (shards own disjoint cells, so per-cell traces are identical);
//!  - and `eafl trace summarize` reproducing the run's own summary
//!    numbers exactly from events alone.
//!
//! The wall-time profile sidecar (`*.profile.json`) is deliberately NOT
//! byte-compared — it is the non-deterministic channel.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use eafl::util::json::Json;

const BIN: &str = env!("CARGO_BIN_EXE_eafl");

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eafl-trace-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_ok(output: &Output, what: &str) {
    assert!(
        output.status.success(),
        "{what} failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        output.status,
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr)
    );
}

/// Run `eafl run --trace` under explicit worker/drain settings (the
/// suite itself runs under EAFL_WORKERS / EAFL_EAGER_DRAIN variations
/// in CI, so inherited env must never leak into the comparison) and
/// return the trace bytes.
fn traced_run(dir: &Path, tag: &str, workers: &str, eager: Option<&str>) -> Vec<u8> {
    let out = dir.join(format!("out-{tag}"));
    let trace = dir.join(format!("{tag}.trace.jsonl"));
    let mut cmd = Command::new(BIN);
    cmd.args([
        "run",
        "--mock",
        "--selector",
        "eafl",
        "--rounds",
        "10",
        "--clients",
        "24",
        "--scenario",
        "diurnal",
    ])
    .arg("--out")
    .arg(&out)
    .arg("--trace")
    .arg(&trace)
    .env("EAFL_WORKERS", workers)
    .env_remove("EAFL_EAGER_DRAIN");
    if let Some(v) = eager {
        cmd.env("EAFL_EAGER_DRAIN", v);
    }
    assert_ok(&cmd.output().expect("spawning eafl run"), &format!("run {tag}"));
    std::fs::read(&trace).unwrap_or_else(|e| panic!("reading {}: {e}", trace.display()))
}

fn assert_is_trace(bytes: &[u8], what: &str) {
    let text = std::str::from_utf8(bytes).expect("trace is UTF-8");
    let mut lines = text.lines();
    assert_eq!(
        lines.next(),
        Some(r#"{"schema": "eafl-trace-v1"}"#),
        "{what}: header line"
    );
    // A 10-round run produces a non-trivial stream: one run_started,
    // per-round planned/selected/outcome events, one committed each.
    assert!(
        text.contains(r#""ev": "run_started""#),
        "{what}: missing run_started"
    );
    assert_eq!(
        text.matches(r#""ev": "round_committed""#).count(),
        10,
        "{what}: expected 10 round_committed events"
    );
    assert!(
        text.contains(r#""ev": "client_selected""#),
        "{what}: missing client_selected"
    );
}

#[test]
fn trace_bytes_identical_across_worker_counts() {
    let dir = tmp_dir("workers");
    let w1 = traced_run(&dir, "w1", "1", None);
    let w8 = traced_run(&dir, "w8", "8", None);
    assert_is_trace(&w1, "workers=1");
    assert_eq!(w1, w8, "trace bytes must not depend on EAFL_WORKERS");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn trace_bytes_identical_across_drain_modes() {
    let dir = tmp_dir("drain");
    let lazy = traced_run(&dir, "lazy", "1", None);
    let eager = traced_run(&dir, "eager", "1", Some("1"));
    assert_is_trace(&lazy, "lazy");
    assert_eq!(lazy, eager, "trace bytes must not depend on EAFL_EAGER_DRAIN");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn run_writes_the_profile_sidecar_separately() {
    let dir = tmp_dir("profile");
    let _ = traced_run(&dir, "prof", "1", None);
    let profile = dir.join("prof.trace.profile.json");
    let text = std::fs::read_to_string(&profile)
        .unwrap_or_else(|e| panic!("reading {}: {e}", profile.display()));
    let json = Json::parse(&text).expect("profile parses");
    assert_eq!(
        json.get("schema").and_then(Json::as_str),
        Some("eafl-profile-v1")
    );
    // All six seams (plus eval) were timed at least once per round.
    let phases = json.get("phases").expect("profile has phases");
    for phase in ["plan", "sim", "exec", "commit", "account", "feedback", "eval", "record"] {
        assert!(phases.get(phase).is_some(), "profile missing phase {phase}");
    }
    // The wall-time channel never contaminates the event stream.
    let trace = std::fs::read_to_string(dir.join("prof.trace.jsonl")).unwrap();
    assert!(!trace.contains("profile"), "trace must not carry profile data");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// 2 selectors x 2 seeds grid, small enough for CI, non-degenerate
/// under the FNV shard partition.
const GRID: &[&str] = &[
    "--mock",
    "--rounds",
    "3",
    "--clients",
    "12",
    "--selectors",
    "random,eafl",
    "--seeds",
    "1,2",
];

fn sweep(grid: &[&str], extra: &[&str], out: &Path, trace: &Path) -> Output {
    let mut cmd = Command::new(BIN);
    cmd.arg("sweep")
        .args(grid)
        .args(extra)
        .arg("--out")
        .arg(out)
        .arg("--trace")
        .arg(trace)
        .env("EAFL_WORKERS", "1")
        .env_remove("EAFL_EAGER_DRAIN");
    cmd.output().expect("spawning eafl sweep")
}

fn trace_files(dir: &Path) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("reading {}: {e}", dir.display()))
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".trace.jsonl"))
        .collect();
    names.sort();
    names
}

#[test]
fn per_cell_traces_identical_across_shard_splits() {
    let dir = tmp_dir("shards");
    let (out_a, trace_a) = (dir.join("out-a"), dir.join("trace-a"));
    let (out_b, trace_b) = (dir.join("out-b"), dir.join("trace-b"));

    assert_ok(&sweep(GRID, &[], &out_a, &trace_a), "single-process sweep");
    for index in 0..2 {
        let shard = format!("{index}/2");
        assert_ok(
            &sweep(GRID, &["--shard", &shard, "--jobs", "1"], &out_b, &trace_b),
            &format!("shard {shard}"),
        );
    }

    let names = trace_files(&trace_a);
    assert_eq!(names.len(), 4, "one trace per grid cell: {names:?}");
    assert_eq!(names, trace_files(&trace_b), "shards must cover the same cells");
    for name in &names {
        let a = std::fs::read(trace_a.join(name)).unwrap();
        let b = std::fs::read(trace_b.join(name)).unwrap();
        assert!(!a.is_empty(), "{name} is empty");
        assert_eq!(a, b, "{name}: trace bytes must not depend on the shard split");
        // Campaign traces are self-describing: cell identity first.
        let text = String::from_utf8(a).unwrap();
        assert_eq!(
            text.lines().nth(1).map(|l| l.contains(r#""ev": "campaign_cell""#)),
            Some(true),
            "{name}: second line should be the campaign_cell head"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn summarize_reproduces_the_run_summary_exactly() {
    let dir = tmp_dir("summarize");
    let _ = traced_run(&dir, "sum", "1", None);
    let trace = dir.join("sum.trace.jsonl");
    let sum_dir = dir.join("figures");

    let mut cmd = Command::new(BIN);
    cmd.arg("trace")
        .arg("summarize")
        .arg(&trace)
        .arg("--out")
        .arg(&sum_dir);
    let output = cmd.output().expect("spawning eafl trace summarize");
    assert_ok(&output, "trace summarize");

    let folded_doc = Json::parse(
        &std::fs::read_to_string(sum_dir.join("summary.json")).expect("summary.json"),
    )
    .unwrap();
    let folded = &folded_doc.as_arr().expect("summary.json is an array")[0];
    let reference = Json::parse(
        &std::fs::read_to_string(dir.join("out-sum").join("run-eafl.summary.json"))
            .expect("run summary"),
    )
    .unwrap();

    // Same floats through the same writer: the folded numbers are not
    // approximately right, they are the *same JSON values*.
    for key in [
        "name",
        "rounds",
        "committed_rounds",
        "final_accuracy",
        "best_accuracy",
        "total_dropouts",
        "total_fl_energy_j",
        "wall_clock_h",
    ] {
        assert_eq!(
            folded.get(key),
            reference.get(key),
            "summarize diverges from the run summary on {key:?}"
        );
    }

    // The figure CSVs cover every round of the run.
    let tta = std::fs::read_to_string(sum_dir.join("time_to_accuracy.csv")).unwrap();
    let drops = std::fs::read_to_string(sum_dir.join("dropouts.csv")).unwrap();
    assert_eq!(drops.lines().count(), 1 + 10, "header + one row per round");
    assert!(tta.lines().count() >= 2, "at least one committed round:\n{tta}");
    std::fs::remove_dir_all(&dir).unwrap();
}
