# EAFL build entry points. The Rust side is fully offline
# (vendor/anyhow is in-tree); `artifacts` needs the Python/JAX
# toolchain and is only required for `--features xla` builds.

.PHONY: build test bench verify sweep artifacts

build:
	cargo build --release

test:
	cargo test -q

# Runs every bench; plan_path_throughput records the perf trajectory
# into BENCH_plan.json at the repo root (eafl-bench-v1 schema, default
# --out of that bench), and each run is appended — stamped with the git
# SHA — to BENCH_history.jsonl so the trend across commits is queryable.
bench:
	cargo bench
	./scripts/append_bench_history.sh BENCH_plan.json BENCH_history.jsonl

# Tier-1 verification: build + tests + (if installed) clippy + fmt.
verify:
	./ci.sh

# Smoke the campaign runner end to end on the mock runtime.
sweep: build
	./target/release/eafl sweep --mock --rounds 60 --out results/campaign

# AOT-lower the JAX model to HLO text for the PJRT runtime (Layer 2).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
