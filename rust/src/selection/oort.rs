//! Oort guided participant selection (Lai et al., OSDI'21) — the
//! state-of-the-art baseline the paper modifies.
//!
//! Faithful to the published design in structure:
//!  - utility Eq. (2): statistical utility × system (deadline) penalty;
//!  - ε-greedy exploration of never-measured clients, ε decaying per
//!    round to a floor;
//!  - UCB-style staleness bonus on stale utility estimates;
//!  - a pacer that sets the round deadline T at a percentile of client
//!    durations and relaxes it when aggregate utility stalls;
//!  - exploitation samples from the top-(1+ε_cut)·k utility band rather
//!    than strictly top-k (Oort's randomized cutoff), which spreads
//!    selection across near-ties.
//!
//! Deliberately battery-oblivious: this is precisely the behaviour the
//! paper's Fig. 4a shows causing mass drop-outs.
//!
//! **Fast path:** only the top band ever needs ordering, so the former
//! full `sort_by` of the explored pool is a `select_nth_unstable_by`
//! partition + band sort ([`rank_top_band`]), and the weighted draw
//! goes through the shared Fenwick sampler
//! ([`crate::selection::sampler`]) — O(E + band·log band + k·log band)
//! per round instead of O(E log E + k·E). All intermediate buffers are
//! selector-owned scratch, reused across rounds.

use crate::util::rng::Rng;

use crate::config::SelectorConfig;

use super::sampler::FenwickSampler;
use super::utility::{oort_utility, staleness_bonus};
use super::{percentile_in_place, rank_top_band, Candidate, RoundFeedback, Selector};

/// Width of the exploitation cutoff band (fraction of k over-sampled
/// before the final weighted draw).
const CUTOFF_BAND: f64 = 0.5;

pub struct OortSelector {
    cfg: SelectorConfig,
    /// Pacer state: deadline relaxation accumulated when utility stalls.
    pacer_relax_s: f64,
    /// Sum of selected-client utilities in recent rounds (pacer signal).
    recent_utils: Vec<f64>,
    /// Reusable percentile buffer: `deadline_s` and the utility-scale
    /// computation run once per round over the whole candidate pool, so
    /// a per-call Vec allocation is pure waste at 100k clients.
    scratch: Vec<f64>,
    /// Reusable candidate-index partitions and the scored band.
    explored_idx: Vec<u32>,
    unexplored_ids: Vec<usize>,
    scored: Vec<(usize, f64)>,
    /// Reusable Fenwick sampler (tree + quantized weights) for the
    /// per-round weighted draws.
    sampler: FenwickSampler,
}

/// Score an explored candidate: Eq. (2) + staleness bonus scaled by the
/// candidate pool's utility range. Free function so the hot loop can
/// split-borrow the selector's scratch buffers.
fn score(
    cfg: &SelectorConfig,
    c: &Candidate,
    round: u64,
    deadline: f64,
    util_scale: f64,
) -> f64 {
    let stat = c.stat_util.unwrap_or(0.0);
    let duration = c.measured_duration_s.unwrap_or(c.expected_duration_s);
    oort_utility(stat, deadline, duration, cfg.alpha)
        + staleness_bonus(round, c.last_selected_round, cfg.ucb_weight) * util_scale
}

impl OortSelector {
    pub fn new(cfg: SelectorConfig) -> Self {
        Self {
            cfg,
            pacer_relax_s: 0.0,
            recent_utils: Vec::new(),
            scratch: Vec::new(),
            explored_idx: Vec::new(),
            unexplored_ids: Vec::new(),
            scored: Vec::new(),
            sampler: FenwickSampler::empty(),
        }
    }

    /// Current exploration fraction ε for `round` (1-based).
    pub fn epsilon(&self, round: u64) -> f64 {
        (self.cfg.explore_init * self.cfg.explore_decay.powi(round.saturating_sub(1) as i32))
            .max(self.cfg.min_explore)
    }

    /// Whether the pacer currently holds a relaxed deadline — i.e. the
    /// last window comparison saw aggregate utility stall. The budget
    /// family's deadline-aware policy reads this as its spend-ahead
    /// signal.
    pub(super) fn pacer_relaxed(&self) -> bool {
        self.pacer_relax_s > 0.0
    }

    /// Weighted sample of `k` distinct ids from `(id, weight)` pairs —
    /// THE draw primitive for both selectors (EAFL's exploration loop
    /// routes here too). One `gen_f64` per pick; Fenwick inverse-CDF
    /// descent, provably identical to the linear-scan reference
    /// (`sampler::weighted_sample_linear`) over the same pool. The
    /// caller passes its own reusable sampler, and weights are
    /// quantized straight out of the pool, so steady-state draws
    /// allocate nothing pool-sized.
    pub(super) fn weighted_pick(
        sampler: &mut FenwickSampler,
        pool: &[(usize, f64)],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        sampler.rebuild_from(pool.iter().map(|&(_, w)| w));
        sampler.sample_distinct(k, rng).into_iter().map(|i| pool[i].0).collect()
    }

    /// The select body with the round deadline already computed —
    /// shared by `select` (computes it fresh) and `plan` (computes it
    /// once for both selection and the returned deadline).
    fn select_with_deadline(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        deadline: f64,
        rng: &mut Rng,
    ) -> Vec<usize> {
        if candidates.is_empty() || k == 0 {
            return Vec::new();
        }
        let eps = self.epsilon(round);

        self.explored_idx.clear();
        self.unexplored_ids.clear();
        for (i, c) in candidates.iter().enumerate() {
            if c.stat_util.is_none() {
                self.unexplored_ids.push(c.id);
            } else {
                self.explored_idx.push(i as u32);
            }
        }

        // Exploration quota: ε·k, but never more than available. One
        // shuffle covers both the quota and the thin-pool fallback.
        let k_explore = ((eps * k as f64).round() as usize)
            .min(self.unexplored_ids.len())
            .min(k);
        rng.shuffle(&mut self.unexplored_ids);
        let mut selected: Vec<usize> = self.unexplored_ids[..k_explore].to_vec();

        // Exploitation: weighted draw from the top utility band.
        let k_exploit = k - selected.len();
        if k_exploit > 0 && !self.explored_idx.is_empty() {
            self.scratch.clear();
            self.scratch.extend(
                self.explored_idx
                    .iter()
                    .map(|&i| candidates[i as usize].stat_util.unwrap_or(0.0)),
            );
            let util_scale = percentile_in_place(&mut self.scratch, 0.95).max(1e-9);
            self.scored.clear();
            for &i in &self.explored_idx {
                let c = &candidates[i as usize];
                self.scored.push((c.id, score(&self.cfg, c, round, deadline, util_scale)));
            }
            let band = ((k_exploit as f64) * (1.0 + CUTOFF_BAND)).ceil() as usize;
            rank_top_band(&mut self.scored, band.max(k_exploit));
            selected.extend(Self::weighted_pick(&mut self.sampler, &self.scored, k_exploit, rng));
        } else if k_exploit > 0 {
            // Nothing explored yet: fill from the unexplored remainder
            // (already uniformly shuffled above, disjoint from the
            // exploration picks by construction).
            selected.extend(
                self.unexplored_ids[k_explore..].iter().take(k_exploit).copied(),
            );
        }
        selected
    }
}

impl Selector for OortSelector {
    fn select(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let deadline = self.deadline_s(candidates);
        self.select_with_deadline(round, candidates, k, deadline, rng)
    }

    fn plan(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        rng: &mut Rng,
    ) -> (Vec<usize>, f64) {
        // One pacer-percentile pass serves both the Eq. (2) penalty
        // inside selection and the round deadline the engine needs.
        let deadline = self.deadline_s(candidates);
        let selected = self.select_with_deadline(round, candidates, k, deadline, rng);
        (selected, deadline)
    }

    fn feedback(&mut self, fb: &RoundFeedback<'_>) {
        // Pacer signal: total utility delivered by this round's cohort.
        let total: f64 = fb
            .outcomes
            .iter()
            .filter(|o| o.completed)
            .filter_map(|o| o.stat_util)
            .sum();
        self.recent_utils.push(total);
        let n = self.recent_utils.len();
        // Oort's pacer: compare the last two windows of 5 rounds; if
        // aggregate utility fell, relax the deadline by pacer_step.
        const W: usize = 5;
        if n >= 2 * W && n % W == 0 {
            let prev: f64 = self.recent_utils[n - 2 * W..n - W].iter().sum();
            let cur: f64 = self.recent_utils[n - W..].iter().sum();
            if cur < prev {
                self.pacer_relax_s += self.cfg.pacer_step_s;
            } else if self.pacer_relax_s > 0.0 {
                // Utility recovered: claw back half a step.
                self.pacer_relax_s =
                    (self.pacer_relax_s - 0.5 * self.cfg.pacer_step_s).max(0.0);
            }
        }
    }

    fn deadline_s(&mut self, candidates: &[Candidate]) -> f64 {
        self.scratch.clear();
        self.scratch.extend(
            candidates
                .iter()
                .map(|c| c.measured_duration_s.unwrap_or(c.expected_duration_s)),
        );
        percentile_in_place(&mut self.scratch, self.cfg.pacer_percentile).max(1.0)
            + self.pacer_relax_s
    }

    fn name(&self) -> &'static str {
        "oort"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::ParticipantOutcome;

    fn cand(id: usize, util: Option<f64>, dur: f64, battery: f64) -> Candidate {
        Candidate {
            id,
            stat_util: util,
            measured_duration_s: util.map(|_| dur),
            expected_duration_s: dur,
            last_selected_round: None,
            battery_frac: battery,
            projected_drain_frac: 0.02,
            round_energy_j: 50.0,
        }
    }

    #[test]
    fn epsilon_decays_to_floor() {
        let s = OortSelector::new(SelectorConfig::default());
        assert!(s.epsilon(1) > s.epsilon(50));
        assert!((s.epsilon(10_000) - s.cfg.min_explore).abs() < 1e-9);
    }

    #[test]
    fn prefers_high_utility_when_exploitation_dominates() {
        let mut cfg = SelectorConfig::default();
        cfg.explore_init = 0.0;
        cfg.min_explore = 0.0;
        cfg.ucb_weight = 0.0;
        let mut s = OortSelector::new(cfg);
        let mut cands: Vec<Candidate> =
            (0..20).map(|i| cand(i, Some(i as f64 + 1.0), 100.0, 1.0)).collect();
        Rng::seed_from_u64(0).shuffle(&mut cands);
        let mut hits = 0;
        for seed in 0..50 {
            let picked = s.select(100, &cands, 5, &mut Rng::seed_from_u64(seed));
            assert_eq!(picked.len(), 5);
            hits += picked.iter().filter(|&&id| id >= 13).count();
        }
        // Top band is ids 13..20 (utility 14..20 within 1.5x cutoff);
        // high-utility clients must dominate selections.
        assert!(hits > 150, "high-utility ids picked {hits}/250 times");
    }

    #[test]
    fn band_partition_matches_full_sort() {
        // The select_nth band must hold exactly what a full sort would
        // keep, in the same (score desc, id asc) order — including ties.
        let mut rng = Rng::seed_from_u64(42);
        for n in [1usize, 5, 40, 500] {
            for band in [1usize, 3, 10, n, n + 7] {
                let mut scored: Vec<(usize, f64)> = (0..n)
                    .map(|id| (id, (rng.gen_range_usize(0, 8) as f64) * 0.5))
                    .collect();
                let mut reference = scored.clone();
                reference.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
                reference.truncate(band);
                rank_top_band(&mut scored, band);
                assert_eq!(scored, reference, "n={n} band={band}");
            }
        }
    }

    #[test]
    fn stragglers_get_penalized() {
        let mut cfg = SelectorConfig::default();
        cfg.explore_init = 0.0;
        cfg.min_explore = 0.0;
        cfg.ucb_weight = 0.0;
        cfg.pacer_percentile = 0.5;
        let mut s = OortSelector::new(cfg);
        // Same statistical utility; one is a 10x straggler.
        let cands = vec![
            cand(0, Some(10.0), 100.0, 1.0),
            cand(1, Some(10.0), 100.0, 1.0),
            cand(2, Some(10.0), 1000.0, 1.0),
        ];
        let mut straggler_picks = 0;
        for seed in 0..100 {
            let picked = s.select(10, &cands, 1, &mut Rng::seed_from_u64(seed));
            if picked == vec![2] {
                straggler_picks += 1;
            }
        }
        assert!(straggler_picks < 20, "straggler picked {straggler_picks}/100");
    }

    #[test]
    fn exploration_picks_unexplored() {
        let mut cfg = SelectorConfig::default();
        cfg.explore_init = 1.0;
        cfg.explore_decay = 1.0;
        cfg.min_explore = 1.0;
        let mut s = OortSelector::new(cfg);
        let cands = vec![
            cand(0, Some(100.0), 100.0, 1.0),
            cand(1, None, 100.0, 1.0),
            cand(2, None, 100.0, 1.0),
        ];
        let picked = s.select(1, &cands, 2, &mut Rng::seed_from_u64(4));
        assert_eq!(picked.len(), 2);
        // ε=1 ⇒ all picks are exploration ⇒ explored id 0 never chosen.
        assert!(!picked.contains(&0));
    }

    #[test]
    fn battery_is_ignored_by_design() {
        let mut cfg = SelectorConfig::default();
        cfg.explore_init = 0.0;
        cfg.min_explore = 0.0;
        cfg.ucb_weight = 0.0;
        let mut s = OortSelector::new(cfg);
        // High utility + nearly dead battery vs low utility + full.
        let cands = vec![cand(0, Some(100.0), 100.0, 0.03), cand(1, Some(1.0), 100.0, 1.0)];
        let picked = s.select(10, &cands, 1, &mut Rng::seed_from_u64(0));
        assert_eq!(picked, vec![0], "Oort must chase utility regardless of battery");
    }

    #[test]
    fn pacer_relaxes_deadline_on_utility_drop() {
        let mut s = OortSelector::new(SelectorConfig::default());
        let cands = vec![cand(0, Some(1.0), 100.0, 1.0)];
        let d0 = s.deadline_s(&cands);
        let out = |u: f64| ParticipantOutcome {
            id: 0,
            stat_util: Some(u),
            duration_s: 100.0,
            completed: true,
        };
        // 5 good rounds then 5 bad rounds => relax.
        for r in 0..5 {
            s.feedback(&RoundFeedback { round: r, outcomes: &[out(10.0)] });
        }
        for r in 5..10 {
            s.feedback(&RoundFeedback { round: r, outcomes: &[out(0.1)] });
        }
        let d1 = s.deadline_s(&cands);
        assert!(d1 > d0, "deadline must relax: {d0} -> {d1}");
    }

    #[test]
    fn never_selects_more_than_k_or_duplicates() {
        let mut s = OortSelector::new(SelectorConfig::default());
        let cands: Vec<Candidate> = (0..30)
            .map(|i| cand(i, if i % 2 == 0 { Some(i as f64) } else { None }, 50.0, 1.0))
            .collect();
        for round in 1..30 {
            let picked =
                s.select(round, &cands, 10, &mut Rng::seed_from_u64(round));
            assert!(picked.len() <= 10);
            let mut d = picked.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), picked.len());
        }
    }
}
