//! Quickstart: the smallest end-to-end EAFL run.
//!
//! Loads the AOT artifacts (falls back to the mock runtime with
//! `--mock` or if artifacts are missing), builds a small federation,
//! runs 20 rounds with the paper's EAFL selector and prints the
//! per-round metrics.
//!
//! Run:  cargo run --release --example quickstart            (real PJRT)
//!       cargo run --release --example quickstart -- --mock  (analytic)

use anyhow::Result;

use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::runtime::{MockRuntime, ModelRuntime, XlaRuntime};

fn main() -> Result<()> {
    let use_mock = std::env::args().any(|a| a == "--mock");
    let runtime: Box<dyn ModelRuntime> = if use_mock {
        println!("using analytic mock runtime");
        Box::new(MockRuntime::default())
    } else {
        match XlaRuntime::load(&XlaRuntime::default_dir()) {
            Ok(rt) => {
                println!("loaded PJRT artifacts from {:?}", XlaRuntime::default_dir());
                Box::new(rt)
            }
            Err(e) => {
                println!("artifacts unavailable ({e}); falling back to mock runtime");
                Box::new(MockRuntime::default())
            }
        }
    };

    // Paper §5 defaults, shrunk to a 20-round / 40-client quick run.
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.name = "quickstart".into();
    cfg.federation.rounds = 60; // past the non-IID cold start
    cfg.federation.eval_interval = 5;
    cfg.data.min_samples = 60;
    cfg.data.max_samples = 240;

    println!(
        "federation: {} clients, K={}, {} rounds, selector={}, f={}",
        cfg.federation.num_clients,
        cfg.federation.participants_per_round,
        cfg.federation.rounds,
        cfg.selector.kind,
        cfg.selector.eafl_f
    );

    let log = Coordinator::new(cfg, runtime.as_ref())?.run()?;

    println!("\nround  wall(h)  dur(s)  done/sel  drop  acc     loss    fairness");
    for r in log.records.iter().step_by(3) {
        println!(
            "{:>5}  {:>7.3}  {:>6.1}  {:>4}/{:<4} {:>4}  {:.4}  {:>6.3}  {:.3}",
            r.round,
            r.wall_clock_h,
            r.round_duration_s,
            r.completed,
            r.selected,
            r.cumulative_dead,
            r.test_accuracy,
            r.train_loss,
            r.fairness
        );
    }

    let s = log.summary();
    println!(
        "\nfinal: accuracy={:.4} dropouts={} energy={:.1} kJ over {:.2} simulated hours",
        s.final_accuracy,
        s.total_dropouts,
        s.total_fl_energy_j / 1000.0,
        s.wall_clock_h
    );
    Ok(())
}
