//! Experiment configuration: typed structs + TOML-subset codec (see
//! `util::toml` — the build is offline, so the codec is in-tree).
//!
//! Every knob of the simulation is here so that the paper's experiments
//! are plain config files and the benches/examples construct variants
//! programmatically. Defaults reproduce the paper's §5 setup: lr = 0.05,
//! batch = 20, K = 10 participants/round, f = 0.25, non-IID 4-of-35
//! labels per client.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::util::toml::{TomlDoc, TomlWriter};

/// Hard ceiling on the simulated population. The registry sizes every
/// SoA pool column, liveness index and drain-ledger anchor vector to N
/// up front, so an absurd `--clients` must fail validation with a clear
/// message instead of an allocator abort. 100M clients ≈ a few tens of
/// GB of pool state — an order of magnitude past the benchmarked 10M
/// tier, and past any machine this simulator targets.
pub const MAX_CLIENTS: usize = 100_000_000;

/// Which participant-selection policy the coordinator runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectorKind {
    /// Uniform random over eligible clients (paper's "Random").
    Random,
    /// Oort guided selection (Lai et al., OSDI'21) — utility Eq. (2).
    Oort,
    /// EAFL — Oort utility blended with remaining battery, Eq. (1).
    Eafl,
    /// EAFL's reward ranking constrained by a campaign energy budget
    /// (requires `selector.budget_j > 0`; policy via `budget_policy`).
    Budget,
}

impl std::fmt::Display for SelectorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SelectorKind::Random => write!(f, "random"),
            SelectorKind::Oort => write!(f, "oort"),
            SelectorKind::Eafl => write!(f, "eafl"),
            SelectorKind::Budget => write!(f, "budget"),
        }
    }
}

impl std::str::FromStr for SelectorKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Ok(Self::Random),
            "oort" => Ok(Self::Oort),
            "eafl" => Ok(Self::Eafl),
            "budget" => Ok(Self::Budget),
            other => bail!("unknown selector {other:?} (random|oort|eafl|budget)"),
        }
    }
}

/// How the budget selector translates the remaining campaign envelope
/// into a per-round spending allowance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetPolicy {
    /// Spend against the full remaining envelope; never start a round
    /// that would breach it (k shrinks greedily).
    HardCap,
    /// Per-round allowance = remaining budget / remaining rounds.
    Amortized,
    /// Amortized, but spend ahead (allowance × `budget_spend_ahead`)
    /// while the Oort pacer reports stalled utility.
    DeadlineAware,
}

impl std::fmt::Display for BudgetPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BudgetPolicy::HardCap => write!(f, "hard-cap"),
            BudgetPolicy::Amortized => write!(f, "amortized"),
            BudgetPolicy::DeadlineAware => write!(f, "deadline-aware"),
        }
    }
}

impl std::str::FromStr for BudgetPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hard-cap" | "hardcap" => Ok(Self::HardCap),
            "amortized" => Ok(Self::Amortized),
            "deadline-aware" | "deadlineaware" => Ok(Self::DeadlineAware),
            other => bail!(
                "unknown budget policy {other:?} (hard-cap|amortized|deadline-aware)"
            ),
        }
    }
}

/// One shard of a sharded campaign: `index` of `count` (0-based), as
/// written on the command line (`--shard 0/4`). Which grid cells a
/// shard owns is decided by a stable hash of the cell *name* (see
/// `campaign::shard_of`), so shards need no coordination: any process
/// given the same grid and the same `I/N` computes the same partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// 0-based shard index, `< count`.
    pub index: usize,
    /// Total number of shards, `>= 1`.
    pub count: usize,
}

impl std::fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

impl std::str::FromStr for ShardSpec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let Some((index, count)) = s.split_once('/') else {
            bail!("shard spec {s:?} must be I/N (0-based index I of N shards, e.g. 0/4)");
        };
        let index: usize = index
            .trim()
            .parse()
            .with_context(|| format!("bad shard index in {s:?}"))?;
        let count: usize = count
            .trim()
            .parse()
            .with_context(|| format!("bad shard count in {s:?}"))?;
        ensure!(count >= 1, "shard count must be >= 1 (got {s:?})");
        ensure!(
            index < count,
            "shard index must be in 0..count (got {s:?}; the index is 0-based)"
        );
        Ok(Self { index, count })
    }
}

/// Server-side aggregation rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggregatorKind {
    /// Sample-weighted parameter averaging (McMahan et al.).
    FedAvg,
    /// YoGi adaptive server optimizer over the pseudo-gradient
    /// (paper §5 uses YoGi, per Reddi et al. / Ramaswamy et al.).
    Yogi,
}

impl std::fmt::Display for AggregatorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AggregatorKind::FedAvg => write!(f, "fedavg"),
            AggregatorKind::Yogi => write!(f, "yogi"),
        }
    }
}

impl std::str::FromStr for AggregatorKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fedavg" => Ok(Self::FedAvg),
            "yogi" => Ok(Self::Yogi),
            other => bail!("unknown aggregator {other:?} (fedavg|yogi)"),
        }
    }
}

/// Federation-level parameters (paper §5 "Experimental Setup").
#[derive(Debug, Clone, PartialEq)]
pub struct FederationConfig {
    /// Total client population N.
    pub num_clients: usize,
    /// Participants per round K (paper: 10).
    pub participants_per_round: usize,
    /// Total training rounds (paper: 500).
    pub rounds: usize,
    /// Minimum fraction of K that must report for a round to commit
    /// (FedScale-style round-failure threshold).
    pub min_report_fraction: f64,
    /// Evaluate the global model every this many rounds.
    pub eval_interval: usize,
    /// Aggregation rule.
    pub aggregator: AggregatorKind,
}

impl Default for FederationConfig {
    fn default() -> Self {
        Self {
            num_clients: 200,
            participants_per_round: 10,
            rounds: 500,
            min_report_fraction: 0.5,
            eval_interval: 10,
            aggregator: AggregatorKind::Yogi,
        }
    }
}

/// Local-training parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingConfig {
    /// Client learning rate (paper: 0.05).
    pub learning_rate: f32,
    /// Local SGD steps per selected client per round.
    pub local_steps: usize,
    /// Server learning rate for YoGi.
    pub server_learning_rate: f32,
    /// Model init seed.
    pub init_seed: u32,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        Self { learning_rate: 0.05, local_steps: 5, server_learning_rate: 0.05, init_seed: 42 }
    }
}

/// Selector-specific knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct SelectorConfig {
    pub kind: SelectorKind,
    /// EAFL's f in Eq. (1): reward = f·Util + (1−f)·power. Paper: 0.25.
    pub eafl_f: f64,
    /// Oort exploration fraction at round 1 (decays to `min_explore`).
    pub explore_init: f64,
    /// Exploration decay factor per round.
    pub explore_decay: f64,
    /// Exploration floor.
    pub min_explore: f64,
    /// Oort α: straggler penalty exponent in Eq. (2).
    pub alpha: f64,
    /// UCB confidence weight on rounds-since-last-selection.
    pub ucb_weight: f64,
    /// Pacer: target round duration percentile among candidate speeds.
    pub pacer_percentile: f64,
    /// Pacer: seconds added to the deadline when utility stalls.
    pub pacer_step_s: f64,
    /// Clients below this battery fraction are ineligible (safety floor;
    /// mirrors mobile OSes refusing background work on low battery).
    pub min_battery_frac: f64,
    /// Campaign energy budget in joules; 0 = unlimited. When > 0 the
    /// coordinator runs an energy ledger for ANY selector (terminal
    /// stop on exhaustion); the `budget` selector additionally plans
    /// each round against it.
    pub budget_j: f64,
    /// How the budget selector paces spend (hard-cap | amortized |
    /// deadline-aware). Ignored by other selectors.
    pub budget_policy: BudgetPolicy,
    /// Deadline-aware policy: allowance multiplier while the pacer
    /// reports stalled utility. Must be >= 1.
    pub budget_spend_ahead: f64,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        Self {
            kind: SelectorKind::Eafl,
            eafl_f: 0.25,
            explore_init: 0.9,
            explore_decay: 0.98,
            min_explore: 0.2,
            alpha: 2.0,
            ucb_weight: 0.1,
            pacer_percentile: 0.8,
            pacer_step_s: 10.0,
            min_battery_frac: 0.02,
            budget_j: 0.0,
            budget_policy: BudgetPolicy::HardCap,
            budget_spend_ahead: 2.0,
        }
    }
}

/// Synthetic speech-commands dataset + non-IID partition (paper §5
/// "Data Partitioning": each learner gets a random 10% of the labels —
/// 4 of 35 — with uniform sample counts).
#[derive(Debug, Clone, PartialEq)]
pub struct DataConfig {
    /// Labels each client holds (paper: 4 of 35).
    pub labels_per_client: usize,
    /// Per-client sample count is uniform in [min_samples, max_samples].
    pub min_samples: usize,
    pub max_samples: usize,
    /// Local minibatch size B (paper: 20). Must equal the AOT artifact's
    /// baked train batch.
    pub batch_size: usize,
    /// Held-out IID test-set size.
    pub test_samples: usize,
    /// Feature-noise stddev (class templates are unit-scale).
    pub noise_std: f32,
    /// Dataset/partition RNG seed.
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self {
            labels_per_client: 4,
            min_samples: 60,
            max_samples: 240,
            batch_size: 20,
            test_samples: 1024,
            noise_std: 0.6,
            seed: 7,
        }
    }
}

/// Device-population mix and battery behaviour.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceConfig {
    /// Fractions of high/mid/low-end devices (Table 2 tiers); must sum
    /// to ~1.
    pub tier_fractions: [f64; 3],
    /// Initial battery fraction is uniform in [min_init, max_init].
    pub min_init_battery: f64,
    pub max_init_battery: f64,
    /// Idle drain in battery-fraction per hour for unselected devices.
    pub idle_drain_per_hour: f64,
    /// Normal-usage (screen-on) drain in fraction/hour.
    pub busy_drain_per_hour: f64,
    /// Probability an unselected device is in the busy state.
    pub busy_probability: f64,
    /// If > 0, a dead device returns after this many hours at this
    /// recharge fraction (0 disables recovery — paper's harsh scenario).
    pub recharge_after_hours: f64,
    pub recharge_to_fraction: f64,
    /// Trace RNG seed.
    pub seed: u64,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        Self {
            tier_fractions: [0.25, 0.40, 0.35],
            min_init_battery: 0.25,
            max_init_battery: 1.0,
            idle_drain_per_hour: 0.005,
            busy_drain_per_hour: 0.04,
            busy_probability: 0.3,
            recharge_after_hours: 0.0,
            recharge_to_fraction: 0.8,
            seed: 13,
        }
    }
}

/// Network trace generation (MobiPerf substitute, DESIGN.md §2).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkConfig {
    /// Fraction of clients on WiFi (rest on 3G/cellular).
    pub wifi_fraction: f64,
    /// Log-normal medians (Mbps) per medium.
    pub wifi_down_mbps: f64,
    pub wifi_up_mbps: f64,
    pub cell_down_mbps: f64,
    pub cell_up_mbps: f64,
    /// Log-normal sigma (spread) of bandwidth draws.
    pub sigma: f64,
    pub seed: u64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        Self {
            wifi_fraction: 0.6,
            wifi_down_mbps: 20.0,
            wifi_up_mbps: 8.0,
            cell_down_mbps: 6.0,
            cell_up_mbps: 2.0,
            sigma: 0.6,
            seed: 17,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Experiment name (used in output file names).
    pub name: String,
    /// Environment scenario: a preset name (`steady`, `diurnal`,
    /// `commuter`, `solar-edge`) or a path to a scenario TOML file —
    /// resolved by the coordinator via `scenario::Scenario::resolve`.
    pub scenario: String,
    pub federation: FederationConfig,
    pub training: TrainingConfig,
    pub selector: SelectorConfig,
    pub data: DataConfig,
    pub devices: DeviceConfig,
    pub network: NetworkConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: String::new(),
            scenario: "steady".to_string(),
            federation: FederationConfig::default(),
            training: TrainingConfig::default(),
            selector: SelectorConfig::default(),
            data: DataConfig::default(),
            devices: DeviceConfig::default(),
            network: NetworkConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Paper §5 defaults with a given selector.
    pub fn paper_default(kind: SelectorKind) -> Self {
        let mut c = Self::default();
        c.selector.kind = kind;
        c.name = format!("paper-{kind}");
        c
    }

    /// A small/fast configuration for tests and smoke runs.
    pub fn smoke(kind: SelectorKind) -> Self {
        let mut c = Self::paper_default(kind);
        c.name = format!("smoke-{kind}");
        c.federation.num_clients = 40;
        c.federation.rounds = 30;
        c.federation.eval_interval = 5;
        c.data.min_samples = 20;
        c.data.max_samples = 60;
        c.data.test_samples = 256;
        c
    }

    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let cfg = Self::from_toml(&text)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from TOML text. Missing keys fall back to defaults, so
    /// partial configs (just the overrides) are valid.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text).context("parsing TOML config")?;
        let mut c = Self::default();
        if let Some(v) = doc.get_str("name") {
            c.name = v.to_string();
        }
        if let Some(v) = doc.get_str("scenario") {
            c.scenario = v.to_string();
        }

        let f = &mut c.federation;
        if let Some(v) = doc.get_usize("federation.num_clients") {
            f.num_clients = v;
        }
        if let Some(v) = doc.get_usize("federation.participants_per_round") {
            f.participants_per_round = v;
        }
        if let Some(v) = doc.get_usize("federation.rounds") {
            f.rounds = v;
        }
        if let Some(v) = doc.get_f64("federation.min_report_fraction") {
            f.min_report_fraction = v;
        }
        if let Some(v) = doc.get_usize("federation.eval_interval") {
            f.eval_interval = v;
        }
        if let Some(v) = doc.get_str("federation.aggregator") {
            f.aggregator = v.parse()?;
        }

        let t = &mut c.training;
        if let Some(v) = doc.get_f32("training.learning_rate") {
            t.learning_rate = v;
        }
        if let Some(v) = doc.get_usize("training.local_steps") {
            t.local_steps = v;
        }
        if let Some(v) = doc.get_f32("training.server_learning_rate") {
            t.server_learning_rate = v;
        }
        if let Some(v) = doc.get_u32("training.init_seed") {
            t.init_seed = v;
        }

        let s = &mut c.selector;
        if let Some(v) = doc.get_str("selector.kind") {
            s.kind = v.parse()?;
        }
        if let Some(v) = doc.get_f64("selector.eafl_f") {
            s.eafl_f = v;
        }
        if let Some(v) = doc.get_f64("selector.explore_init") {
            s.explore_init = v;
        }
        if let Some(v) = doc.get_f64("selector.explore_decay") {
            s.explore_decay = v;
        }
        if let Some(v) = doc.get_f64("selector.min_explore") {
            s.min_explore = v;
        }
        if let Some(v) = doc.get_f64("selector.alpha") {
            s.alpha = v;
        }
        if let Some(v) = doc.get_f64("selector.ucb_weight") {
            s.ucb_weight = v;
        }
        if let Some(v) = doc.get_f64("selector.pacer_percentile") {
            s.pacer_percentile = v;
        }
        if let Some(v) = doc.get_f64("selector.pacer_step_s") {
            s.pacer_step_s = v;
        }
        if let Some(v) = doc.get_f64("selector.min_battery_frac") {
            s.min_battery_frac = v;
        }
        if let Some(v) = doc.get_f64("selector.budget_j") {
            s.budget_j = v;
        }
        if let Some(v) = doc.get_str("selector.budget_policy") {
            s.budget_policy = v.parse()?;
        }
        if let Some(v) = doc.get_f64("selector.budget_spend_ahead") {
            s.budget_spend_ahead = v;
        }

        let d = &mut c.data;
        if let Some(v) = doc.get_usize("data.labels_per_client") {
            d.labels_per_client = v;
        }
        if let Some(v) = doc.get_usize("data.min_samples") {
            d.min_samples = v;
        }
        if let Some(v) = doc.get_usize("data.max_samples") {
            d.max_samples = v;
        }
        if let Some(v) = doc.get_usize("data.batch_size") {
            d.batch_size = v;
        }
        if let Some(v) = doc.get_usize("data.test_samples") {
            d.test_samples = v;
        }
        if let Some(v) = doc.get_f32("data.noise_std") {
            d.noise_std = v;
        }
        if let Some(v) = doc.get_u64("data.seed") {
            d.seed = v;
        }

        let dev = &mut c.devices;
        if let Some(v) = doc.get_num_array("devices.tier_fractions") {
            ensure!(v.len() == 3, "devices.tier_fractions must have 3 entries");
            dev.tier_fractions = [v[0], v[1], v[2]];
        }
        if let Some(v) = doc.get_f64("devices.min_init_battery") {
            dev.min_init_battery = v;
        }
        if let Some(v) = doc.get_f64("devices.max_init_battery") {
            dev.max_init_battery = v;
        }
        if let Some(v) = doc.get_f64("devices.idle_drain_per_hour") {
            dev.idle_drain_per_hour = v;
        }
        if let Some(v) = doc.get_f64("devices.busy_drain_per_hour") {
            dev.busy_drain_per_hour = v;
        }
        if let Some(v) = doc.get_f64("devices.busy_probability") {
            dev.busy_probability = v;
        }
        if let Some(v) = doc.get_f64("devices.recharge_after_hours") {
            dev.recharge_after_hours = v;
        }
        if let Some(v) = doc.get_f64("devices.recharge_to_fraction") {
            dev.recharge_to_fraction = v;
        }
        if let Some(v) = doc.get_u64("devices.seed") {
            dev.seed = v;
        }

        let n = &mut c.network;
        if let Some(v) = doc.get_f64("network.wifi_fraction") {
            n.wifi_fraction = v;
        }
        if let Some(v) = doc.get_f64("network.wifi_down_mbps") {
            n.wifi_down_mbps = v;
        }
        if let Some(v) = doc.get_f64("network.wifi_up_mbps") {
            n.wifi_up_mbps = v;
        }
        if let Some(v) = doc.get_f64("network.cell_down_mbps") {
            n.cell_down_mbps = v;
        }
        if let Some(v) = doc.get_f64("network.cell_up_mbps") {
            n.cell_up_mbps = v;
        }
        if let Some(v) = doc.get_f64("network.sigma") {
            n.sigma = v;
        }
        if let Some(v) = doc.get_u64("network.seed") {
            n.seed = v;
        }

        Ok(c)
    }

    pub fn to_toml(&self) -> String {
        // f32 -> f64 via decimal shortest-repr so 0.05f32 emits as
        // "0.05", not "0.05000000074505806".
        fn f32d(v: f32) -> f64 {
            v.to_string().parse().unwrap_or(v as f64)
        }
        let mut w = TomlWriter::new();
        w.str("name", &self.name);
        w.str("scenario", &self.scenario);

        w.table("federation");
        w.num("num_clients", self.federation.num_clients as f64)
            .num("participants_per_round", self.federation.participants_per_round as f64)
            .num("rounds", self.federation.rounds as f64)
            .num("min_report_fraction", self.federation.min_report_fraction)
            .num("eval_interval", self.federation.eval_interval as f64)
            .str("aggregator", &self.federation.aggregator.to_string());

        w.table("training");
        w.num("learning_rate", f32d(self.training.learning_rate))
            .num("local_steps", self.training.local_steps as f64)
            .num("server_learning_rate", f32d(self.training.server_learning_rate))
            .num("init_seed", self.training.init_seed as f64);

        w.table("selector");
        w.str("kind", &self.selector.kind.to_string())
            .num("eafl_f", self.selector.eafl_f)
            .num("explore_init", self.selector.explore_init)
            .num("explore_decay", self.selector.explore_decay)
            .num("min_explore", self.selector.min_explore)
            .num("alpha", self.selector.alpha)
            .num("ucb_weight", self.selector.ucb_weight)
            .num("pacer_percentile", self.selector.pacer_percentile)
            .num("pacer_step_s", self.selector.pacer_step_s)
            .num("min_battery_frac", self.selector.min_battery_frac)
            .num("budget_j", self.selector.budget_j)
            .str("budget_policy", &self.selector.budget_policy.to_string())
            .num("budget_spend_ahead", self.selector.budget_spend_ahead);

        w.table("data");
        w.num("labels_per_client", self.data.labels_per_client as f64)
            .num("min_samples", self.data.min_samples as f64)
            .num("max_samples", self.data.max_samples as f64)
            .num("batch_size", self.data.batch_size as f64)
            .num("test_samples", self.data.test_samples as f64)
            .num("noise_std", f32d(self.data.noise_std))
            .num("seed", self.data.seed as f64);

        w.table("devices");
        w.num_array("tier_fractions", &self.devices.tier_fractions)
            .num("min_init_battery", self.devices.min_init_battery)
            .num("max_init_battery", self.devices.max_init_battery)
            .num("idle_drain_per_hour", self.devices.idle_drain_per_hour)
            .num("busy_drain_per_hour", self.devices.busy_drain_per_hour)
            .num("busy_probability", self.devices.busy_probability)
            .num("recharge_after_hours", self.devices.recharge_after_hours)
            .num("recharge_to_fraction", self.devices.recharge_to_fraction)
            .num("seed", self.devices.seed as f64);

        w.table("network");
        w.num("wifi_fraction", self.network.wifi_fraction)
            .num("wifi_down_mbps", self.network.wifi_down_mbps)
            .num("wifi_up_mbps", self.network.wifi_up_mbps)
            .num("cell_down_mbps", self.network.cell_down_mbps)
            .num("cell_up_mbps", self.network.cell_up_mbps)
            .num("sigma", self.network.sigma)
            .num("seed", self.network.seed as f64);

        w.finish()
    }

    /// Sanity checks; call after construction or deserialization.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            !self.scenario.trim().is_empty(),
            "scenario must not be empty (use \"steady\" for the baseline)"
        );
        let f = &self.federation;
        ensure!(f.num_clients > 0, "num_clients must be > 0");
        ensure!(
            f.num_clients <= MAX_CLIENTS,
            "num_clients must be <= {MAX_CLIENTS} (got {}) — the SoA pool and \
             liveness indices size O(N) buffers up front",
            f.num_clients
        );
        ensure!(
            f.participants_per_round > 0 && f.participants_per_round <= f.num_clients,
            "participants_per_round must be in 1..=num_clients"
        );
        ensure!(f.rounds > 0, "rounds must be > 0");
        ensure!(
            (0.0..=1.0).contains(&f.min_report_fraction),
            "min_report_fraction must be in [0,1]"
        );
        ensure!(f.eval_interval > 0, "eval_interval must be > 0");
        ensure!(self.training.learning_rate > 0.0, "learning_rate must be > 0");
        ensure!(self.training.local_steps > 0, "local_steps must be > 0");
        ensure!((0.0..=1.0).contains(&self.selector.eafl_f), "eafl_f must be in [0,1]");
        ensure!(
            self.selector.budget_j.is_finite() && self.selector.budget_j >= 0.0,
            "selector.budget_j must be finite and >= 0 (0 = unlimited)"
        );
        ensure!(
            self.selector.kind != SelectorKind::Budget || self.selector.budget_j > 0.0,
            "the budget selector requires selector.budget_j > 0 (set --budget-j)"
        );
        ensure!(
            self.selector.budget_spend_ahead >= 1.0,
            "selector.budget_spend_ahead must be >= 1"
        );
        let tiers: f64 = self.devices.tier_fractions.iter().sum();
        ensure!((tiers - 1.0).abs() < 1e-6, "tier_fractions must sum to 1 (got {tiers})");
        ensure!(
            self.devices.min_init_battery <= self.devices.max_init_battery
                && self.devices.min_init_battery >= 0.0
                && self.devices.max_init_battery <= 1.0,
            "init battery range must satisfy 0 <= min <= max <= 1"
        );
        ensure!(self.data.labels_per_client >= 1, "labels_per_client must be >= 1");
        ensure!(
            self.data.min_samples <= self.data.max_samples && self.data.min_samples > 0,
            "sample range must satisfy 0 < min <= max"
        );
        ensure!((0.0..=1.0).contains(&self.network.wifi_fraction), "wifi_fraction in [0,1]");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_section5() {
        let c = ExperimentConfig::paper_default(SelectorKind::Eafl);
        assert_eq!(c.training.learning_rate, 0.05);
        assert_eq!(c.data.batch_size, 20);
        assert_eq!(c.federation.participants_per_round, 10);
        assert_eq!(c.federation.rounds, 500);
        assert_eq!(c.selector.eafl_f, 0.25);
        assert_eq!(c.data.labels_per_client, 4);
        assert_eq!(c.federation.aggregator, AggregatorKind::Yogi);
        assert_eq!(c.scenario, "steady", "default environment is the paper's baseline");
        c.validate().unwrap();
    }

    #[test]
    fn toml_roundtrip_exact() {
        let mut c = ExperimentConfig::paper_default(SelectorKind::Oort);
        c.scenario = "diurnal".to_string();
        c.devices.recharge_after_hours = 2.5;
        c.network.sigma = 0.33;
        let text = c.to_toml();
        let back = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn partial_toml_uses_defaults() {
        let cfg =
            ExperimentConfig::from_toml("[selector]\nkind = \"oort\"\n").unwrap();
        assert_eq!(cfg.selector.kind, SelectorKind::Oort);
        assert_eq!(cfg.federation.participants_per_round, 10);
        assert_eq!(cfg.data.batch_size, 20);
        assert_eq!(cfg.scenario, "steady");
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = ExperimentConfig::default();
        c.federation.participants_per_round = 0;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.selector.eafl_f = 1.5;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.devices.tier_fractions = [0.5, 0.5, 0.5];
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.scenario = String::new();
        assert!(c.validate().is_err());

        // Budget knobs: NaN / negative budgets, a budget selector
        // without a budget, and a sub-1 spend-ahead are all invalid.
        let mut c = ExperimentConfig::default();
        c.selector.budget_j = f64::NAN;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.selector.budget_j = -5.0;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.selector.kind = SelectorKind::Budget;
        assert!(c.validate().is_err(), "budget selector needs budget_j > 0");
        c.selector.budget_j = 1_000.0;
        c.validate().unwrap();

        let mut c = ExperimentConfig::default();
        c.selector.budget_spend_ahead = 0.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn selector_kind_parses() {
        assert_eq!("eafl".parse::<SelectorKind>().unwrap(), SelectorKind::Eafl);
        assert_eq!("OORT".parse::<SelectorKind>().unwrap(), SelectorKind::Oort);
        assert_eq!("budget".parse::<SelectorKind>().unwrap(), SelectorKind::Budget);
        assert!("bogus".parse::<SelectorKind>().is_err());
    }

    #[test]
    fn budget_policy_parses_and_roundtrips() {
        for (text, policy) in [
            ("hard-cap", BudgetPolicy::HardCap),
            ("amortized", BudgetPolicy::Amortized),
            ("deadline-aware", BudgetPolicy::DeadlineAware),
        ] {
            assert_eq!(text.parse::<BudgetPolicy>().unwrap(), policy);
            assert_eq!(policy.to_string(), text);
        }
        assert_eq!("HardCap".parse::<BudgetPolicy>().unwrap(), BudgetPolicy::HardCap);
        assert!("bogus".parse::<BudgetPolicy>().is_err());
    }

    #[test]
    fn budget_knobs_roundtrip_through_toml() {
        let mut c = ExperimentConfig::paper_default(SelectorKind::Budget);
        c.selector.budget_j = 250_000.0;
        c.selector.budget_policy = BudgetPolicy::DeadlineAware;
        c.selector.budget_spend_ahead = 3.5;
        let back = ExperimentConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back, c);
        back.validate().unwrap();
    }

    #[test]
    fn shard_spec_parses_and_validates() {
        let s: ShardSpec = "0/4".parse().unwrap();
        assert_eq!(s, ShardSpec { index: 0, count: 4 });
        assert_eq!(s.to_string(), "0/4");
        let s: ShardSpec = " 3 / 4 ".trim().parse().unwrap();
        assert_eq!(s.index, 3);
        assert_eq!("0/1".parse::<ShardSpec>().unwrap().count, 1);
        // Index is 0-based and must stay below the count.
        assert!("4/4".parse::<ShardSpec>().is_err());
        assert!("1/0".parse::<ShardSpec>().is_err());
        assert!("2".parse::<ShardSpec>().is_err());
        assert!("a/b".parse::<ShardSpec>().is_err());
        assert!("-1/2".parse::<ShardSpec>().is_err());
    }

    #[test]
    fn bad_tier_array_len_rejected_at_parse() {
        let text = "[devices]\ntier_fractions = [0.5, 0.5]\n";
        assert!(ExperimentConfig::from_toml(text).is_err());
    }
}
