//! Network substrate: per-client link profiles (MobiPerf substitute).
//!
//! Each client is assigned a communication medium (WiFi or 3G/cellular)
//! and log-normally distributed down/up bandwidths around configurable
//! medians. Transfer durations drive both the round timeline and the
//! Table-1 communication-energy model (which keys on medium + duration).

use crate::util::rng::Rng;

use crate::config::NetworkConfig;

/// Wireless medium (Table 1 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Medium {
    Wifi,
    /// Cellular; the paper's Table 1 measured 3G.
    Cell3G,
}

/// Per-client link profile.
#[derive(Debug, Clone, Copy)]
pub struct LinkProfile {
    pub medium: Medium,
    pub down_mbps: f64,
    pub up_mbps: f64,
}

impl LinkProfile {
    /// Seconds to download `bytes` over this link.
    pub fn download_secs(&self, bytes: usize) -> f64 {
        transfer_secs(bytes, self.down_mbps)
    }

    /// Seconds to upload `bytes` over this link.
    pub fn upload_secs(&self, bytes: usize) -> f64 {
        transfer_secs(bytes, self.up_mbps)
    }
}

/// Seconds to move `bytes` at `mbps` megabits/second.
pub fn transfer_secs(bytes: usize, mbps: f64) -> f64 {
    debug_assert!(mbps > 0.0);
    (bytes as f64 * 8.0) / (mbps * 1e6)
}

/// Deterministically generate `n` link profiles from the config seed.
pub fn generate_links(cfg: &NetworkConfig, n: usize) -> Vec<LinkProfile> {
    let mut rng = Rng::seed_from_u64(cfg.seed);
    // Log-normal around the medium's median; sigma controls the spread.
    // Floor at 1% of the median so no link is pathologically dead.
    let draw = |rng: &mut Rng, median: f64, sigma: f64| -> f64 {
        rng.lognormal(median, sigma).max(median * 0.01)
    };
    (0..n)
        .map(|_| {
            let medium =
                if rng.gen_bool(cfg.wifi_fraction) { Medium::Wifi } else { Medium::Cell3G };
            let (dm, um) = match medium {
                Medium::Wifi => (cfg.wifi_down_mbps, cfg.wifi_up_mbps),
                Medium::Cell3G => (cfg.cell_down_mbps, cfg.cell_up_mbps),
            };
            LinkProfile {
                medium,
                down_mbps: draw(&mut rng, dm, cfg.sigma),
                up_mbps: draw(&mut rng, um, cfg.sigma),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_math() {
        // 1 MB at 8 Mbps = 1 second.
        assert!((transfer_secs(1_000_000, 8.0) - 1.0).abs() < 1e-12);
        // Larger payloads take proportionally longer.
        assert!((transfer_secs(2_000_000, 8.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = NetworkConfig::default();
        let a = generate_links(&cfg, 50);
        let b = generate_links(&cfg, 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.medium, y.medium);
            assert_eq!(x.down_mbps, y.down_mbps);
        }
    }

    #[test]
    fn wifi_fraction_respected() {
        let mut cfg = NetworkConfig::default();
        cfg.wifi_fraction = 1.0;
        assert!(generate_links(&cfg, 100).iter().all(|l| l.medium == Medium::Wifi));
        cfg.wifi_fraction = 0.0;
        assert!(generate_links(&cfg, 100).iter().all(|l| l.medium == Medium::Cell3G));
    }

    #[test]
    fn bandwidths_positive_and_spread() {
        let cfg = NetworkConfig::default();
        let links = generate_links(&cfg, 500);
        assert!(links.iter().all(|l| l.down_mbps > 0.0 && l.up_mbps > 0.0));
        let downs: Vec<f64> = links.iter().map(|l| l.down_mbps).collect();
        let min = downs.iter().cloned().fold(f64::MAX, f64::min);
        let max = downs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max > min * 2.0, "log-normal draws should spread: {min}..{max}");
    }

    #[test]
    fn wifi_faster_than_cell_in_median() {
        let cfg = NetworkConfig::default();
        let links = generate_links(&cfg, 2000);
        let med = |m: Medium| {
            let mut v: Vec<f64> = links
                .iter()
                .filter(|l| l.medium == m)
                .map(|l| l.down_mbps)
                .collect();
            v.sort_by(f64::total_cmp);
            v[v.len() / 2]
        };
        assert!(med(Medium::Wifi) > med(Medium::Cell3G));
    }
}
