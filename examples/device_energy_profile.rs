//! Energy-model walkthrough: reproduces the paper's Table 1 and
//! Table 2 and shows what one training round costs each device tier —
//! the §4.2 model (E_comp = P·t, comm from the Table 1 linear fits).
//!
//! Run: cargo run --release --example device_energy_profile

use eafl::device::{DeviceSpec, Tier, ALL_TIERS};
use eafl::energy::{comm_energy_joules, comm_energy_percent, CommDirection, RoundEnergy};
use eafl::network::{LinkProfile, Medium};

fn main() {
    println!("=== Table 1: communication energy (Kalic et al., MIPRO'12) ===");
    println!("battery-% of the reference handset per duration on medium:\n");
    println!(
        "{:<6} {:>12} {:>12} {:>14} {:>14}",
        "hours", "WiFi down", "WiFi up", "3G down", "3G up"
    );
    for hours in [0.25, 0.5, 1.0, 2.0] {
        println!(
            "{:<6} {:>11.2}% {:>11.2}% {:>13.2}% {:>13.2}%",
            hours,
            comm_energy_percent(Medium::Wifi, CommDirection::Download, hours),
            comm_energy_percent(Medium::Wifi, CommDirection::Upload, hours),
            comm_energy_percent(Medium::Cell3G, CommDirection::Download, hours),
            comm_energy_percent(Medium::Cell3G, CommDirection::Upload, hours),
        );
    }

    println!("\n=== Table 2: device tiers ===\n");
    println!(
        "{:<36} {:>8} {:>10} {:>8} {:>10} {:>10}",
        "device", "power W", "perf/W", "RAM GB", "mAh", "kJ"
    );
    for tier in ALL_TIERS {
        let s = DeviceSpec::for_tier(tier);
        println!(
            "{:<36} {:>8.2} {:>10.2} {:>8.0} {:>10.0} {:>10.1}",
            s.model,
            s.avg_power_w,
            s.perf_per_watt,
            s.ram_gb,
            s.battery_mah,
            s.battery_joules() / 1000.0
        );
    }

    // One round: ~270 KB model each way, 100 samples of local training.
    println!("\n=== One FL round per tier (paper §4.2 decomposition) ===\n");
    let payload = 69_123 * 4; // flat f32 params
    let wifi = LinkProfile { medium: Medium::Wifi, down_mbps: 20.0, up_mbps: 8.0 };
    let cell = LinkProfile { medium: Medium::Cell3G, down_mbps: 6.0, up_mbps: 2.0 };
    println!(
        "{:<10} {:<6} {:>10} {:>12} {:>10} {:>10} {:>12}",
        "tier", "link", "train(s)", "compute(J)", "down(J)", "up(J)", "battery-%"
    );
    for tier in ALL_TIERS {
        let spec = DeviceSpec::for_tier(tier);
        // 100 samples at the tier's relative speed (0.5 samples/s low).
        let train_secs = 100.0 / (0.5 * spec.relative_speed());
        for (link, lname) in [(&wifi, "wifi"), (&cell, "3g")] {
            let e = RoundEnergy::for_participation(&spec, link, payload, train_secs);
            println!(
                "{:<10} {:<6} {:>10.1} {:>12.1} {:>10.2} {:>10.2} {:>11.2}%",
                format!("{tier:?}"),
                lname,
                train_secs,
                e.compute_j,
                e.download_j,
                e.upload_j,
                e.total() / spec.battery_joules() * 100.0
            );
        }
    }

    println!("\nlong-transfer check: 1 h of 3G upload costs");
    println!(
        "  {:.0} J = {:.1}% of a {} battery",
        comm_energy_joules(Medium::Cell3G, CommDirection::Upload, 3600.0),
        comm_energy_joules(Medium::Cell3G, CommDirection::Upload, 3600.0)
            / DeviceSpec::for_tier(Tier::Low).battery_joules()
            * 100.0,
        DeviceSpec::for_tier(Tier::Low).model
    );
}
