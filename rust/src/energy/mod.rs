//! Energy substrate — the paper's §4.2 consumption model.
//!
//! Two drain sources for selected clients: local **computation**
//! (E = P·t, per-tier power from Table 2) and wireless **communication**
//! (Table 1's linear battery-%-vs-hours models), plus background
//! idle/busy drain for unselected devices.

mod comm;
mod compute;

pub use comm::{comm_energy_joules, comm_energy_percent, CommDirection, HTC_DESIRE_HD_JOULES};
pub use compute::{background_energy_joules, compute_energy_joules, RoundEnergy};
