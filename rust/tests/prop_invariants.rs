//! Property-based invariant tests over the coordinator stack (in-tree
//! prop harness — see util::prop). Each property runs under many seeded
//! RNG streams and random configurations.

use eafl::config::{AggregatorKind, ExperimentConfig, SelectorKind};
use eafl::coordinator::{Coordinator, Registry};
use eafl::metrics::jain_index;
use eafl::runtime::MockRuntime;
use eafl::selection::{make_selector, Candidate};
use eafl::sim::{simulate_round, ParticipantPlan};
use eafl::util::prop::forall;
use eafl::util::rng::Rng;

fn random_candidates(rng: &mut Rng, n: usize) -> Vec<Candidate> {
    (0..n)
        .map(|id| Candidate {
            id,
            stat_util: if rng.gen_bool(0.5) {
                Some(rng.gen_range_f64(0.0, 300.0))
            } else {
                None
            },
            measured_duration_s: if rng.gen_bool(0.5) {
                Some(rng.gen_range_f64(10.0, 2000.0))
            } else {
                None
            },
            expected_duration_s: rng.gen_range_f64(10.0, 2000.0),
            last_selected_round: if rng.gen_bool(0.5) {
                Some(rng.gen_range_usize(0, 40) as u64)
            } else {
                None
            },
            battery_frac: rng.gen_f64(),
            projected_drain_frac: rng.gen_range_f64(0.0, 0.2),
            round_energy_j: rng.gen_range_f64(1.0, 500.0),
        })
        .collect()
}

/// Every selector: |selected| <= K, ids distinct, ids ∈ candidates.
#[test]
fn prop_selection_never_exceeds_k_and_is_valid() {
    forall(96, |rng| {
        let n = rng.gen_range_usize(0, 60);
        let k = rng.gen_range_usize(1, 15);
        let round = rng.gen_range_usize(1, 100) as u64;
        let cands = random_candidates(rng, n);
        let valid: std::collections::HashSet<usize> = cands.iter().map(|c| c.id).collect();
        for kind in [SelectorKind::Random, SelectorKind::Oort, SelectorKind::Eafl] {
            let mut cfg = eafl::config::SelectorConfig::default();
            cfg.kind = kind;
            let mut selector = make_selector(&cfg);
            let picked = selector.select(round, &cands, k, rng);
            assert!(picked.len() <= k, "{kind:?} picked {} > K={k}", picked.len());
            assert!(picked.len() <= n);
            let mut dedup = picked.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), picked.len(), "{kind:?} duplicated ids");
            assert!(picked.iter().all(|id| valid.contains(id)), "{kind:?} invented an id");
        }
    });
}

/// Round simulation: energy spent never exceeds charge or the round's
/// energy demand; completed + failed == selected; duration bounded by
/// the deadline when stragglers exist.
#[test]
fn prop_round_sim_conserves_energy_and_counts() {
    forall(128, |rng| {
        let n = rng.gen_range_usize(1, 20);
        let deadline = rng.gen_range_f64(10.0, 2000.0);
        let plans: Vec<ParticipantPlan> = (0..n)
            .map(|id| ParticipantPlan {
                id,
                download_s: rng.gen_range_f64(0.1, 50.0),
                compute_s: rng.gen_range_f64(1.0, 2000.0),
                upload_s: rng.gen_range_f64(0.1, 50.0),
                round_energy_j: rng.gen_range_f64(0.0, 3000.0),
                charge_j: rng.gen_range_f64(0.0, 3000.0),
            })
            .collect();
        let out = simulate_round(&plans, deadline);
        assert_eq!(out.results.len(), plans.len());
        let mut completed = 0;
        let mut failed = 0;
        for (r, p) in out.results.iter().zip(&plans) {
            assert!(r.energy_spent_j <= p.charge_j + 1e-9, "spent more than charge");
            assert!(r.energy_spent_j <= p.round_energy_j + 1e-9, "spent more than demand");
            assert!(r.energy_spent_j >= 0.0);
            assert!(r.active_s >= 0.0);
            if r.completed {
                completed += 1;
                assert!(r.failure.is_none());
                assert!(r.active_s <= deadline + 1e-9);
            } else {
                failed += 1;
                assert!(r.failure.is_some());
            }
        }
        assert_eq!(completed + failed, n);
        assert!(out.duration_s <= deadline.max(0.0) + 1e-9 || failed == 0);
    });
}

/// Jain's index is always in (0, 1] and 1/n lower-bounded.
#[test]
fn prop_jain_bounds() {
    forall(128, |rng| {
        let n = rng.gen_range_usize(1, 200);
        let counts: Vec<u64> =
            (0..n).map(|_| rng.gen_range_usize(0, 50) as u64).collect();
        let j = jain_index(&counts);
        assert!(j > 0.0 && j <= 1.0 + 1e-12, "jain {j} out of bounds");
        if counts.iter().any(|&c| c > 0) {
            assert!(j >= 1.0 / n as f64 - 1e-12);
        }
    });
}

fn random_smoke_config(rng: &mut Rng, kind: SelectorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::smoke(kind);
    cfg.federation.num_clients = rng.gen_range_usize(8, 30);
    cfg.federation.participants_per_round =
        rng.gen_range_usize(1, cfg.federation.num_clients.min(8));
    cfg.federation.rounds = rng.gen_range_usize(3, 12);
    cfg.federation.aggregator = if rng.gen_bool(0.5) {
        AggregatorKind::Yogi
    } else {
        AggregatorKind::FedAvg
    };
    cfg.devices.min_init_battery = rng.gen_range_f64(0.02, 0.3);
    cfg.devices.max_init_battery =
        rng.gen_range_f64(cfg.devices.min_init_battery, 1.0);
    cfg.devices.seed = rng.next_u64();
    cfg.data.seed = rng.next_u64();
    cfg.network.seed = rng.next_u64();
    // Tiny data so MockRuntime batches stay cheap.
    cfg.data.min_samples = 5;
    cfg.data.max_samples = 20;
    cfg.data.test_samples = 256;
    cfg
}

/// Full coordinator runs (mock runtime): battery never increases
/// (recharge off), round accounting conserves clients, energies and
/// fairness stay in range.
#[test]
fn prop_coordinator_accounting_invariants() {
    forall(24, |rng| {
        let kind = *rng
            .choose(&[SelectorKind::Random, SelectorKind::Oort, SelectorKind::Eafl])
            .unwrap();
        let cfg = random_smoke_config(rng, kind);
        let runtime = MockRuntime {
            train_batch: cfg.data.batch_size,
            ..MockRuntime::default()
        };
        let log = Coordinator::new(cfg.clone(), &runtime).unwrap().run().unwrap();
        let mut last_battery = f64::MAX;
        let mut last_dead = 0usize;
        let mut last_energy = 0.0f64;
        let mut last_wall = 0.0f64;
        for r in &log.records {
            assert_eq!(
                r.completed + r.dropped + r.deadline_missed,
                r.selected,
                "round {} does not conserve participants",
                r.round
            );
            assert!(r.selected <= cfg.federation.participants_per_round);
            assert!(r.cumulative_dead >= last_dead, "dead count must be monotone");
            assert!(r.total_fl_energy_j >= last_energy - 1e-6, "energy must be monotone");
            assert!(r.wall_clock_h > last_wall, "clock must advance");
            assert!((0.0..=1.0).contains(&r.test_accuracy));
            assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
            assert!((0.0..=1.0).contains(&r.alive_fraction));
            // Mean battery over alive clients can rise when low-battery
            // clients die out of the mean, but population mean must not
            // exceed the previous value plus that effect; we check the
            // weaker invariant that it stays in [0, 1].
            assert!((0.0..=1.0).contains(&r.mean_battery));
            last_dead = r.cumulative_dead;
            last_energy = r.total_fl_energy_j;
            last_wall = r.wall_clock_h;
            last_battery = last_battery.min(r.mean_battery);
        }
    });
}

/// Registry candidates never include dead or below-floor clients.
#[test]
fn prop_candidates_respect_eligibility() {
    forall(48, |rng| {
        let cfg = random_smoke_config(rng, SelectorKind::Eafl);
        let mut registry = Registry::build(&cfg, 35, 1000);
        // Randomly kill/drain some clients.
        for id in 0..registry.len() {
            if rng.gen_bool(0.3) {
                let cap = registry.client(id).battery.capacity_joules();
                registry.drain_fl(id, cap * rng.gen_range_f64(0.5, 2.0), 1.0);
            }
        }
        let floor = rng.gen_range_f64(0.0, 0.3);
        let cands = registry.candidates(1, floor, 5, cfg.data.batch_size);
        for cand in &cands {
            let c = registry.client(cand.id);
            assert!(c.battery.is_alive());
            assert!(c.battery.fraction() > floor);
            assert!(cand.expected_duration_s > 0.0);
            assert!(cand.projected_drain_frac >= 0.0);
        }
    });
}

/// Determinism: identical config + seeds => identical metrics CSV.
#[test]
fn prop_runs_are_reproducible() {
    forall(8, |rng| {
        let kind = *rng
            .choose(&[SelectorKind::Random, SelectorKind::Oort, SelectorKind::Eafl])
            .unwrap();
        let cfg = random_smoke_config(rng, kind);
        let runtime = MockRuntime {
            train_batch: cfg.data.batch_size,
            ..MockRuntime::default()
        };
        let a = Coordinator::new(cfg.clone(), &runtime).unwrap().run().unwrap();
        let b = Coordinator::new(cfg, &runtime).unwrap().run().unwrap();
        assert_eq!(a.to_csv(), b.to_csv(), "same seeds must reproduce bit-identical runs");
    });
}
