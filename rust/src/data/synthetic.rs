//! Procedural speech-commands-like dataset.
//!
//! Each of the 35 classes owns a deterministic "spectro-temporal
//! template": a mix of 2-D Gaussian energy blobs (formant-like) and
//! harmonic stripes (pitch-like) on a 32×32 log-mel-style grid. A
//! sample is its class template under a random gain, time shift, and
//! additive noise — so classes are separable but samples vary, and
//! per-client channel gain adds client-level skew on top of the label
//! skew from the partitioner.
//!
//! Everything is keyed on (seed, class, sample index) through counter-
//! keyed xoshiro256++ streams: sample `i` of class `c` is identical across
//! runs, machines, and access orders — which is what makes simulation
//! runs reproducible end to end.

use crate::util::rng::Rng;

use super::SampleRef;

/// Number of Gaussian blobs per class template.
const BLOBS: usize = 4;
/// Number of harmonic stripes per class template.
const STRIPES: usize = 2;

/// Procedural dataset generator.
pub struct SyntheticSpeech {
    hw: usize,
    num_classes: usize,
    noise_std: f32,
    seed: u64,
    /// Precomputed class templates, `num_classes × hw*hw`.
    templates: Vec<Vec<f32>>,
}

impl SyntheticSpeech {
    pub fn new(hw: usize, num_classes: usize, noise_std: f32, seed: u64) -> Self {
        let templates = (0..num_classes)
            .map(|c| Self::build_template(hw, seed, c as u64))
            .collect();
        Self { hw, num_classes, noise_std, seed, templates }
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    pub fn feature_len(&self) -> usize {
        self.hw * self.hw
    }

    fn build_template(hw: usize, seed: u64, class: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed ^ (0xC1A5_5E5E ^ class.wrapping_mul(0x9E37)));
        let mut t = vec![0.0f32; hw * hw];
        let hwf = hw as f32;
        // Formant-like Gaussian blobs.
        for _ in 0..BLOBS {
            let cx: f32 = rng.gen_range_f32(0.1, 0.9) * hwf;
            let cy: f32 = rng.gen_range_f32(0.1, 0.9) * hwf;
            let sx: f32 = rng.gen_range_f32(1.5, 5.0);
            let sy: f32 = rng.gen_range_f32(1.5, 5.0);
            let amp: f32 = rng.gen_range_f32(0.6, 1.4);
            for y in 0..hw {
                for x in 0..hw {
                    let dx = (x as f32 - cx) / sx;
                    let dy = (y as f32 - cy) / sy;
                    t[y * hw + x] += amp * (-0.5 * (dx * dx + dy * dy)).exp();
                }
            }
        }
        // Pitch-like harmonic stripes along the time axis.
        for _ in 0..STRIPES {
            let row = rng.gen_range_usize(0, hw - 1);
            let amp: f32 = rng.gen_range_f32(0.3, 0.8);
            let freq: f32 = rng.gen_range_f32(0.3, 1.2);
            for x in 0..hw {
                t[row * hw + x] += amp * (freq * x as f32).sin().abs();
            }
        }
        t
    }

    /// Write the features of `sample` into `out` (len = hw*hw);
    /// `channel_gain` models the per-client microphone/channel skew.
    pub fn fill_features(&self, sample: SampleRef, channel_gain: f32, out: &mut [f32]) {
        let (class, idx) = sample;
        debug_assert!((class as usize) < self.num_classes);
        debug_assert_eq!(out.len(), self.feature_len());
        let mut rng = Rng::seed_from_u64(
            self.seed ^ ((class as u64) << 32) ^ (idx as u64).wrapping_mul(0x517C_C1B7),
        );
        let gain: f32 = rng.gen_range_f32(0.7, 1.3) * channel_gain;
        let shift: i32 = rng.gen_range_i32(-3, 3); // time shift (columns)
        let template = &self.templates[class as usize];
        let hw = self.hw as i32;
        for y in 0..hw {
            for x in 0..hw {
                let sx = (x - shift).rem_euclid(hw);
                let v = template[(y * hw + sx) as usize] * gain
                    + rng.gen_range_f32(-1.0, 1.0) * self.noise_std;
                out[(y * hw + x) as usize] = v;
            }
        }
    }

    /// Materialize a full batch: cycles through `samples` if fewer than
    /// the batch size (XLA executables are shape-monomorphic, so short
    /// shards pad by repetition — standard practice for fixed batches).
    pub fn fill_batch(
        &self,
        samples: &[SampleRef],
        channel_gain: f32,
        x: &mut [f32],
        y: &mut [i32],
    ) {
        let fl = self.feature_len();
        let batch = y.len();
        debug_assert_eq!(x.len(), batch * fl);
        debug_assert!(!samples.is_empty());
        for b in 0..batch {
            let s = samples[b % samples.len()];
            self.fill_features(s, channel_gain, &mut x[b * fl..(b + 1) * fl]);
            y[b] = s.0 as i32;
        }
    }

    /// An IID test set: `n` samples cycling over classes, with indices
    /// offset far away from any training shard.
    pub fn test_set(&self, n: usize) -> Vec<SampleRef> {
        (0..n)
            .map(|i| ((i % self.num_classes) as u16, 1_000_000 + (i / self.num_classes) as u32))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SyntheticSpeech {
        SyntheticSpeech::new(32, 35, 0.6, 7)
    }

    #[test]
    fn deterministic_features() {
        let d = ds();
        let mut a = vec![0.0; d.feature_len()];
        let mut b = vec![0.0; d.feature_len()];
        d.fill_features((3, 17), 1.0, &mut a);
        d.fill_features((3, 17), 1.0, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn different_samples_differ() {
        let d = ds();
        let mut a = vec![0.0; d.feature_len()];
        let mut b = vec![0.0; d.feature_len()];
        d.fill_features((3, 17), 1.0, &mut a);
        d.fill_features((3, 18), 1.0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn classes_are_separable_by_template_distance() {
        // Mean same-class distance must be well below cross-class
        // distance, otherwise nothing could learn this dataset.
        let d = ds();
        let fl = d.feature_len();
        let sample = |c: u16, i: u32| {
            let mut v = vec![0.0; fl];
            d.fill_features((c, i), 1.0, &mut v);
            v
        };
        let dist = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        let mut same = 0.0;
        let mut cross = 0.0;
        let mut n = 0;
        for c in 0..8u16 {
            let a = sample(c, 0);
            same += dist(&a, &sample(c, 1));
            cross += dist(&a, &sample((c + 1) % 35, 0));
            n += 1;
        }
        assert!(cross / n as f32 > 1.2 * same / n as f32, "cross={cross} same={same}");
    }

    #[test]
    fn fill_batch_cycles_short_shards() {
        let d = ds();
        let samples = vec![(1u16, 0u32), (2, 0)];
        let mut x = vec![0.0; 5 * d.feature_len()];
        let mut y = vec![0i32; 5];
        d.fill_batch(&samples, 1.0, &mut x, &mut y);
        assert_eq!(y, vec![1, 2, 1, 2, 1]);
    }

    #[test]
    fn test_set_covers_all_classes() {
        let d = ds();
        let ts = d.test_set(70);
        for c in 0..35u16 {
            assert!(ts.iter().any(|&(cc, _)| cc == c));
        }
        // Test indices don't collide with training indices (< 1e6).
        assert!(ts.iter().all(|&(_, i)| i >= 1_000_000));
    }

    #[test]
    fn channel_gain_scales_features() {
        let d = ds();
        let mut a = vec![0.0; d.feature_len()];
        let mut b = vec![0.0; d.feature_len()];
        d.fill_features((5, 9), 1.0, &mut a);
        d.fill_features((5, 9), 2.0, &mut b);
        // Gain applies to template signal, not the noise; energy rises.
        let ea: f32 = a.iter().map(|v| v * v).sum();
        let eb: f32 = b.iter().map(|v| v * v).sum();
        assert!(eb > ea);
    }
}
