//! The staged RoundEngine: one FL round decomposed into explicit,
//! individually testable phases with typed inputs and outputs.
//!
//! ```text
//!            ┌────────────┐   RoundPlan    ┌───────────┐  SimulatedRound
//!  Registry ─► PlanPhase  ├───────────────►│ SimPhase  ├──────────────┐
//!  Selector  └────────────┘ (selected,     └───────────┘ (per-client  │
//!                            plans, T)                    outcomes)   │
//!            ┌─────────────────────────────────────────────────────┐  │
//!            │ ExecPhase — REAL local SGD for completing clients,  │◄─┘
//!            │ parallel over worker threads, committed in          │
//!            │ deterministic client order                          │
//!            └───────────────┬─────────────────────────────────────┘
//!                            │ ExecutionOutcome (updates, outcomes)
//!            ┌───────────────▼──────────┐   ┌──────────────────────┐
//!            │ CommitPhase — quorum     ├──►│ BatteryAccounting +  │
//!            │ check, aggregate         │   │ RechargePolicy       │
//!            └───────────────┬──────────┘   │ (accounting module)  │
//!                            │              └──────────┬───────────┘
//!            ┌───────────────▼──────────┐   ┌──────────▼───────────┐
//!            │ FeedbackPhase — client   ├──►│ RecordPhase —        │
//!            │ stats, blacklist,        │   │ RoundRecord row      │
//!            │ selector feedback        │   └──────────────────────┘
//!            └──────────────────────────┘
//! ```
//!
//! Each phase is a plain struct whose `run` takes exactly the state it
//! reads and returns a typed result. The environment enters through the
//! scenario seams: `PlanPhase` intersects candidates with the
//! scenario's [`AvailabilityModel`](crate::scenario::AvailabilityModel)
//! (diurnal presence, trace churn), `SimPhase` resolves timing and
//! energy on the scenario's effective
//! [`NetworkModel`](crate::scenario::NetworkModel) links (degraded
//! tails, congestion windows), and the accounting step applies the
//! scenario's recharge policy — so whole environments swap without
//! touching the loop in `server.rs`.
//!
//! **Determinism:** the execution phase trains the round's K completing
//! clients concurrently (`std::thread::scope`, one `TrainerBufs` per
//! worker), but each client's local SGD depends only on the immutable
//! round inputs, and results are committed strictly in simulation
//! order — so seeded runs are bit-identical at any worker count
//! (`EAFL_WORKERS=1` vs `=8` produce byte-identical metrics CSVs).

use anyhow::Result;

use crate::aggregation::{Aggregator, ClientUpdate};
use crate::config::{ExperimentConfig, FederationConfig, TrainingConfig};
use crate::data::SyntheticSpeech;
use crate::energy::RoundEnergy;
use crate::metrics::{jain_index_from_moments, RoundRecord};
use crate::runtime::ModelRuntime;
use crate::scenario::ScenarioEnv;
use crate::selection::{Candidate, ParticipantOutcome, RoundFeedback, Selector};
use crate::sim::{simulate_round, FailureKind, ParticipantPlan, RoundSimOutcome};
use crate::training::{LocalTrainResult, Trainer, TrainerBufs};
use crate::util::rng::Rng;

use super::registry::{AvailabilityView, Registry};

/// Consecutive deadline misses before a client is benched.
pub const MISS_BLACKLIST_THRESHOLD: u32 = 3;
/// Rounds a benched client stays ineligible.
pub const MISS_BLACKLIST_COOLDOWN: u64 = 10;
/// Wall-clock seconds attributed to a round nobody was eligible for:
/// the server backs off to a re-poll cadence instead of spinning on
/// ~1 s empty-pool deadlines, so simulated time can actually reach the
/// next availability or charging window (diurnal troughs, overnight
/// recharge) within a realistic round budget.
pub const EMPTY_ROUND_WAIT_S: f64 = 300.0;

// ---------------------------------------------------------------------------
// Phase 1: candidate planning
// ---------------------------------------------------------------------------

/// Output of [`PlanPhase`]: who participates and on what timeline.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    pub round: u64,
    /// Candidates that survived the battery-floor + availability +
    /// blacklist gates this round (what the selector chose from).
    pub eligible: usize,
    /// Registry ids the selector picked (selection order).
    pub selected: Vec<usize>,
    /// One timing/energy plan per selected client (same order).
    pub plans: Vec<ParticipantPlan>,
    /// Straggler deadline T for this round, seconds.
    pub deadline_s: f64,
}

/// Builds candidates from the registry, intersects them with the
/// scenario's availability model (a client that is offline at round
/// start cannot be selected, whatever its utility), runs the selector,
/// and projects each pick's download/compute/upload timeline and energy
/// demand. An empty eligible pool yields an empty plan — the round is
/// skipped downstream, never a panic.
///
/// Fast path: the registry maintains an incremental eligible arena
/// ([`Registry::refresh_eligible`]) patched per round from change
/// events (battery-floor crossings, blacklist releases, availability
/// flips, guard-level mutations) instead of re-walking all N clients;
/// the selected clients' timing and energy plans are copied from the
/// build-time projection cache instead of re-running the energy model.
/// `EAFL_REBUILD_CANDIDATES=1` forces the legacy O(N)
/// [`Registry::fill_candidates`] walk into the caller-owned `arena`
/// every round — bit-identical output, legacy cost (ci.sh's
/// incremental-vs-rebuild determinism tier).
///
/// `avail`, when present, is the coordinator's
/// [`WakeWheel`](crate::scenario::WakeWheel) state already advanced to
/// `clock_h` — the cached bitmap plus the ids whose bit flipped during
/// that advance (the arena's availability change list). `None` falls
/// back to direct model calls through `fill_candidates` — same bits
/// either way (the wheel's soundness contract), but without a change
/// list the arena cannot patch, so that path always rebuilds.
pub struct PlanPhase;

impl PlanPhase {
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        registry: &mut Registry,
        selector: &mut dyn Selector,
        cfg: &ExperimentConfig,
        env: &ScenarioEnv,
        round: u64,
        clock_h: f64,
        avail: Option<(&[bool], &[u32])>,
        rng: &mut Rng,
        arena: &mut Vec<Candidate>,
    ) -> RoundPlan {
        let k = cfg.federation.participants_per_round;
        let floor = cfg.selector.min_battery_frac;
        let incremental = !super::accounting::rebuild_candidates_forced();

        let candidates: &[Candidate] = if env.availability.is_always_available() {
            if incremental {
                registry.refresh_eligible(round, floor, AvailabilityView::AlwaysOn);
                registry.eligible()
            } else {
                registry.fill_candidates(round, floor, |_| true, arena);
                arena
            }
        } else if let Some((bits, changed)) = avail {
            if incremental {
                registry.refresh_eligible(
                    round,
                    floor,
                    AvailabilityView::Cached { bits, changed },
                );
                registry.eligible()
            } else {
                registry.fill_candidates(round, floor, |id| bits[id], arena);
                arena
            }
        } else {
            let availability = &env.availability;
            registry.fill_candidates(
                round,
                floor,
                |id| availability.available(id, clock_h),
                arena,
            );
            arena
        };
        // One call yields both picks and deadline, so the pacer
        // percentile runs once per round instead of twice.
        let eligible = candidates.len();
        let (selected, deadline_s) = selector.plan(round, candidates, k, rng);

        let pool = registry.pool();
        let plans: Vec<ParticipantPlan> = selected
            .iter()
            .map(|&id| ParticipantPlan {
                id,
                download_s: pool.download_s[id],
                compute_s: pool.compute_s[id],
                upload_s: pool.upload_s[id],
                round_energy_j: pool.round_energy_j[id],
                // Drain-effective, not the raw mirror: under lazy drain
                // the mirror lags until the next touch, and this value
                // decides mid-round battery deaths in the sim phase.
                charge_j: registry.effective_charge_j(id),
            })
            .collect();
        RoundPlan { round, eligible, selected, plans, deadline_s }
    }
}

// ---------------------------------------------------------------------------
// Phase 2: event-driven round simulation
// ---------------------------------------------------------------------------

/// Output of [`SimPhase`]: per-client outcomes plus the round's clock.
#[derive(Debug, Clone)]
pub struct SimulatedRound {
    pub outcome: RoundSimOutcome,
    /// Wall-clock duration the server attributes to the round, seconds
    /// (an empty round still waits out the deadline).
    pub round_duration_s: f64,
    pub round_hours: f64,
}

/// Resolves the round on the deterministic event queue.
///
/// The *plan* carries the server's estimates (registered link
/// profiles); the simulation replaces them with the scenario's
/// effective links at round start, so a degraded or congested network
/// surfaces as longer transfers, more comm energy and more deadline
/// misses than the selector budgeted for. Under the static network
/// model the plan's timings are reused verbatim.
pub struct SimPhase;

impl SimPhase {
    pub fn run(
        plan: &RoundPlan,
        registry: &Registry,
        env: &ScenarioEnv,
        clock_h: f64,
    ) -> SimulatedRound {
        let outcome = if env.network.is_static() {
            simulate_round(&plan.plans, plan.deadline_s)
        } else {
            let adjusted: Vec<ParticipantPlan> = plan
                .plans
                .iter()
                .map(|p| {
                    let c = registry.client(p.id);
                    let link = env.network.link_at(c.id, &c.link, clock_h);
                    let energy = RoundEnergy::for_participation(
                        &c.device.spec,
                        &link,
                        registry.payload_bytes(),
                        p.compute_s,
                    )
                    .total();
                    ParticipantPlan {
                        id: p.id,
                        download_s: link.download_secs(registry.payload_bytes()),
                        compute_s: p.compute_s,
                        upload_s: link.upload_secs(registry.payload_bytes()),
                        round_energy_j: energy,
                        charge_j: p.charge_j,
                    }
                })
                .collect();
            simulate_round(&adjusted, plan.deadline_s)
        };
        // An empty round still advances time: the server waits out the
        // deadline, then backs off to the re-poll cadence rather than
        // burning a round per simulated second.
        let round_duration_s = if plan.selected.is_empty() {
            plan.deadline_s.max(EMPTY_ROUND_WAIT_S)
        } else {
            outcome.duration_s.max(1.0)
        };
        SimulatedRound { outcome, round_duration_s, round_hours: round_duration_s / 3600.0 }
    }
}

// ---------------------------------------------------------------------------
// Phase 3: local execution (parallel)
// ---------------------------------------------------------------------------

/// Output of [`ExecPhase`]: aggregable updates plus per-participant
/// outcomes and failure tallies.
#[derive(Debug)]
pub struct ExecutionOutcome {
    /// One update per completing client, in simulation order.
    pub updates: Vec<ClientUpdate>,
    /// One outcome per selected client, in simulation order.
    pub outcomes: Vec<ParticipantOutcome>,
    /// Sum of completing clients' final losses (simulation order).
    pub train_loss_sum: f64,
    /// Mid-round battery deaths.
    pub dropped: usize,
    /// Straggler deadline misses.
    pub deadline_missed: usize,
}

/// Runs REAL local SGD for every client the simulation says completed.
///
/// The hot loop of the whole system: clients are independent given the
/// round's global parameters, so they train concurrently on scoped
/// worker threads — each worker owns its own [`TrainerBufs`] from the
/// coordinator's pool — and results are committed sequentially in
/// simulation order, keeping seeded runs bit-identical at any worker
/// count.
pub struct ExecPhase<'e> {
    pub runtime: &'e dyn ModelRuntime,
    pub data: &'e SyntheticSpeech,
    /// Worker threads to spread clients over (1 = inline, no spawn).
    pub workers: usize,
}

impl ExecPhase<'_> {
    pub fn run(
        &self,
        registry: &Registry,
        global: &[f32],
        plan: &RoundPlan,
        sim: &SimulatedRound,
        training: &TrainingConfig,
        bufs_pool: &mut Vec<TrainerBufs>,
    ) -> Result<ExecutionOutcome> {
        let results = &sim.outcome.results;
        let clients = registry.clients();
        // Indices (into `results`) of clients that completed, in order.
        let tasks: Vec<usize> = results
            .iter()
            .enumerate()
            .filter(|(_, r)| r.completed)
            .map(|(i, _)| i)
            .collect();
        let workers = self.workers.max(1).min(tasks.len().max(1));
        while bufs_pool.len() < workers {
            bufs_pool.push(TrainerBufs::new(self.runtime));
        }

        let mut slots: Vec<Option<Result<LocalTrainResult>>> = Vec::new();
        slots.resize_with(tasks.len(), || None);

        if workers <= 1 {
            let mut trainer = Trainer::with_bufs(
                self.runtime,
                self.data,
                std::mem::replace(&mut bufs_pool[0], TrainerBufs::empty()),
            );
            for (slot, &ti) in slots.iter_mut().zip(&tasks) {
                let client = &clients[results[ti].id];
                *slot = Some(trainer.train_client(
                    global,
                    &client.shard,
                    training.learning_rate,
                    training.local_steps,
                    plan.round,
                ));
            }
            bufs_pool[0] = trainer.into_bufs();
        } else {
            // Contiguous chunks keep the slot/task pairing trivial; the
            // per-client cost is uniform enough that static partitioning
            // loses nothing to work stealing here.
            let chunk = (tasks.len() + workers - 1) / workers;
            std::thread::scope(|scope| {
                for ((task_chunk, slot_chunk), buf) in tasks
                    .chunks(chunk)
                    .zip(slots.chunks_mut(chunk))
                    .zip(bufs_pool.iter_mut())
                {
                    scope.spawn(move || {
                        let mut trainer = Trainer::with_bufs(
                            self.runtime,
                            self.data,
                            std::mem::replace(buf, TrainerBufs::empty()),
                        );
                        for (slot, &ti) in slot_chunk.iter_mut().zip(task_chunk) {
                            let client = &clients[results[ti].id];
                            *slot = Some(trainer.train_client(
                                global,
                                &client.shard,
                                training.learning_rate,
                                training.local_steps,
                                plan.round,
                            ));
                        }
                        *buf = trainer.into_bufs();
                    });
                }
            });
        }

        // Commit strictly in simulation order — this is what makes the
        // parallel phase deterministic.
        let mut updates: Vec<ClientUpdate> = Vec::with_capacity(tasks.len());
        let mut outcomes: Vec<ParticipantOutcome> = Vec::with_capacity(results.len());
        let mut train_loss_sum = 0.0f64;
        let mut dropped = 0usize;
        let mut deadline_missed = 0usize;
        let mut next_task = 0usize;
        for (r, p) in results.iter().zip(&plan.plans) {
            let mut stat_util = None;
            if r.completed {
                let res = slots[next_task]
                    .take()
                    .expect("execution phase left a completed client untrained")?;
                next_task += 1;
                train_loss_sum += res.final_loss as f64;
                stat_util = Some(res.stat_util);
                updates.push(ClientUpdate { params: res.params, weight: res.weight });
            } else {
                match r.failure {
                    Some(FailureKind::BatteryDeath) => dropped += 1,
                    _ => deadline_missed += 1,
                }
            }
            // For deadline misses report the client's TRUE round
            // duration (not the deadline-clamped active time) so Oort's
            // Eq. (2) straggler penalty sees t_i > T.
            let duration_s = match r.failure {
                Some(FailureKind::DeadlineMiss) => p.total_duration_s(),
                _ => r.active_s,
            };
            outcomes.push(ParticipantOutcome {
                id: r.id,
                stat_util,
                duration_s,
                completed: r.completed,
            });
        }
        Ok(ExecutionOutcome { updates, outcomes, train_loss_sum, dropped, deadline_missed })
    }
}

// ---------------------------------------------------------------------------
// Phase 4: commit / quorum
// ---------------------------------------------------------------------------

/// Output of [`CommitPhase`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommitDecision {
    /// Reports needed for the round to commit.
    pub required: usize,
    /// Whether the round met quorum (its time elapses either way).
    pub committed: bool,
}

/// Reports required for a round to commit: `ceil(K · min_report_fraction)`,
/// at least 1, but never more than were actually selected (a thin
/// candidate pool must not make every round unwinnable).
pub fn quorum_required(k: usize, min_report_fraction: f64, selected: usize) -> usize {
    let required = ((k as f64) * min_report_fraction).ceil().max(1.0) as usize;
    required.min(selected.max(1))
}

/// FedScale-style round failure: too few reports → the round's time
/// elapses but nothing aggregates.
pub struct CommitPhase;

impl CommitPhase {
    /// Pure quorum decision (unit-testable without a coordinator).
    pub fn decide(fed: &FederationConfig, selected: usize, completed: usize) -> CommitDecision {
        let required =
            quorum_required(fed.participants_per_round, fed.min_report_fraction, selected);
        CommitDecision { required, committed: completed >= required }
    }

    /// Decide, then aggregate into `global` when quorum was met.
    pub fn run(
        fed: &FederationConfig,
        aggregator: &mut dyn Aggregator,
        global: &mut Vec<f32>,
        selected: usize,
        updates: &[ClientUpdate],
    ) -> Result<CommitDecision> {
        let decision = Self::decide(fed, selected, updates.len());
        if decision.committed && !updates.is_empty() {
            aggregator.aggregate(global, updates)?;
        }
        Ok(decision)
    }
}

// ---------------------------------------------------------------------------
// Phase 5: selector feedback + client stats
// ---------------------------------------------------------------------------

/// Writes per-client stats (selection counts, measured durations,
/// utilities, the Oort-style miss blacklist) and feeds the outcomes
/// back to the selector. Stats go through [`Registry::stats_mut`]
/// guards, which keep the SoA pool mirrors and the Jain moments
/// (Σc, Σc²) incrementally up to date — O(selected) total, no
/// population rescans downstream.
pub struct FeedbackPhase;

impl FeedbackPhase {
    pub fn run(
        registry: &mut Registry,
        selector: &mut dyn Selector,
        round: u64,
        outcomes: &[ParticipantOutcome],
    ) {
        for o in outcomes {
            let mut stats = registry.stats_mut(o.id);
            stats.times_selected += 1;
            stats.last_selected_round = Some(round);
            stats.measured_duration_s = Some(o.duration_s);
            if o.completed {
                stats.times_completed += 1;
                stats.stat_util = o.stat_util;
                stats.consecutive_misses = 0;
            } else {
                // Oort-style blacklist: repeated deadline misses bench
                // the client for a cooldown window.
                stats.consecutive_misses += 1;
                if stats.consecutive_misses >= MISS_BLACKLIST_THRESHOLD {
                    stats.banned_until_round = round + MISS_BLACKLIST_COOLDOWN;
                    stats.consecutive_misses = 0;
                }
            }
        }
        selector.feedback(&RoundFeedback { round, outcomes });
    }
}

// ---------------------------------------------------------------------------
// Campaign energy ledger
// ---------------------------------------------------------------------------

/// Tracks the campaign's energy spend against a fixed joule budget.
///
/// Two columns are kept side by side: `projected_j` accumulates the
/// *planned* per-participant `round_energy_j` from the original round
/// plan (what the selector budgeted against), while `actual_j`
/// accumulates the simulation's `energy_spent_j` (what the round really
/// cost — less on early battery deaths or deadline misses, potentially
/// more than the registered projection on degraded/congested networks
/// where `SimPhase` re-resolves link energy upward). The ledger is
/// reconciled once per round, after the record phase, so the budget
/// decision for round `r+1` always sees round `r`'s true spend.
///
/// `budget_j == 0` means *unlimited*: the ledger still tallies (the
/// frontier reports read `actual_j` either way) but never gates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyLedger {
    /// Campaign budget in joules; `0.0` disables gating.
    pub budget_j: f64,
    /// Σ planned participant energy over all reconciled rounds.
    pub projected_j: f64,
    /// Σ simulated participant energy over all reconciled rounds.
    pub actual_j: f64,
}

impl EnergyLedger {
    pub fn new(budget_j: f64) -> Self {
        Self { budget_j, projected_j: 0.0, actual_j: 0.0 }
    }

    /// Whether the ledger gates rounds (a positive budget was set).
    pub fn active(&self) -> bool {
        self.budget_j > 0.0
    }

    /// Budget left to spend, by *actual* reconciled energy. Never
    /// negative; meaningless (`f64::INFINITY`) when inactive.
    pub fn remaining_j(&self) -> f64 {
        if self.active() {
            (self.budget_j - self.actual_j).max(0.0)
        } else {
            f64::INFINITY
        }
    }

    /// Reconcile one round: fold its planned and simulated energy in.
    pub fn record(&mut self, projected_j: f64, actual_j: f64) {
        self.projected_j += projected_j;
        self.actual_j += actual_j;
    }

    /// Terminal condition: an active budget with nothing left to spend.
    pub fn exhausted(&self) -> bool {
        self.active() && self.budget_j - self.actual_j <= 0.0
    }
}

// ---------------------------------------------------------------------------
// Phase 6: metrics record
// ---------------------------------------------------------------------------

/// Assembles the round's [`RoundRecord`] row from the phase outputs and
/// the post-accounting registry state.
///
/// O(1) in the population size: the alive count, mean alive battery,
/// total FL energy and the Jain fairness moments all come from the
/// registry's incrementally maintained
/// [`PoolAggregates`](super::registry::PoolAggregates) — this phase
/// used to rescan the registry ~5 times (including an N-element
/// selection-counts Vec per round just to feed Jain's index).
pub struct RecordPhase;

impl RecordPhase {
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        registry: &Registry,
        plan: &RoundPlan,
        sim: &SimulatedRound,
        exec: &ExecutionOutcome,
        commit: &CommitDecision,
        end_clock_h: f64,
        test_accuracy: f64,
        test_loss: f64,
    ) -> RoundRecord {
        let completed = exec.updates.len();
        RoundRecord {
            round: plan.round,
            wall_clock_h: end_clock_h,
            round_duration_s: sim.round_duration_s,
            selected: plan.selected.len(),
            completed,
            dropped: exec.dropped,
            deadline_missed: exec.deadline_missed,
            committed: commit.committed,
            train_loss: if completed > 0 {
                exec.train_loss_sum / completed as f64
            } else {
                f64::NAN
            },
            test_accuracy,
            test_loss,
            fairness: jain_index_from_moments(
                registry.len(),
                registry.aggregates().selected_sum,
                registry.aggregates().selected_sum_sq,
            ),
            cumulative_dead: registry.dead_count(),
            alive_fraction: registry.alive_count() as f64 / registry.len().max(1) as f64,
            mean_battery: registry.mean_battery_alive(),
            total_fl_energy_j: registry.total_fl_energy_j(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SelectorKind;
    use crate::runtime::MockRuntime;
    use crate::scenario::{CongestionWindow, DiurnalAvailability};
    use crate::selection::make_selector;

    fn fixture() -> (ExperimentConfig, Registry, MockRuntime, ScenarioEnv) {
        let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        cfg.data.min_samples = 5;
        cfg.data.max_samples = 20;
        let rt = MockRuntime { train_batch: cfg.data.batch_size, ..MockRuntime::default() };
        let registry = Registry::build(&cfg, rt.num_classes, rt.param_count);
        let env = ScenarioEnv::steady(&cfg.devices);
        (cfg, registry, rt, env)
    }

    /// An environment whose diurnal availability admits nobody, ever.
    fn blackout_env(cfg: &ExperimentConfig) -> ScenarioEnv {
        let mut env = ScenarioEnv::steady(&cfg.devices);
        env.name = "blackout".to_string();
        env.availability = Box::new(DiurnalAvailability {
            seed: 1,
            peak_hour: 12.0,
            min_available: 0.0,
            max_available: 0.0,
            phase_jitter_h: 0.0,
        });
        env
    }

    /// PlanPhase::run with a throwaway arena (tests don't care about
    /// arena reuse).
    fn run_plan(
        registry: &mut Registry,
        selector: &mut dyn Selector,
        cfg: &ExperimentConfig,
        env: &ScenarioEnv,
        round: u64,
        clock_h: f64,
        rng: &mut Rng,
    ) -> RoundPlan {
        let mut arena = Vec::new();
        PlanPhase::run(registry, selector, cfg, env, round, clock_h, None, rng, &mut arena)
    }

    #[test]
    fn plan_phase_projects_each_selected_client() {
        let (cfg, mut registry, _rt, env) = fixture();
        let mut selector = make_selector(&cfg.selector);
        let mut rng = Rng::seed_from_u64(1);
        let plan =
            run_plan(&mut registry, selector.as_mut(), &cfg, &env, 1, 0.0, &mut rng);
        assert_eq!(plan.selected.len(), plan.plans.len());
        assert!(plan.selected.len() <= cfg.federation.participants_per_round);
        assert!(plan.deadline_s > 0.0);
        for (id, p) in plan.selected.iter().zip(&plan.plans) {
            assert_eq!(*id, p.id);
            assert!(p.total_duration_s() > 0.0);
            assert!(p.round_energy_j > 0.0);
        }
    }

    #[test]
    fn plan_phase_with_zero_availability_selects_nobody() {
        let (cfg, mut registry, _rt, _) = fixture();
        let env = blackout_env(&cfg);
        let mut selector = make_selector(&cfg.selector);
        let mut rng = Rng::seed_from_u64(2);
        let plan =
            run_plan(&mut registry, selector.as_mut(), &cfg, &env, 1, 0.0, &mut rng);
        assert!(plan.selected.is_empty(), "offline population must yield an empty plan");
        assert!(plan.plans.is_empty());
        // And the empty plan flows through the sim without panicking.
        let sim = SimPhase::run(&plan, &registry, &env, 0.0);
        assert!(sim.outcome.results.is_empty());
        assert!(sim.round_duration_s >= 1.0);
    }

    #[test]
    fn sim_phase_empty_round_advances_by_repoll_or_deadline() {
        let (_cfg, registry, _rt, env) = fixture();
        // A short empty-pool deadline is stretched to the re-poll wait…
        let plan = RoundPlan {
            round: 3,
            eligible: 0,
            selected: vec![],
            plans: vec![],
            deadline_s: 42.0,
        };
        let sim = SimPhase::run(&plan, &registry, &env, 0.0);
        assert_eq!(sim.round_duration_s, EMPTY_ROUND_WAIT_S);
        assert!(sim.outcome.results.is_empty());
        // …while a deadline longer than the re-poll wait still wins.
        let plan = RoundPlan {
            round: 4,
            eligible: 0,
            selected: vec![],
            plans: vec![],
            deadline_s: 900.0,
        };
        let sim = SimPhase::run(&plan, &registry, &env, 0.0);
        assert_eq!(sim.round_duration_s, 900.0);
    }

    #[test]
    fn sim_phase_congestion_slows_and_drains_more_than_static() {
        let (cfg, mut registry, _rt, steady) = fixture();
        let mut selector = make_selector(&cfg.selector);
        let mut rng = Rng::seed_from_u64(5);
        let plan =
            run_plan(&mut registry, selector.as_mut(), &cfg, &steady, 1, 0.0, &mut rng);
        assert!(!plan.selected.is_empty());

        let mut congested = ScenarioEnv::steady(&cfg.devices);
        congested.network =
            Box::new(CongestionWindow { start_hour: 0.0, end_hour: 24.0, factor: 0.1 });

        let a = SimPhase::run(&plan, &registry, &steady, 0.0);
        let b = SimPhase::run(&plan, &registry, &congested, 0.0);
        // 10x slower links: every participant is active at least as
        // long, and whoever moves bytes spends more comm energy.
        let active_a: f64 = a.outcome.results.iter().map(|r| r.active_s).sum();
        let active_b: f64 = b.outcome.results.iter().map(|r| r.active_s).sum();
        assert!(
            active_b > active_a,
            "congestion must lengthen activity: {active_b} vs {active_a}"
        );

        // The static path reuses the plan's exact timings.
        let replan = SimPhase::run(&plan, &registry, &steady, 0.0);
        for (x, y) in a.outcome.results.iter().zip(&replan.outcome.results) {
            assert_eq!(x.active_s, y.active_s);
            assert_eq!(x.energy_spent_j, y.energy_spent_j);
        }
    }

    #[test]
    fn static_scenario_matches_plan_timings_exactly() {
        let (cfg, mut registry, _rt, env) = fixture();
        let mut selector = make_selector(&cfg.selector);
        let mut rng = Rng::seed_from_u64(8);
        let plan =
            run_plan(&mut registry, selector.as_mut(), &cfg, &env, 1, 0.0, &mut rng);
        assert!(env.network.is_static());
        let sim = SimPhase::run(&plan, &registry, &env, 0.0);
        // Completed clients' active time equals the planned timeline —
        // the steady scenario reproduces the pre-scenario engine.
        for (r, p) in sim.outcome.results.iter().zip(&plan.plans) {
            if r.completed {
                assert!((r.active_s - p.total_duration_s()).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn exec_phase_identical_at_1_and_4_workers() {
        let (cfg, mut registry, rt, env) = fixture();
        let mut selector = make_selector(&cfg.selector);
        let mut rng = Rng::seed_from_u64(9);
        let plan =
            run_plan(&mut registry, selector.as_mut(), &cfg, &env, 1, 0.0, &mut rng);
        let sim = SimPhase::run(&plan, &registry, &env, 0.0);
        let global = rt.init_params(0).unwrap();
        let data = SyntheticSpeech::new(rt.input_hw, rt.num_classes, 0.3, cfg.data.seed);

        let run_with = |workers: usize| {
            let mut pool = Vec::new();
            ExecPhase { runtime: &rt, data: &data, workers }
                .run(&registry, &global, &plan, &sim, &cfg.training, &mut pool)
                .unwrap()
        };
        let a = run_with(1);
        let b = run_with(4);
        assert_eq!(a.updates.len(), b.updates.len());
        assert_eq!(a.train_loss_sum, b.train_loss_sum);
        for (ua, ub) in a.updates.iter().zip(&b.updates) {
            assert_eq!(ua.params, ub.params);
            assert_eq!(ua.weight, ub.weight);
        }
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (oa, ob) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(oa.id, ob.id);
            assert_eq!(oa.stat_util, ob.stat_util);
        }
    }

    #[test]
    fn feedback_phase_bans_after_repeated_misses() {
        let (cfg, mut registry, _rt, _env) = fixture();
        let mut selector = make_selector(&cfg.selector);
        let miss =
            ParticipantOutcome { id: 0, stat_util: None, duration_s: 1e4, completed: false };
        for round in 1..=MISS_BLACKLIST_THRESHOLD as u64 {
            FeedbackPhase::run(&mut registry, selector.as_mut(), round, &[miss]);
        }
        let stats = &registry.client(0).stats;
        assert_eq!(stats.consecutive_misses, 0, "reset after the ban fires");
        assert_eq!(
            stats.banned_until_round,
            MISS_BLACKLIST_THRESHOLD as u64 + MISS_BLACKLIST_COOLDOWN
        );
        assert_eq!(stats.times_selected, MISS_BLACKLIST_THRESHOLD as u64);
        assert_eq!(stats.times_completed, 0);
    }

    #[test]
    fn energy_ledger_gates_only_when_budgeted() {
        let mut unlimited = EnergyLedger::new(0.0);
        assert!(!unlimited.active());
        unlimited.record(500.0, 450.0);
        assert!(!unlimited.exhausted());
        assert_eq!(unlimited.remaining_j(), f64::INFINITY);
        assert_eq!(unlimited.actual_j, 450.0);
        assert_eq!(unlimited.projected_j, 500.0);

        let mut capped = EnergyLedger::new(1000.0);
        assert!(capped.active());
        assert_eq!(capped.remaining_j(), 1000.0);
        capped.record(600.0, 550.0);
        assert!(!capped.exhausted());
        assert_eq!(capped.remaining_j(), 450.0);
        capped.record(600.0, 550.0);
        assert!(capped.exhausted());
        assert_eq!(capped.remaining_j(), 0.0, "remaining clamps at zero");
    }

    #[test]
    fn quorum_required_boundaries() {
        // Paper default: K=10, half must report.
        assert_eq!(quorum_required(10, 0.5, 10), 5);
        // Fraction rounds UP.
        assert_eq!(quorum_required(10, 0.55, 10), 6);
        // Never below 1, even at fraction 0.
        assert_eq!(quorum_required(10, 0.0, 10), 1);
        // Capped by how many were actually selected.
        assert_eq!(quorum_required(10, 0.9, 4), 4);
        // Empty selection: still demands 1 (so it can never commit).
        assert_eq!(quorum_required(10, 0.5, 0), 1);
    }
}
