//! Jain's fairness index over per-client selection counts (Fig. 3c):
//!
//! J(x) = (Σ x_i)² / (n · Σ x_i²),  J ∈ [1/n, 1]
//!
//! J = 1 when every client has participated equally; J → 1/n as
//! participation concentrates on a single client. The paper plots J
//! over the whole population as training unwinds.

/// Jain's fairness index of `counts`. Returns 1.0 for an empty or
/// all-zero population (vacuously fair).
pub fn jain_index(counts: &[u64]) -> f64 {
    let sum: u64 = counts.iter().sum();
    let sum_sq: u128 = counts.iter().map(|&c| (c as u128) * (c as u128)).sum();
    jain_index_from_moments(counts.len(), sum, sum_sq)
}

/// Jain's index straight from the Σc / Σc² moments the registry
/// maintains incrementally — the O(1) fast path for the per-round
/// metrics row (no N-element counts Vec, no O(N) rescan). Exact
/// integer moments mean this agrees with [`jain_index`] on the same
/// population by construction.
pub fn jain_index_from_moments(n: usize, sum: u64, sum_sq: u128) -> f64 {
    if n == 0 || sum == 0 {
        return 1.0;
    }
    let s = sum as f64;
    (s * s) / (n as f64 * sum_sq as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfectly_equal_is_one() {
        assert!((jain_index(&[3, 3, 3, 3]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_hog_is_one_over_n() {
        let j = jain_index(&[10, 0, 0, 0, 0]);
        assert!((j - 0.2).abs() < 1e-12);
    }

    #[test]
    fn bounds_hold() {
        let counts = [7, 1, 0, 4, 2, 9];
        let j = jain_index(&counts);
        assert!(j > 1.0 / counts.len() as f64 && j < 1.0);
    }

    #[test]
    fn empty_and_zero_are_vacuously_fair() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0, 0, 0]), 1.0);
    }

    #[test]
    fn more_even_is_fairer() {
        assert!(jain_index(&[5, 5, 4, 6]) > jain_index(&[1, 9, 0, 10]));
    }

    #[test]
    fn moments_path_agrees_with_counts_path() {
        for counts in [
            vec![],
            vec![0, 0],
            vec![3, 3, 3],
            vec![10, 0, 0, 0, 0],
            vec![7, 1, 0, 4, 2, 9],
        ] {
            let sum: u64 = counts.iter().sum();
            let sum_sq: u128 = counts.iter().map(|&c| (c as u128) * (c as u128)).sum();
            assert_eq!(
                jain_index(&counts),
                jain_index_from_moments(counts.len(), sum, sum_sq),
                "{counts:?}"
            );
        }
    }
}
