//! Fig. 4 regeneration bench: cumulative battery drop-outs (4a) and
//! per-round duration (4b) for EAFL vs Oort vs Random under identical
//! seeds in the battery-constrained regime.
//!
//! Mock runtime (coordinator dynamics only); the real-SGD version is
//! `examples/e2e_speech_training.rs`.
//!
//! Run: cargo bench --bench fig4_dropouts

use eafl::benchkit::Bench;
use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::metrics::MetricsLog;
use eafl::runtime::MockRuntime;

fn run(kind: SelectorKind, rounds: usize) -> MetricsLog {
    let runtime = MockRuntime::default();
    let mut cfg = ExperimentConfig::paper_default(kind);
    cfg.name = format!("fig4-{kind}");
    cfg.federation.rounds = rounds;
    cfg.federation.num_clients = 100;
    // Battery-tight: the regime where Fig. 4a separates the methods.
    cfg.devices.min_init_battery = 0.10;
    cfg.devices.max_init_battery = 0.6;
    Coordinator::new(cfg, &runtime).unwrap().run().unwrap()
}

fn main() {
    const ROUNDS: usize = 200;
    let mut bench = Bench::heavy();
    let mut logs = Vec::new();
    for kind in [SelectorKind::Eafl, SelectorKind::Oort, SelectorKind::Random] {
        let log = bench.run_once(&format!("fig4 series {kind} ({ROUNDS} rounds, mock)"), || {
            run(kind, ROUNDS)
        });
        logs.push((kind, log));
    }

    println!("\n=== Fig 4a (cumulative drop-outs) & 4b (round duration) ===");
    println!(
        "{:<8} {:>6} {:>9} {:>10} {:>14}",
        "selector", "round", "wall(h)", "dropouts", "round_dur(s)"
    );
    for (kind, log) in &logs {
        for r in log.records.iter().step_by(40) {
            println!(
                "{:<8} {:>6} {:>9.2} {:>10} {:>14.1}",
                kind.to_string(),
                r.round,
                r.wall_clock_h,
                r.cumulative_dead,
                r.round_duration_s
            );
        }
    }

    println!("\n=== expected shape checks (paper Fig. 4) ===");
    let get = |k: SelectorKind| logs.iter().find(|(kk, _)| *kk == k).unwrap().1.summary();
    let eafl = get(SelectorKind::Eafl);
    let oort = get(SelectorKind::Oort);
    let random = get(SelectorKind::Random);
    println!(
        "dropouts: eafl={} oort={} random={}  (paper 4a: oort >> eafl: {})",
        eafl.total_dropouts,
        oort.total_dropouts,
        random.total_dropouts,
        if oort.total_dropouts > eafl.total_dropouts { "HOLDS" } else { "VIOLATED" }
    );
    if eafl.total_dropouts > 0 {
        println!(
            "oort/eafl drop-out ratio: {:.2}x (paper: up to 2.45x)",
            oort.total_dropouts as f64 / eafl.total_dropouts as f64
        );
    }
    println!(
        "mean round duration: eafl={:.1}s oort={:.1}s random={:.1}s  (paper 4b: random longest: {})",
        eafl.mean_round_duration_s,
        oort.mean_round_duration_s,
        random.mean_round_duration_s,
        if random.mean_round_duration_s >= oort.mean_round_duration_s.min(eafl.mean_round_duration_s) {
            "HOLDS"
        } else {
            "VIOLATED"
        }
    );
}
