"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this
package must match its oracle to float32 tolerance across the shape/dtype
sweep in python/tests/test_kernels.py (hypothesis-driven).
"""

import jax.numpy as jnp


def dense_ref(x, w, b, activation: str = "id"):
    """Reference for kernels.dense.dense: y = act(x @ w + b).

    x: f32[M, K], w: f32[K, N], b: f32[N] -> f32[M, N]
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "id":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def matmul_ref(x, w):
    """Reference for the bias-less matmul used by dense's backward pass."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def softmax_xent_ref(logits, onehot):
    """Reference for kernels.softmax_xent: per-example cross-entropy.

    logits: f32[B, C], onehot: f32[B, C] -> f32[B]
    Numerically-stable log-softmax via max subtraction.
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[:, 0]
    return lse - jnp.sum(onehot * logits, axis=-1)


def softmax_ref(logits):
    """Softmax over the last axis (used by the xent backward pass)."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
