//! The shard supervisor: fault-tolerant self-orchestration for
//! `eafl sweep --jobs P`.
//!
//! The parent spawns one `eafl sweep --shard I/P --jobs 1` child per
//! shard over a shared output directory, then *supervises* rather than
//! waits: children are reaped concurrently as they exit (a hung early
//! shard never blocks reaping later ones), each child's
//! `<out>/shard-<I>.progress.json` heartbeat is polled for stall
//! detection (`--stall-timeout-s`), and failed shards are restarted
//! with deterministic exponential backoff up to `--max-retries` — each
//! restart leans on the fingerprint-checked cell resume, so a retried
//! shard recomputes only what its predecessor left unfinished. On any
//! fatal error (a child's usage error, a deterministic cell failure,
//! or a parent-side error) every surviving sibling is killed *and
//! reaped*, so no orphan process keeps writing into `--out`.
//!
//! ## Exit-code taxonomy
//!
//! | code | meaning                                                |
//! |------|--------------------------------------------------------|
//! | 0    | campaign complete, merged report written               |
//! | 1    | internal error (I/O, merge machinery)                  |
//! | 2    | usage/config error — fix the invocation ([`EXIT_USAGE`]) |
//! | 3    | deterministic cell failure, named on stderr ([`EXIT_CELL_FAILURE`]) |
//! | 4    | retries exhausted; culprit shards/cells named ([`EXIT_RETRIES_EXHAUSTED`]) |
//! | 70   | injected fault crash (`fault::EXIT_FAULT_CRASH`, children only) |
//!
//! Convergence: after every round of children the supervisor runs
//! [`report::merge_with_detail`]. Quarantined or missing cells map
//! back to their owning shards ([`shard_of`]) and those shards rerun;
//! a clean merge ends the loop. Crashed-and-retried sweeps therefore
//! produce byte-identical campaign/merge/trace output to a fault-free
//! run — the determinism contract `rust/tests/campaign_sharding.rs`
//! pins with injected faults.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::config::ShardSpec;
use crate::report::{self, CampaignReport, MergeDetail};
use crate::util::json::Json;

use super::shard_of;

/// Exit code for usage/config errors (bad flags, malformed specs).
pub const EXIT_USAGE: i32 = 2;
/// Exit code for a deterministic cell/run failure (retry won't help).
pub const EXIT_CELL_FAILURE: i32 = 3;
/// Exit code when shards keep failing past `--max-retries`.
pub const EXIT_RETRIES_EXHAUSTED: i32 = 4;

/// Default restart budget per shard (`--max-retries`).
pub const DEFAULT_MAX_RETRIES: usize = 2;

/// Schema tag of the per-shard progress heartbeat file.
pub const PROGRESS_SCHEMA: &str = "eafl-shard-progress-v1";

const POLL_INTERVAL: Duration = Duration::from_millis(25);
const BACKOFF_BASE_MS: u64 = 100;
const BACKOFF_CAP_MS: u64 = 2_000;

/// `<out>/shard-<I>.progress.json` — where shard `I` heartbeats.
pub fn progress_path(out: &Path, shard_index: usize) -> PathBuf {
    out.join(format!("shard-{shard_index}.progress.json"))
}

/// A shard child's progress heartbeat, written atomically (temp file +
/// rename) at shard start and after every finished cell. Advisory:
/// write failures are swallowed — progress must never fail a sweep —
/// and the supervisor only uses it for display and stall detection
/// (the merge's completeness authority stays the manifest). The
/// monotonic `seq` makes every write byte-distinct, so "the file
/// changed" is exactly "the shard made progress".
pub struct ShardProgress {
    out: PathBuf,
    campaign: String,
    shard: ShardSpec,
    owned: usize,
    done: AtomicUsize,
    seq: AtomicU64,
}

impl ShardProgress {
    pub fn create(out: &Path, campaign: &str, shard: ShardSpec, owned: usize, done: usize) -> Self {
        let p = Self {
            out: out.to_path_buf(),
            campaign: campaign.to_string(),
            shard,
            owned,
            done: AtomicUsize::new(done),
            seq: AtomicU64::new(0),
        };
        p.write();
        p
    }

    /// One more owned cell finished (its artifacts are on disk).
    pub fn cell_done(&self) {
        self.done.fetch_add(1, Ordering::SeqCst);
        self.write();
    }

    fn write(&self) {
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        let mut m = std::collections::BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(PROGRESS_SCHEMA.to_string()));
        m.insert("campaign".to_string(), Json::Str(self.campaign.clone()));
        m.insert("shard".to_string(), Json::Num(self.shard.index as f64));
        m.insert("count".to_string(), Json::Num(self.shard.count as f64));
        m.insert("owned".to_string(), Json::Num(self.owned as f64));
        m.insert("done".to_string(), Json::Num(self.done.load(Ordering::SeqCst) as f64));
        m.insert("seq".to_string(), Json::Num(seq as f64));
        m.insert("pid".to_string(), Json::Num(std::process::id() as f64));
        let text = Json::Obj(m).to_string_pretty();
        let tmp = self
            .out
            .join(format!(".shard-{}.progress.{}.tmp", self.shard.index, std::process::id()));
        let _ = std::fs::write(&tmp, &text)
            .and_then(|_| std::fs::rename(&tmp, progress_path(&self.out, self.shard.index)));
    }
}

/// Everything the supervisor needs to spawn and re-spawn shards.
pub struct SupervisorSpec {
    /// The `eafl` binary (normally `std::env::current_exe()`).
    pub exe: PathBuf,
    /// The sweep argv minus orchestration flags (`--jobs`, `--shard`,
    /// `--out`, `--fault`, `--max-retries`, `--stall-timeout-s`) —
    /// forwarded verbatim so every child derives the same grid. Fault
    /// plans reach children via the `EAFL_FAULT` environment instead,
    /// scoped per attempt through `EAFL_FAULT_ATTEMPT`.
    pub forwarded: Vec<String>,
    pub out: PathBuf,
    /// Shard count (= child process count).
    pub procs: usize,
    /// Restarts allowed per shard before giving up.
    pub max_retries: usize,
    /// Kill a shard whose progress file stops changing for this long.
    /// `None` disables stall detection. Must comfortably exceed the
    /// slowest single cell — progress only ticks at cell boundaries.
    pub stall_timeout: Option<Duration>,
}

/// A supervision failure carrying its exit-code class, so `main` can
/// map it without error downcasting (the vendored `anyhow` has none).
#[derive(Debug)]
pub struct SupervisorError {
    pub exit_code: i32,
    pub message: String,
}

impl std::fmt::Display for SupervisorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for SupervisorError {}

fn internal(message: String) -> SupervisorError {
    SupervisorError { exit_code: 1, message }
}

/// How one child's exit (or stall-kill) is handled.
enum Outcome {
    Done,
    /// Crash, signal, stall, injected fault: restart the shard.
    Retry(String),
    /// Exit 2/3: retrying cannot help — kill siblings and propagate.
    Fatal(i32, String),
}

fn classify(shard: usize, procs: usize, code: Option<i32>) -> Outcome {
    match code {
        Some(0) => Outcome::Done,
        Some(EXIT_USAGE) => Outcome::Fatal(
            EXIT_USAGE,
            format!(
                "shard {shard}/{procs} exited {EXIT_USAGE} (usage/config error) — \
                 see its stderr above; retrying cannot help"
            ),
        ),
        Some(EXIT_CELL_FAILURE) => Outcome::Fatal(
            EXIT_CELL_FAILURE,
            format!(
                "shard {shard}/{procs} reported a cell failure (exit {EXIT_CELL_FAILURE}) — \
                 deterministic, so it is not retried; the failing cell is named on its \
                 stderr above"
            ),
        ),
        Some(code) => Outcome::Retry(format!("shard {shard}/{procs} crashed (exit {code})")),
        None => Outcome::Retry(format!("shard {shard}/{procs} was killed by a signal")),
    }
}

/// One running shard child plus its last observed heartbeat.
struct Running {
    shard: usize,
    child: Child,
    heartbeat: String,
    last_change: Instant,
    announced_done: Option<usize>,
}

/// The children of one supervision round. Dropping the brood kills and
/// reaps every child still in it — the no-orphans guarantee on every
/// parent error/panic path.
#[derive(Default)]
struct Brood {
    children: Vec<Running>,
}

impl Drop for Brood {
    fn drop(&mut self) {
        for r in &mut self.children {
            let _ = r.child.kill();
            let _ = r.child.wait();
        }
    }
}

/// Run `eafl sweep --jobs P` to completion under supervision; returns
/// the merged report (the caller writes/prints it). See the module
/// docs for the retry/merge convergence loop and exit taxonomy.
pub fn supervise(spec: &SupervisorSpec) -> Result<CampaignReport, SupervisorError> {
    let mut restarts = vec![0usize; spec.procs];
    let mut last_failure: Vec<Option<String>> = vec![None; spec.procs];
    let mut pending: BTreeSet<usize> = (0..spec.procs).collect();
    let mut round = 0usize;
    loop {
        let failures = run_round(spec, &pending, &restarts)?;
        let mut next: BTreeSet<usize> = BTreeSet::new();
        for (shard, why) in failures {
            eprintln!("[supervisor] {why}");
            last_failure[shard] = Some(why);
            next.insert(shard);
        }
        let mut cells_note = String::new();
        if next.is_empty() {
            // Every child exited cleanly — but clean exits don't prove
            // complete artifacts (corruption is silent by design), so
            // the merge is the arbiter. It quarantines bad cells as a
            // side effect; their owners rerun below.
            match report::merge_with_detail(&[spec.out.clone()]) {
                Ok(MergeDetail::Complete { report, .. }) => return Ok(report),
                Ok(MergeDetail::NoManifest { .. }) => {
                    eprintln!(
                        "[supervisor] campaign manifest missing or quarantined — rerunning \
                         every shard to regenerate it"
                    );
                    next = (0..spec.procs).collect();
                }
                Ok(MergeDetail::Incomplete { problems, total }) => {
                    let mut named: Vec<String> = Vec::new();
                    for p in &problems {
                        let owner = shard_of(&p.cell, spec.procs);
                        next.insert(owner);
                        if named.len() < 8 {
                            named.push(format!("{} ({})", p.cell, p.reason));
                        }
                    }
                    let more = problems.len().saturating_sub(named.len());
                    let suffix =
                        if more > 0 { format!(" (+{more} more)") } else { String::new() };
                    cells_note =
                        format!("; unfinished cells: {}{suffix}", named.join(", "));
                    eprintln!(
                        "[supervisor] merge incomplete: {}/{total} cells unfinished or \
                         quarantined{cells_note} — rerunning shard(s) {}",
                        problems.len(),
                        join_shards(&next)
                    );
                }
                Err(e) => return Err(internal(format!("merging {}: {e:#}", spec.out.display()))),
            }
        }
        let exhausted: Vec<usize> =
            next.iter().copied().filter(|&s| restarts[s] >= spec.max_retries).collect();
        if !exhausted.is_empty() {
            let causes: Vec<String> = exhausted
                .iter()
                .map(|&s| match &last_failure[s] {
                    Some(why) => format!("shard {s}/{}: {why}", spec.procs),
                    None => format!("shard {s}/{}: merge still incomplete", spec.procs),
                })
                .collect();
            return Err(SupervisorError {
                exit_code: EXIT_RETRIES_EXHAUSTED,
                message: format!(
                    "retries exhausted after {} restart(s) per shard: {}{cells_note} — \
                     rerun the same sweep to resume (finished cells are skipped), or \
                     raise --max-retries",
                    spec.max_retries,
                    causes.join("; ")
                ),
            });
        }
        round += 1;
        let backoff = BACKOFF_BASE_MS
            .saturating_mul(1u64 << (round - 1).min(10) as u32)
            .min(BACKOFF_CAP_MS);
        eprintln!(
            "[supervisor] retrying shard(s) {} in {backoff} ms (restart {} of {})",
            join_shards(&next),
            next.iter().map(|&s| restarts[s] + 1).max().unwrap_or(1),
            spec.max_retries
        );
        std::thread::sleep(Duration::from_millis(backoff));
        for &s in &next {
            restarts[s] += 1;
        }
        pending = next;
    }
}

fn join_shards(shards: &BTreeSet<usize>) -> String {
    shards.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
}

/// Spawn the given shards and supervise them until all have exited (or
/// been stall-killed). Returns the retryable failures; fatal child
/// outcomes return `Err` after the brood guard kills+reaps siblings.
fn run_round(
    spec: &SupervisorSpec,
    shards: &BTreeSet<usize>,
    restarts: &[usize],
) -> Result<Vec<(usize, String)>, SupervisorError> {
    let mut brood = Brood::default();
    for &i in shards {
        let child = Command::new(&spec.exe)
            .arg("sweep")
            .args(&spec.forwarded)
            .arg("--shard")
            .arg(format!("{i}/{}", spec.procs))
            .arg("--jobs")
            .arg("1")
            .arg("--out")
            .arg(&spec.out)
            .env("EAFL_FAULT_ATTEMPT", restarts[i].to_string())
            .stdout(Stdio::null())
            .spawn()
            .map_err(|e| internal(format!("spawning shard {i}/{}: {e}", spec.procs)))?;
        brood.children.push(Running {
            shard: i,
            child,
            heartbeat: String::new(),
            last_change: Instant::now(),
            announced_done: None,
        });
    }
    let mut failures: Vec<(usize, String)> = Vec::new();
    while !brood.children.is_empty() {
        let mut k = 0;
        while k < brood.children.len() {
            let r = &mut brood.children[k];
            let shard = r.shard;
            match r.child.try_wait() {
                Ok(Some(status)) => {
                    // Reaped (try_wait collects the exit status);
                    // remove without re-killing.
                    brood.children.swap_remove(k);
                    match classify(shard, spec.procs, status.code()) {
                        Outcome::Done => {}
                        Outcome::Retry(why) => failures.push((shard, why)),
                        // Dropping `brood` on return kills + reaps the
                        // surviving siblings.
                        Outcome::Fatal(code, message) => {
                            return Err(SupervisorError { exit_code: code, message })
                        }
                    }
                }
                Ok(None) => {
                    poll_heartbeat(spec, r);
                    if let Some(timeout) = spec.stall_timeout {
                        if r.last_change.elapsed() > timeout {
                            let _ = r.child.kill();
                            let _ = r.child.wait();
                            failures.push((
                                shard,
                                format!(
                                    "shard {shard}/{} stalled (no progress for {:.1}s) — killed",
                                    spec.procs,
                                    timeout.as_secs_f64()
                                ),
                            ));
                            brood.children.swap_remove(k);
                            continue;
                        }
                    }
                    k += 1;
                }
                Err(e) => {
                    let _ = r.child.kill();
                    let _ = r.child.wait();
                    failures
                        .push((shard, format!("shard {shard}/{}: wait failed: {e}", spec.procs)));
                    brood.children.swap_remove(k);
                }
            }
        }
        if !brood.children.is_empty() {
            std::thread::sleep(POLL_INTERVAL);
        }
    }
    Ok(failures)
}

/// Read a shard's heartbeat; any byte change resets its stall clock,
/// and done/owned transitions are narrated to stderr.
fn poll_heartbeat(spec: &SupervisorSpec, r: &mut Running) {
    let text = std::fs::read_to_string(progress_path(&spec.out, r.shard)).unwrap_or_default();
    if text == r.heartbeat {
        return;
    }
    r.heartbeat = text;
    r.last_change = Instant::now();
    if let Ok(j) = Json::parse(&r.heartbeat) {
        let done = j.get("done").and_then(Json::as_usize);
        let owned = j.get("owned").and_then(Json::as_usize);
        if let (Some(done), Some(owned)) = (done, owned) {
            if r.announced_done != Some(done) {
                r.announced_done = Some(done);
                eprintln!("[supervisor] shard {}/{}: {done}/{owned} cells done", r.shard, spec.procs);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn progress_heartbeat_roundtrips_and_each_write_is_distinct() {
        let dir = std::env::temp_dir().join(format!("eafl-progress-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = ShardProgress::create(
            &dir,
            "sweep",
            ShardSpec { index: 1, count: 3 },
            5,
            2,
        );
        let path = progress_path(&dir, 1);
        let first = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&first).unwrap();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(PROGRESS_SCHEMA));
        assert_eq!(j.get("shard").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("owned").and_then(Json::as_usize), Some(5));
        assert_eq!(j.get("done").and_then(Json::as_usize), Some(2));
        p.cell_done();
        let second = std::fs::read_to_string(&path).unwrap();
        assert_ne!(first, second, "every heartbeat write must change the bytes");
        let j = Json::parse(&second).unwrap();
        assert_eq!(j.get("done").and_then(Json::as_usize), Some(3));
        // No temp files leak (atomic rename), and no dotfile confuses
        // the manifest scan.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn exit_classification_maps_the_taxonomy() {
        assert!(matches!(classify(0, 2, Some(0)), Outcome::Done));
        assert!(matches!(classify(0, 2, Some(EXIT_USAGE)), Outcome::Fatal(c, _) if c == EXIT_USAGE));
        assert!(matches!(
            classify(0, 2, Some(EXIT_CELL_FAILURE)),
            Outcome::Fatal(c, _) if c == EXIT_CELL_FAILURE
        ));
        assert!(matches!(classify(0, 2, Some(crate::fault::EXIT_FAULT_CRASH)), Outcome::Retry(_)));
        assert!(matches!(classify(0, 2, Some(137)), Outcome::Retry(_)));
        assert!(matches!(classify(0, 2, None), Outcome::Retry(_)), "signal deaths retry");
    }
}
