//! Scenario subsystem — declarative, seed-deterministic environment
//! models plugged into the RoundEngine's phase seams.
//!
//! The paper evaluates EAFL in a *static* environment: every client is
//! always reachable, links never change, and recharge is at most a
//! cooldown. Related work makes the environment the variable (AutoFL's
//! runtime variance, "Learn More by Using Less"'s hard energy budgets),
//! so this module turns the environment into data:
//!
//!  - [`AvailabilityModel`] — who is present when a round is planned
//!    (consumed by `PlanPhase`): always-on, diurnal sine-wave presence
//!    with per-client phase offsets, trace-driven on/off churn;
//!  - [`NetworkModel`] — how link profiles evolve over simulated time
//!    (consumed by `SimPhase`): static, degraded-tail, congestion
//!    windows;
//!  - recharge policies keyed to the simulated wall clock ([`recharge`]):
//!    overnight charging windows, piecewise-linear solar traces —
//!    implementing the accounting module's `RechargePolicy`;
//!  - [`Scenario`] — a named bundle of all three plus device overrides,
//!    loadable from TOML (`util::toml`) with built-in presets
//!    ([`Scenario::preset`]): `steady`, `diurnal`, `commuter`,
//!    `solar-edge`.
//!
//! Every model is a pure function of (seed, client, simulated time) —
//! no RNG state advances during a run — so scenarios preserve the
//! engine's worker-count invariance: seeded campaigns are byte-identical
//! at any `EAFL_WORKERS` / job count.

mod availability;
mod network;
pub mod recharge;

pub use availability::{
    AlwaysOn, AvailabilityModel, DiurnalAvailability, TraceAvailability, WakeWheel,
};
pub use network::{in_daily_window, CongestionWindow, DegradedTail, NetworkModel, StaticNetwork};
pub use recharge::{daily_window_overlap_h, OvernightRecharge, SolarRecharge};

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::config::{DeviceConfig, ExperimentConfig};
use crate::coordinator::{recharge_policy_from, NoRecharge, RechargePolicy};
use crate::util::toml::{TomlDoc, TomlWriter};

/// Stateless hash → uniform f64 in [0, 1): the scenario models' source
/// of per-(client, time-slot) randomness. splitmix64-style finalizer so
/// nearby inputs give uncorrelated outputs.
pub(crate) fn hash01(seed: u64, a: u64, b: u64) -> f64 {
    let mut x = seed
        .wrapping_add(a.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(b.wrapping_mul(0xD1B5_4A32_D192_ED03));
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Declarative availability-model choice.
#[derive(Debug, Clone, PartialEq)]
pub enum AvailabilitySpec {
    AlwaysOn,
    Diurnal {
        peak_hour: f64,
        min_available: f64,
        max_available: f64,
        phase_jitter_h: f64,
    },
    Trace {
        period_h: f64,
        slot_h: f64,
        duty_cycle: f64,
        churn: f64,
    },
}

/// Declarative network-model choice.
#[derive(Debug, Clone, PartialEq)]
pub enum NetworkSpec {
    Static,
    DegradedTail { fraction: f64, factor: f64 },
    Congestion { start_hour: f64, end_hour: f64, factor: f64 },
}

/// Declarative recharge-policy choice.
#[derive(Debug, Clone, PartialEq)]
pub enum RechargeSpec {
    /// Defer to the device config (cooldown model, or none) — what the
    /// seed system always did.
    FromConfig,
    /// Dead devices never return regardless of config.
    None,
    Overnight {
        start_hour: f64,
        end_hour: f64,
        rate_frac_per_h: f64,
    },
    Solar {
        /// (hour_of_day, frac_per_h) points, sorted by hour.
        trace: Vec<(f64, f64)>,
    },
}

/// Optional device-config overrides a scenario carries (e.g. an edge
/// deployment with heavier background drain).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeviceOverrides {
    pub idle_drain_per_hour: Option<f64>,
    pub busy_drain_per_hour: Option<f64>,
    pub busy_probability: Option<f64>,
    pub min_init_battery: Option<f64>,
    pub max_init_battery: Option<f64>,
}

/// A named environment: availability + network + recharge + overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    pub availability: AvailabilitySpec,
    pub network: NetworkSpec,
    pub recharge: RechargeSpec,
    pub overrides: DeviceOverrides,
}

/// The built-in preset names, in listing order.
pub const PRESET_NAMES: [&str; 4] = ["steady", "diurnal", "commuter", "solar-edge"];

impl Scenario {
    /// Look up a built-in preset by name.
    pub fn preset(name: &str) -> Option<Scenario> {
        let base = |name: &str, description: &str| Scenario {
            name: name.to_string(),
            description: description.to_string(),
            availability: AvailabilitySpec::AlwaysOn,
            network: NetworkSpec::Static,
            recharge: RechargeSpec::FromConfig,
            overrides: DeviceOverrides::default(),
        };
        match name {
            "steady" => Some(base(
                "steady",
                "always-on clients, static links, recharge from the device config \
                 (the paper's baseline environment)",
            )),
            "diurnal" => {
                let mut s = base(
                    "diurnal",
                    "sine-wave client presence peaking at 20:00 with per-client phase \
                     offsets; links and recharge unchanged",
                );
                s.availability = AvailabilitySpec::Diurnal {
                    peak_hour: 20.0,
                    min_available: 0.15,
                    max_available: 0.95,
                    phase_jitter_h: 3.0,
                };
                Some(s)
            }
            "commuter" => {
                let mut s = base(
                    "commuter",
                    "trace-driven on/off churn, evening congestion window (17-21h, 0.35x \
                     bandwidth), overnight charging 22-6h",
                );
                s.availability = AvailabilitySpec::Trace {
                    period_h: 24.0,
                    slot_h: 0.5,
                    duty_cycle: 0.6,
                    churn: 0.15,
                };
                s.network = NetworkSpec::Congestion {
                    start_hour: 17.0,
                    end_hour: 21.0,
                    factor: 0.35,
                };
                s.recharge = RechargeSpec::Overnight {
                    start_hour: 22.0,
                    end_hour: 6.0,
                    rate_frac_per_h: 0.25,
                };
                Some(s)
            }
            "solar-edge" => {
                let mut s = base(
                    "solar-edge",
                    "solar-harvesting edge fleet: 30% of clients on a 0.25x degraded \
                     link tail, daylight piecewise-linear recharge, heavier background \
                     drain",
                );
                s.network = NetworkSpec::DegradedTail { fraction: 0.3, factor: 0.25 };
                s.recharge = RechargeSpec::Solar { trace: default_solar_trace() };
                s.overrides.idle_drain_per_hour = Some(0.008);
                s.overrides.busy_drain_per_hour = Some(0.05);
                Some(s)
            }
            _ => None,
        }
    }

    /// All built-in presets, in listing order.
    pub fn presets() -> Vec<Scenario> {
        PRESET_NAMES
            .iter()
            .map(|n| Self::preset(n).expect("preset table is consistent"))
            .collect()
    }

    /// Resolve a `--scenario` argument: a preset name, a TOML file
    /// path, or empty (⇒ `steady`).
    pub fn resolve(arg: &str) -> Result<Scenario> {
        let arg = arg.trim();
        if arg.is_empty() {
            return Ok(Self::preset("steady").expect("steady preset exists"));
        }
        if let Some(s) = Self::preset(arg) {
            return Ok(s);
        }
        let path = Path::new(arg);
        if path.exists() {
            return Self::from_toml_file(path);
        }
        bail!(
            "unknown scenario {arg:?}: not a preset ({}) and no such file",
            PRESET_NAMES.join(", ")
        )
    }

    pub fn from_toml_file(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {path:?}"))?;
        Self::from_toml(&text).with_context(|| format!("parsing scenario {path:?}"))
    }

    /// Parse from TOML text. Missing sections fall back to the `steady`
    /// defaults, so a file holding just `[availability]` is valid.
    pub fn from_toml(text: &str) -> Result<Scenario> {
        let doc = TomlDoc::parse(text).context("parsing scenario TOML")?;
        let mut s = Self::preset("steady").expect("steady preset exists");
        s.name = doc.get_str("name").unwrap_or("custom").to_string();
        s.description =
            doc.get_str("description").unwrap_or("user-defined scenario").to_string();

        if let Some(kind) = doc.get_str("availability.kind") {
            s.availability = match kind {
                "always-on" => AvailabilitySpec::AlwaysOn,
                "diurnal" => AvailabilitySpec::Diurnal {
                    peak_hour: doc.get_f64("availability.peak_hour").unwrap_or(20.0),
                    min_available: doc
                        .get_f64("availability.min_available")
                        .unwrap_or(0.15),
                    max_available: doc
                        .get_f64("availability.max_available")
                        .unwrap_or(0.95),
                    phase_jitter_h: doc
                        .get_f64("availability.phase_jitter_h")
                        .unwrap_or(3.0),
                },
                "trace" => AvailabilitySpec::Trace {
                    period_h: doc.get_f64("availability.period_h").unwrap_or(24.0),
                    slot_h: doc.get_f64("availability.slot_h").unwrap_or(0.5),
                    duty_cycle: doc.get_f64("availability.duty_cycle").unwrap_or(0.6),
                    churn: doc.get_f64("availability.churn").unwrap_or(0.15),
                },
                other => {
                    bail!("unknown availability.kind {other:?} (always-on|diurnal|trace)")
                }
            };
        }

        if let Some(kind) = doc.get_str("network.kind") {
            s.network = match kind {
                "static" => NetworkSpec::Static,
                "degraded-tail" => NetworkSpec::DegradedTail {
                    fraction: doc.get_f64("network.fraction").unwrap_or(0.3),
                    factor: doc.get_f64("network.factor").unwrap_or(0.25),
                },
                "congestion" => NetworkSpec::Congestion {
                    start_hour: doc.get_f64("network.start_hour").unwrap_or(17.0),
                    end_hour: doc.get_f64("network.end_hour").unwrap_or(21.0),
                    factor: doc.get_f64("network.factor").unwrap_or(0.35),
                },
                other => {
                    bail!("unknown network.kind {other:?} (static|degraded-tail|congestion)")
                }
            };
        }

        if let Some(kind) = doc.get_str("recharge.kind") {
            s.recharge = match kind {
                "from-config" => RechargeSpec::FromConfig,
                "none" => RechargeSpec::None,
                "overnight" => RechargeSpec::Overnight {
                    start_hour: doc.get_f64("recharge.start_hour").unwrap_or(22.0),
                    end_hour: doc.get_f64("recharge.end_hour").unwrap_or(6.0),
                    rate_frac_per_h: doc
                        .get_f64("recharge.rate_frac_per_h")
                        .unwrap_or(0.25),
                },
                "solar" => {
                    let trace = match (
                        doc.get_num_array("recharge.trace_hours"),
                        doc.get_num_array("recharge.trace_rates"),
                    ) {
                        (Some(hours), Some(rates)) => {
                            ensure!(
                                hours.len() == rates.len() && !hours.is_empty(),
                                "recharge.trace_hours and recharge.trace_rates must be \
                                 equal-length and non-empty"
                            );
                            hours.iter().zip(rates).map(|(&h, &r)| (h, r)).collect()
                        }
                        (None, None) => default_solar_trace(),
                        _ => bail!(
                            "recharge.trace_hours and recharge.trace_rates must be \
                             provided together (or both omitted for the default curve)"
                        ),
                    };
                    RechargeSpec::Solar { trace }
                }
                other => {
                    bail!("unknown recharge.kind {other:?} (from-config|none|overnight|solar)")
                }
            };
        }

        s.overrides = DeviceOverrides {
            idle_drain_per_hour: doc.get_f64("overrides.idle_drain_per_hour"),
            busy_drain_per_hour: doc.get_f64("overrides.busy_drain_per_hour"),
            busy_probability: doc.get_f64("overrides.busy_probability"),
            min_init_battery: doc.get_f64("overrides.min_init_battery"),
            max_init_battery: doc.get_f64("overrides.max_init_battery"),
        };
        s.validate()?;
        Ok(s)
    }

    /// Emit the scenario as TOML (templates for custom files; inverse
    /// of [`Scenario::from_toml`]).
    pub fn to_toml(&self) -> String {
        let mut w = TomlWriter::new();
        w.str("name", &self.name);
        w.str("description", &self.description);

        w.table("availability");
        match &self.availability {
            AvailabilitySpec::AlwaysOn => {
                w.str("kind", "always-on");
            }
            AvailabilitySpec::Diurnal {
                peak_hour,
                min_available,
                max_available,
                phase_jitter_h,
            } => {
                w.str("kind", "diurnal");
                w.num("peak_hour", *peak_hour)
                    .num("min_available", *min_available)
                    .num("max_available", *max_available)
                    .num("phase_jitter_h", *phase_jitter_h);
            }
            AvailabilitySpec::Trace { period_h, slot_h, duty_cycle, churn } => {
                w.str("kind", "trace");
                w.num("period_h", *period_h)
                    .num("slot_h", *slot_h)
                    .num("duty_cycle", *duty_cycle)
                    .num("churn", *churn);
            }
        }

        w.table("network");
        match &self.network {
            NetworkSpec::Static => {
                w.str("kind", "static");
            }
            NetworkSpec::DegradedTail { fraction, factor } => {
                w.str("kind", "degraded-tail");
                w.num("fraction", *fraction).num("factor", *factor);
            }
            NetworkSpec::Congestion { start_hour, end_hour, factor } => {
                w.str("kind", "congestion");
                w.num("start_hour", *start_hour)
                    .num("end_hour", *end_hour)
                    .num("factor", *factor);
            }
        }

        w.table("recharge");
        match &self.recharge {
            RechargeSpec::FromConfig => {
                w.str("kind", "from-config");
            }
            RechargeSpec::None => {
                w.str("kind", "none");
            }
            RechargeSpec::Overnight { start_hour, end_hour, rate_frac_per_h } => {
                w.str("kind", "overnight");
                w.num("start_hour", *start_hour)
                    .num("end_hour", *end_hour)
                    .num("rate_frac_per_h", *rate_frac_per_h);
            }
            RechargeSpec::Solar { trace } => {
                w.str("kind", "solar");
                let hours: Vec<f64> = trace.iter().map(|(h, _)| *h).collect();
                let rates: Vec<f64> = trace.iter().map(|(_, r)| *r).collect();
                w.num_array("trace_hours", &hours).num_array("trace_rates", &rates);
            }
        }

        w.table("overrides");
        let o = &self.overrides;
        if let Some(v) = o.idle_drain_per_hour {
            w.num("idle_drain_per_hour", v);
        }
        if let Some(v) = o.busy_drain_per_hour {
            w.num("busy_drain_per_hour", v);
        }
        if let Some(v) = o.busy_probability {
            w.num("busy_probability", v);
        }
        if let Some(v) = o.min_init_battery {
            w.num("min_init_battery", v);
        }
        if let Some(v) = o.max_init_battery {
            w.num("max_init_battery", v);
        }
        w.finish()
    }

    /// Range checks; called after parsing and before building an env.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.trim().is_empty(), "scenario name must not be empty");
        match &self.availability {
            AvailabilitySpec::AlwaysOn => {}
            AvailabilitySpec::Diurnal {
                min_available, max_available, phase_jitter_h, ..
            } => {
                ensure!(
                    (0.0..=1.0).contains(min_available)
                        && (0.0..=1.0).contains(max_available)
                        && min_available <= max_available,
                    "diurnal availability needs 0 <= min_available <= max_available <= 1"
                );
                ensure!(*phase_jitter_h >= 0.0, "phase_jitter_h must be >= 0");
            }
            AvailabilitySpec::Trace { period_h, slot_h, duty_cycle, churn } => {
                ensure!(
                    *period_h > 0.0 && *slot_h > 0.0 && *slot_h <= *period_h,
                    "trace availability needs 0 < slot_h <= period_h"
                );
                ensure!((0.0..=1.0).contains(duty_cycle), "duty_cycle must be in [0,1]");
                ensure!((0.0..=1.0).contains(churn), "churn must be in [0,1]");
            }
        }
        // Daily windows interpret hours of day and wrap midnight via
        // start > end; an hour like 30 would be silently clipped at 24
        // instead, so reject it (encode 22:00→06:00 as start 22, end 6).
        let check_window = |what: &str, start: f64, end: f64| -> Result<()> {
            ensure!(
                (0.0..24.0).contains(&start) && (0.0..24.0).contains(&end),
                "{what} start_hour/end_hour must be in [0, 24) \
                 (a window crossing midnight uses start > end)"
            );
            Ok(())
        };
        match &self.network {
            NetworkSpec::Static => {}
            NetworkSpec::DegradedTail { fraction, factor } => {
                ensure!(
                    (0.0..=1.0).contains(fraction),
                    "degraded-tail fraction must be in [0,1]"
                );
                ensure!(*factor > 0.0, "degraded-tail factor must be > 0");
            }
            NetworkSpec::Congestion { start_hour, end_hour, factor } => {
                check_window("congestion", *start_hour, *end_hour)?;
                ensure!(*factor > 0.0, "congestion factor must be > 0");
            }
        }
        match &self.recharge {
            RechargeSpec::Overnight { start_hour, end_hour, rate_frac_per_h } => {
                check_window("overnight", *start_hour, *end_hour)?;
                ensure!(*rate_frac_per_h >= 0.0, "overnight rate_frac_per_h must be >= 0");
            }
            RechargeSpec::Solar { trace } => {
                ensure!(!trace.is_empty(), "solar trace must not be empty");
                ensure!(
                    trace.windows(2).all(|w| w[0].0 <= w[1].0),
                    "solar trace hours must be sorted ascending"
                );
                // rate_at interpolates within one day and wraps from the
                // last point back to the first; an out-of-range hour
                // would extrapolate with a negative parameter instead.
                ensure!(
                    trace.iter().all(|(h, _)| (0.0..24.0).contains(h)),
                    "solar trace hours must be in [0, 24) (encode midnight as 0)"
                );
            }
            _ => {}
        }
        let o = &self.overrides;
        for (key, v) in [
            ("idle_drain_per_hour", o.idle_drain_per_hour),
            ("busy_drain_per_hour", o.busy_drain_per_hour),
        ] {
            if let Some(v) = v {
                ensure!(v >= 0.0, "override {key} must be >= 0");
            }
        }
        for (key, v) in [
            ("busy_probability", o.busy_probability),
            ("min_init_battery", o.min_init_battery),
            ("max_init_battery", o.max_init_battery),
        ] {
            if let Some(v) = v {
                ensure!((0.0..=1.0).contains(&v), "override {key} must be in [0,1]");
            }
        }
        Ok(())
    }

    /// Apply the scenario's device overrides onto an experiment config
    /// (before `validate`, so the combined result is still checked).
    pub fn apply_overrides(&self, cfg: &mut ExperimentConfig) {
        let o = &self.overrides;
        let d = &mut cfg.devices;
        if let Some(v) = o.idle_drain_per_hour {
            d.idle_drain_per_hour = v;
        }
        if let Some(v) = o.busy_drain_per_hour {
            d.busy_drain_per_hour = v;
        }
        if let Some(v) = o.busy_probability {
            d.busy_probability = v;
        }
        if let Some(v) = o.min_init_battery {
            d.min_init_battery = v;
        }
        if let Some(v) = o.max_init_battery {
            d.max_init_battery = v;
        }
    }

    /// Instantiate the runtime models for one experiment. `seed` must
    /// derive from the experiment seeds only (the coordinator's job),
    /// `num_clients` sizes trace generation, and `dev` backs the
    /// `FromConfig` recharge choice.
    pub fn build_env(
        &self,
        seed: u64,
        num_clients: usize,
        dev: &DeviceConfig,
    ) -> ScenarioEnv {
        let availability: Box<dyn AvailabilityModel> = match &self.availability {
            AvailabilitySpec::AlwaysOn => Box::new(AlwaysOn),
            AvailabilitySpec::Diurnal {
                peak_hour,
                min_available,
                max_available,
                phase_jitter_h,
            } => Box::new(DiurnalAvailability {
                seed: seed ^ 0xA11A_B177,
                peak_hour: *peak_hour,
                min_available: *min_available,
                max_available: *max_available,
                phase_jitter_h: *phase_jitter_h,
            }),
            AvailabilitySpec::Trace { period_h, slot_h, duty_cycle, churn } => {
                Box::new(TraceAvailability::generate(
                    seed ^ 0x7124_CE00,
                    num_clients,
                    *period_h,
                    *slot_h,
                    *duty_cycle,
                    *churn,
                ))
            }
        };
        let network: Box<dyn NetworkModel> = match &self.network {
            NetworkSpec::Static => Box::new(StaticNetwork),
            NetworkSpec::DegradedTail { fraction, factor } => Box::new(DegradedTail {
                seed: seed ^ 0x0E77_0A1C,
                fraction: *fraction,
                factor: *factor,
            }),
            NetworkSpec::Congestion { start_hour, end_hour, factor } => {
                Box::new(CongestionWindow {
                    start_hour: *start_hour,
                    end_hour: *end_hour,
                    factor: *factor,
                })
            }
        };
        let recharge: Box<dyn RechargePolicy> = match &self.recharge {
            RechargeSpec::FromConfig => recharge_policy_from(dev),
            RechargeSpec::None => Box::new(NoRecharge),
            RechargeSpec::Overnight { start_hour, end_hour, rate_frac_per_h } => {
                Box::new(OvernightRecharge {
                    start_hour: *start_hour,
                    end_hour: *end_hour,
                    rate_frac_per_h: *rate_frac_per_h,
                })
            }
            RechargeSpec::Solar { trace } => {
                Box::new(SolarRecharge { trace: trace.clone() })
            }
        };
        ScenarioEnv { name: self.name.clone(), availability, network, recharge }
    }
}

/// Default daylight curve for the `solar-edge` preset (fraction of
/// battery capacity harvested per hour).
fn default_solar_trace() -> Vec<(f64, f64)> {
    vec![(0.0, 0.0), (6.0, 0.0), (9.0, 0.12), (13.0, 0.3), (17.0, 0.12), (19.0, 0.0)]
}

/// A scenario instantiated for one experiment: the concrete models the
/// engine consults every round.
pub struct ScenarioEnv {
    pub name: String,
    pub availability: Box<dyn AvailabilityModel>,
    pub network: Box<dyn NetworkModel>,
    pub recharge: Box<dyn RechargePolicy>,
}

impl ScenarioEnv {
    /// The baseline environment (always-on, static links, config
    /// recharge) — what every pre-scenario experiment ran under.
    pub fn steady(dev: &DeviceConfig) -> Self {
        Scenario::preset("steady")
            .expect("steady preset exists")
            .build_env(0, 0, dev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SelectorKind;

    #[test]
    fn hash01_is_deterministic_and_bounded() {
        for seed in [0u64, 1, u64::MAX] {
            for a in 0..50u64 {
                let v = hash01(seed, a, 7);
                assert_eq!(v, hash01(seed, a, 7));
                assert!((0.0..1.0).contains(&v), "hash01 out of range: {v}");
            }
        }
        // Different inputs decorrelate.
        assert_ne!(hash01(1, 2, 3), hash01(1, 2, 4));
        assert_ne!(hash01(1, 2, 3), hash01(2, 2, 3));
    }

    #[test]
    fn every_preset_resolves_and_validates() {
        for name in PRESET_NAMES {
            let s = Scenario::resolve(name).unwrap();
            assert_eq!(s.name, name);
            s.validate().unwrap();
        }
        assert_eq!(Scenario::presets().len(), PRESET_NAMES.len());
        // Empty arg means steady.
        assert_eq!(Scenario::resolve("").unwrap().name, "steady");
        assert_eq!(Scenario::resolve("  ").unwrap().name, "steady");
    }

    #[test]
    fn unknown_scenario_is_a_helpful_error() {
        let err = Scenario::resolve("no-such-thing").unwrap_err().to_string();
        assert!(err.contains("no-such-thing"));
        assert!(err.contains("steady"), "error should list presets: {err}");
    }

    #[test]
    fn presets_roundtrip_through_toml() {
        for preset in Scenario::presets() {
            let text = preset.to_toml();
            let back = Scenario::from_toml(&text).unwrap();
            assert_eq!(back, preset, "roundtrip broke preset {}", preset.name);
        }
    }

    #[test]
    fn partial_toml_defaults_to_steady_shape() {
        let s = Scenario::from_toml("name = \"just-named\"\n").unwrap();
        assert_eq!(s.availability, AvailabilitySpec::AlwaysOn);
        assert_eq!(s.network, NetworkSpec::Static);
        assert_eq!(s.recharge, RechargeSpec::FromConfig);

        let s = Scenario::from_toml(
            "[availability]\nkind = \"diurnal\"\nmin_available = 0\nmax_available = 0\n",
        )
        .unwrap();
        match s.availability {
            AvailabilitySpec::Diurnal { min_available, max_available, .. } => {
                assert_eq!(min_available, 0.0);
                assert_eq!(max_available, 0.0);
            }
            other => panic!("expected diurnal, got {other:?}"),
        }
    }

    #[test]
    fn bad_toml_is_rejected() {
        assert!(Scenario::from_toml("[availability]\nkind = \"bogus\"\n").is_err());
        assert!(Scenario::from_toml("[network]\nkind = \"bogus\"\n").is_err());
        assert!(Scenario::from_toml("[recharge]\nkind = \"bogus\"\n").is_err());
        // min > max availability.
        assert!(Scenario::from_toml(
            "[availability]\nkind = \"diurnal\"\nmin_available = 0.9\nmax_available = 0.1\n"
        )
        .is_err());
        // Mismatched solar arrays.
        assert!(Scenario::from_toml(
            "[recharge]\nkind = \"solar\"\ntrace_hours = [1, 2]\ntrace_rates = [0.1]\n"
        )
        .is_err());
        // One array without the other must not silently fall back to
        // the default curve.
        assert!(Scenario::from_toml(
            "[recharge]\nkind = \"solar\"\ntrace_hours = [6, 12, 18]\n"
        )
        .is_err());
        // Solar hours outside one day: rate_at wraps at 24, so a 28
        // would interpolate with a negative parameter — rejected.
        assert!(Scenario::from_toml(
            "[recharge]\nkind = \"solar\"\ntrace_hours = [20, 28]\ntrace_rates = [0.1, 0.2]\n"
        )
        .is_err());
        // Daily windows wrap via start > end; hours >= 24 would be
        // silently clipped, so they are rejected too.
        assert!(Scenario::from_toml(
            "[recharge]\nkind = \"overnight\"\nstart_hour = 22\nend_hour = 30\n"
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[network]\nkind = \"congestion\"\nstart_hour = 17\nend_hour = 25\n"
        )
        .is_err());
        // The wrap encoding itself is fine.
        assert!(Scenario::from_toml(
            "[recharge]\nkind = \"overnight\"\nstart_hour = 22\nend_hour = 6\n"
        )
        .is_ok());
    }

    #[test]
    fn overrides_apply_onto_config() {
        let mut s = Scenario::preset("solar-edge").unwrap();
        s.overrides.busy_probability = Some(0.7);
        let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        s.apply_overrides(&mut cfg);
        assert_eq!(cfg.devices.idle_drain_per_hour, 0.008);
        assert_eq!(cfg.devices.busy_drain_per_hour, 0.05);
        assert_eq!(cfg.devices.busy_probability, 0.7);
        cfg.validate().unwrap();
    }

    #[test]
    fn build_env_matches_spec_choices() {
        let dev = ExperimentConfig::smoke(SelectorKind::Eafl).devices;
        let steady = Scenario::preset("steady").unwrap().build_env(1, 10, &dev);
        assert_eq!(steady.availability.name(), "always-on");
        assert_eq!(steady.network.name(), "static");
        assert!(steady.network.is_static());

        let commuter = Scenario::preset("commuter").unwrap().build_env(1, 10, &dev);
        assert_eq!(commuter.availability.name(), "trace");
        assert_eq!(commuter.network.name(), "congestion");
        assert_eq!(commuter.recharge.name(), "overnight");

        let solar = Scenario::preset("solar-edge").unwrap().build_env(1, 10, &dev);
        assert_eq!(solar.network.name(), "degraded-tail");
        assert_eq!(solar.recharge.name(), "solar");

        // FromConfig honours the device knobs.
        let mut dev2 = dev.clone();
        dev2.recharge_after_hours = 2.0;
        let env = Scenario::preset("steady").unwrap().build_env(1, 10, &dev2);
        assert_eq!(env.recharge.name(), "cooldown");
    }

    #[test]
    fn steady_env_admits_everyone() {
        let dev = ExperimentConfig::smoke(SelectorKind::Eafl).devices;
        let env = ScenarioEnv::steady(&dev);
        for id in 0..100 {
            assert!(env.availability.available(id, 3.7));
        }
    }
}
