//! Deterministic analytic [`ModelRuntime`] stand-in.
//!
//! Used by unit/property tests and the coordinator-only criterion
//! benches so they measure *coordinator* cost, not XLA compile/execute.
//! The loss trajectory follows an exponential decay toward an
//! irreducible floor, modulated per-example by a cheap hash so that
//! Oort's statistical utility still sees client-to-client variance.
//! "Accuracy" rises as loss falls. NOT a learning model — a fixture.

use anyhow::{ensure, Result};

use super::{EvalOutput, ModelRuntime, TrainOutput};

/// Analytic mock runtime. `strength` scales how fast loss decays per
/// step; `floor` is the irreducible loss.
pub struct MockRuntime {
    pub param_count: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub num_classes: usize,
    pub input_hw: usize,
    pub strength: f32,
    pub floor: f32,
}

impl Default for MockRuntime {
    fn default() -> Self {
        Self {
            // Matches the real manifest so shard/batch plumbing is
            // exercised with authentic sizes.
            param_count: 69_123,
            train_batch: 20,
            eval_batch: 128,
            num_classes: 35,
            input_hw: 32,
            strength: 0.04,
            floor: 0.35,
        }
    }
}

impl MockRuntime {
    /// Tiny variant for fast property tests (small P, small batches).
    pub fn tiny() -> Self {
        Self {
            param_count: 16,
            train_batch: 4,
            eval_batch: 8,
            num_classes: 5,
            input_hw: 4,
            strength: 0.08,
            floor: 0.2,
        }
    }

    /// Loss is carried in params[0] (initialized to ln C — a uniform
    /// predictor); the remaining slots are inert ballast so the vector
    /// has realistic size. Reads are clamped so server-side optimizers
    /// (YoGi momentum) can overshoot without breaking the fixture.
    fn current_loss(&self, params: &[f32]) -> f32 {
        let lmax = (self.num_classes as f32).ln();
        params[0].clamp(self.floor * 0.5, lmax * 2.0)
    }

    fn hash01(x: u32) -> f32 {
        // xorshift-style scramble -> [0, 1)
        let mut h = x.wrapping_mul(0x9E37_79B9) ^ 0x85EB_CA6B;
        h ^= h >> 13;
        h = h.wrapping_mul(0xC2B2_AE35);
        h ^= h >> 16;
        (h as f32) / (u32::MAX as f32)
    }
}

impl ModelRuntime for MockRuntime {
    fn param_count(&self) -> usize {
        self.param_count
    }
    fn train_batch(&self) -> usize {
        self.train_batch
    }
    fn eval_batch(&self) -> usize {
        self.eval_batch
    }
    fn num_classes(&self) -> usize {
        self.num_classes
    }
    fn input_hw(&self) -> usize {
        self.input_hw
    }

    fn init_params(&self, seed: u32) -> Result<Vec<f32>> {
        let mut p = vec![0.0; self.param_count];
        p[0] = (self.num_classes as f32).ln(); // uniform-predictor loss
        if self.param_count > 1 {
            p[1] = seed as f32; // seed marker, keeps runs distinguishable
        }
        Ok(p)
    }

    fn train_step(&self, params: &[f32], x: &[f32], y: &[i32], lr: f32) -> Result<TrainOutput> {
        ensure!(params.len() == self.param_count, "params length");
        ensure!(x.len() == self.train_batch * self.input_hw * self.input_hw, "x length");
        ensure!(y.len() == self.train_batch, "y length");
        let loss = self.current_loss(params);
        // Exponential decay toward the floor, scaled by lr relative to
        // the paper's 0.05 so lr sweeps still do something.
        let rate = self.strength * (lr / 0.05);
        let new_loss = self.floor + (loss - self.floor) * (1.0 - rate).max(0.0);
        let mut new_params = params.to_vec();
        new_params[0] = new_loss;
        let per_example: Vec<f32> = y
            .iter()
            .enumerate()
            .map(|(i, &label)| {
                // +-30% per-example spread keyed on label and position.
                let jitter = 0.7 + 0.6 * Self::hash01(label as u32 ^ ((i as u32) << 8));
                new_loss * jitter
            })
            .collect();
        let mean = per_example.iter().sum::<f32>() / per_example.len() as f32;
        Ok(TrainOutput { params: new_params, mean_loss: mean, per_example_loss: per_example })
    }

    fn eval_step(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOutput> {
        ensure!(params.len() == self.param_count, "params length");
        ensure!(x.len() == self.eval_batch * self.input_hw * self.input_hw, "x length");
        ensure!(y.len() == self.eval_batch, "y length");
        let loss = self.current_loss(params);
        let lmax = (self.num_classes as f32).ln();
        // Map loss in [floor, ln C] to accuracy in [1/C, ~0.95].
        let frac = ((lmax - loss) / (lmax - self.floor)).clamp(0.0, 1.0);
        let acc = (1.0 / self.num_classes as f32) + frac * 0.92;
        let correct = ((self.eval_batch as f32) * acc).round() as i32;
        Ok(EvalOutput { correct: correct.min(self.eval_batch as i32), mean_loss: loss })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_decays_monotonically_to_floor() {
        let rt = MockRuntime::default();
        let mut p = rt.init_params(0).unwrap();
        let x = vec![0.0; rt.train_batch * rt.input_hw * rt.input_hw];
        let y = vec![1i32; rt.train_batch];
        let mut last = f32::MAX;
        for _ in 0..400 {
            let out = rt.train_step(&p, &x, &y, 0.05).unwrap();
            assert!(out.params[0] <= last);
            last = out.params[0];
            p = out.params;
        }
        assert!((last - rt.floor).abs() < 0.05, "loss {last} should approach floor");
    }

    #[test]
    fn accuracy_rises_with_training() {
        let rt = MockRuntime::default();
        let mut p = rt.init_params(0).unwrap();
        let x = vec![0.0; rt.train_batch * rt.input_hw * rt.input_hw];
        let y = vec![1i32; rt.train_batch];
        let xe = vec![0.0; rt.eval_batch * rt.input_hw * rt.input_hw];
        let ye = vec![1i32; rt.eval_batch];
        let before = rt.eval_step(&p, &xe, &ye).unwrap();
        for _ in 0..200 {
            p = rt.train_step(&p, &x, &y, 0.05).unwrap().params;
        }
        let after = rt.eval_step(&p, &xe, &ye).unwrap();
        assert!(after.correct > before.correct);
        assert!(after.mean_loss < before.mean_loss);
    }

    #[test]
    fn per_example_losses_have_variance() {
        let rt = MockRuntime::default();
        let p = rt.init_params(0).unwrap();
        let x = vec![0.0; rt.train_batch * rt.input_hw * rt.input_hw];
        let y: Vec<i32> = (0..rt.train_batch as i32).collect();
        let out = rt.train_step(&p, &x, &y, 0.05).unwrap();
        let mn = out.per_example_loss.iter().cloned().fold(f32::MAX, f32::min);
        let mx = out.per_example_loss.iter().cloned().fold(f32::MIN, f32::max);
        assert!(mx > mn, "per-example losses must not be constant");
    }

    #[test]
    fn shape_validation_errors() {
        let rt = MockRuntime::tiny();
        let p = rt.init_params(0).unwrap();
        assert!(rt.train_step(&p, &[0.0; 3], &[0; 4], 0.05).is_err());
        assert!(rt.eval_step(&p[..4], &[0.0; 128], &[0; 8]).is_err());
    }
}
