//! Event-driven round simulation.
//!
//! The paper (§5): "This is an event-driven simulation with time
//! calculated based on the completion time of the learners." Within a
//! round, every participant's download → compute → upload timeline and
//! possible mid-round battery death are resolved in event order on a
//! deterministic event queue; the round's wall-clock duration falls out
//! of the latest relevant event.

mod events;
mod round;

pub use events::{Event, EventQueue};
pub use round::{
    simulate_round, FailureKind, ParticipantPlan, ParticipantResult, RoundSimOutcome,
};
