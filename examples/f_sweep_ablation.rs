//! Ablation over EAFL's f (Eq. 1 blend weight) — the paper's §3.1 Q2
//! trade-off between model quality and energy efficiency.
//!
//! Sweeps f ∈ {0, 0.25, 0.5, 0.75, 1.0} under identical seeds as ONE
//! campaign (see `eafl::campaign`): the runs execute across threads and
//! merge into a single campaign.json/.csv under --out.
//!  - f = 0    → pure battery chasing (selection ignores utility),
//!  - f = 0.25 → the paper's operating point,
//!  - f = 1    → pure Oort (battery-oblivious).
//!
//! Expected shape: drop-outs increase with f; time-to-accuracy improves
//! with f until drop-outs erase the gain.
//!
//! Run: cargo run --release --example f_sweep_ablation -- \
//!          [--mock] [--rounds N] [--jobs N] [--out DIR]

use std::path::PathBuf;

use anyhow::Result;

use eafl::campaign::{run_campaign, CampaignGrid, CampaignSpec};
use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::runtime::{MockRuntime, ModelRuntime, XlaRuntime};

fn flag<T: std::str::FromStr>(args: &[String], name: &str) -> Option<T> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(|v| {
        v.parse()
            .unwrap_or_else(|_| panic!("invalid {name} value {v:?} (expected {name} N)"))
    })
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let use_mock = args.iter().any(|a| a == "--mock");
    let rounds = flag::<usize>(&args, "--rounds").unwrap_or(if use_mock { 150 } else { 60 });
    let out = PathBuf::from(flag::<String>(&args, "--out").unwrap_or_else(|| "results/fsweep".into()));

    let runtime: Box<dyn ModelRuntime> = if use_mock {
        Box::new(MockRuntime::default())
    } else {
        Box::new(XlaRuntime::load(&XlaRuntime::default_dir())?)
    };

    let mut cfg = ExperimentConfig::paper_default(SelectorKind::Eafl);
    cfg.federation.rounds = rounds;
    cfg.federation.num_clients = 100;
    // Battery-tight scenario so the energy term has bite.
    cfg.devices.min_init_battery = 0.15;
    cfg.devices.max_init_battery = 0.7;

    let mut spec = CampaignSpec::new("fsweep", cfg);
    spec.grid = CampaignGrid {
        selectors: vec![SelectorKind::Eafl],
        scenarios: Vec::new(),
        seeds: vec![spec.base.data.seed],
        f_values: vec![0.0, 0.25, 0.5, 0.75, 1.0],
        client_counts: Vec::new(),
    };
    if let Some(j) = flag::<usize>(&args, "--jobs") {
        spec.jobs = j.max(1);
    }

    let report = run_campaign(&spec, runtime.as_ref(), Some(&out))?;

    println!(
        "{:<6} {:>9} {:>9} {:>10} {:>12} {:>10} {:>12}",
        "f", "acc", "fairness", "dropouts", "mean_rnd(s)", "wall(h)", "energy(kJ)"
    );
    for r in &report.runs {
        let s = &r.summary;
        println!(
            "{:<6} {:>9.4} {:>9.3} {:>10} {:>12.1} {:>10.2} {:>12.1}",
            r.f,
            s.final_accuracy,
            s.final_fairness,
            s.total_dropouts,
            s.mean_round_duration_s,
            s.wall_clock_h,
            s.total_fl_energy_j / 1000.0
        );
    }
    println!(
        "\nmerged campaign summary: {}",
        out.join(format!("{}.campaign.json", report.name)).display()
    );
    Ok(())
}
