//! END-TO-END DRIVER — the full system on a real workload.
//!
//! Proves all three layers compose: the Rust coordinator (L3) drives
//! real SGD through the AOT-compiled XLA executables (L2) whose dense /
//! softmax-xent hot paths are Pallas kernels (L1), over the synthetic
//! speech-commands federation, for the paper's full §5 configuration
//! (500 rounds, 200 clients, K=10, lr=0.05, B=20, f=0.25, non-IID
//! 4-of-35 labels), for all three selectors under identical seeds.
//!
//! Regenerates Figs. 3a/3b/3c and 4a/4b as CSV series in results/e2e/
//! and prints the headline comparison. Recorded in EXPERIMENTS.md.
//!
//! Run: cargo run --release --example e2e_speech_training -- \
//!          [--rounds N] [--clients N] [--out DIR]

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::Coordinator;
use eafl::metrics::Summary;
use eafl::runtime::XlaRuntime;

fn flag<T: std::str::FromStr>(args: &[String], name: &str) -> Option<T> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rounds: usize = flag(&args, "--rounds").unwrap_or(500); // paper §5
    let clients: usize = flag(&args, "--clients").unwrap_or(200);
    let out = PathBuf::from(
        flag::<String>(&args, "--out").unwrap_or_else(|| "results/e2e".into()),
    );
    std::fs::create_dir_all(&out)?;

    println!("loading AOT artifacts (L1 Pallas kernels inside L2 XLA executables)...");
    let t0 = Instant::now();
    let runtime = XlaRuntime::load(&XlaRuntime::default_dir())?;
    println!("compiled 3 executables in {:.1}s\n", t0.elapsed().as_secs_f64());

    let mut summaries: Vec<(Summary, f64)> = Vec::new();
    let mut logs: Vec<eafl::metrics::MetricsLog> = Vec::new();
    for kind in [SelectorKind::Eafl, SelectorKind::Oort, SelectorKind::Random] {
        let mut cfg = ExperimentConfig::paper_default(kind);
        cfg.name = format!("e2e-{kind}");
        cfg.federation.rounds = rounds;
        cfg.federation.num_clients = clients;
        // Battery-constrained scenario (the paper's motivating regime):
        // tight initial charge so FL-driven drain — not background
        // usage — decides who survives, and a harder dataset so the
        // drop-out phase overlaps convergence.
        cfg.data.noise_std = 2.5;
        cfg.devices.min_init_battery = 0.05;
        cfg.devices.max_init_battery = 0.45;
        cfg.devices.idle_drain_per_hour = 0.002;
        cfg.devices.busy_drain_per_hour = 0.01;
        cfg.validate()?;

        println!("=== {kind}: {clients} clients, {rounds} rounds ===");
        let t = Instant::now();
        let coordinator = Coordinator::new(cfg, &runtime)?;
        let log = coordinator.run()?;
        let elapsed = t.elapsed().as_secs_f64();

        log.write_csv(&out.join(format!("e2e-{kind}.csv")))?;
        log.write_summary_json(&out.join(format!("e2e-{kind}.summary.json")))?;

        // Print the loss curve at a readable cadence.
        println!("round  wall(h)  acc     train_loss  dropouts  fairness");
        let stride = (log.records.len() / 12).max(1);
        for r in log.records.iter().step_by(stride) {
            println!(
                "{:>5}  {:>7.2}  {:.4}  {:>10.4}  {:>8}  {:.3}",
                r.round, r.wall_clock_h, r.test_accuracy, r.train_loss,
                r.cumulative_dead, r.fairness
            );
        }
        if let Some(last) = log.records.last() {
            println!(
                "{:>5}  {:>7.2}  {:.4}  {:>10.4}  {:>8}  {:.3}   (final)",
                last.round, last.wall_clock_h, last.test_accuracy, last.train_loss,
                last.cumulative_dead, last.fairness
            );
        }
        println!("({elapsed:.1}s real time)\n");
        summaries.push((log.summary(), elapsed));
        logs.push(log);
    }

    println!("=== headline comparison (paper Figs. 3-4) ===");
    println!(
        "{:<12} {:>8} {:>8} {:>10} {:>10} {:>12} {:>10}",
        "selector", "acc", "best", "dropouts", "fairness", "mean_rnd(s)", "wall(h)"
    );
    for (s, _) in &summaries {
        println!(
            "{:<12} {:>8.4} {:>8.4} {:>10} {:>10.3} {:>12.1} {:>10.2}",
            s.name.trim_start_matches("e2e-"),
            s.final_accuracy,
            s.best_accuracy,
            s.total_dropouts,
            s.final_fairness,
            s.mean_round_duration_s,
            s.wall_clock_h
        );
    }

    // Matched-wall-clock comparison (how the paper's Fig. 4a is read):
    // drop-outs at common time points, and the peak Oort/EAFL ratio.
    let dead_at = |log: &eafl::metrics::MetricsLog, t_h: f64| -> usize {
        log.records
            .iter()
            .take_while(|r| r.wall_clock_h <= t_h)
            .last()
            .map_or(0, |r| r.cumulative_dead)
    };
    let horizon = logs
        .iter()
        .map(|l| l.records.last().map_or(0.0, |r| r.wall_clock_h))
        .fold(f64::MAX, f64::min);
    let mut peak_ratio: f64 = 0.0;
    println!("\ndrop-outs at matched wall-clock (Fig. 4a reading):");
    println!("{:<8} {:>8} {:>8} {:>8}", "t(h)", "eafl", "oort", "random");
    let mut t_h = horizon / 8.0;
    while t_h <= horizon + 1e-9 {
        let e = dead_at(&logs[0], t_h);
        let o = dead_at(&logs[1], t_h);
        let r = dead_at(&logs[2], t_h);
        if e > 0 {
            peak_ratio = peak_ratio.max(o as f64 / e as f64);
        }
        println!("{:<8.1} {:>8} {:>8} {:>8}", t_h, e, o, r);
        t_h += horizon / 8.0;
    }
    let eafl = &summaries[0].0;
    let oort = &summaries[1].0;
    println!(
        "\npeak drop-out reduction vs Oort: {peak_ratio:.2}x (paper claims up to 2.45x)"
    );
    if oort.final_accuracy > 0.0 {
        println!(
            "accuracy improvement vs Oort: {:+.1}% (paper claims up to +85%; see\n\
             EXPERIMENTS.md — the synthetic dataset compresses accuracy gaps)",
            (eafl.final_accuracy / oort.final_accuracy - 1.0) * 100.0
        );
    }
    let _ = eafl;
    println!("\nseries written to {out:?} (fig3a=test_accuracy, fig3b=train_loss,");
    println!("fig3c=fairness, fig4a=cumulative_dead, fig4b=round_duration_s columns)");
    Ok(())
}
