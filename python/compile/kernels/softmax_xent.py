"""Layer-1 Pallas kernel: fused, numerically-stable softmax cross-entropy.

Produces the PER-EXAMPLE loss vector — this is what feeds Oort/EAFL's
statistical utility (Eq. 2 needs sqrt(mean(loss^2)) over a client's
samples), so it is a first-class output of the train/eval steps rather
than a scalar-only reduction.

Single-block kernel: the (B, C) logits tile is tiny for this model
(B<=128, C=35 padded to the 128-lane boundary), so one program instance
holds everything in VMEM; the fusion (max, exp, sum, log, dot with the
one-hot) avoids materializing softmax probabilities in HBM.

Like `dense`, wrapped in a custom_vjp (softmax(logits) - onehot, scaled
by the incoming cotangent) because pallas_call has no autodiff rule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _xent_kernel(logits_ref, onehot_ref, mask_ref, o_ref):
    """Per-example xent over one (B, Cp) block; mask kills pad columns."""
    logits = logits_ref[...]
    onehot = onehot_ref[...]
    mask = mask_ref[...][None, :]  # 1.0 on real classes, 0.0 on padding
    neg_inf = jnp.float32(-1e30)
    masked = jnp.where(mask > 0.0, logits, neg_inf)
    m = jnp.max(masked, axis=-1, keepdims=True)
    shifted = jnp.where(mask > 0.0, masked - m, neg_inf)
    lse = jnp.log(jnp.sum(jnp.exp(shifted) * mask, axis=-1)) + m[:, 0]
    o_ref[...] = lse - jnp.sum(onehot * logits * mask, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def softmax_xent_fwd_kernel(logits, onehot, interpret: bool = True):
    """Raw fused kernel: per-example cross-entropy f32[B]."""
    b, c = logits.shape
    cp = _round_up(c, _LANE)
    lp = jnp.pad(logits, ((0, 0), (0, cp - c)))
    op = jnp.pad(onehot, ((0, 0), (0, cp - c)))
    mask = jnp.pad(jnp.ones((c,), jnp.float32), (0, cp - c))
    return pl.pallas_call(
        _xent_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(lp, op, mask)


@jax.custom_vjp
def softmax_xent(logits, onehot):
    """Differentiable fused per-example softmax cross-entropy."""
    return softmax_xent_fwd_kernel(logits, onehot)


def _xent_vjp_fwd(logits, onehot):
    loss = softmax_xent_fwd_kernel(logits, onehot)
    return loss, (logits, onehot)


def _xent_vjp_bwd(res, g):
    logits, onehot = res
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    dlogits = (probs - onehot) * g[:, None]
    return dlogits, jnp.zeros_like(onehot)


softmax_xent.defvjp(_xent_vjp_fwd, _xent_vjp_bwd)
