"""Layer-2 JAX model: compact speech-commands CNN with a FLAT parameter
interface, calling the Layer-1 Pallas kernels for its dense hot path.

The paper trains a ResNet on Google Speech Commands (35 labels). Per
DESIGN.md §2 we substitute a compact CNN over 32x32 log-mel-like feature
maps so that REAL per-client SGD runs inside the Rust simulator on CPU.
Selection dynamics (what EAFL/Oort observe) depend on per-client losses
and timings, not on model capacity.

Flat-parameter convention: every exported function takes/returns the
model parameters as ONE ``f32[P]`` vector, so the Rust coordinator
handles exactly one array per direction (see rust/src/runtime). The
packing order is PARAM_SPEC below; `python -m compile.aot` writes it to
artifacts/manifest.json for the Rust side.

Exported (AOT-lowered by compile/aot.py):
  train_step(flat, x, y, lr) -> (flat', mean_loss, per_example_loss)
  eval_step(flat, x, y)      -> (correct_count, mean_loss)
  init_params(seed)          -> flat
"""

import math

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.dense import dense
from .kernels.softmax_xent import softmax_xent

# --- Model geometry ---------------------------------------------------------

NUM_CLASSES = 35  # Google Speech Commands v2 label count
INPUT_HW = 32     # feature map side (log-mel-like)
_C1, _C2 = 8, 16  # conv channels
_FLAT = (INPUT_HW // 4) * (INPUT_HW // 4) * _C2  # after two 2x2 maxpools
_HIDDEN = 64

#: (name, shape) in flat-packing order. Keep in sync with rust runtime
#: via artifacts/manifest.json — never reorder without regenerating.
PARAM_SPEC = [
    ("conv1_w", (3, 3, 1, _C1)),
    ("conv1_b", (_C1,)),
    ("conv2_w", (3, 3, _C1, _C2)),
    ("conv2_b", (_C2,)),
    ("dense1_w", (_FLAT, _HIDDEN)),
    ("dense1_b", (_HIDDEN,)),
    ("dense2_w", (_HIDDEN, NUM_CLASSES)),
    ("dense2_b", (NUM_CLASSES,)),
]

PARAM_COUNT = sum(math.prod(s) for _, s in PARAM_SPEC)


def unflatten(flat):
    """Split the flat f32[P] vector into the PARAM_SPEC dict."""
    params, off = {}, 0
    for name, shape in PARAM_SPEC:
        size = math.prod(shape)
        params[name] = lax.dynamic_slice_in_dim(flat, off, size).reshape(shape)
        off += size
    return params


def flatten(params):
    """Inverse of unflatten."""
    return jnp.concatenate([params[n].reshape(-1) for n, _ in PARAM_SPEC])


# --- Forward pass -----------------------------------------------------------


def _conv_block(x, w, b):
    """3x3 same-conv + bias + relu + 2x2 maxpool (NHWC/HWIO)."""
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = jnp.maximum(y + b[None, None, None, :], 0.0)
    return lax.reduce_window(
        y, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(flat, x):
    """Logits f32[B, NUM_CLASSES] for inputs x f32[B, 32, 32, 1]."""
    p = unflatten(flat)
    h = _conv_block(x, p["conv1_w"], p["conv1_b"])
    h = _conv_block(h, p["conv2_w"], p["conv2_b"])
    h = h.reshape(h.shape[0], -1)
    h = dense(h, p["dense1_w"], p["dense1_b"], "relu")   # Pallas hot path
    return dense(h, p["dense2_w"], p["dense2_b"], "id")  # Pallas hot path


# --- Exported entry points --------------------------------------------------


def per_example_losses(flat, x, y):
    """Fused Pallas softmax-xent per example; y is i32[B] labels."""
    logits = forward(flat, x)
    onehot = jax.nn.one_hot(y, NUM_CLASSES, dtype=jnp.float32)
    return softmax_xent(logits, onehot)


def train_step(flat, x, y, lr):
    """One local SGD step.

    Returns (flat', mean_loss, per_example_loss); per-example losses feed
    Oort/EAFL's statistical utility (Eq. 2) in the Rust coordinator.
    """

    def loss_fn(f):
        per_ex = per_example_losses(f, x, y)
        return jnp.mean(per_ex), per_ex

    (mean_loss, per_ex), grads = jax.value_and_grad(loss_fn, has_aux=True)(flat)
    return flat - lr * grads, mean_loss, per_ex


def eval_step(flat, x, y):
    """Returns (correct_count i32, mean_loss f32) over one batch."""
    logits = forward(flat, x)
    onehot = jax.nn.one_hot(y, NUM_CLASSES, dtype=jnp.float32)
    loss = jnp.mean(softmax_xent(logits, onehot))
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
    return correct, loss


def init_params(seed):
    """He-initialized flat parameter vector from a u32 seed scalar."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in PARAM_SPEC:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            chunks.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = math.prod(shape[:-1])
            std = math.sqrt(2.0 / fan_in)
            chunks.append(
                (jax.random.normal(sub, shape, jnp.float32) * std).reshape(-1)
            )
    return jnp.concatenate(chunks)
