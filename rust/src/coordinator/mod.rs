//! Layer-3 coordinator — the FL server loop that is the paper's system
//! surface: client registry, per-round selection → dispatch → simulate
//! → train → aggregate → account energy → metrics.

mod registry;
mod server;

pub use registry::{ClientState, ClientStats, Registry};
pub use server::Coordinator;
