//! Paper Table 2 — the three representative devices and their measured
//! specs (average power from GFXBench, per the paper):
//!
//! | Device                         | Avg Power | Perf/W      | RAM | Battery |
//! |--------------------------------|-----------|-------------|-----|---------|
//! | Huawei Mate 10 (Kirin 970)     | 6.33 W    | 5.94 fps/W  | 4GB | 4000mAh |
//! | Nexus 6P (Snapdragon 810 v2.1) | 5.44 W    | 4.03 fps/W  | 3GB | 3450mAh |
//! | Huawei P9 (Kirin 955)          | 2.98 W    | 3.55 fps/W  | 3GB | 3000mAh |


/// Nominal Li-ion cell voltage used to convert mAh to energy.
pub const NOMINAL_VOLTAGE: f64 = 3.7;

/// Performance tier of an edge device (paper clusters AI-Benchmark
/// profiles into exactly these three).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    High,
    Mid,
    Low,
}

pub const ALL_TIERS: [Tier; 3] = [Tier::High, Tier::Mid, Tier::Low];

/// Static hardware specification for one device tier (Table 2 row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpec {
    pub tier: Tier,
    /// Representative handset name.
    pub model: &'static str,
    /// Average power draw under training load, watts (GFXBench).
    pub avg_power_w: f64,
    /// Throughput efficiency, fps/W (GFXBench); used to derive relative
    /// compute speed across tiers.
    pub perf_per_watt: f64,
    /// RAM in GB (informational; gates nothing in this model).
    pub ram_gb: f64,
    /// Battery capacity, mAh.
    pub battery_mah: f64,
}

impl DeviceSpec {
    /// Table 2 row for a tier.
    pub const fn for_tier(tier: Tier) -> Self {
        match tier {
            Tier::High => DeviceSpec {
                tier: Tier::High,
                model: "Huawei Mate 10 (Kirin 970)",
                avg_power_w: 6.33,
                perf_per_watt: 5.94,
                ram_gb: 4.0,
                battery_mah: 4000.0,
            },
            Tier::Mid => DeviceSpec {
                tier: Tier::Mid,
                model: "Nexus 6P (Snapdragon 810 v2.1)",
                avg_power_w: 5.44,
                perf_per_watt: 4.03,
                ram_gb: 3.0,
                battery_mah: 3450.0,
            },
            Tier::Low => DeviceSpec {
                tier: Tier::Low,
                model: "Huawei P9 (Kirin 955)",
                avg_power_w: 2.98,
                perf_per_watt: 3.55,
                ram_gb: 3.0,
                battery_mah: 3000.0,
            },
        }
    }

    /// Battery capacity in joules (mAh × 3.7 V × 3.6 J/mWh).
    pub fn battery_joules(&self) -> f64 {
        self.battery_mah * NOMINAL_VOLTAGE * 3.6
    }

    /// Effective training throughput proxy (fps): power × fps/W.
    /// Normalizing to the low tier gives each tier's relative speed.
    pub fn throughput_fps(&self) -> f64 {
        self.avg_power_w * self.perf_per_watt
    }

    /// Compute speed relative to the LOW tier (≥ 1.0).
    pub fn relative_speed(&self) -> f64 {
        self.throughput_fps() / DeviceSpec::for_tier(Tier::Low).throughput_fps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values_pinned() {
        let hi = DeviceSpec::for_tier(Tier::High);
        assert_eq!(hi.avg_power_w, 6.33);
        assert_eq!(hi.perf_per_watt, 5.94);
        assert_eq!(hi.battery_mah, 4000.0);
        let mid = DeviceSpec::for_tier(Tier::Mid);
        assert_eq!(mid.avg_power_w, 5.44);
        assert_eq!(mid.perf_per_watt, 4.03);
        assert_eq!(mid.battery_mah, 3450.0);
        let lo = DeviceSpec::for_tier(Tier::Low);
        assert_eq!(lo.avg_power_w, 2.98);
        assert_eq!(lo.perf_per_watt, 3.55);
        assert_eq!(lo.battery_mah, 3000.0);
    }

    #[test]
    fn battery_energy_conversion() {
        // 4000 mAh * 3.7 V = 14.8 Wh = 53 280 J
        let j = DeviceSpec::for_tier(Tier::High).battery_joules();
        assert!((j - 53_280.0).abs() < 1e-6);
    }

    #[test]
    fn tier_ordering_by_speed() {
        let hi = DeviceSpec::for_tier(Tier::High).relative_speed();
        let mid = DeviceSpec::for_tier(Tier::Mid).relative_speed();
        let lo = DeviceSpec::for_tier(Tier::Low).relative_speed();
        assert!(hi > mid && mid > lo);
        assert!((lo - 1.0).abs() < 1e-12);
    }
}
