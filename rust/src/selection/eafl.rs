//! EAFL participant selection — the paper's contribution (§4).
//!
//! Replaces Oort's pure-utility ranking with the Eq. (1) reward:
//!
//!   reward(i) = f · Util(i) + (1−f) · power(i)
//!   power(i)  = cur_battery_level(i) − battery_used(i)
//!
//! with Util(i) Oort's Eq. (2) utility min-max normalized over the
//! candidate pool so the two terms are commensurate ([0,1] each). As
//! f → 0 selection degenerates to "highest remaining battery"; as
//! f → 1 it degenerates to Oort. The paper's experiments use f = 0.25,
//! weighting energy conservation 3:1 over time-to-accuracy.
//!
//! Exploration of unmeasured clients and the pacer are inherited from
//! the Oort machinery (EAFL is a drop-in replacement for the reward
//! inside Oort's selector loop). Both the energy-weighted exploration
//! draw and the exploitation-band draw route through the ONE weighted
//! sampler — [`OortSelector::weighted_pick`], backed by the Fenwick
//! inverse-CDF sampler — which replaced this module's former inline
//! O(k·N) linear scan.

use crate::util::rng::Rng;

use crate::config::SelectorConfig;

use super::sampler::FenwickSampler;
use super::utility::{
    eafl_reward, min_max_normalize_in_place, oort_utility, power_term, staleness_bonus,
};
use super::{rank_top_band, Candidate, OortSelector, RoundFeedback, Selector};

pub struct EaflSelector {
    cfg: SelectorConfig,
    /// Inner Oort machinery reused for ε schedule + pacer state.
    oort: OortSelector,
    /// Reusable per-round scratch (candidate index partitions, the
    /// normalized-utility buffer, the weighted-draw pool, and the
    /// Fenwick sampler).
    explored_idx: Vec<u32>,
    unexplored_idx: Vec<u32>,
    utils: Vec<f64>,
    pool_scratch: Vec<(usize, f64)>,
    sampler: FenwickSampler,
}

impl EaflSelector {
    pub fn new(cfg: SelectorConfig) -> Self {
        let oort = OortSelector::new(cfg.clone());
        Self {
            cfg,
            oort,
            explored_idx: Vec::new(),
            unexplored_idx: Vec::new(),
            utils: Vec::new(),
            pool_scratch: Vec::new(),
            sampler: FenwickSampler::empty(),
        }
    }

    /// The select body with the round deadline already computed —
    /// shared by `select` and the single-percentile `plan` path.
    fn select_with_deadline(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        deadline: f64,
        rng: &mut Rng,
    ) -> Vec<usize> {
        if candidates.is_empty() || k == 0 {
            return Vec::new();
        }
        let eps = self.oort.epsilon(round);

        self.explored_idx.clear();
        self.unexplored_idx.clear();
        for (i, c) in candidates.iter().enumerate() {
            if c.stat_util.is_none() {
                self.unexplored_idx.push(i as u32);
            } else {
                self.explored_idx.push(i as u32);
            }
        }

        // Exploration — but energy-aware even here: prefer high-power
        // unexplored clients (weighted by the Eq. (1) power term),
        // drawn through the shared Fenwick sampler.
        let k_explore = ((eps * k as f64).round() as usize)
            .min(self.unexplored_idx.len())
            .min(k);
        let mut selected: Vec<usize> = if k_explore > 0 {
            self.pool_scratch.clear();
            for &i in &self.unexplored_idx {
                let c = &candidates[i as usize];
                self.pool_scratch.push((
                    c.id,
                    power_term(c.battery_frac, c.projected_drain_frac).max(1e-6),
                ));
            }
            OortSelector::weighted_pick(&mut self.sampler, &self.pool_scratch, k_explore, rng)
        } else {
            Vec::new()
        };

        // Exploitation by Eq. (1) reward: weighted draw from the top
        // reward band (Oort's randomized-cutoff idiom) rather than a
        // hard top-k — keeps near-ties rotating, which is what keeps
        // EAFL's Jain fairness at Random-like levels (paper Fig. 3c).
        let k_exploit = k - selected.len();
        if k_exploit > 0 && !self.explored_idx.is_empty() {
            self.utils.clear();
            for &i in &self.explored_idx {
                let c = &candidates[i as usize];
                let duration = c.measured_duration_s.unwrap_or(c.expected_duration_s);
                self.utils.push(oort_utility(
                    c.stat_util.unwrap_or(0.0),
                    deadline,
                    duration,
                    self.cfg.alpha,
                ));
            }
            min_max_normalize_in_place(&mut self.utils);
            self.pool_scratch.clear();
            for (&i, &u) in self.explored_idx.iter().zip(&self.utils) {
                let c = &candidates[i as usize];
                let power = power_term(c.battery_frac, c.projected_drain_frac);
                // Staleness bonus operates in normalized-reward space.
                let reward = eafl_reward(self.cfg.eafl_f, u, power)
                    + staleness_bonus(round, c.last_selected_round, self.cfg.ucb_weight)
                        * 0.25;
                self.pool_scratch.push((c.id, reward.max(1e-9)));
            }
            let band = ((k_exploit as f64) * 3.0).ceil() as usize;
            rank_top_band(&mut self.pool_scratch, band.max(k_exploit));
            selected.extend(OortSelector::weighted_pick(
                &mut self.sampler,
                &self.pool_scratch,
                k_exploit,
                rng,
            ));
        } else if k_exploit > 0 {
            // Cold-start fallback (no explored candidates yet, e.g. the
            // entire first round): still energy-aware. A uniform shuffle
            // here would make round 1 battery-blind — the one round
            // where every candidate is unexplored — so the fill routes
            // through the same power-weighted draw as the exploration
            // arm, excluding ids the exploration draw already took.
            self.pool_scratch.clear();
            for &i in &self.unexplored_idx {
                let c = &candidates[i as usize];
                if selected.contains(&c.id) {
                    continue;
                }
                self.pool_scratch.push((
                    c.id,
                    power_term(c.battery_frac, c.projected_drain_frac).max(1e-6),
                ));
            }
            selected.extend(OortSelector::weighted_pick(
                &mut self.sampler,
                &self.pool_scratch,
                k_exploit.min(self.pool_scratch.len()),
                rng,
            ));
        }
        selected
    }
}

impl Selector for EaflSelector {
    fn select(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let deadline = self.deadline_s(candidates);
        self.select_with_deadline(round, candidates, k, deadline, rng)
    }

    fn plan(
        &mut self,
        round: u64,
        candidates: &[Candidate],
        k: usize,
        rng: &mut Rng,
    ) -> (Vec<usize>, f64) {
        // One pacer-percentile pass serves both the reward computation
        // and the round deadline the engine needs.
        let deadline = self.deadline_s(candidates);
        let selected = self.select_with_deadline(round, candidates, k, deadline, rng);
        (selected, deadline)
    }

    fn feedback(&mut self, fb: &RoundFeedback<'_>) {
        self.oort.feedback(fb);
    }

    fn deadline_s(&mut self, candidates: &[Candidate]) -> f64 {
        // Same pacer (and scratch buffer) as Oort (Fig. 4b: EAFL and
        // Oort round durations are nearly identical early on).
        self.oort.deadline_s(candidates)
    }

    fn name(&self) -> &'static str {
        "eafl"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(id: usize, util: Option<f64>, dur: f64, battery: f64) -> Candidate {
        Candidate {
            id,
            stat_util: util,
            measured_duration_s: util.map(|_| dur),
            expected_duration_s: dur,
            last_selected_round: None,
            battery_frac: battery,
            projected_drain_frac: 0.02,
            round_energy_j: 50.0,
        }
    }

    fn exploit_cfg(f: f64) -> SelectorConfig {
        let mut cfg = SelectorConfig::default();
        cfg.explore_init = 0.0;
        cfg.min_explore = 0.0;
        cfg.ucb_weight = 0.0;
        cfg.eafl_f = f;
        cfg
    }

    #[test]
    fn f_zero_picks_highest_battery() {
        let mut s = EaflSelector::new(exploit_cfg(0.0));
        let cands = vec![
            cand(0, Some(100.0), 100.0, 0.10),
            cand(1, Some(1.0), 100.0, 0.95),
            cand(2, Some(50.0), 100.0, 0.50),
        ];
        let picked = s.select(10, &cands, 1, &mut Rng::seed_from_u64(0));
        assert_eq!(picked, vec![1], "f=0 must ignore utility entirely");
    }

    #[test]
    fn f_one_behaves_like_oort_ranking() {
        let mut s = EaflSelector::new(exploit_cfg(1.0));
        let cands = vec![
            cand(0, Some(100.0), 100.0, 0.05),
            cand(1, Some(1.0), 100.0, 1.00),
        ];
        let picked = s.select(10, &cands, 1, &mut Rng::seed_from_u64(0));
        assert_eq!(picked, vec![0], "f=1 must chase utility like Oort");
    }

    #[test]
    fn paper_f_025_prefers_power_on_close_utilities() {
        // With f=0.25 the power term carries 3x the weight: a modest
        // utility edge must not beat a large battery edge.
        let mut s = EaflSelector::new(exploit_cfg(0.25));
        let cands = vec![
            cand(0, Some(10.0), 100.0, 0.15), // slightly higher utility, low battery
            cand(1, Some(8.0), 100.0, 0.90),  // slightly lower utility, high battery
        ];
        let picked = s.select(10, &cands, 1, &mut Rng::seed_from_u64(0));
        assert_eq!(picked, vec![1]);
    }

    #[test]
    fn high_utility_wins_when_batteries_comparable() {
        let mut s = EaflSelector::new(exploit_cfg(0.25));
        let cands = vec![
            cand(0, Some(100.0), 100.0, 0.80),
            cand(1, Some(1.0), 100.0, 0.82),
        ];
        let picked = s.select(10, &cands, 1, &mut Rng::seed_from_u64(0));
        assert_eq!(picked, vec![0]);
    }

    #[test]
    fn exploration_weighted_toward_high_battery() {
        let mut cfg = SelectorConfig::default();
        cfg.explore_init = 1.0;
        cfg.explore_decay = 1.0;
        cfg.min_explore = 1.0;
        let mut s = EaflSelector::new(cfg);
        let cands = vec![cand(0, None, 100.0, 0.05), cand(1, None, 100.0, 0.95)];
        let mut high_battery_first = 0;
        for seed in 0..200 {
            let picked = s.select(1, &cands, 1, &mut Rng::seed_from_u64(seed));
            if picked == vec![1] {
                high_battery_first += 1;
            }
        }
        // power(1)≈0.93 vs power(0)≈0.03 ⇒ ~97% of draws pick id 1.
        assert!(high_battery_first > 150, "got {high_battery_first}/200");
    }

    #[test]
    fn cold_start_fallback_stays_battery_greedy() {
        // Regression: with ε forced to 0 and an all-unexplored pool
        // (the first round of every run), selection lands in the
        // fallback fill — which used to shuffle uniformly, ignoring
        // batteries. It must stay power-weighted, like the exploration
        // arm, for any f (the fallback has no utilities to blend).
        for f in [0.0, 0.25, 1.0] {
            let mut s = EaflSelector::new(exploit_cfg(f));
            let cands = vec![cand(0, None, 100.0, 0.05), cand(1, None, 100.0, 0.95)];
            let mut high_battery = 0;
            for seed in 0..200 {
                let picked = s.select(1, &cands, 1, &mut Rng::seed_from_u64(seed));
                assert_eq!(picked.len(), 1);
                if picked == vec![1] {
                    high_battery += 1;
                }
            }
            // power(1)≈0.93 vs power(0)≈0.03 ⇒ ~97% of draws pick id 1;
            // a uniform shuffle would sit near 100/200.
            assert!(high_battery > 150, "f={f}: got {high_battery}/200");
        }
    }

    #[test]
    fn cold_start_fallback_excludes_exploration_picks() {
        // With ε high enough to take one exploration pick and k larger
        // than the exploration quota, the fallback must fill from the
        // *remaining* unexplored ids only — never duplicating.
        let mut cfg = SelectorConfig::default();
        cfg.explore_init = 0.5;
        cfg.explore_decay = 1.0;
        cfg.min_explore = 0.5;
        let mut s = EaflSelector::new(cfg);
        let cands: Vec<Candidate> = (0..6).map(|i| cand(i, None, 100.0, 0.5)).collect();
        for seed in 0..50 {
            let picked = s.select(1, &cands, 4, &mut Rng::seed_from_u64(seed));
            assert_eq!(picked.len(), 4);
            let mut d = picked.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), picked.len(), "duplicate pick at seed {seed}");
        }
    }

    #[test]
    fn never_exceeds_k() {
        let mut s = EaflSelector::new(SelectorConfig::default());
        let cands: Vec<Candidate> = (0..25)
            .map(|i| cand(i, if i % 3 == 0 { Some(i as f64) } else { None }, 60.0, 0.7))
            .collect();
        for round in 1..20 {
            let picked =
                s.select(round, &cands, 10, &mut Rng::seed_from_u64(round));
            assert!(picked.len() <= 10);
            let mut d = picked.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), picked.len());
        }
    }
}
