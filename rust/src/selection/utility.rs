//! Utility math shared by Oort and EAFL.
//!
//! Eq. (2) (Oort, OSDI'21):
//!   Util(i) = |B_i| · sqrt( (1/|B_i|) Σ_{k∈B_i} Loss(k)² ) × (T/t_i)^{1(T<t_i)·α}
//!
//! Eq. (1) (EAFL):
//!   reward(i) = f · Util(i) + (1−f) · power(i)
//!   power(i)  = cur_battery_level(i) − battery_used(i)

/// Statistical utility from a client's per-example losses:
/// |B| · sqrt(mean(loss²)). Returns 0 for an empty batch.
pub fn statistical_utility(per_example_losses: &[f32]) -> f64 {
    if per_example_losses.is_empty() {
        return 0.0;
    }
    let n = per_example_losses.len() as f64;
    let mean_sq: f64 =
        per_example_losses.iter().map(|&l| (l as f64) * (l as f64)).sum::<f64>() / n;
    n * mean_sq.sqrt()
}

/// Oort's system-efficiency penalty: (T/t_i)^α if the client is slower
/// than the deadline (t_i > T), else 1.
pub fn system_penalty(deadline_s: f64, duration_s: f64, alpha: f64) -> f64 {
    if duration_s > deadline_s && duration_s > 0.0 && deadline_s > 0.0 {
        (deadline_s / duration_s).powf(alpha)
    } else {
        1.0
    }
}

/// Full Eq. (2): statistical utility × system penalty.
pub fn oort_utility(stat_util: f64, deadline_s: f64, duration_s: f64, alpha: f64) -> f64 {
    stat_util * system_penalty(deadline_s, duration_s, alpha)
}

/// Eq. (1) power term: remaining battery after the projected round
/// cost, clamped to [0, 1]. Both inputs are fractions of capacity.
pub fn power_term(battery_frac: f64, projected_drain_frac: f64) -> f64 {
    (battery_frac - projected_drain_frac).clamp(0.0, 1.0)
}

/// Eq. (1): reward = f · util_norm + (1−f) · power.
/// `util_norm` must already be normalized to [0, 1] so the two terms
/// are commensurate (the paper blends them directly).
pub fn eafl_reward(f: f64, util_norm: f64, power: f64) -> f64 {
    let f = f.clamp(0.0, 1.0);
    f * util_norm + (1.0 - f) * power
}

/// Min-max normalize `values` into [0,1]; all-equal values map to 0.5
/// (no preference signal either way).
pub fn min_max_normalize(values: &[f64]) -> Vec<f64> {
    let mut out = values.to_vec();
    min_max_normalize_in_place(&mut out);
    out
}

/// In-place variant of [`min_max_normalize`] — the selectors' hot path
/// normalizes a reused scratch buffer once per round, so the allocating
/// version above is only for one-shot callers.
pub fn min_max_normalize_in_place(values: &mut [f64]) {
    if values.is_empty() {
        return;
    }
    let min = values.iter().cloned().fold(f64::MAX, f64::min);
    let max = values.iter().cloned().fold(f64::MIN, f64::max);
    if (max - min).abs() < 1e-12 {
        values.fill(0.5);
        return;
    }
    for v in values.iter_mut() {
        *v = (*v - min) / (max - min);
    }
}

/// UCB-style staleness bonus: grows with rounds since last selection,
/// encouraging revisits of stale utility estimates (Oort §4.2).
///
/// `last_selected_round` is `None` for a never-selected client, which
/// counts as one round staler than a client picked at round 0 — the
/// old `0 = never` sentinel conflated the two and under-rewarded
/// genuinely-never-picked clients.
pub fn staleness_bonus(round: u64, last_selected_round: Option<u64>, weight: f64) -> f64 {
    let staleness = match last_selected_round {
        Some(r) => round.saturating_sub(r).max(1),
        None => round.saturating_add(1),
    } as f64;
    weight * (0.1 * (round.max(2) as f64).ln() * staleness).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_util_matches_formula() {
        // |B|=4, losses all 2 ⇒ 4·sqrt(4) = 8
        assert!((statistical_utility(&[2.0, 2.0, 2.0, 2.0]) - 8.0).abs() < 1e-9);
        assert_eq!(statistical_utility(&[]), 0.0);
    }

    #[test]
    fn stat_util_rewards_high_loss_clients() {
        let low = statistical_utility(&[0.1; 10]);
        let high = statistical_utility(&[3.0; 10]);
        assert!(high > low);
    }

    #[test]
    fn stat_util_scales_with_batch_size() {
        // Same loss, more data ⇒ more useful (|B| prefactor).
        assert!(statistical_utility(&[1.0; 20]) > statistical_utility(&[1.0; 5]));
    }

    #[test]
    fn penalty_only_for_stragglers() {
        assert_eq!(system_penalty(100.0, 50.0, 2.0), 1.0); // fast: no penalty
        assert_eq!(system_penalty(100.0, 100.0, 2.0), 1.0); // on time
        let p = system_penalty(100.0, 200.0, 2.0); // 2x late: (1/2)^2
        assert!((p - 0.25).abs() < 1e-12);
    }

    #[test]
    fn full_utility_eq2() {
        let u = oort_utility(8.0, 100.0, 200.0, 1.0);
        assert!((u - 4.0).abs() < 1e-12);
    }

    #[test]
    fn power_term_clamps() {
        assert!((power_term(0.8, 0.1) - 0.7).abs() < 1e-12);
        assert_eq!(power_term(0.05, 0.2), 0.0);
        assert_eq!(power_term(1.5, 0.0), 1.0);
    }

    #[test]
    fn reward_extremes() {
        // f=1 ⇒ pure Oort; f=0 ⇒ pure power (paper: f→0 favors battery).
        assert_eq!(eafl_reward(1.0, 0.3, 0.9), 0.3);
        assert_eq!(eafl_reward(0.0, 0.3, 0.9), 0.9);
        let mid = eafl_reward(0.25, 0.4, 0.8);
        assert!((mid - (0.25 * 0.4 + 0.75 * 0.8)).abs() < 1e-12);
    }

    #[test]
    fn normalize_bounds_and_degenerate() {
        let n = min_max_normalize(&[1.0, 3.0, 2.0]);
        assert_eq!(n[0], 0.0);
        assert_eq!(n[1], 1.0);
        assert!((n[2] - 0.5).abs() < 1e-12);
        assert_eq!(min_max_normalize(&[5.0, 5.0]), vec![0.5, 0.5]);
        assert!(min_max_normalize(&[]).is_empty());
    }

    #[test]
    fn staleness_grows() {
        let fresh = staleness_bonus(100, Some(99), 0.1);
        let stale = staleness_bonus(100, Some(10), 0.1);
        assert!(stale > fresh);
        assert!(fresh > 0.0);
    }

    #[test]
    fn never_selected_is_staler_than_selected_at_round_zero() {
        // The old u64 sentinel encoded "never" as 0, identical to
        // "selected at round 0" — the Option must keep them apart, with
        // the never-selected client strictly staler.
        let at_zero = staleness_bonus(10, Some(0), 0.1);
        let never = staleness_bonus(10, None, 0.1);
        assert!(never > at_zero, "never={never} at_zero={at_zero}");
        assert!(staleness_bonus(1, None, 0.1) > 0.0);
    }
}
