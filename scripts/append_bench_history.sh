#!/usr/bin/env bash
# Append one bench result to the JSONL perf history.
#
# `make bench` writes BENCH_plan.json (eafl-bench-v1 schema) for the
# current tree; this script stamps it with the git SHA (and a -dirty
# marker when the tree has uncommitted changes) and appends it as one
# line to BENCH_history.jsonl — the per-commit trend record the ROADMAP
# asks for. Pure shell + git: the bench JSON is flattened by replacing
# newlines with spaces (its strings never contain raw newlines, so the
# result is still valid JSON).
#
# Usage: append_bench_history.sh [BENCH_plan.json] [BENCH_history.jsonl]

set -euo pipefail

src="${1:-BENCH_plan.json}"
hist="${2:-BENCH_history.jsonl}"

if [ ! -f "$src" ]; then
  echo "append_bench_history: no $src — run \`make bench\` first" >&2
  exit 1
fi

sha="$(git rev-parse HEAD 2>/dev/null || echo unknown)"
dirty=""
if ! git diff --quiet 2>/dev/null || ! git diff --cached --quiet 2>/dev/null; then
  dirty="-dirty"
fi

flat="$(tr '\n' ' ' < "$src")"
printf '{"sha": "%s%s", "bench": %s}\n' "$sha" "$dirty" "$flat" >> "$hist"
echo "recorded $src @ ${sha}${dirty} -> $hist"
