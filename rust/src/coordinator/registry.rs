//! Client registry: per-client device + link + battery + data shard +
//! utility statistics. The coordinator's source of truth — selectors
//! see read-only [`Candidate`] projections built here (paper Fig. 2:
//! the coordinator "registers each client's profile ... and forwards
//! the characteristics to the server running EAFL").


use crate::config::ExperimentConfig;
use crate::data::{partition_clients, ClientShard};
use crate::device::{generate_profiles, Battery, DeviceProfile};
use crate::energy::RoundEnergy;
use crate::network::{generate_links, LinkProfile};
use crate::selection::Candidate;

/// Mutable per-client selection statistics.
#[derive(Debug, Clone, Default)]
pub struct ClientStats {
    /// Last measured Oort statistical utility (None = unexplored).
    pub stat_util: Option<f64>,
    /// Last measured participation duration, seconds.
    pub measured_duration_s: Option<f64>,
    /// Round of last selection (0 = never).
    pub last_selected_round: u64,
    pub times_selected: u64,
    pub times_completed: u64,
    /// Consecutive deadline misses (Oort-style blacklist trigger).
    pub consecutive_misses: u32,
    /// Client is ineligible until this round (exclusive).
    pub banned_until_round: u64,
}

/// One registered client.
#[derive(Debug, Clone)]
pub struct ClientState {
    pub id: usize,
    pub device: DeviceProfile,
    pub link: LinkProfile,
    pub battery: Battery,
    pub shard: ClientShard,
    pub stats: ClientStats,
}

impl ClientState {
    /// Seconds of local compute for `local_steps` steps of `batch`.
    pub fn compute_secs(&self, local_steps: usize, batch: usize) -> f64 {
        (local_steps * batch) as f64 / self.device.samples_per_sec
    }

    /// Estimated full-round duration: download + compute + upload.
    pub fn expected_duration_s(
        &self,
        payload_bytes: usize,
        local_steps: usize,
        batch: usize,
    ) -> f64 {
        self.link.download_secs(payload_bytes)
            + self.compute_secs(local_steps, batch)
            + self.link.upload_secs(payload_bytes)
    }

    /// Projected energy of the next round's participation.
    pub fn projected_energy(
        &self,
        payload_bytes: usize,
        local_steps: usize,
        batch: usize,
    ) -> RoundEnergy {
        RoundEnergy::for_participation(
            &self.device.spec,
            &self.link,
            payload_bytes,
            self.compute_secs(local_steps, batch),
        )
    }
}

/// The full client population.
pub struct Registry {
    pub clients: Vec<ClientState>,
    /// Model payload exchanged each round (flat params as f32 bytes).
    pub payload_bytes: usize,
}

impl Registry {
    /// Build the population from the experiment config: device traces,
    /// link traces and the non-IID partition are all seeded and merged
    /// 1:1 by client index.
    pub fn build(cfg: &ExperimentConfig, num_classes: usize, param_count: usize) -> Self {
        let n = cfg.federation.num_clients;
        let devices = generate_profiles(&cfg.devices, n);
        let links = generate_links(&cfg.network, n);
        let partition = partition_clients(&cfg.data, num_classes, n);
        let clients = devices
            .into_iter()
            .zip(links)
            .zip(partition.shards)
            .enumerate()
            .map(|(id, ((device, link), shard))| {
                let battery = Battery::new(&device.spec, device.init_battery_frac);
                ClientState { id, device, link, battery, shard, stats: ClientStats::default() }
            })
            .collect();
        Self { clients, payload_bytes: param_count * 4 }
    }

    pub fn len(&self) -> usize {
        self.clients.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    /// Clients currently alive (battery not dead).
    pub fn alive_count(&self) -> usize {
        self.clients.iter().filter(|c| c.battery.is_alive()).count()
    }

    /// Clients whose battery has died so far (Fig. 4a's cumulative
    /// drop-out count).
    pub fn dead_count(&self) -> usize {
        self.len() - self.alive_count()
    }

    /// Mean battery fraction over alive clients (1.0 if none alive).
    pub fn mean_battery_alive(&self) -> f64 {
        let alive: Vec<f64> = self
            .clients
            .iter()
            .filter(|c| c.battery.is_alive())
            .map(|c| c.battery.fraction())
            .collect();
        if alive.is_empty() {
            0.0
        } else {
            alive.iter().sum::<f64>() / alive.len() as f64
        }
    }

    /// Total FL energy drawn across the population, joules.
    pub fn total_fl_energy_j(&self) -> f64 {
        self.clients.iter().map(|c| c.battery.fl_energy_j).sum()
    }

    /// Per-client selection counts (Jain's fairness input).
    pub fn selection_counts(&self) -> Vec<u64> {
        self.clients.iter().map(|c| c.stats.times_selected).collect()
    }

    /// Build selector candidates: alive clients above the battery
    /// floor and not blacklisted, with timing and energy projections
    /// attached. `round` is the upcoming round (1-based).
    pub fn candidates(
        &self,
        round: u64,
        min_battery_frac: f64,
        local_steps: usize,
        batch: usize,
    ) -> Vec<Candidate> {
        self.clients
            .iter()
            .filter(|c| {
                c.battery.is_alive()
                    && c.battery.fraction() > min_battery_frac
                    && c.stats.banned_until_round <= round
            })
            .map(|c| {
                let energy =
                    c.projected_energy(self.payload_bytes, local_steps, batch).total();
                Candidate {
                    id: c.id,
                    stat_util: c.stats.stat_util,
                    measured_duration_s: c.stats.measured_duration_s,
                    expected_duration_s: c.expected_duration_s(
                        self.payload_bytes,
                        local_steps,
                        batch,
                    ),
                    last_selected_round: c.stats.last_selected_round,
                    battery_frac: c.battery.fraction(),
                    projected_drain_frac: energy / c.battery.capacity_joules(),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SelectorKind;

    fn registry() -> Registry {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        Registry::build(&cfg, 35, 1000)
    }

    #[test]
    fn build_merges_profiles_one_to_one() {
        let r = registry();
        assert_eq!(r.len(), 40);
        assert_eq!(r.payload_bytes, 4000);
        for (i, c) in r.clients.iter().enumerate() {
            assert_eq!(c.id, i);
            assert!(!c.shard.samples.is_empty());
            assert!(c.battery.is_alive());
        }
    }

    #[test]
    fn expected_duration_decomposes() {
        let r = registry();
        let c = &r.clients[0];
        let d = c.expected_duration_s(r.payload_bytes, 5, 20);
        let manual = c.link.download_secs(r.payload_bytes)
            + c.compute_secs(5, 20)
            + c.link.upload_secs(r.payload_bytes);
        assert!((d - manual).abs() < 1e-12);
        assert!(d > 0.0);
    }

    #[test]
    fn candidates_respect_battery_floor() {
        let mut r = registry();
        // Kill half the clients.
        let cap = r.clients[0].battery.capacity_joules();
        for c in r.clients.iter_mut().take(20) {
            c.battery.drain_fl(cap * 2.0, 0.0);
        }
        let cands = r.candidates(1, 0.02, 5, 20);
        assert!(cands.len() <= 20);
        assert!(cands.iter().all(|c| c.battery_frac > 0.02));
        assert_eq!(r.dead_count(), 20);
    }

    #[test]
    fn projections_are_positive_fractions() {
        let r = registry();
        for cand in r.candidates(1, 0.0, 5, 20) {
            assert!(cand.projected_drain_frac > 0.0);
            assert!(cand.projected_drain_frac < 1.0, "one round must not eat a full battery");
            assert!((0.0..=1.0).contains(&cand.battery_frac));
        }
    }

    #[test]
    fn selection_counts_track_stats() {
        let mut r = registry();
        r.clients[3].stats.times_selected = 7;
        let counts = r.selection_counts();
        assert_eq!(counts[3], 7);
        assert_eq!(counts.iter().sum::<u64>(), 7);
    }
}
